//! Paper-table bench target: `cargo bench` regenerates every figure in
//! fast mode through the same registry the CLI uses, timing each one.
//! (The full-scale regeneration is `cargo run --release -- figures
//! --all`; see EXPERIMENTS.md for archived full-scale outputs.)

use loraserve::figures::{registry, FigOpts};
use std::time::Instant;

fn main() {
    // Bench harnesses run from the crate root; keep results separate
    // from full-scale runs.
    let opts = FigOpts {
        fast: true,
        seed: 0,
    };
    println!("figure regeneration benchmark (fast mode)\n");
    let mut total = 0.0;
    for (id, desc, f) in registry() {
        let t = Instant::now();
        f(&opts).unwrap_or_else(|e| panic!("{id}: {e}"));
        let dt = t.elapsed().as_secs_f64();
        total += dt;
        println!(">>> {id:10} {dt:7.2}s  {desc}");
    }
    println!("\ntotal: {total:.1}s for {} figures", registry().len());
}
