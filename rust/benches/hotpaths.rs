//! Hot-path micro-benchmarks (custom harness — criterion is not
//! available offline). Measures the L3 request-path and control-plane
//! operations; `cargo bench` prints ns/op tables and writes
//! results/bench_hotpaths.csv.
//!
//! Paper-table benches (end-to-end figure regenerations) live behind
//! the `figures` CLI; this file owns the microbenchmarks the §Perf pass
//! optimizes: router sampling, placement epoch, DES event loop,
//! demand tracking, trace generation, and percentile computation.

use loraserve::autoscale::{ScaleController, ScaleDecision, ScaleSignals};
use loraserve::config::{AutoscaleConfig, ClusterConfig};
use loraserve::coordinator::{DemandTracker, Router, RoutingTable};
use loraserve::costmodel;
use loraserve::placement::loraserve::LoraServePlacer;
use loraserve::placement::{place_onto, Placer, PlacementCtx};
use loraserve::sim::{self, SimConfig, SystemKind};
use loraserve::trace::azure::{self, AzureConfig};
use loraserve::trace::LengthModel;
use loraserve::util::argmin::ArgminTree;
use loraserve::util::rng::Pcg32;
use loraserve::util::stats::Samples;
use loraserve::util::table::Table;
use loraserve::workload::{AdapterId, AdapterSet, RANK_CLASSES};
use std::collections::BTreeMap;
use std::hint::black_box;
use std::time::Instant;

struct Bench {
    table: Table,
}

impl Bench {
    fn new() -> Self {
        Bench {
            table: Table::new(
                "hot-path microbenchmarks",
                &["bench", "iters", "total", "per-op"],
            ),
        }
    }

    /// Run `f` repeatedly for ~0.5 s (after warmup) and record ns/op.
    fn run<F: FnMut() -> u64>(&mut self, name: &str, mut f: F) {
        // warmup + calibration
        let t0 = Instant::now();
        let mut ops = f();
        while t0.elapsed().as_millis() < 50 {
            ops += f();
        }
        let per_call = ops.max(1);
        let _ = per_call;
        let start = Instant::now();
        let mut total_ops = 0u64;
        while start.elapsed().as_millis() < 500 {
            total_ops += f();
        }
        let elapsed = start.elapsed();
        let per_op = elapsed.as_nanos() as f64 / total_ops.max(1) as f64;
        let per_op_str = if per_op > 1e6 {
            format!("{:.2} ms", per_op / 1e6)
        } else if per_op > 1e3 {
            format!("{:.2} us", per_op / 1e3)
        } else {
            format!("{per_op:.0} ns")
        };
        println!("{name:32} {total_ops:>10} ops  {per_op_str}/op");
        self.table.row(vec![
            name.to_string(),
            total_ops.to_string(),
            format!("{:.3}s", elapsed.as_secs_f64()),
            per_op_str,
        ]);
    }
}

fn main() {
    let mut b = Bench::new();
    let model = loraserve::config::ModelSpec::LLAMA_7B;

    // --- router sampling (per-request hot path)
    let adapters = AdapterSet::power_law_counts(1000, &RANK_CLASSES, 1.0, &model);
    let demand: BTreeMap<AdapterId, f64> =
        adapters.iter().map(|a| (a.id, 100.0)).collect();
    let oppoints = costmodel::operating_points(
        &loraserve::config::ServerConfig::default(),
        &RANK_CLASSES,
    );
    let ctx = PlacementCtx {
        adapters: &adapters,
        n_servers: 64,
        demand_tps: &demand,
        operating_points: &oppoints,
        prev: None,
    };
    let asg = LoraServePlacer::new().place(&ctx);
    let table = RoutingTable::from_assignment(&asg);
    let router = Router::Table(table);
    let outstanding = vec![0.0f64; 64];
    let mut rng = Pcg32::new(1);
    b.run("router: table route (1k ad.)", || {
        let mut acc = 0usize;
        for i in 0..1024u32 {
            acc += router.route(i % 1000, &mut rng);
        }
        black_box(acc);
        1024
    });
    let mut toppings = Router::toppings(64);
    toppings.set_loads(&outstanding);
    b.run("router: toppings least-work", || {
        let mut acc = 0usize;
        for i in 0..1024u32 {
            let t = toppings.route(i % 1000, &mut rng);
            acc += t;
            // the routed server's load changes: O(log n) tree update
            toppings.update_load(t, (i % 17) as f64);
        }
        black_box(acc);
        1024
    });
    // the raw index at big-fleet width: one load update + argmin query
    let mut tree = ArgminTree::new(512);
    for s in 0..512 {
        tree.update(s, (s % 41) as f64);
    }
    b.run("router: argmin tree x512 srv", || {
        let mut acc = 0usize;
        for i in 0..1024usize {
            tree.update((i * 7) % 512, (i % 23) as f64);
            acc += tree.argmin();
        }
        black_box(acc);
        1024
    });

    // --- placement epoch (control plane: 1000 adapters x 64 servers)
    b.run("placement: 1000x64 epoch", || {
        let mut placer = LoraServePlacer::new();
        black_box(placer.place(&ctx));
        1
    });
    let prev = LoraServePlacer::new().place(&ctx);
    let ctx_prev = PlacementCtx {
        prev: Some(&prev),
        ..ctx
    };
    b.run("placement: epoch + permutation", || {
        let mut placer = LoraServePlacer::new();
        black_box(placer.place(&ctx_prev));
        1
    });
    // assignment diff on the wholesale-rebalance path (sorted-merge
    // membership, not the old O(copies²) contains scan)
    let next_asg = LoraServePlacer::new().place(&ctx_prev);
    b.run("placement: migration_bytes diff", || {
        black_box(next_asg.migration_bytes(&asg, &adapters));
        1
    });

    // --- autoscaler decision path: signal evaluation (per tick) and
    // re-placement on a topology change (the drain/scale-up hot path)
    let mut ctl = ScaleController::new(AutoscaleConfig {
        max_servers: 128,
        ..Default::default()
    });
    let cand: Vec<(usize, f64)> =
        (0..64).map(|s| (s, (s % 7) as f64)).collect();
    let sig = ScaleSignals {
        busy_frac: 0.95,
        violation_rate: 0.1,
        queue_depth: 512,
        projected_tps: 1.0e5,
        server_tps_capacity: 700.0,
    };
    let mut tick_t = 0.0f64;
    b.run("autoscale: decide (64 srv)", || {
        let mut ups = 0u64;
        for _ in 0..1024 {
            tick_t += 120.0;
            if matches!(
                ctl.decide(tick_t, &sig, &cand, 0),
                ScaleDecision::Up(_)
            ) {
                ups += 1;
            }
        }
        black_box(ups);
        1024
    });
    b.run("autoscale: re-place 1000x63", || {
        // drain one of 64 servers: project prev, re-pack, remap
        let active: Vec<usize> = (0..63).collect();
        let mut placer = LoraServePlacer::new();
        black_box(place_onto(
            &mut placer,
            &adapters,
            &active,
            &demand,
            &oppoints,
            Some(&prev),
        ));
        1
    });

    // --- demand tracker
    b.run("demand: record + roll (1k ad.)", || {
        let mut d = DemandTracker::new(60.0, 16);
        for i in 0..1000u32 {
            d.record(i, 640);
        }
        d.roll_window();
        black_box(d.projected_tps());
        1000
    });

    // --- DES end-to-end events/sec
    let trace = azure::generate(&AzureConfig {
        rps: 20.0,
        duration: 120.0,
        lengths: LengthModel::fixed(256, 32),
        ..Default::default()
    });
    let cluster = ClusterConfig::default();
    b.run("sim: 120s x 20rps x 4srv run", || {
        let rep = sim::run(
            &trace,
            &SimConfig::new(cluster.clone(), SystemKind::LoraServe),
        );
        black_box(rep.completed);
        1
    });

    // --- sharded DES scaling: same workload on a wider fleet with the
    // shard count swept. Every shard count produces the byte-identical
    // report digest (the epoch-barrier contract — see
    // tests/sharded_determinism.rs), so this measures wall-clock only;
    // the `bench` CLI subcommand runs the pinned large-fleet scenario.
    let wide = azure::generate(&AzureConfig {
        rps: 60.0,
        duration: 120.0,
        lengths: LengthModel::fixed(256, 32),
        ..Default::default()
    });
    let wide_cluster = ClusterConfig {
        n_servers: 8,
        ..Default::default()
    };
    for shards in [1usize, 2, 4, 8] {
        b.run(&format!("sim: shard scaling x{shards} (8srv)"), || {
            let rep = sim::run(
                &wide,
                &SimConfig::new(
                    wide_cluster.clone(),
                    SystemKind::LoraServe,
                )
                .with_shards(shards),
            );
            black_box(rep.events);
            1
        });
    }

    // --- allocation pressure: the per-event hot paths must not
    // allocate. The event heap orders on one packed-u128 key compare;
    // the server loop reuses its admission/decode scratch and appends
    // completions into a caller-owned buffer.
    {
        use loraserve::sim::event::EventQueue;
        let mut q: EventQueue<u32> = EventQueue::with_capacity(8192);
        let mut t = 0.0f64;
        b.run("event: push+pop 8k (packed key)", || {
            for i in 0..8192u32 {
                q.push(t + (i % 97) as f64, i);
            }
            while let Some((now, ev)) = q.pop() {
                t = t.max(now);
                black_box(ev);
            }
            8192
        });
    }
    {
        use loraserve::sim::server::{build_policy, SimReq, SimServer};
        use loraserve::workload::Request;
        let scfg = loraserve::config::ServerConfig::default();
        let cm = loraserve::costmodel::CostModel::new(scfg);
        let ops = costmodel::operating_points(&scfg, &RANK_CLASSES);
        let mut srv = SimServer::with_policy(
            0,
            cm,
            build_policy(
                loraserve::config::BatchPolicyKind::Fifo,
                loraserve::config::DecodePolicyKind::Unified,
                &ops,
            ),
        );
        let mut out = Vec::new();
        let mut now = 0.0f64;
        b.run("sim: serve 64 reqs, reused outbox", || {
            for i in 0..64u32 {
                srv.enqueue_ready(SimReq {
                    req: Request {
                        id: i as u64,
                        adapter: i % 8,
                        prompt_len: 128,
                        output_len: 8,
                        arrival: now,
                    },
                    uid: i,
                    rank: 8,
                    adapter_bytes: 1 << 20,
                    est: 0.05,
                    remote: false,
                });
            }
            let mut done = 0u64;
            while let Some(dt) = srv.start_iteration(now) {
                now += dt;
                out.clear();
                srv.finish_iteration_into(now, &mut out);
                done += out.len() as u64;
            }
            black_box(done);
            64
        });
    }

    // --- rank-aware batch scheduling (admission is on the DES hot
    // path: one policy call per iteration)
    b.run("sim: rank-bucketed admission run", || {
        let cfg = SimConfig::new(
            cluster.clone(),
            SystemKind::SLoraRandom,
        )
        .with_params(|p| {
            p.batch(loraserve::config::BatchPolicyKind::RankBucketed {
                max_wait_iters: 8,
                select: loraserve::config::ClassSelect::LargestQueue,
            })
        });
        let rep = sim::run(&trace, &cfg);
        black_box(rep.completed);
        1
    });

    // --- decode-set composition (one compose_decode call per decode
    // round; partitioned rounds also multiply IterDone events)
    {
        use loraserve::sim::server::{
            ActiveReq, BatchPolicy, Fifo, RankPartitionedDecode, SimReq,
        };
        use loraserve::workload::Request;
        let cm = loraserve::costmodel::CostModel::new(
            loraserve::config::ServerConfig::default(),
        );
        let mut rng = Pcg32::new(9);
        let active: Vec<ActiveReq> = (0..24)
            .map(|i| ActiveReq {
                sreq: SimReq {
                    req: Request {
                        id: i as u64,
                        adapter: i as u32,
                        prompt_len: 256,
                        output_len: 64,
                        arrival: 0.0,
                    },
                    uid: i as u32,
                    rank: RANK_CLASSES[rng.below(5) as usize],
                    adapter_bytes: 1 << 20,
                    est: 0.1,
                    remote: false,
                },
                produced: 1 + (i as u32 % 16),
                first_token_at: 0.0,
                seq: i as u64,
            })
            .collect();
        let mut pol = RankPartitionedDecode::new(Box::new(Fifo));
        b.run("sched: compose_decode (24 act.)", || {
            for _ in 0..1024 {
                black_box(pol.compose_decode(&active, 24, &cm, None));
            }
            1024
        });
    }
    b.run("sim: rank-partitioned decode run", || {
        let cfg = SimConfig::new(
            cluster.clone(),
            SystemKind::SLoraRandom,
        )
        .with_params(|p| {
            p.decode(loraserve::config::DecodePolicyKind::RankPartitioned)
        });
        let rep = sim::run(&trace, &cfg);
        black_box(rep.completed);
        1
    });

    // --- cost model evaluations (per-iteration hot path in DES)
    let server = loraserve::config::ServerConfig::default();
    b.run("costmodel: prefill_time", || {
        let mut acc = 0.0;
        for i in 0..4096u64 {
            acc += costmodel::prefill_time(&server, 512 + i % 64, 64);
        }
        black_box(acc);
        4096
    });
    b.run("costmodel: decode_time", || {
        let mut acc = 0.0;
        for i in 0..4096 {
            acc +=
                costmodel::decode_time(&server, 16, 8192 + i % 128, 64);
        }
        black_box(acc);
        4096
    });

    // --- trace generation + percentile stats
    b.run("trace: azure gen (12k reqs)", || {
        let t = azure::generate(&AzureConfig {
            rps: 20.0,
            duration: 600.0,
            ..Default::default()
        });
        black_box(t.requests.len() as u64)
    });
    b.run("stats: p95 of 100k samples", || {
        let mut s = Samples::new();
        let mut rng = Pcg32::new(3);
        for _ in 0..100_000 {
            s.push(rng.f64());
        }
        black_box(s.p95());
        100_000
    });

    b.table.emit("results", "bench_hotpaths").unwrap();
}
