//! Real mini-cluster driver: spawns N PJRT-backed server threads,
//! routes a timed workload through the coordinator, rebalances
//! periodically (LORASERVE), and reports wall-clock latencies.

use super::store::AdapterStore;
use super::{serve_loop, ServeRequest, ServeResult};
use crate::coordinator::{DemandTracker, Router, RoutingTable};
use crate::placement::baselines::{ContiguousPlacer, RandomPlacer};
use crate::placement::loraserve::LoraServePlacer;
use crate::placement::{Assignment, PlacementCtx, Placer};
use crate::runtime::ModelEngine;
use crate::sim::SystemKind;
use crate::util::rng::Pcg32;
use crate::util::stats::Samples;
use crate::workload::{Adapter, AdapterId, AdapterSet, ServerId};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct RealClusterConfig {
    pub n_servers: usize,
    pub artifacts_dir: String,
    pub system: SystemKind,
    /// Wall-clock seconds between rebalances (LORASERVE only).
    pub rebalance_period: f64,
    pub seed: u64,
}

impl Default for RealClusterConfig {
    fn default() -> Self {
        RealClusterConfig {
            n_servers: 2,
            artifacts_dir: "artifacts".into(),
            system: SystemKind::LoraServe,
            rebalance_period: 5.0,
            seed: 0,
        }
    }
}

/// A timed request for the real cluster.
#[derive(Debug, Clone)]
pub struct TimedRequest {
    /// Seconds after workload start.
    pub at: f64,
    pub adapter: AdapterId,
    pub prompt: Vec<i32>,
    pub output_len: usize,
}

#[derive(Debug, Default)]
pub struct RealReport {
    pub system: String,
    pub ttft: Samples,
    pub tbt: Samples,
    pub completed: u64,
    pub wall_secs: f64,
    pub fetches: u64,
    pub fetch_bytes: u64,
    pub per_server_completed: Vec<u64>,
    pub per_server_resident: Vec<usize>,
    pub rebalances: u64,
}

impl RealReport {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.wall_secs
        }
    }
}

pub struct RealCluster {
    cfg: RealClusterConfig,
    pub adapters: AdapterSet,
    store: AdapterStore,
    senders: Vec<mpsc::Sender<ServeRequest>>,
    results: mpsc::Receiver<ServeResult>,
    handles: Vec<thread::JoinHandle<Result<()>>>,
    router: Router,
    placer: LoraServePlacer,
    assignment: Assignment,
    oppoints: BTreeMap<u32, f64>,
    rng: Pcg32,
}

impl RealCluster {
    /// Spawn the server threads (each compiles its own engine) and
    /// compute the initial placement.
    pub fn start(cfg: RealClusterConfig) -> Result<RealCluster> {
        let bank = ModelEngine::load_bank(&cfg.artifacts_dir)
            .context("load adapter bank")?;
        let adapters = AdapterSet::new(
            bank.iter()
                .enumerate()
                .map(|(i, a)| Adapter {
                    id: i as AdapterId,
                    rank: a.rank,
                    size_bytes: a.size_bytes(),
                })
                .collect(),
        );
        // operating points: relative capacity per rank from the real
        // engine's own cost structure is unknown a priori; use the
        // analytic model's relative shape (rank-monotone), which is all
        // Algorithm 1 needs.
        let oppoints = crate::costmodel::operating_points(
            &crate::config::ServerConfig::default(),
            &adapters.unique_ranks(),
        );
        let uniform: BTreeMap<AdapterId, f64> =
            adapters.iter().map(|a| (a.id, 100.0)).collect();
        let ctx = PlacementCtx {
            adapters: &adapters,
            n_servers: cfg.n_servers,
            demand_tps: &uniform,
            operating_points: &oppoints,
            prev: None,
        };
        let mut placer = LoraServePlacer::new();
        let assignment = match cfg.system {
            SystemKind::LoraServe => placer.place(&ctx),
            SystemKind::SLoraRandom => {
                RandomPlacer::new(cfg.seed).place(&ctx)
            }
            SystemKind::SLoraContiguous => {
                ContiguousPlacer::new().place(&ctx)
            }
            SystemKind::Toppings => {
                let mut a = Assignment::new(adapters.len());
                for ad in adapters.iter() {
                    for s in 0..cfg.n_servers {
                        a.add(ad.id, s, 1.0 / cfg.n_servers as f64);
                    }
                }
                a
            }
        };
        let homes: Vec<Vec<ServerId>> = assignment
            .shares
            .iter()
            .map(|ss| ss.iter().map(|(s, _)| *s).collect())
            .collect();
        let store = AdapterStore::new(cfg.n_servers, &bank, &homes);

        let (res_tx, results) = mpsc::channel::<ServeResult>();
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        for s in 0..cfg.n_servers {
            let (tx, rx) = mpsc::channel::<ServeRequest>();
            senders.push(tx);
            let store = store.clone();
            let res_tx = res_tx.clone();
            let dir = cfg.artifacts_dir.clone();
            handles.push(thread::spawn(move || {
                serve_loop(s, &dir, store, rx, res_tx)
            }));
        }
        let router = match cfg.system {
            SystemKind::Toppings => Router::toppings(cfg.n_servers),
            _ => Router::Table(RoutingTable::from_assignment(&assignment)),
        };
        let rng = Pcg32::with_stream(cfg.seed, 0x2ea1);
        Ok(RealCluster {
            cfg,
            adapters,
            store,
            senders,
            results,
            handles,
            router,
            placer,
            assignment,
            oppoints,
            rng,
        })
    }

    /// Replay a timed workload and gather completions.
    pub fn run(&mut self, workload: &[TimedRequest]) -> Result<RealReport> {
        let n = self.cfg.n_servers;
        let mut report = RealReport {
            system: self.cfg.system.label().to_string(),
            per_server_completed: vec![0; n],
            ..Default::default()
        };
        let mut demand =
            DemandTracker::new(self.cfg.rebalance_period.max(0.1), 16);
        let mut outstanding = vec![0.0f64; n];
        let start = Instant::now();
        let mut next_rebalance = self.cfg.rebalance_period;
        let mut sent = 0u64;
        let dynamic =
            matches!(self.cfg.system, SystemKind::LoraServe);

        let mut workload: Vec<&TimedRequest> = workload.iter().collect();
        workload.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());

        for (i, req) in workload.iter().enumerate() {
            // absorb completions opportunistically (keeps outstanding
            // estimates fresh for Toppings)
            while let Ok(r) = self.results.try_recv() {
                absorb(&mut report, &mut outstanding, r);
            }
            let now = start.elapsed().as_secs_f64();
            if req.at > now {
                thread::sleep(Duration::from_secs_f64(req.at - now));
            }
            let now = start.elapsed().as_secs_f64();
            if dynamic && now >= next_rebalance {
                self.rebalance(&mut demand);
                report.rebalances += 1;
                next_rebalance = now + self.cfg.rebalance_period;
            }
            demand.record(
                req.adapter,
                (req.prompt.len() + req.output_len) as u64,
            );
            // the outstanding estimates changed since the last route
            // (absorbed completions + our own additions): re-seed the
            // least-work index in bulk before routing
            self.router.set_loads(&outstanding);
            let target =
                self.router.route(req.adapter, &mut self.rng);
            let est = 0.001
                * (req.prompt.len() as f64
                    + 4.0 * req.output_len as f64);
            outstanding[target] += est;
            self.senders[target]
                .send(ServeRequest {
                    id: i as u64,
                    adapter: req.adapter,
                    prompt: req.prompt.clone(),
                    output_len: req.output_len,
                    submitted: Instant::now(),
                })
                .context("server thread died")?;
            sent += 1;
        }
        while report.completed < sent {
            let r = self
                .results
                .recv_timeout(Duration::from_secs(120))
                .context("timed out waiting for completions")?;
            absorb(&mut report, &mut outstanding, r);
        }
        report.wall_secs = start.elapsed().as_secs_f64();
        let (f, fb) = self.store.fetch_stats();
        report.fetches = f;
        report.fetch_bytes = fb;
        report.per_server_resident =
            (0..n).map(|s| self.store.resident_count(s)).collect();
        self.store
            .check_coverage(self.adapters.len())
            .map_err(|e| anyhow::anyhow!(e))?;
        Ok(report)
    }

    fn rebalance(&mut self, demand: &mut DemandTracker) {
        demand.roll_window();
        let projected = demand.projected_tps();
        let ctx = PlacementCtx {
            adapters: &self.adapters,
            n_servers: self.cfg.n_servers,
            demand_tps: &projected,
            operating_points: &self.oppoints,
            prev: Some(&self.assignment),
        };
        let next = self.placer.place(&ctx);
        self.router
            .update_table(RoutingTable::from_assignment(&next));
        let homes: Vec<Vec<ServerId>> = next
            .shares
            .iter()
            .map(|ss| ss.iter().map(|(s, _)| *s).collect())
            .collect();
        self.store.apply_assignment(&homes);
        self.assignment = next;
    }

    /// Shut down server threads.
    pub fn shutdown(mut self) {
        self.senders.clear(); // closes channels; serve loops exit
        for h in self.handles.drain(..) {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => eprintln!("server thread error: {e:#}"),
                Err(_) => eprintln!("server thread panicked"),
            }
        }
    }
}

fn absorb(report: &mut RealReport, outstanding: &mut [f64], r: ServeResult) {
    report.ttft.push(r.ttft);
    if r.tbt.is_finite() {
        report.tbt.push(r.tbt);
    }
    report.completed += 1;
    report.per_server_completed[r.server] += 1;
    let est = 0.001 * (r.tokens.len() as f64 * 4.0);
    outstanding[r.server] = (outstanding[r.server] - est).max(0.0);
}
