//! The *real* mini-cluster: server threads executing AOT PJRT
//! artifacts behind the same coordinator/placement code the simulator
//! uses. Proves all three layers compose and provides wall-clock
//! TTFT/TBT/throughput for the E2E example.

pub mod cluster;
pub mod store;

pub use cluster::{RealCluster, RealClusterConfig, RealReport};
pub use store::AdapterStore;

use crate::runtime::{argmax, BankAdapter, ModelEngine};
use crate::workload::AdapterId;
use anyhow::Result;
use std::sync::mpsc;
use std::time::Instant;

/// A request submitted to a real server.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: u64,
    pub adapter: AdapterId,
    pub prompt: Vec<i32>,
    pub output_len: usize,
    pub submitted: Instant,
}

/// Completion record with wall-clock latencies.
#[derive(Debug, Clone)]
pub struct ServeResult {
    pub id: u64,
    pub server: usize,
    pub adapter: AdapterId,
    pub tokens: Vec<i32>,
    /// Seconds from submission to first token.
    pub ttft: f64,
    /// Mean seconds between subsequent tokens (NaN if output_len <= 1).
    pub tbt: f64,
    pub fetched_adapter: bool,
}

/// Dynamic-batching serving loop for one server. Runs on its own
/// thread; owns a `ModelEngine` (PJRT clients are not shared across
/// threads). Batches whatever is queued (up to the largest artifact
/// batch), prefills once, then decodes the batch to completion —
/// dynamic batching rather than the simulator's continuous batching
/// (documented difference; iteration-level join needs KV compaction
/// across fixed artifact shapes).
pub fn serve_loop(
    server_id: usize,
    artifacts_dir: &str,
    store: AdapterStore,
    rx: mpsc::Receiver<ServeRequest>,
    tx: mpsc::Sender<ServeResult>,
) -> Result<()> {
    let engine = ModelEngine::load(artifacts_dir)?;
    let slots_cap = engine.manifest.batch_slots;
    let max_b = engine
        .prefill_shapes()
        .iter()
        .map(|(b, _)| *b)
        .max()
        .unwrap_or(1);

    loop {
        // block for the first request; then drain a batch window
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return Ok(()), // cluster shut down
        };
        let mut batch = vec![first];
        while batch.len() < max_b {
            match rx.try_recv() {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        // cap distinct adapters at the stack slot count
        let mut slot_of: Vec<usize> = Vec::with_capacity(batch.len());
        let mut slot_ids: Vec<AdapterId> = Vec::new();
        let mut deferred: Vec<ServeRequest> = Vec::new();
        let mut kept: Vec<ServeRequest> = Vec::new();
        for r in batch {
            if let Some(i) = slot_ids.iter().position(|&a| a == r.adapter)
            {
                slot_of.push(i);
                kept.push(r);
            } else if slot_ids.len() < slots_cap {
                slot_ids.push(r.adapter);
                slot_of.push(slot_ids.len() - 1);
                kept.push(r);
            } else {
                deferred.push(r);
            }
        }
        let batch = kept;
        // materialize adapters (the distributed-pool path)
        let mut fetched = vec![false; batch.len()];
        let mut slot_weights: Vec<std::sync::Arc<BankAdapter>> =
            Vec::new();
        for &aid in &slot_ids {
            let (w, was_fetch) = store.get_or_fetch(server_id, aid);
            if was_fetch {
                for (i, r) in batch.iter().enumerate() {
                    if r.adapter == aid {
                        fetched[i] = true;
                    }
                }
            }
            slot_weights.push(w);
        }
        run_batch(
            server_id, &engine, &batch, &slot_of, &slot_weights, &tx,
            &fetched,
        )?;
        // re-queue deferred requests to ourselves via results channel?
        // No — process them immediately as the next batch.
        if !deferred.is_empty() {
            let mut slot_of = Vec::new();
            let mut slot_ids: Vec<AdapterId> = Vec::new();
            let mut fetched = vec![false; deferred.len()];
            for (i, r) in deferred.iter().enumerate() {
                if let Some(j) =
                    slot_ids.iter().position(|&a| a == r.adapter)
                {
                    slot_of.push(j);
                } else {
                    slot_ids.push(r.adapter);
                    slot_of.push(slot_ids.len() - 1);
                    let (_, was) =
                        store.get_or_fetch(server_id, r.adapter);
                    fetched[i] = was;
                }
            }
            let slot_weights: Vec<std::sync::Arc<BankAdapter>> = slot_ids
                .iter()
                .map(|&a| store.get_or_fetch(server_id, a).0)
                .collect();
            run_batch(
                server_id,
                &engine,
                &deferred,
                &slot_of,
                &slot_weights,
                &tx,
                &fetched,
            )?;
        }
    }
}

fn run_batch(
    server_id: usize,
    engine: &ModelEngine,
    batch: &[ServeRequest],
    slot_of: &[usize],
    slot_weights: &[std::sync::Arc<BankAdapter>],
    tx: &mpsc::Sender<ServeResult>,
    fetched: &[bool],
) -> Result<()> {
    if batch.is_empty() {
        return Ok(());
    }
    let refs: Vec<Option<&BankAdapter>> =
        slot_weights.iter().map(|w| Some(w.as_ref())).collect();
    let stack = engine.stack_adapters(&refs)?;
    let max_prompt = batch.iter().map(|r| r.prompt.len()).max().unwrap();
    let shape = engine
        .pick_shape(batch.len(), max_prompt)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "no artifact fits batch {} x prompt {max_prompt}",
                batch.len()
            )
        })?;
    let prompts: Vec<Vec<i32>> =
        batch.iter().map(|r| r.prompt.clone()).collect();
    let (logits, mut kv) =
        engine.prefill(shape, &prompts, slot_of, &stack)?;
    let first_token_at = Instant::now();
    let mut outputs: Vec<Vec<i32>> =
        logits.iter().map(|l| vec![argmax(l)]).collect();
    let ttfts: Vec<f64> = batch
        .iter()
        .map(|r| first_token_at.duration_since(r.submitted).as_secs_f64())
        .collect();

    // decode the batch to the longest requested output
    let b = kv.batch;
    let max_out = batch.iter().map(|r| r.output_len).max().unwrap();
    let mut pos: Vec<i32> =
        batch.iter().map(|r| r.prompt.len() as i32).collect();
    pos.resize(b, 1);
    let mut slots_row: Vec<usize> = slot_of.to_vec();
    slots_row.resize(b, 0);
    let lmax = engine.manifest.model.max_seq as i32;
    for _step in 1..max_out {
        let mut tokens = vec![0i32; b];
        for (i, out) in outputs.iter().enumerate() {
            tokens[i] = *out.last().unwrap();
        }
        if pos.iter().take(batch.len()).any(|&p| p >= lmax) {
            break; // KV budget exhausted
        }
        let (logits, nkv) =
            engine.decode(kv, &tokens, &slots_row, &pos, &stack)?;
        kv = nkv;
        for (i, out) in outputs.iter_mut().enumerate().take(batch.len())
        {
            if out.len() < batch[i].output_len {
                out.push(argmax(&logits[i]));
            }
        }
        for p in pos.iter_mut() {
            *p += 1;
        }
    }
    let done = Instant::now();
    for (i, r) in batch.iter().enumerate() {
        let n_out = outputs[i].len();
        let tbt = if n_out > 1 {
            done.duration_since(first_token_at).as_secs_f64()
                / (n_out - 1) as f64
        } else {
            f64::NAN
        };
        tx.send(ServeResult {
            id: r.id,
            server: server_id,
            adapter: r.adapter,
            tokens: outputs[i].clone(),
            ttft: ttfts[i],
            tbt,
            fetched_adapter: fetched[i],
        })
        .ok();
    }
    Ok(())
}
