//! Real distributed adapter store: the actual weight bytes behind the
//! pool metadata. Each server holds its resident adapters' tensors;
//! a miss copies them from a peer (the mini-cluster's stand-in for the
//! GPUDirect-RDMA path — same code structure, real bytes moving).

use crate::runtime::BankAdapter;
use crate::workload::{AdapterId, ServerId};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

#[derive(Debug, Default)]
struct StoreInner {
    /// resident[s][adapter] -> weights
    resident: Vec<BTreeMap<AdapterId, Arc<BankAdapter>>>,
    fetches: u64,
    fetch_bytes: u64,
}

/// Shared across server threads.
#[derive(Debug, Clone)]
pub struct AdapterStore {
    inner: Arc<Mutex<StoreInner>>,
}

impl AdapterStore {
    /// Seed each adapter's weights at its home servers.
    pub fn new(
        n_servers: usize,
        bank: &[BankAdapter],
        homes: &[Vec<ServerId>],
    ) -> Self {
        assert_eq!(bank.len(), homes.len());
        let mut resident = vec![BTreeMap::new(); n_servers];
        for (a, servers) in homes.iter().enumerate() {
            assert!(!servers.is_empty(), "adapter {a} homeless");
            let arc = Arc::new(bank[a].clone());
            for &s in servers {
                resident[s].insert(a as AdapterId, Arc::clone(&arc));
            }
        }
        AdapterStore {
            inner: Arc::new(Mutex::new(StoreInner {
                resident,
                fetches: 0,
                fetch_bytes: 0,
            })),
        }
    }

    /// Get the adapter on `server`, fetching from a peer on miss.
    /// Returns (weights, fetched_now). Panics if no replica exists
    /// anywhere (coverage invariant).
    pub fn get_or_fetch(
        &self,
        server: ServerId,
        adapter: AdapterId,
    ) -> (Arc<BankAdapter>, bool) {
        let mut g = self.inner.lock().unwrap();
        if let Some(w) = g.resident[server].get(&adapter) {
            return (Arc::clone(w), false);
        }
        let src = g
            .resident
            .iter()
            .find_map(|m| m.get(&adapter))
            .unwrap_or_else(|| panic!("adapter {adapter}: no replica"));
        // The "transfer": in the mini-cluster both hosts share memory,
        // so the RDMA copy is a deep clone of the tensors (real bytes,
        // real memcpy time).
        let copied = Arc::new(BankAdapter::clone(src));
        g.fetches += 1;
        g.fetch_bytes += copied.size_bytes();
        g.resident[server].insert(adapter, Arc::clone(&copied));
        (copied, true)
    }

    /// Apply a new placement: drop copies that are no longer assigned,
    /// never dropping the last replica (same GC rule as `pool`).
    pub fn apply_assignment(&self, homes: &[Vec<ServerId>]) {
        let mut g = self.inner.lock().unwrap();
        let n = g.resident.len();
        for (a, servers) in homes.iter().enumerate() {
            let a = a as AdapterId;
            let holders: Vec<ServerId> = (0..n)
                .filter(|&s| g.resident[s].contains_key(&a))
                .collect();
            let assigned_holders: Vec<ServerId> = holders
                .iter()
                .copied()
                .filter(|s| servers.contains(s))
                .collect();
            let keep: Vec<ServerId> = if assigned_holders.is_empty() {
                holders.first().copied().into_iter().collect()
            } else {
                assigned_holders
            };
            for s in holders {
                if !keep.contains(&s) {
                    g.resident[s].remove(&a);
                }
            }
        }
    }

    pub fn resident_count(&self, server: ServerId) -> usize {
        self.inner.lock().unwrap().resident[server].len()
    }

    pub fn fetch_stats(&self) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.fetches, g.fetch_bytes)
    }

    pub fn check_coverage(&self, n_adapters: usize) -> Result<(), String> {
        let g = self.inner.lock().unwrap();
        for a in 0..n_adapters as AdapterId {
            if !g.resident.iter().any(|m| m.contains_key(&a)) {
                return Err(format!("adapter {a} lost"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bank(n: usize) -> Vec<BankAdapter> {
        (0..n)
            .map(|i| BankAdapter {
                rank: 8,
                alpha: 16.0,
                a: vec![i as f32; 64],
                b: vec![i as f32; 64],
            })
            .collect()
    }

    #[test]
    fn hit_and_miss() {
        let b = bank(3);
        let store =
            AdapterStore::new(2, &b, &[vec![0], vec![0], vec![1]]);
        let (w, fetched) = store.get_or_fetch(0, 1);
        assert!(!fetched);
        assert_eq!(w.a[0], 1.0);
        let (w, fetched) = store.get_or_fetch(1, 0);
        assert!(fetched);
        assert_eq!(w.a[0], 0.0);
        // second access is a hit
        let (_, fetched) = store.get_or_fetch(1, 0);
        assert!(!fetched);
        assert_eq!(store.fetch_stats().0, 1);
        assert_eq!(store.resident_count(1), 2);
    }

    #[test]
    fn gc_respects_last_replica() {
        let b = bank(2);
        let store = AdapterStore::new(2, &b, &[vec![0], vec![1]]);
        // reassign adapter 0 to server 1 without fetching it yet
        store.apply_assignment(&[vec![1], vec![1]]);
        store.check_coverage(2).unwrap();
        // adapter 0 still only on server 0 (survivor)
        assert_eq!(store.resident_count(0), 1);
        // fetch lands on server 1, then GC drops the old copy
        store.get_or_fetch(1, 0);
        store.apply_assignment(&[vec![1], vec![1]]);
        assert_eq!(store.resident_count(0), 0);
        store.check_coverage(2).unwrap();
    }
}
