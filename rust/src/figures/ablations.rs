//! Ablations of Algorithm 1's design choices (DESIGN.md §8).
//!
//! A1 rank-aware budgeting vs rank-agnostic load balancing,
//! A2 churn-minimizing permutation step on/off (migration bytes),
//! A3 trend extrapolation vs last-value demand projection,
//! A4 distributed pool vs full replication (memory/fetch trade).

use super::helpers::{FigOpts, RESULTS_DIR};
use crate::config::ClusterConfig;
use crate::sim::{run, LoraServeOpts, SimConfig, SystemKind};
use crate::trace::{azure, Trace};
use crate::util::table::{fmt_bytes, fmt_secs, Table};

fn drift_trace(opts: &FigOpts) -> Trace {
    // shifting skew stresses every mechanism under ablation
    azure::generate(&azure::AzureConfig {
        arrival: azure::Arrival::Poisson,
        popularity: azure::RankPopularity::ShiftingSkew,
        rps: 20.0,
        duration: opts.scale(1200.0),
        seed: opts.seed,
        ..Default::default()
    })
}

pub fn ablations(opts: &FigOpts) -> std::io::Result<()> {
    let trace = drift_trace(opts);
    let cluster = ClusterConfig {
        n_servers: 4,
        ..Default::default()
    };
    let variants: Vec<(&str, LoraServeOpts)> = vec![
        ("full", LoraServeOpts::default()),
        (
            "A1 rank-agnostic",
            LoraServeOpts {
                rank_agnostic: true,
                ..Default::default()
            },
        ),
        (
            "A2 no-permutation",
            LoraServeOpts {
                skip_permutation: true,
                ..Default::default()
            },
        ),
        (
            "A3 last-value demand",
            LoraServeOpts {
                last_value_demand: true,
                ..Default::default()
            },
        ),
        (
            "A4 full replication",
            LoraServeOpts {
                full_replication: true,
                ..Default::default()
            },
        ),
    ];
    let mut table = Table::new(
        "Ablations — LORASERVE variants on a shifting-skew trace (20 RPS)",
        &[
            "variant", "p95 ttft", "p95 tbt", "drops",
            "migrated", "fetches", "max resident",
        ],
    );
    for (name, lopts) in variants {
        let mut cfg = SimConfig::new(cluster.clone(), SystemKind::LoraServe);
        cfg.opts = lopts;
        let mut rep = run(&trace, &cfg);
        table.row(vec![
            name.to_string(),
            fmt_secs(rep.ttft_p95()),
            fmt_secs(rep.tbt_p95()),
            rep.timeouts.to_string(),
            fmt_bytes(rep.migration_bytes),
            rep.fetches.to_string(),
            rep.per_server_max_adapters
                .iter()
                .max()
                .unwrap()
                .to_string(),
        ]);
    }
    table.emit(RESULTS_DIR, "ablations")
}
