//! The `memory` figure: throughput and tail TTFT vs the unified HBM
//! page budget, across eviction policies.
//!
//! The workload is the memory-constrained class the paper never
//! isolates: long-context prompts (KV-heavy) over a many-adapter
//! fleet, so per-request KV footprints and adapter residency contend
//! for the same paged pool (`pool::hbm::HbmPool`). An unbounded pool
//! (the default config, `hbm_pages = 0`) anchors the comparison; each
//! bounded budget then runs every eviction policy at identical
//! pressure, so the rows isolate the victim-selection knob.

use super::helpers::{run_system, FigOpts, RESULTS_DIR};
use crate::config::{ClusterConfig, ModelSpec};
use crate::sim::SystemKind;
use crate::trace::Trace;
use crate::util::rng::{Pcg32, PowerLaw};
use crate::util::table::{fmt_secs, Table};
use crate::workload::{AdapterSet, Request};

/// RNG stream tag for the memory-pressure trace (disjoint from the
/// drift figure's 0xd21f7, the production trace's 0x9d0d, the
/// scenario trace's 0x5ce7a, and the engine's 0x51).
const MEMORY_STREAM: u64 = 0x4b1df;

/// Long-context × many-adapter trace: flat Poisson arrivals split
/// power-law across a two-class (rank 8 / rank 64) fleet, with
/// lognormal prompt lengths centred near 640 tokens — each active
/// sequence holds hundreds of KV pages, so a bounded pool feels
/// pressure from admission alone. Expected total ≈ `rps × duration`.
pub fn memory_trace(
    n_adapters: usize,
    rps: f64,
    duration: f64,
    seed: u64,
) -> Trace {
    let adapters = AdapterSet::uniform_per_rank(
        n_adapters,
        &[8u32, 64],
        &ModelSpec::LLAMA_7B,
    );
    let splitter = PowerLaw::new(n_adapters.max(1), 1.5);
    let mut rng = Pcg32::with_stream(seed, MEMORY_STREAM);
    let minutes = ((duration / 60.0).ceil() as usize).max(1);
    let lambda = rps * duration / minutes as f64;
    let mut requests: Vec<Request> = Vec::new();
    for m in 0..minutes {
        for _ in 0..rng.poisson(lambda) {
            let t = (m as f64 + rng.f64()) * 60.0;
            if t > duration {
                continue;
            }
            let adapter = splitter.sample(&mut rng) as u32;
            let prompt = rng
                .lognormal((640.0f64).ln(), 0.35)
                .round()
                .clamp(64.0, 1536.0) as u32;
            let output = rng
                .lognormal((32.0f64).ln(), 0.4)
                .round()
                .clamp(4.0, 96.0) as u32;
            requests.push(Request {
                id: 0,
                adapter,
                prompt_len: prompt,
                output_len: output,
                arrival: t,
            });
        }
    }
    Trace::new(
        &format!("memory-n{n_adapters}-s{seed}"),
        adapters,
        requests,
    )
}

pub fn memory(opts: &FigOpts) -> std::io::Result<()> {
    use crate::pool::hbm::EvictPolicy;
    let duration = opts.scale(1200.0);
    let trace = memory_trace(48, 8.0, duration, opts.seed);
    let base = ClusterConfig {
        n_servers: 4,
        ..Default::default()
    };
    let mut table = Table::new(
        "memory — unified HBM page budget × eviction policy on a \
         long-context many-adapter trace (loraserve, 4 servers)",
        &[
            "hbm pages",
            "policy",
            "p95 ttft",
            "p99 ttft",
            "tput rps",
            "completed",
            "evictions",
            "peak pages",
            "fetch stall",
        ],
    );
    // unbounded anchor first, then each budget across every policy
    let mut arms: Vec<(usize, EvictPolicy)> =
        vec![(0, EvictPolicy::Lru)];
    for pages in [2048usize, 1024] {
        for pol in [
            EvictPolicy::Lru,
            EvictPolicy::RankWeighted,
            EvictPolicy::SloAware,
        ] {
            arms.push((pages, pol));
        }
    }
    for (pages, pol) in arms {
        let mut cluster = base.clone();
        cluster.server.hbm_pages = pages;
        cluster.server.evict_policy = pol;
        let mut rep =
            run_system(&trace, &cluster, SystemKind::LoraServe);
        let (evictions, peak) = rep
            .hbm
            .as_ref()
            .map(|h| (h.evictions, h.peak_pages))
            .unwrap_or((0, 0));
        table.row(vec![
            if pages == 0 {
                "unbounded".to_string()
            } else {
                pages.to_string()
            },
            if pages == 0 {
                "-".to_string()
            } else {
                pol.label().to_string()
            },
            fmt_secs(rep.ttft.p95()),
            fmt_secs(rep.ttft.p99()),
            format!("{:.2}", rep.throughput_rps()),
            rep.completed.to_string(),
            evictions.to_string(),
            peak.to_string(),
            fmt_secs(rep.fetch_stall_s),
        ]);
    }
    table.emit(RESULTS_DIR, "memory")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_trace_shape() {
        let t = memory_trace(48, 8.0, 600.0, 1);
        // expected total within a loose Poisson band
        let n = t.requests.len() as f64;
        assert!((n - 4800.0).abs() < 4800.0 * 0.15, "n={n}");
        assert!(t.duration() <= 600.0);
        assert_eq!(t.adapters.len(), 48);
        // long-context: the mean prompt dwarfs the default chat model
        let mean = t
            .requests
            .iter()
            .map(|r| r.prompt_len as f64)
            .sum::<f64>()
            / n;
        assert!(mean > 400.0, "mean prompt {mean} too short");
        // deterministic per seed
        let t2 = memory_trace(48, 8.0, 600.0, 1);
        assert_eq!(t.requests.len(), t2.requests.len());
        assert_eq!(t.requests[11], t2.requests[11]);
        // different seeds differ
        let t3 = memory_trace(48, 8.0, 600.0, 2);
        assert!(
            t.requests.len() != t3.requests.len()
                || t.requests
                    .iter()
                    .zip(t3.requests.iter())
                    .any(|(a, b)| a != b)
        );
    }
}
