//! Shared scaffolding for the figure harnesses.

use crate::config::ClusterConfig;
use crate::sim::{run, SimConfig, SimReport, SystemKind};
use crate::trace::Trace;

pub const RESULTS_DIR: &str = "results";

/// Global knobs for a figures run.
#[derive(Debug, Clone, Copy)]
pub struct FigOpts {
    /// Shrink workloads for smoke runs / CI.
    pub fast: bool,
    pub seed: u64,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts {
            fast: false,
            seed: 0,
        }
    }
}

impl FigOpts {
    /// Scale a duration/request count down in fast mode.
    pub fn scale(&self, x: f64) -> f64 {
        if self.fast {
            x / 4.0
        } else {
            x
        }
    }
}

/// Steady-state warmup excluded from figure statistics: two rebalance
/// periods, enough for LORASERVE's first demand-informed placement to
/// take effect (the paper reports steady-state latencies).
pub fn warmup_secs(cluster: &ClusterConfig) -> f64 {
    2.0 * cluster.rebalance_period
}

/// Steady-state warmup derived from a run's *actual* rebalance
/// timestamps (`SimReport::rebalance_times`): measurement starts at
/// the second demand-informed re-placement — the first may act on a
/// half-window of history — floored at one `rebalance_period`, so a
/// periodic run's quarter-period bootstrap re-places (or an early
/// trigger fire) don't pull the cutoff into the cold-start backlog
/// those early re-places exist to drain. The old
/// `2 × rebalance_period` formula assumed rebalances arrive on the
/// period, which is wrong once they are trigger-driven (the period
/// may never elapse); it remains the fallback when the run rebalanced
/// fewer than twice — e.g. a triggered run on a stable trace, where
/// the trigger (correctly) never fired and there is no steady-state
/// transition to wait out.
pub fn steady_warmup(
    cluster: &ClusterConfig,
    rebalance_times: &[f64],
) -> f64 {
    match rebalance_times.get(1) {
        Some(&t) => t.max(cluster.rebalance_period),
        None => warmup_secs(cluster),
    }
}

/// Run one (trace, system) pair on a cluster.
pub fn run_system(
    trace: &Trace,
    cluster: &ClusterConfig,
    system: SystemKind,
) -> SimReport {
    // never let warmup swallow more than a third of the trace
    let warmup = warmup_secs(cluster).min(trace.duration() / 3.0);
    run(
        trace,
        &SimConfig::new(cluster.clone(), system).with_warmup(warmup),
    )
}

/// Largest RPS (within `tol`) at which `system` still meets the SLO on
/// rescalings of `trace` — the paper's "max throughput under SLA"
/// metric (Fig 17/21). Monotone bisection over trace rescaling.
pub fn max_rps_under_slo(
    trace: &Trace,
    cluster: &ClusterConfig,
    system: SystemKind,
    lo: f64,
    hi: f64,
    tol: f64,
) -> f64 {
    let meets = |rps: f64| -> bool {
        let t = trace.scale_to_rps(rps);
        let mut rep = run_system(&t, cluster, system);
        rep.meets_slo(cluster.slo.ttft_p95)
    };
    if !meets(lo) {
        return 0.0;
    }
    let (mut lo, mut hi) = (lo, hi);
    if meets(hi) {
        return hi;
    }
    while hi - lo > tol {
        let mid = 0.5 * (lo + hi);
        if meets(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Smallest server count (1..=max) meeting the SLO at the trace's
/// native rate — the "GPUs needed" metric behind the paper's
/// "up to 50% fewer GPUs" claim. Thin wrapper over the capacity
/// planner's bisection (O(log n) simulations instead of the old
/// linear scan).
pub fn min_servers_under_slo(
    trace: &Trace,
    base: &ClusterConfig,
    system: SystemKind,
    max_servers: usize,
) -> Option<usize> {
    crate::autoscale::plan_min_fleet(
        trace,
        base,
        system,
        &crate::autoscale::SloSpec::ttft_p95(base.slo.ttft_p95),
        max_servers,
    )
    .min_servers
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::azure::{self, AzureConfig};
    use crate::trace::LengthModel;

    fn trace() -> Trace {
        azure::generate(&AzureConfig {
            rps: 8.0,
            duration: 90.0,
            lengths: LengthModel::fixed(512, 128),
            ..Default::default()
        })
    }

    #[test]
    fn steady_warmup_prefers_observed_rebalances() {
        let cluster = ClusterConfig {
            rebalance_period: 60.0,
            ..Default::default()
        };
        // no rebalances observed: the old formula is the fallback
        assert_eq!(steady_warmup(&cluster, &[]), 120.0);
        assert_eq!(steady_warmup(&cluster, &[33.0]), 120.0);
        // early (bootstrap-cadence) re-places: floored at one period
        // so the cold-start backlog stays excluded
        assert_eq!(steady_warmup(&cluster, &[15.0, 30.0, 45.0]), 60.0);
        // trigger-driven rebalances landing late: steady state starts
        // at the second one, still well before 2 × period would
        assert_eq!(steady_warmup(&cluster, &[70.0, 95.0]), 95.0);
    }

    #[test]
    fn bisection_brackets_capacity() {
        let cluster = ClusterConfig {
            n_servers: 2,
            ..Default::default()
        };
        let cap = max_rps_under_slo(
            &trace(),
            &cluster,
            SystemKind::LoraServe,
            1.0,
            64.0,
            2.0,
        );
        assert!(cap > 1.0 && cap < 64.0, "cap={cap}");
        // more servers => more capacity
        let cluster4 = ClusterConfig {
            n_servers: 4,
            ..Default::default()
        };
        let cap4 = max_rps_under_slo(
            &trace(),
            &cluster4,
            SystemKind::LoraServe,
            1.0,
            64.0,
            2.0,
        );
        assert!(cap4 > cap, "cap4={cap4} cap2={cap}");
    }

    #[test]
    fn min_servers_monotone_in_load() {
        let base = ClusterConfig::default();
        let light = trace().scale_to_rps(2.0);
        let heavy = trace().scale_to_rps(12.0);
        let n_light = min_servers_under_slo(
            &light,
            &base,
            SystemKind::LoraServe,
            8,
        )
        .unwrap();
        let n_heavy = min_servers_under_slo(
            &heavy,
            &base,
            SystemKind::LoraServe,
            8,
        )
        .unwrap();
        assert!(n_heavy >= n_light, "{n_heavy} < {n_light}");
    }
}
