//! The `sched` ablation: batch scheduling × placement, and the
//! prefill-policy × decode-policy grid.
//!
//! The paper fixes the scheduler (FIFO continuous batching) and varies
//! *placement*; CaraServe-style rank-aware scheduling is the other
//! half of the heterogeneous-rank design space. Three tables:
//!
//! * `sched` — every system under each `BatchPolicyKind` on a
//!   mixed-rank prefill-heavy trace: rank-agnostic placement + `fifo`
//!   is "neither", rank-agnostic placement + `rank-bucketed` is
//!   "scheduling-only", LORASERVE + `fifo` is "placement-only",
//!   LORASERVE + `rank-bucketed` is "both". The high-rank iteration
//!   share and the padded-token volume are the interference-tax
//!   indicators the policies trade against latency.
//! * `sched_decode` — the prefill-policy × decode-policy grid on a
//!   *skewed-rank, decode-heavy* trace (mostly rank-8 traffic with a
//!   high-rank minority, long outputs): under unified decode one
//!   co-resident rank-128 tenant bills every decode step at rank 128
//!   for the whole tail; `rank-partitioned`/`class-subbatch` decode
//!   shrink the cluster-wide high-rank decode-step share and the
//!   low-rank classes' P99 TBT, at the cost of per-sub-batch launch
//!   overhead.
//! * `sched_slo` — open-loop vs SLO-feedback scheduling on a *bursty*
//!   skewed-rank trace (a standing multi-class decode set + periodic
//!   TTFT-sensitive prefill bursts): preemptible decode rounds, the
//!   SLO-aware rotor, and adaptive knobs against the best open-loop
//!   policies — the closed-loop half of this repo's scheduler seam.

use super::helpers::{FigOpts, RESULTS_DIR};
use crate::config::{
    BatchPolicyKind, ClassSelect, ClusterConfig, DecodePolicyKind,
    ModelSpec, SloFeedbackConfig,
};
use crate::sim::{run, SimConfig, SystemKind};
use crate::trace::azure::{self, AzureConfig, RankPopularity};
use crate::trace::{LengthModel, Trace};
use crate::util::rng::Pcg32;
use crate::util::table::{fmt_secs, Table};
use crate::workload::{AdapterSet, Request};
use std::collections::BTreeMap;

/// Systems × batch policies on one trace. Split from [`sched`] so the
/// test suite can smoke-run it on a tiny trace.
pub fn sched_table(trace: &Trace, cluster: &ClusterConfig) -> Table {
    let policies = [
        BatchPolicyKind::Fifo,
        BatchPolicyKind::RankBucketed {
            max_wait_iters: BatchPolicyKind::DEFAULT_MAX_WAIT_ITERS,
            select: ClassSelect::LargestQueue,
        },
        BatchPolicyKind::RankBucketed {
            max_wait_iters: BatchPolicyKind::DEFAULT_MAX_WAIT_ITERS,
            select: ClassSelect::CostWeighted,
        },
        BatchPolicyKind::RankCap {
            factor: BatchPolicyKind::DEFAULT_CAP_FACTOR,
        },
    ];
    let mut table = Table::new(
        "sched — placement × batch-policy ablation (mixed ranks)",
        &[
            "system",
            "batch policy",
            "p95 ttft",
            "p95 tbt",
            "drops",
            "hi-rank iters",
            "mixed prefills",
            "padded tokens",
        ],
    );
    for system in SystemKind::all() {
        for &policy in &policies {
            let cfg = SimConfig::new(cluster.clone(), system)
                .with_params(|p| p.batch(policy));
            let mut rep = run(trace, &cfg);
            table.row(vec![
                system.label().to_string(),
                policy.label(),
                fmt_secs(rep.ttft_p95()),
                fmt_secs(rep.tbt_p95()),
                rep.timeouts.to_string(),
                format!("{:.1}%", rep.highrank_iter_share() * 100.0),
                format!("{:.1}%", rep.mixed_prefill_share() * 100.0),
                rep.pad_rank_tokens.to_string(),
            ]);
        }
    }
    table
}

/// Prefill-policy × decode-policy grid on one (skewed-rank,
/// decode-heavy) trace, placement held rank-agnostic (S-LoRA Random)
/// so the decode effect is isolated. Split from [`sched`] so the test
/// suite can smoke-run it on a tiny trace.
pub fn sched_decode_table(trace: &Trace, cluster: &ClusterConfig) -> Table {
    let prefills = [
        BatchPolicyKind::Fifo,
        BatchPolicyKind::RankBucketed {
            max_wait_iters: BatchPolicyKind::DEFAULT_MAX_WAIT_ITERS,
            select: ClassSelect::LargestQueue,
        },
    ];
    let decodes = [
        DecodePolicyKind::Unified,
        DecodePolicyKind::RankPartitioned,
        DecodePolicyKind::ClassSubBatch {
            max_groups: DecodePolicyKind::DEFAULT_MAX_GROUPS,
        },
    ];
    let mut table = Table::new(
        "sched_decode — prefill × decode policy grid \
         (skewed ranks, decode-heavy, slora-random placement)",
        &[
            "prefill policy",
            "decode policy",
            "p95 ttft",
            "p99 tbt r8",
            "p99 tbt r128",
            "hi-rank decode",
            "mixed decode",
            "decode pad",
            "drops",
        ],
    );
    for &prefill in &prefills {
        for &decode in &decodes {
            let cfg =
                SimConfig::new(cluster.clone(), SystemKind::SLoraRandom)
                    .with_params(|p| p.batch(prefill).decode(decode));
            let mut rep = run(trace, &cfg);
            let tbt_lo = rep.tbt_p99_class(8);
            let tbt_hi = rep.tbt_p99_class(128);
            table.row(vec![
                prefill.label(),
                decode.label(),
                fmt_secs(rep.ttft_p95()),
                fmt_secs(tbt_lo),
                fmt_secs(tbt_hi),
                format!("{:.1}%", rep.highrank_decode_share() * 100.0),
                format!("{:.1}%", rep.mixed_decode_share() * 100.0),
                rep.decode_pad_rank.to_string(),
                rep.timeouts.to_string(),
            ]);
        }
    }
    table
}

/// The skewed-rank, decode-heavy workload of the decode grid:
/// exponential rank popularity (most traffic rank-8, a high-rank
/// minority) with long outputs so the decode tail dominates.
pub fn skewed_decode_trace(rps: f64, seed: u64, duration: f64) -> Trace {
    azure::generate(&AzureConfig {
        popularity: RankPopularity::Exponential,
        rps,
        duration,
        seed,
        lengths: LengthModel::fixed(256, 64),
        ..Default::default()
    })
}

/// The bursty skewed-rank workload of the `sched_slo` grid.
///
/// Two populations:
///
/// * a **standing decode set** — 20 long-output requests across all
///   five rank classes (rank-8 plurality, a heavy high-rank tail),
///   arriving in the first second and then decoding for the rest of
///   the trace, so a multi-class decode round is almost always in
///   flight;
/// * **TTFT-sensitive prefill bursts** — 4 short rank-8 requests every
///   1.5 s whose time-to-first-token is dominated by how long the
///   scheduler makes them wait out the round in flight.
///
/// Open-loop policies make a burst wait for the *whole* remaining
/// round; the feedback layer preempts at the next sub-batch step
/// boundary — exactly the gap the `sched_slo` table (and the
/// acceptance test in `tests/slo_feedback.rs`) measures. Measurements
/// start after a 2 s warmup, so the standing set's cold-start prefill
/// storm never pollutes the percentiles.
pub fn bursty_slo_trace(seed: u64, duration: f64) -> Trace {
    let adapters = AdapterSet::uniform_per_rank(
        10,
        &[8, 16, 32, 64, 128],
        &ModelSpec::LLAMA_7B,
    );
    let mut by_rank: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
    for a in adapters.iter() {
        by_rank.entry(a.rank).or_default().push(a.id);
    }
    let mut rng = Pcg32::new(seed);
    let pick = |rank: u32, rng: &mut Pcg32| -> u32 {
        let pool = &by_rank[&rank];
        pool[rng.below(pool.len() as u64) as usize]
    };
    let mut requests: Vec<Request> = Vec::new();
    // standing set: rank-8 plurality with a heavy high-rank tail, so
    // rounds are multi-class and the late (high-rank) sub-batch steps
    // carry real kernel time
    let standing: [(u32, usize); 5] =
        [(8, 6), (16, 2), (32, 2), (64, 4), (128, 6)];
    // sized to keep decoding past the last burst (~36 tokens/s of
    // per-member round cadence)
    let output = (duration * 36.0) as u32 + 256;
    let mut i = 0usize;
    for &(rank, count) in &standing {
        for _ in 0..count {
            requests.push(Request {
                id: 0, // reassigned by Trace::new
                adapter: pick(rank, &mut rng),
                prompt_len: 512,
                output_len: output,
                arrival: 0.045 * i as f64,
            });
            i += 1;
        }
    }
    // TTFT-sensitive bursts: 4 rank-8 prompts every 1.5 s (past the
    // 2 s measurement warmup). The burst arrives as one instant so no
    // scheduler can split it across admissions — every policy prefills
    // the whole burst in a single iteration and the TTFT difference is
    // purely how long the burst waits out the decode round in flight.
    let mut t = 2.25;
    while t < duration {
        for _ in 0..4 {
            requests.push(Request {
                id: 0,
                adapter: pick(8, &mut rng),
                prompt_len: 256,
                output_len: 4,
                arrival: t,
            });
        }
        t += 1.5;
    }
    Trace::new("bursty-slo-skew", adapters, requests)
}

/// The feedback configuration the `sched_slo` grid (and the acceptance
/// test) runs: tight scheduler-level targets with an aggressive
/// pressure threshold, so a queued burst preempts the round in flight
/// at the next sub-batch boundary.
pub fn slo_grid_feedback() -> SloFeedbackConfig {
    SloFeedbackConfig {
        enabled: true,
        ttft_target: 0.1,
        tbt_target: 0.05,
        preempt_decode: true,
        pressure_theta: 0.95,
    }
}

/// Open-loop vs SLO-feedback scheduling on the bursty skewed-rank
/// trace. Split from [`sched`] so the test suite can smoke-run it (and
/// assert the acceptance criterion) on the same harness.
pub fn sched_slo_table(trace: &Trace, cluster: &ClusterConfig) -> Table {
    let fb = slo_grid_feedback();
    let bucketed = BatchPolicyKind::RankBucketed {
        max_wait_iters: BatchPolicyKind::DEFAULT_MAX_WAIT_ITERS,
        select: ClassSelect::LargestQueue,
    };
    let rows: [(BatchPolicyKind, DecodePolicyKind, Option<SloFeedbackConfig>);
        6] = [
        (BatchPolicyKind::Fifo, DecodePolicyKind::Unified, None),
        (BatchPolicyKind::Fifo, DecodePolicyKind::RankPartitioned, None),
        (
            BatchPolicyKind::Fifo,
            DecodePolicyKind::ClassSubBatch { max_groups: 2 },
            None,
        ),
        (
            BatchPolicyKind::Fifo,
            DecodePolicyKind::RankPartitioned,
            Some(fb),
        ),
        (
            BatchPolicyKind::Fifo,
            DecodePolicyKind::ClassSubBatchAuto,
            Some(fb),
        ),
        (
            bucketed,
            DecodePolicyKind::ClassSubBatch { max_groups: 2 },
            Some(fb),
        ),
    ];
    let mut table = Table::new(
        "sched_slo — open-loop vs SLO-feedback scheduling \
         (bursty skewed ranks, 1 server)",
        &[
            "prefill policy",
            "decode policy",
            "feedback",
            "p95 ttft",
            "p99 ttft",
            "p99 tbt r8",
            "thr req/s",
            "preempts",
            "drops",
        ],
    );
    for (batch, decode, feedback) in rows {
        let mut cfg =
            SimConfig::new(cluster.clone(), SystemKind::SLoraRandom)
                .with_params(|p| p.batch(batch).decode(decode))
                .with_warmup(2.0);
        if let Some(f) = feedback {
            cfg = cfg.with_params(|p| p.slo(f));
        }
        let mut rep = run(trace, &cfg);
        table.row(vec![
            batch.label(),
            decode.label(),
            if feedback.is_some() {
                "preempt+slo".to_string()
            } else {
                "open-loop".to_string()
            },
            fmt_secs(rep.ttft.p95()),
            fmt_secs(rep.ttft.p99()),
            fmt_secs(rep.tbt_p99_class(8)),
            format!("{:.2}", rep.throughput_rps()),
            rep.decode_preemptions.to_string(),
            rep.timeouts.to_string(),
        ]);
    }
    table
}

pub fn sched(opts: &FigOpts) -> std::io::Result<()> {
    // Mixed ranks with short outputs: prefill iterations dominate, so
    // batch *composition* (not decode-set mixing) drives the
    // iteration mix; the load keeps queues deep enough that admission
    // actually has choices to make.
    let trace = azure::generate(&AzureConfig {
        rps: 24.0,
        duration: opts.scale(480.0),
        seed: opts.seed,
        lengths: LengthModel::fixed(512, 4),
        ..Default::default()
    });
    let cluster = ClusterConfig {
        n_servers: 4,
        rebalance_period: 30.0,
        ..Default::default()
    };
    sched_table(&trace, &cluster).emit(RESULTS_DIR, "sched")?;
    // Decode grid: skewed ranks + long outputs on a small fleet, so
    // active sets mix classes and the decode tail is where the rank
    // tax lands.
    let decode_trace =
        skewed_decode_trace(14.0, opts.seed, opts.scale(480.0));
    let decode_cluster = ClusterConfig {
        n_servers: 2,
        rebalance_period: 30.0,
        ..Default::default()
    };
    sched_decode_table(&decode_trace, &decode_cluster)
        .emit(RESULTS_DIR, "sched_decode")?;
    // SLO grid: one server under a standing multi-class decode load
    // with periodic prefill bursts, so the feedback layer's preemption
    // points and rotor actually get exercised.
    let slo_trace = bursty_slo_trace(opts.seed, opts.scale(90.0));
    let slo_cluster = ClusterConfig {
        n_servers: 1,
        rebalance_period: 30.0,
        ..Default::default()
    };
    sched_slo_table(&slo_trace, &slo_cluster)
        .emit(RESULTS_DIR, "sched_slo")
}
