//! The `sched` ablation: batch scheduling × placement, and the
//! prefill-policy × decode-policy grid.
//!
//! The paper fixes the scheduler (FIFO continuous batching) and varies
//! *placement*; CaraServe-style rank-aware scheduling is the other
//! half of the heterogeneous-rank design space. Two tables:
//!
//! * `sched` — every system under each `BatchPolicyKind` on a
//!   mixed-rank prefill-heavy trace: rank-agnostic placement + `fifo`
//!   is "neither", rank-agnostic placement + `rank-bucketed` is
//!   "scheduling-only", LORASERVE + `fifo` is "placement-only",
//!   LORASERVE + `rank-bucketed` is "both". The high-rank iteration
//!   share and the padded-token volume are the interference-tax
//!   indicators the policies trade against latency.
//! * `sched_decode` — the prefill-policy × decode-policy grid on a
//!   *skewed-rank, decode-heavy* trace (mostly rank-8 traffic with a
//!   high-rank minority, long outputs): under unified decode one
//!   co-resident rank-128 tenant bills every decode step at rank 128
//!   for the whole tail; `rank-partitioned`/`class-subbatch` decode
//!   shrink the cluster-wide high-rank decode-step share and the
//!   low-rank classes' P99 TBT, at the cost of per-sub-batch launch
//!   overhead.

use super::helpers::{FigOpts, RESULTS_DIR};
use crate::config::{
    BatchPolicyKind, ClassSelect, ClusterConfig, DecodePolicyKind,
};
use crate::sim::{run, SimConfig, SystemKind};
use crate::trace::azure::{self, AzureConfig, RankPopularity};
use crate::trace::{LengthModel, Trace};
use crate::util::table::{fmt_secs, Table};

/// Systems × batch policies on one trace. Split from [`sched`] so the
/// test suite can smoke-run it on a tiny trace.
pub fn sched_table(trace: &Trace, cluster: &ClusterConfig) -> Table {
    let policies = [
        BatchPolicyKind::Fifo,
        BatchPolicyKind::RankBucketed {
            max_wait_iters: BatchPolicyKind::DEFAULT_MAX_WAIT_ITERS,
            select: ClassSelect::LargestQueue,
        },
        BatchPolicyKind::RankBucketed {
            max_wait_iters: BatchPolicyKind::DEFAULT_MAX_WAIT_ITERS,
            select: ClassSelect::CostWeighted,
        },
        BatchPolicyKind::RankCap {
            factor: BatchPolicyKind::DEFAULT_CAP_FACTOR,
        },
    ];
    let mut table = Table::new(
        "sched — placement × batch-policy ablation (mixed ranks)",
        &[
            "system",
            "batch policy",
            "p95 ttft",
            "p95 tbt",
            "drops",
            "hi-rank iters",
            "mixed prefills",
            "padded tokens",
        ],
    );
    for system in SystemKind::all() {
        for &policy in &policies {
            let cfg = SimConfig::new(cluster.clone(), system)
                .with_batch_policy(policy);
            let mut rep = run(trace, &cfg);
            table.row(vec![
                system.label().to_string(),
                policy.label(),
                fmt_secs(rep.ttft_p95()),
                fmt_secs(rep.tbt_p95()),
                rep.timeouts.to_string(),
                format!("{:.1}%", rep.highrank_iter_share() * 100.0),
                format!("{:.1}%", rep.mixed_prefill_share() * 100.0),
                rep.pad_rank_tokens.to_string(),
            ]);
        }
    }
    table
}

/// Prefill-policy × decode-policy grid on one (skewed-rank,
/// decode-heavy) trace, placement held rank-agnostic (S-LoRA Random)
/// so the decode effect is isolated. Split from [`sched`] so the test
/// suite can smoke-run it on a tiny trace.
pub fn sched_decode_table(trace: &Trace, cluster: &ClusterConfig) -> Table {
    let prefills = [
        BatchPolicyKind::Fifo,
        BatchPolicyKind::RankBucketed {
            max_wait_iters: BatchPolicyKind::DEFAULT_MAX_WAIT_ITERS,
            select: ClassSelect::LargestQueue,
        },
    ];
    let decodes = [
        DecodePolicyKind::Unified,
        DecodePolicyKind::RankPartitioned,
        DecodePolicyKind::ClassSubBatch {
            max_groups: DecodePolicyKind::DEFAULT_MAX_GROUPS,
        },
    ];
    let mut table = Table::new(
        "sched_decode — prefill × decode policy grid \
         (skewed ranks, decode-heavy, slora-random placement)",
        &[
            "prefill policy",
            "decode policy",
            "p95 ttft",
            "p99 tbt r8",
            "p99 tbt r128",
            "hi-rank decode",
            "mixed decode",
            "decode pad",
            "drops",
        ],
    );
    for &prefill in &prefills {
        for &decode in &decodes {
            let cfg =
                SimConfig::new(cluster.clone(), SystemKind::SLoraRandom)
                    .with_batch_policy(prefill)
                    .with_decode_policy(decode);
            let mut rep = run(trace, &cfg);
            let tbt_lo = rep.tbt_p99_class(8);
            let tbt_hi = rep.tbt_p99_class(128);
            table.row(vec![
                prefill.label(),
                decode.label(),
                fmt_secs(rep.ttft_p95()),
                fmt_secs(tbt_lo),
                fmt_secs(tbt_hi),
                format!("{:.1}%", rep.highrank_decode_share() * 100.0),
                format!("{:.1}%", rep.mixed_decode_share() * 100.0),
                rep.decode_pad_rank.to_string(),
                rep.timeouts.to_string(),
            ]);
        }
    }
    table
}

/// The skewed-rank, decode-heavy workload of the decode grid:
/// exponential rank popularity (most traffic rank-8, a high-rank
/// minority) with long outputs so the decode tail dominates.
pub fn skewed_decode_trace(rps: f64, seed: u64, duration: f64) -> Trace {
    azure::generate(&AzureConfig {
        popularity: RankPopularity::Exponential,
        rps,
        duration,
        seed,
        lengths: LengthModel::fixed(256, 64),
        ..Default::default()
    })
}

pub fn sched(opts: &FigOpts) -> std::io::Result<()> {
    // Mixed ranks with short outputs: prefill iterations dominate, so
    // batch *composition* (not decode-set mixing) drives the
    // iteration mix; the load keeps queues deep enough that admission
    // actually has choices to make.
    let trace = azure::generate(&AzureConfig {
        rps: 24.0,
        duration: opts.scale(480.0),
        seed: opts.seed,
        lengths: LengthModel::fixed(512, 4),
        ..Default::default()
    });
    let cluster = ClusterConfig {
        n_servers: 4,
        rebalance_period: 30.0,
        ..Default::default()
    };
    sched_table(&trace, &cluster).emit(RESULTS_DIR, "sched")?;
    // Decode grid: skewed ranks + long outputs on a small fleet, so
    // active sets mix classes and the decode tail is where the rank
    // tax lands.
    let decode_trace =
        skewed_decode_trace(14.0, opts.seed, opts.scale(480.0));
    let decode_cluster = ClusterConfig {
        n_servers: 2,
        rebalance_period: 30.0,
        ..Default::default()
    };
    sched_decode_table(&decode_trace, &decode_cluster)
        .emit(RESULTS_DIR, "sched_decode")
}
