//! The `sched` ablation: batch scheduling × placement.
//!
//! The paper fixes the scheduler (FIFO continuous batching) and varies
//! *placement*; CaraServe-style rank-aware scheduling is the other
//! half of the heterogeneous-rank design space. This harness runs
//! every system under each `BatchPolicyKind` on a mixed-rank trace:
//! rank-agnostic placement + `fifo` is "neither", rank-agnostic
//! placement + `rank-bucketed` is "scheduling-only", LORASERVE +
//! `fifo` is "placement-only", LORASERVE + `rank-bucketed` is "both".
//! The high-rank iteration share and the padded-token volume are the
//! interference-tax indicators the policies trade against latency.

use super::helpers::{FigOpts, RESULTS_DIR};
use crate::config::{BatchPolicyKind, ClusterConfig};
use crate::sim::{run, SimConfig, SystemKind};
use crate::trace::azure::{self, AzureConfig};
use crate::trace::{LengthModel, Trace};
use crate::util::table::{fmt_secs, Table};

/// Systems × batch policies on one trace. Split from [`sched`] so the
/// test suite can smoke-run it on a tiny trace.
pub fn sched_table(trace: &Trace, cluster: &ClusterConfig) -> Table {
    let policies = [
        BatchPolicyKind::Fifo,
        BatchPolicyKind::RankBucketed {
            max_wait_iters: BatchPolicyKind::DEFAULT_MAX_WAIT_ITERS,
        },
        BatchPolicyKind::RankCap {
            factor: BatchPolicyKind::DEFAULT_CAP_FACTOR,
        },
    ];
    let mut table = Table::new(
        "sched — placement × batch-policy ablation (mixed ranks)",
        &[
            "system",
            "batch policy",
            "p95 ttft",
            "p95 tbt",
            "drops",
            "hi-rank iters",
            "mixed prefills",
            "padded tokens",
        ],
    );
    for system in SystemKind::all() {
        for &policy in &policies {
            let cfg = SimConfig::new(cluster.clone(), system)
                .with_batch_policy(policy);
            let mut rep = run(trace, &cfg);
            table.row(vec![
                system.label().to_string(),
                policy.label(),
                fmt_secs(rep.ttft_p95()),
                fmt_secs(rep.tbt_p95()),
                rep.timeouts.to_string(),
                format!("{:.1}%", rep.highrank_iter_share() * 100.0),
                format!("{:.1}%", rep.mixed_prefill_share() * 100.0),
                rep.pad_rank_tokens.to_string(),
            ]);
        }
    }
    table
}

pub fn sched(opts: &FigOpts) -> std::io::Result<()> {
    // Mixed ranks with short outputs: prefill iterations dominate, so
    // batch *composition* (not decode-set mixing) drives the
    // iteration mix; the load keeps queues deep enough that admission
    // actually has choices to make.
    let trace = azure::generate(&AzureConfig {
        rps: 24.0,
        duration: opts.scale(480.0),
        seed: opts.seed,
        lengths: LengthModel::fixed(512, 4),
        ..Default::default()
    });
    let cluster = ClusterConfig {
        n_servers: 4,
        rebalance_period: 30.0,
        ..Default::default()
    };
    sched_table(&trace, &cluster).emit(RESULTS_DIR, "sched")
}
