//! Sensitivity studies: rank skew (Fig 22), model size (Fig 23), tensor
//! parallelism (Fig 24).

use super::helpers::{run_system, FigOpts, RESULTS_DIR};
use crate::config::{ClusterConfig, ModelSpec};
use crate::sim::SystemKind;
use crate::trace::{LengthModel, Trace};
use crate::util::rng::{Pcg32, PowerLaw};
use crate::util::table::{fmt_secs, Table};
use crate::workload::{AdapterSet, Request, RANK_CLASSES};

/// Power-law-popularity Poisson trace: 100 adapters (20 per rank),
/// adapter popularity ∝ (idx+1)^-α with small ranks first (Fig 22's
/// setup; α ∈ {1/3, 1, 3}).
pub fn skew_trace(alpha: f64, rps: f64, duration: f64, seed: u64) -> Trace {
    let model = ModelSpec::LLAMA_7B;
    let adapters = AdapterSet::uniform_per_rank(100, &RANK_CLASSES, &model);
    // order adapters by rank ascending (they already are) so the power
    // law favors small ranks, as in the paper
    let pl = PowerLaw::new(100, alpha);
    let lengths = LengthModel::default();
    let mut rng = Pcg32::with_stream(seed, 0xf22);
    let mut reqs = Vec::new();
    let mut t = 0.0;
    loop {
        t += rng.exponential(rps);
        if t > duration {
            break;
        }
        let adapter = pl.sample(&mut rng) as u32;
        let (p, o) = lengths.sample(&mut rng);
        reqs.push(Request {
            id: 0,
            adapter,
            prompt_len: p,
            output_len: o,
            arrival: t,
        });
    }
    Trace::new(&format!("skew-a{alpha:.2}"), adapters, reqs)
}

/// Fig 22: varying α in the popularity power law. The paper runs this
/// at 36 RPS on its A100 testbed; our simulated cluster saturates at
/// ~0.72x the paper's absolute rate (see EXPERIMENTS.md scale note), so
/// the harness runs at 26 RPS — the same relative operating point.
pub fn fig22(opts: &FigOpts) -> std::io::Result<()> {
    let mut table = Table::new(
        "Fig 22 — power-law popularity skew (22 RPS Poisson, 100 adapters)",
        &["alpha", "system", "p50 ttft", "p95 ttft", "drops"],
    );
    for alpha in [1.0 / 3.0, 1.0, 3.0] {
        let trace =
            skew_trace(alpha, 22.0, opts.scale(1200.0), opts.seed);
        let cluster = ClusterConfig {
            n_servers: 4,
            ..Default::default()
        };
        for system in SystemKind::all() {
            let mut rep = run_system(&trace, &cluster, system);
            let dropped = rep.completion_rate() < 0.99;
            table.row(vec![
                format!("{alpha:.2}"),
                system.label().to_string(),
                fmt_secs(rep.ttft.p50()),
                if dropped {
                    "TIMEOUT".into()
                } else {
                    fmt_secs(rep.ttft_p95())
                },
                rep.timeouts.to_string(),
            ]);
        }
    }
    table.emit(RESULTS_DIR, "fig22")
}

/// Fig 23: model-size sensitivity (Llama 7B/30B/70B, TP8), fixed trace
/// per model with load scaled to each model's capacity regime.
pub fn fig23(opts: &FigOpts) -> std::io::Result<()> {
    let mut table = Table::new(
        "Fig 23 — model sizes (TP8): P95 TTFT per system",
        &["model", "rps", "loraserve", "slora-random",
          "slora-contiguous", "toppings"],
    );
    for (model, rps) in [
        (ModelSpec::LLAMA_7B, 20.0),
        (ModelSpec::LLAMA_30B, 6.0),
        (ModelSpec::LLAMA_70B, 3.0),
    ] {
        let trace =
            skew_trace(1.0, rps, opts.scale(1200.0), opts.seed);
        let mut cluster = ClusterConfig {
            n_servers: 4,
            ..Default::default()
        };
        cluster.server.model = model;
        cluster.server.tp = 8;
        let mut row =
            vec![model.name.to_string(), format!("{rps:.0}")];
        for system in SystemKind::all() {
            let mut rep = run_system(&trace, &cluster, system);
            if rep.completion_rate() < 0.99 {
                row.push("TIMEOUT".into());
            } else {
                row.push(fmt_secs(rep.ttft_p95()));
            }
        }
        table.row(row);
    }
    table.emit(RESULTS_DIR, "fig23")
}

/// Fig 24: TP sensitivity on Llama-7B — LORASERVE's gains persist at
/// every TP degree.
pub fn fig24(opts: &FigOpts) -> std::io::Result<()> {
    let mut table = Table::new(
        "Fig 24 — TP sensitivity (Llama-7B): P95 TTFT per system",
        &["tp", "rps", "loraserve", "slora-random",
          "slora-contiguous", "toppings"],
    );
    for (tp, rps) in [(2usize, 12.0), (4, 22.0), (8, 28.0)] {
        let trace =
            skew_trace(1.0, rps, opts.scale(1200.0), opts.seed);
        let mut cluster = ClusterConfig {
            n_servers: 4,
            ..Default::default()
        };
        cluster.server.tp = tp;
        let mut row = vec![format!("TP={tp}"), format!("{rps:.0}")];
        for system in SystemKind::all() {
            let mut rep = run_system(&trace, &cluster, system);
            if rep.completion_rate() < 0.99 {
                row.push("TIMEOUT".into());
            } else {
                row.push(fmt_secs(rep.ttft_p95()));
            }
        }
        table.row(row);
    }
    table.emit(RESULTS_DIR, "fig24")
}
