//! The `resilience` figure: p99 TTFT and SLO-violation rate through
//! server crash + recovery, on a churn/diurnal production scenario —
//! periodic vs triggered vs triggered+remote-attach rebalancing.
//!
//! The workload is the `trace::scenario` generator's production pack:
//! tenant-lifecycle adapter churn over a Zipf-popular population with
//! diurnal rate modulation. The failure process (seeded MTBF, see
//! `sim::scenario`) crashes servers mid-trace; in-flight requests
//! requeue, last-copy adapters re-fetch from host memory, and the
//! rebalance layer reacts to the lost capacity — or doesn't, which is
//! the comparison. Each arm runs twice on the identical trace: once
//! with failures disabled (baseline) and once with the crash process
//! on, so the *degradation* column isolates what the crash window
//! costs under each rebalance mode.

use super::drift::drift_rebalance;
use super::helpers::{FigOpts, RESULTS_DIR};
use crate::config::{ClusterConfig, RebalanceMode};
use crate::sim::scenario::{FailureConfig, RegionConfig, ScenarioConfig};
use crate::sim::{run, SimConfig, SimReport, SystemKind};
use crate::trace::scenario::{generate, ScenarioTraceConfig};
use crate::trace::Trace;
use crate::util::table::{fmt_secs, Table};

/// TTFT SLO the violation-rate columns report against: tighter than
/// the autoscaler's `SloConfig` default so the crash window's queueing
/// and host re-fetch stalls actually register as violations.
pub const SLO_TTFT: f64 = 0.5;

/// The churn + diurnal workload the resilience comparison runs on
/// (generator defaults: Zipf 1.2 popularity, half the population
/// churning with 300 s mean lifetimes, 2 diurnal cycles).
pub fn resilience_trace(duration: f64, seed: u64) -> Trace {
    generate(&ScenarioTraceConfig {
        n_adapters: 48,
        rps: 16.0,
        duration,
        seed,
        ..Default::default()
    })
}

/// The failure process of the comparison: crashes eligible after the
/// cold-start window, expected every `mtbf` seconds, each down for
/// ~`mttr`; in-flight requests requeue. Two regions so inter-region
/// RDMA is priced distinctly in the cost model.
pub fn resilience_scenario() -> ScenarioConfig {
    ScenarioConfig {
        failures: FailureConfig {
            enabled: true,
            mtbf: 90.0,
            mttr: 45.0,
            start: 60.0,
            max_crashes: 2,
            requeue: true,
        },
        regions: RegionConfig {
            n_regions: 2,
            ..Default::default()
        },
    }
}

fn run_arm(
    trace: &Trace,
    cluster: &ClusterConfig,
    scenario: ScenarioConfig,
    warmup: f64,
) -> SimReport {
    run(
        trace,
        &SimConfig::new(cluster.clone(), SystemKind::LoraServe)
            .with_warmup(warmup)
            .with_params(|p| p.scenario(scenario)),
    )
}

/// p99 TTFT degradation of one rebalance arm: crash-enabled run minus
/// the failure-free baseline on the identical trace/warmup. Exposed so
/// the resilience acceptance test asserts the mode ordering on the
/// same harness the figure renders.
pub fn p99_degradation(
    trace: &Trace,
    cluster: &ClusterConfig,
    mode: RebalanceMode,
    remote_attach: bool,
    scenario: ScenarioConfig,
    warmup: f64,
) -> f64 {
    let mut cl = cluster.clone();
    cl.rebalance = drift_rebalance(mode, remote_attach);
    let mut baseline = scenario;
    baseline.failures.enabled = false;
    let mut base = run_arm(trace, &cl, baseline, warmup);
    let mut crash = run_arm(trace, &cl, scenario, warmup);
    crash.ttft.p99() - base.ttft.p99()
}

/// One row per rebalance arm: baseline vs crash-enabled percentiles,
/// the degradation delta, and the crash bookkeeping (requeues, host
/// re-fetches) behind it. Split from [`resilience`] so the test suite
/// can smoke-run it on a tiny trace.
pub fn resilience_table(
    trace: &Trace,
    cluster: &ClusterConfig,
    scenario: ScenarioConfig,
    warmup: f64,
) -> Table {
    let mut table = Table::new(
        "resilience — crash + recovery on churn/diurnal demand \
         (loraserve placement)",
        &[
            "mode",
            "remote",
            "crashes",
            "recoveries",
            "requeued",
            "host fetches",
            "p99 ttft base",
            "p99 ttft crash",
            "degradation",
            "viol% base",
            "viol% crash",
        ],
    );
    let arms = [
        (RebalanceMode::Periodic, false),
        (RebalanceMode::Triggered, false),
        (RebalanceMode::Triggered, true),
    ];
    for (mode, remote) in arms {
        let mut cl = cluster.clone();
        cl.rebalance = drift_rebalance(mode, remote);
        let mut baseline = scenario;
        baseline.failures.enabled = false;
        let mut base = run_arm(trace, &cl, baseline, warmup);
        let mut crash = run_arm(trace, &cl, scenario, warmup);
        let viol =
            |rep: &SimReport| (1.0 - rep.ttft.frac_leq(SLO_TTFT)) * 100.0;
        table.row(vec![
            mode.label().to_string(),
            if remote { "on" } else { "off" }.to_string(),
            crash.crashes.to_string(),
            crash.recoveries.to_string(),
            crash.crash_requeued.to_string(),
            crash.host_fetches.to_string(),
            fmt_secs(base.ttft.p99()),
            fmt_secs(crash.ttft.p99()),
            fmt_secs(crash.ttft.p99() - base.ttft.p99()),
            format!("{:.2}", viol(&base)),
            format!("{:.2}", viol(&crash)),
        ]);
    }
    table
}

pub fn resilience(opts: &FigOpts) -> std::io::Result<()> {
    let trace = resilience_trace(opts.scale(1200.0), opts.seed);
    // Period longer than the crash window: the periodic arm re-places
    // on its timer, not in reaction to the crash — exactly the gap the
    // triggered arms close.
    let cluster = ClusterConfig {
        n_servers: 4,
        rebalance_period: 120.0,
        ..Default::default()
    };
    let scenario = resilience_scenario();
    // Measurement starts where crashes become eligible, same cutoff
    // for every arm and for baseline and crash runs alike, so each
    // degradation column isolates the policy over the identical slice.
    let warmup = scenario.failures.start.min(trace.duration() / 3.0);
    resilience_table(&trace, &cluster, scenario, warmup)
        .emit(RESULTS_DIR, "resilience")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resilience_table_smoke() {
        let trace = resilience_trace(120.0, 3);
        let cluster = ClusterConfig {
            n_servers: 3,
            rebalance_period: 60.0,
            ..Default::default()
        };
        let mut sc = resilience_scenario();
        sc.failures.mtbf = 20.0;
        sc.failures.start = 10.0;
        let table = resilience_table(&trace, &cluster, sc, 10.0);
        assert_eq!(table.rows.len(), 3, "one row per rebalance arm");
        for row in &table.rows {
            for cell in row {
                assert!(!cell.is_empty(), "empty cell in {row:?}");
            }
        }
        let md = table.to_markdown();
        assert!(md.contains("periodic"));
        assert!(md.contains("triggered"));
    }
}
