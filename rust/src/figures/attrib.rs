//! The `attrib` figure: per-request SLO-violation attribution across
//! rebalance modes on the drift workload.
//!
//! Runs the same DriftUp/DriftDown trace as the `drift` figure with
//! the latency decomposition enabled, and shows *where* the tail TTFT
//! comes from under each policy: open-loop periodic re-placement pays
//! repeated fetch stalls and rank-padding skew every time the timer
//! moves copies, while the trigger (and especially triggered +
//! remote-attach) moves less and should shrink the `fetch` and `skew`
//! components of the p99 cohort. The `recon` column is the worst
//! per-request |component sum − measured latency| in the cohort —
//! near zero by construction, so the breakdown can be trusted to
//! explain the measured percentiles.

use super::drift::{drift_rebalance, drift_trace};
use super::helpers::{steady_warmup, FigOpts, RESULTS_DIR};
use crate::config::{ClusterConfig, RebalanceMode};
use crate::obs::ObsConfig;
use crate::sim::{run, run_observed, SimConfig, SystemKind};
use crate::util::table::{fmt_secs, Table};

pub fn attrib(opts: &FigOpts) -> std::io::Result<()> {
    let duration = opts.scale(1200.0);
    let trace = drift_trace(40, 12.0, duration, opts.seed);
    let base = ClusterConfig {
        n_servers: 4,
        rebalance_period: 60.0,
        ..Default::default()
    };
    let obs = ObsConfig {
        attrib: true,
        ..Default::default()
    };
    let modes = [
        (RebalanceMode::Periodic, false),
        (RebalanceMode::Triggered, false),
        (RebalanceMode::Triggered, true),
    ];
    // Same two-pass protocol as the `drift` figure: derive one shared
    // steady-state cutoff from probe runs so every row's cohorts cover
    // the identical slice of the non-stationary trace.
    let mut warmup = 0.0f64;
    for (mode, remote) in modes {
        let mut cluster = base.clone();
        cluster.rebalance = drift_rebalance(mode, remote);
        let probe = run(
            &trace,
            &SimConfig::new(cluster.clone(), SystemKind::LoraServe),
        );
        warmup = warmup
            .max(steady_warmup(&cluster, &probe.rebalance_times));
    }
    let warmup = warmup.min(trace.duration() / 3.0);
    let mut table = Table::new(
        "attrib — where TTFT goes, by rebalance mode (drift trace, \
         loraserve placement, 4 servers; component means in seconds)",
        &[
            "mode",
            "remote",
            "cohort",
            "n",
            "p99 ttft",
            "mean ttft",
            "queue",
            "fetch",
            "prefill",
            "skew",
            "remote-att",
            "decode",
            "launch",
            "preempt",
            "recon",
        ],
    );
    for (mode, remote) in modes {
        let mut cluster = base.clone();
        cluster.rebalance = drift_rebalance(mode, remote);
        let (mut rep, _) = run_observed(
            &trace,
            &SimConfig::new(cluster, SystemKind::LoraServe)
                .with_warmup(warmup)
                .with_obs(obs),
        );
        let p99 = rep.ttft.p99();
        let a = rep
            .attribution
            .expect("attribution enabled but no measured completions");
        for (cohort, b) in [("all", a.all), ("p99 tail", a.tail)] {
            table.row(vec![
                mode.label().to_string(),
                if remote { "on" } else { "off" }.to_string(),
                cohort.to_string(),
                b.n.to_string(),
                fmt_secs(p99),
                fmt_secs(b.ttft),
                fmt_secs(b.queue_wait),
                fmt_secs(b.fetch_stall),
                fmt_secs(b.prefill_service),
                fmt_secs(b.skew()),
                fmt_secs(b.remote()),
                fmt_secs(b.decode_service),
                fmt_secs(b.decode_launch),
                fmt_secs(b.preempt_delay),
                format!("{:.1e}", b.recon),
            ]);
        }
    }
    table.emit(RESULTS_DIR, "attrib")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attrib_components_reconcile_on_drift() {
        // one short drift run with the decomposition on: the summed
        // components must reconcile with the measured latencies, and
        // the tail cohort's mean TTFT must sit at (or above) the
        // measured p99
        let trace = drift_trace(20, 8.0, 300.0, 3);
        let mut cluster = ClusterConfig {
            n_servers: 4,
            ..Default::default()
        };
        cluster.rebalance =
            drift_rebalance(RebalanceMode::Periodic, false);
        let (mut rep, _) = run_observed(
            &trace,
            &SimConfig::new(cluster, SystemKind::LoraServe).with_obs(
                ObsConfig {
                    attrib: true,
                    ..Default::default()
                },
            ),
        );
        let a = rep.attribution.expect("measured completions");
        assert!(a.all.n > 100, "n={}", a.all.n);
        assert!(a.all.recon < 1e-6, "recon={}", a.all.recon);
        assert!(a.tail.recon < 1e-6, "recon={}", a.tail.recon);
        // the tail cohort (top 1% by TTFT) explains the p99 end of
        // the measured distribution: its mean must not sit below the
        // measured p99 (small slack for percentile interpolation)
        assert!(
            a.tail.ttft >= 0.95 * rep.ttft.p99(),
            "tail mean {} vs p99 {}",
            a.tail.ttft,
            rep.ttft.p99()
        );
    }
}
