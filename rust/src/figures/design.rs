//! Design-section figures: placement illustration (Fig 12) and the
//! fetch-latency benchmark behind the distributed pool (Fig 14).

use super::helpers::{FigOpts, RESULTS_DIR};
use crate::config::{GpuSpec, ModelSpec};
use crate::costmodel::{fetch_time, operating_points, FetchSource};
use crate::placement::baselines::{ContiguousPlacer, RandomPlacer};
use crate::placement::loraserve::LoraServePlacer;
use crate::placement::{PlacementCtx, Placer};
use crate::util::rng::Pcg32;
use crate::util::table::{fmt_f, fmt_secs, Table};
use crate::workload::{AdapterId, AdapterSet, RANK_CLASSES};
use std::collections::BTreeMap;

/// Fig 12: qualitative placement comparison — load balance vs rank
/// heterogeneity for Random / Contiguous / LORASERVE on one instance.
pub fn fig12(opts: &FigOpts) -> std::io::Result<()> {
    let n_servers = 4;
    let adapters = AdapterSet::power_law_counts(
        16,
        &RANK_CLASSES,
        1.0,
        &ModelSpec::LLAMA_7B,
    );
    let mut rng = Pcg32::with_stream(opts.seed, 0xf12);
    let mut demand: BTreeMap<AdapterId, f64> = BTreeMap::new();
    for a in adapters.iter() {
        demand.insert(a.id, rng.lognormal((300.0f64).ln(), 1.0));
    }
    let oppoints = operating_points(
        &crate::config::ServerConfig::default(),
        &RANK_CLASSES,
    );
    let ctx = PlacementCtx {
        adapters: &adapters,
        n_servers,
        demand_tps: &demand,
        operating_points: &oppoints,
        prev: None,
    };
    let mut table = Table::new(
        "Fig 12 — placement quality: load balance vs rank heterogeneity",
        &[
            "placer", "util cv", "mean ranks/server", "max ranks/server",
            "server loads",
        ],
    );
    let placers: Vec<Box<dyn Placer>> = vec![
        Box::new(RandomPlacer::new(opts.seed)),
        Box::new(ContiguousPlacer::new()),
        Box::new(LoraServePlacer::new()),
    ];
    for mut p in placers {
        let asg = p.place(&ctx);
        asg.validate(n_servers).unwrap();
        let utils =
            asg.server_utils(n_servers, &adapters, &demand, &oppoints);
        let mean = utils.iter().sum::<f64>() / n_servers as f64;
        let var = utils.iter().map(|u| (u - mean).powi(2)).sum::<f64>()
            / n_servers as f64;
        let cv = var.sqrt() / mean;
        let het = asg.heterogeneity(n_servers, &adapters);
        table.row(vec![
            p.name().to_string(),
            fmt_f(cv, 3),
            fmt_f(
                het.iter().sum::<usize>() as f64 / n_servers as f64,
                2,
            ),
            het.iter().max().unwrap().to_string(),
            format!(
                "[{}]",
                utils
                    .iter()
                    .map(|u| format!("{u:.2}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        ]);
    }
    table.emit(RESULTS_DIR, "fig12")
}

/// Fig 14: latency of fetching a tensor from each source vs size.
pub fn fig14(_opts: &FigOpts) -> std::io::Result<()> {
    let gpu = GpuSpec::A100_40G;
    let mut table = Table::new(
        "Fig 14 — tensor fetch latency by source",
        &["size", "local host mem", "remote GPU (RDMA)", "local SSD"],
    );
    for mb in [16u64, 32, 64, 128, 256, 512, 1024, 2048] {
        let bytes = mb << 20;
        table.row(vec![
            format!("{mb} MiB"),
            fmt_secs(fetch_time(&gpu, FetchSource::LocalHostMem, bytes)),
            fmt_secs(fetch_time(&gpu, FetchSource::RemoteRdma, bytes)),
            fmt_secs(fetch_time(&gpu, FetchSource::LocalSsd, bytes)),
        ]);
    }
    // adapter-scale reference rows
    for rank in [8u32, 128] {
        let bytes = ModelSpec::LLAMA_7B.adapter_bytes(rank);
        table.row(vec![
            format!("7B rank-{rank} adapter"),
            fmt_secs(fetch_time(&gpu, FetchSource::LocalHostMem, bytes)),
            fmt_secs(fetch_time(&gpu, FetchSource::RemoteRdma, bytes)),
            fmt_secs(fetch_time(&gpu, FetchSource::LocalSsd, bytes)),
        ]);
    }
    table.emit(RESULTS_DIR, "fig14")
}
