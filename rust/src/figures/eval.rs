//! Main evaluation figures: production traces (Fig 17, 18), derived
//! Azure traces (Fig 19, 20), and weak scaling (Fig 21).

use super::helpers::{
    max_rps_under_slo, min_servers_under_slo, run_system, FigOpts,
    RESULTS_DIR,
};
use crate::config::ClusterConfig;
use crate::sim::SystemKind;
use crate::trace::production::{self, ProductionConfig};
use crate::trace::{azure, Trace};
use crate::util::table::{fmt_bytes, fmt_secs, Table};

fn cluster4() -> ClusterConfig {
    ClusterConfig {
        n_servers: 4,
        ..Default::default()
    }
}

fn production_trace(n_adapters: usize, opts: &FigOpts) -> Trace {
    production::generate(&ProductionConfig {
        n_adapters,
        n_requests: opts.scale(40_000.0) as usize,
        duration: opts.scale(2400.0),
        seed: opts.seed,
        ..Default::default()
    })
}

/// Fig 17: production traces with 50/100/200 adapters — max sustainable
/// RPS under the SLA per system, plus the GPU-savings view (min servers
/// to serve a fixed 24 RPS).
pub fn fig17(opts: &FigOpts) -> std::io::Result<()> {
    let mut table = Table::new(
        "Fig 17 — production trace: max RPS under SLA / min servers @24 RPS",
        &[
            "#adapters", "system", "max rps (4 srv)", "min servers",
            "p95 ttft @20rps",
        ],
    );
    let sizes: &[usize] = if opts.fast { &[100] } else { &[50, 100, 200] };
    for &n_adapters in sizes {
        let trace = production_trace(n_adapters, opts);
        for system in SystemKind::all() {
            let cap = max_rps_under_slo(
                &trace,
                &cluster4(),
                system,
                2.0,
                60.0,
                1.0,
            );
            let fixed = trace.scale_to_rps(24.0);
            let min_srv =
                min_servers_under_slo(&fixed, &cluster4(), system, 12);
            let at20 = trace.scale_to_rps(20.0);
            let mut rep = run_system(&at20, &cluster4(), system);
            table.row(vec![
                n_adapters.to_string(),
                system.label().to_string(),
                format!("{cap:.0}"),
                min_srv
                    .map(|n| n.to_string())
                    .unwrap_or_else(|| ">12".into()),
                fmt_secs(rep.ttft_p95()),
            ]);
        }
    }
    table.emit(RESULTS_DIR, "fig17")
}

/// Fig 18: per-server tail latency and max resident adapters on the
/// 100-adapter production trace. The paper runs 30 RPS on its testbed;
/// scaled to this testbed's capacity the same relative operating point
/// is ~20 RPS (see EXPERIMENTS.md scale note).
pub fn fig18(opts: &FigOpts) -> std::io::Result<()> {
    let trace = production_trace(100, opts).scale_to_rps(20.0);
    let mut top = Table::new(
        "Fig 18 (top) — per-server P95 TTFT (queueing + prefill), 20 RPS",
        &["system", "srv0", "srv1", "srv2", "srv3", "timeouts"],
    );
    let mut bottom = Table::new(
        "Fig 18 (bottom) — max adapters resident per server",
        &["system", "srv0", "srv1", "srv2", "srv3", "max/loraserve-max"],
    );
    let mut loraserve_max = 1usize;
    let mut rows = Vec::new();
    for system in SystemKind::all() {
        let mut rep = run_system(&trace, &cluster4(), system);
        let mut row = vec![system.label().to_string()];
        for s in 0..4 {
            row.push(fmt_secs(rep.per_server_ttft[s].p95()));
        }
        row.push(rep.timeouts.to_string());
        top.row(row);
        let max_here =
            *rep.per_server_max_adapters.iter().max().unwrap();
        if system == SystemKind::LoraServe {
            loraserve_max = max_here.max(1);
        }
        rows.push((system, rep.per_server_max_adapters.clone()));
    }
    for (system, per) in rows {
        let mut row = vec![system.label().to_string()];
        for s in 0..4 {
            row.push(per[s].to_string());
        }
        row.push(format!(
            "{:.1}x",
            *per.iter().max().unwrap() as f64 / loraserve_max as f64
        ));
        bottom.row(row);
    }
    top.emit(RESULTS_DIR, "fig18_latency")?;
    bottom.emit(RESULTS_DIR, "fig18_adapters")
}

fn six_traces(opts: &FigOpts, rps: f64) -> Vec<Trace> {
    azure::six_trace_matrix()
        .into_iter()
        .map(|(arrival, popularity)| {
            azure::generate(&azure::AzureConfig {
                arrival,
                popularity,
                rps,
                duration: opts.scale(1200.0),
                seed: opts.seed,
                ..Default::default()
            })
        })
        .collect()
}

/// Fig 19 (TTFT) and Fig 20 (TBT) on the six derived traces, per
/// system, across an RPS sweep.
pub fn fig19_20(opts: &FigOpts) -> std::io::Result<()> {
    let mut ttft = Table::new(
        "Fig 19 — P95 TTFT across derived traces (TIMEOUT = >1% drops)",
        &["trace", "rps", "loraserve", "slora-random",
          "slora-contiguous", "toppings"],
    );
    let mut tbt = Table::new(
        "Fig 20 — P95 TBT across derived traces",
        &["trace", "rps", "loraserve", "slora-random",
          "slora-contiguous", "toppings"],
    );
    let rps_grid: &[f64] = if opts.fast {
        &[12.0, 20.0]
    } else {
        &[8.0, 14.0, 20.0, 26.0]
    };
    for base in six_traces(opts, 10.0) {
        for &rps in rps_grid {
            let trace = base.scale_to_rps(rps);
            let mut trow = vec![base.name.clone(), format!("{rps:.0}")];
            let mut brow = trow.clone();
            for system in SystemKind::all() {
                let mut rep = run_system(&trace, &cluster4(), system);
                if rep.completion_rate() < 0.99 {
                    trow.push("TIMEOUT".into());
                    brow.push("TIMEOUT".into());
                } else {
                    trow.push(fmt_secs(rep.ttft_p95()));
                    brow.push(fmt_secs(rep.tbt_p95()));
                }
            }
            ttft.row(trow);
            tbt.row(brow);
        }
    }
    ttft.emit(RESULTS_DIR, "fig19")?;
    tbt.emit(RESULTS_DIR, "fig20")
}

/// Fig 21: weak scaling — clusters of 4/8/12 servers with adapters and
/// traffic scaled proportionally; report max RPS under a 10 s P95 SLO.
pub fn fig21(opts: &FigOpts) -> std::io::Result<()> {
    let mut table = Table::new(
        "Fig 21 — weak scaling (adapters & traffic ∝ servers, SLO 10s)",
        &["servers", "adapters", "max rps", "rps/server"],
    );
    let sizes: &[usize] = if opts.fast { &[4, 8] } else { &[4, 8, 12] };
    for &n in sizes {
        let trace = azure::generate(&azure::AzureConfig {
            adapters_per_rank: n + 1, // 25/45/65 adapters for 4/8/12
            rps: 10.0,
            duration: opts.scale(900.0),
            seed: opts.seed,
            ..Default::default()
        });
        let cluster = ClusterConfig {
            n_servers: n,
            ..Default::default()
        };
        let cap = max_rps_under_slo(
            &trace,
            &cluster,
            SystemKind::LoraServe,
            4.0,
            40.0 * n as f64,
            2.0,
        );
        table.row(vec![
            n.to_string(),
            trace.adapters.len().to_string(),
            format!("{cap:.0}"),
            format!("{:.1}", cap / n as f64),
        ]);
    }
    table.emit(RESULTS_DIR, "fig21")
}

/// Fig 18-adjacent summary also used in EXPERIMENTS.md: adapter storage
/// footprint per system (bytes high-water) on the production trace.
pub fn storage_summary(opts: &FigOpts) -> std::io::Result<()> {
    let trace = production_trace(100, opts).scale_to_rps(20.0);
    let mut table = Table::new(
        "Adapter storage — max resident count and fetch traffic",
        &["system", "max resident", "fetches", "fetch bytes"],
    );
    for system in SystemKind::all() {
        let rep = run_system(&trace, &cluster4(), system);
        table.row(vec![
            system.label().to_string(),
            rep.per_server_max_adapters
                .iter()
                .max()
                .unwrap()
                .to_string(),
            rep.fetches.to_string(),
            fmt_bytes(rep.fetch_bytes),
        ]);
    }
    table.emit(RESULTS_DIR, "storage")
}
