//! Figure harnesses: one entry per table/figure in the paper's
//! evaluation (see DESIGN.md §6 for the experiment index). Each prints
//! a markdown table and writes `results/<id>.csv`.
//!
//! ```text
//! cargo run --release -- figures --all [--fast]
//! cargo run --release -- figures --fig 17
//! ```

pub mod ablations;
pub mod attrib;
pub mod characterization;
pub mod design;
pub mod drift;
pub mod elastic;
pub mod eval;
pub mod helpers;
pub mod memory;
pub mod motivation;
pub mod resilience;
pub mod sched;
pub mod sensitivity;

pub use helpers::FigOpts;

type FigFn = fn(&FigOpts) -> std::io::Result<()>;

/// Registry: (id, description, runner).
pub fn registry() -> Vec<(&'static str, &'static str, FigFn)> {
    vec![
        ("fig1", "co-serving interference (P95 TTFT per pair)",
         motivation::fig1 as FigFn),
        ("fig3", "isolated TTFT/TBT vs input size per rank",
         motivation::fig3),
        ("fig4", "relative TTFT vs model size", motivation::fig4),
        ("fig5", "relative TTFT vs TP", motivation::fig5),
        ("fig6", "4 RPS Poisson per rank vs SLO", motivation::fig6),
        ("fig7", "adapters + footprint per base model",
         characterization::fig7),
        ("fig8", "adapter request shares (top-5 > 70%)",
         characterization::fig8),
        ("fig9", "server shares per model/region",
         characterization::fig9),
        ("fig10", "weekly RPM of top-5 adapters",
         characterization::fig10),
        ("fig12", "placement quality comparison", design::fig12),
        ("fig14", "tensor fetch latency by source", design::fig14),
        ("fig15", "rank-wise request/token distribution",
         characterization::fig15),
        ("fig16", "shifting-skew schedule", characterization::fig16),
        ("fig17", "production traces: max RPS + GPU savings",
         eval::fig17),
        ("fig18", "per-server latency + resident adapters",
         eval::fig18),
        ("fig19", "TTFT (and fig20 TBT) on six derived traces",
         eval::fig19_20),
        ("fig21", "weak scaling 4/8/12 servers", eval::fig21),
        ("fig22", "rank-skew sensitivity (alpha sweep)",
         sensitivity::fig22),
        ("fig23", "model-size sensitivity", sensitivity::fig23),
        ("fig24", "TP sensitivity", sensitivity::fig24),
        ("tops", "operating-point table", motivation::tops),
        ("storage", "adapter storage/fetch summary",
         eval::storage_summary),
        ("ablations", "Algorithm 1 design-choice ablations",
         ablations::ablations),
        ("sched", "batch scheduling × placement ablation + \
                   prefill × decode policy grid + SLO-feedback grid",
         sched::sched),
        ("drift", "drift-reactive rebalancing: periodic vs triggered \
                   vs triggered+remote-attach",
         drift::drift),
        ("attrib", "SLO-violation attribution: TTFT component \
                    breakdown by rebalance mode",
         attrib::attrib),
        ("resilience", "crash + recovery on churn/diurnal demand: \
                        p99 TTFT + SLO violations by rebalance mode",
         resilience::resilience),
        ("memory", "unified HBM economy: throughput + p99 TTFT vs \
                    page budget across eviction policies",
         memory::memory),
        ("gpus", "min fleet under SLO per system (GPU savings)",
         elastic::gpus_under_slo),
        ("fleet", "SLO-aware autoscaler fleet-size timeline",
         elastic::fleet_timeline),
    ]
}

/// Run one figure by id.
pub fn run_one(id: &str, opts: &FigOpts) -> std::io::Result<bool> {
    for (fid, _, f) in registry() {
        if fid == id || fid.strip_prefix("fig") == Some(id) {
            f(opts)?;
            return Ok(true);
        }
    }
    Ok(false)
}

/// Run everything (the `make figures` target).
pub fn run_all(opts: &FigOpts) -> std::io::Result<()> {
    for (id, desc, f) in registry() {
        println!("\n===== {id}: {desc} =====");
        let t = std::time::Instant::now();
        f(opts)?;
        println!("[{id} done in {:.1}s]", t.elapsed().as_secs_f64());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_unique_and_resolvable() {
        let reg = registry();
        let ids: std::collections::BTreeSet<&str> =
            reg.iter().map(|(id, _, _)| *id).collect();
        assert_eq!(ids.len(), reg.len());
        assert!(ids.contains("fig17") && ids.contains("ablations"));
    }

    #[test]
    fn cheap_figures_run() {
        // run the closed-form/characterization harnesses end to end in
        // a temp dir (they write results/)
        let tmp = std::env::temp_dir().join("loraserve_figs");
        std::fs::create_dir_all(&tmp).unwrap();
        let old = std::env::current_dir().unwrap();
        std::env::set_current_dir(&tmp).unwrap();
        let opts = FigOpts {
            fast: true,
            seed: 0,
        };
        for id in ["fig3", "fig4", "fig5", "fig7", "fig8", "fig9",
                   "fig12", "fig14", "fig16", "tops"] {
            assert!(run_one(id, &opts).unwrap(), "{id} missing");
        }
        assert!(!run_one("nope", &opts).unwrap());
        std::env::set_current_dir(old).unwrap();
    }
}
