//! The `drift` figure: open-loop periodic rebalancing vs the
//! drift-reactive trigger (with and without remote-attach serving) on
//! the production trace's drift shapes.
//!
//! The workload isolates Fig 10's DriftUp/DriftDown archetypes: one
//! rank class's demand ramps 0.5× → 1.5× of its mean while the
//! other's ramps 1.5× → 0.5×, so the per-server load genuinely
//! crosses over mid-trace. An open-loop timer re-places (and moves
//! bytes) every period whether or not anything drifted; the trigger
//! fires only when the projected imbalance actually crosses its
//! threshold, and the incremental planner then moves only the copies
//! whose queued-token relief beats their RDMA cost — remote attach
//! additionally serves the rejected moves out of their old homes'
//! HBM, so routing follows the drift without the bytes following it.

use super::helpers::{steady_warmup, FigOpts, RESULTS_DIR};
use crate::config::{ClusterConfig, ModelSpec, RebalanceMode};
use crate::sim::{run, SimConfig, SystemKind};
use crate::trace::production::{ArrivalShape, SHAPES};
use crate::trace::Trace;
use crate::util::rng::{Pcg32, PowerLaw};
use crate::util::table::{fmt_bytes, fmt_secs, Table};
use crate::workload::{AdapterSet, Request};

/// Two-population drift trace on the `production.rs` arrival shapes:
/// the rank-8 adapters ride [`ArrivalShape::DriftUp`] while the
/// rank-64 adapters ride [`ArrivalShape::DriftDown`] (per-minute
/// Poisson thinning, power-law traffic split within each class), so
/// demand drifts across the placement for the whole trace. Expected
/// total ≈ `rps × duration` requests.
pub fn drift_trace(
    n_adapters: usize,
    rps: f64,
    duration: f64,
    seed: u64,
) -> Trace {
    let ranks = [8u32, 64];
    let shapes = [ArrivalShape::DriftUp, ArrivalShape::DriftDown];
    debug_assert!(SHAPES.contains(&shapes[0]));
    let adapters =
        AdapterSet::uniform_per_rank(n_adapters, &ranks, &ModelSpec::LLAMA_7B);
    let mut class_members: Vec<Vec<u32>> = vec![Vec::new(); ranks.len()];
    for a in adapters.iter() {
        let k = ranks.iter().position(|&r| r == a.rank).unwrap();
        class_members[k].push(a.id);
    }
    let splitters: Vec<PowerLaw> = class_members
        .iter()
        .map(|m| PowerLaw::new(m.len().max(1), 1.5))
        .collect();
    let mut rng = Pcg32::with_stream(seed, 0xd21f7);
    let minutes = ((duration / 60.0).ceil() as usize).max(1);
    // normalize so the expected request total is rps × duration
    let mut norm = 0.0;
    for shape in &shapes {
        for m in 0..minutes {
            let f = m as f64 / minutes as f64;
            norm += 0.5 * shape.intensity(f);
        }
    }
    let base = rps * duration / norm;
    let mut requests: Vec<Request> = Vec::new();
    for m in 0..minutes {
        let f = m as f64 / minutes as f64;
        for (k, shape) in shapes.iter().enumerate() {
            let lambda = 0.5 * shape.intensity(f) * base;
            for _ in 0..rng.poisson(lambda) {
                let t = (m as f64 + rng.f64()) * 60.0;
                if t > duration {
                    continue;
                }
                let within = splitters[k].sample(&mut rng);
                requests.push(Request {
                    id: 0,
                    adapter: class_members[k][within],
                    prompt_len: 512,
                    output_len: 16,
                    arrival: t,
                });
            }
        }
    }
    Trace::new(&format!("drift-n{n_adapters}-s{seed}"), adapters, requests)
}

/// The trigger knobs the drift comparison runs with: sensitive enough
/// that the DriftUp/DriftDown crossover (≈1.5× end-state imbalance)
/// reliably fires, with the default hysteresis/min-interval guards.
pub fn drift_rebalance(
    mode: RebalanceMode,
    remote_attach: bool,
) -> crate::config::RebalanceConfig {
    crate::config::RebalanceConfig {
        mode,
        imbalance_threshold: 1.2,
        remote_attach,
        ..Default::default()
    }
}

pub fn drift(opts: &FigOpts) -> std::io::Result<()> {
    let duration = opts.scale(1200.0);
    let trace = drift_trace(40, 12.0, duration, opts.seed);
    let base = ClusterConfig {
        n_servers: 4,
        rebalance_period: 60.0,
        ..Default::default()
    };
    let mut table = Table::new(
        "drift — rebalance modes on DriftUp/DriftDown demand \
         (loraserve placement, 4 servers)",
        &[
            "mode",
            "remote",
            "p95 ttft",
            "p99 ttft",
            "rebalances",
            "triggered",
            "moves",
            "rejected",
            "migrated",
            "fetched",
            "remote served",
        ],
    );
    let modes = [
        (RebalanceMode::Periodic, false),
        (RebalanceMode::Triggered, false),
        (RebalanceMode::Triggered, true),
        (RebalanceMode::Hybrid, false),
    ];
    // Two passes. The probe runs derive each mode's steady-state
    // cutoff from its *observed* rebalance timestamps (trigger-driven
    // runs may never see 2 × period elapse); the measured runs then
    // all apply the SAME cutoff — the worst (latest) one — so every
    // row's percentiles cover the identical slice of this
    // non-stationary trace and the comparison isolates the policy,
    // not the measurement window.
    let mut warmup = 0.0f64;
    for (mode, remote) in modes {
        let mut cluster = base.clone();
        cluster.rebalance = drift_rebalance(mode, remote);
        let probe = run(
            &trace,
            &SimConfig::new(cluster.clone(), SystemKind::LoraServe),
        );
        warmup = warmup
            .max(steady_warmup(&cluster, &probe.rebalance_times));
    }
    let warmup = warmup.min(trace.duration() / 3.0);
    for (mode, remote) in modes {
        let mut cluster = base.clone();
        cluster.rebalance = drift_rebalance(mode, remote);
        let mut rep = run(
            &trace,
            &SimConfig::new(cluster, SystemKind::LoraServe)
                .with_warmup(warmup),
        );
        table.row(vec![
            mode.label().to_string(),
            if remote { "on" } else { "off" }.to_string(),
            fmt_secs(rep.ttft.p95()),
            fmt_secs(rep.ttft.p99()),
            rep.rebalances.to_string(),
            rep.triggered_rebalances.to_string(),
            rep.incremental_moves.to_string(),
            rep.rejected_moves.to_string(),
            fmt_bytes(rep.migration_bytes),
            fmt_bytes(rep.fetch_bytes),
            rep.remote_served.to_string(),
        ]);
    }
    table.emit(RESULTS_DIR, "drift")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drift_trace_shape() {
        let t = drift_trace(20, 6.0, 600.0, 1);
        // expected total within a loose Poisson band
        let n = t.requests.len() as f64;
        assert!((n - 3600.0).abs() < 3600.0 * 0.15, "n={n}");
        assert!(t.duration() <= 600.0);
        assert_eq!(t.adapters.len(), 20);
        // drift: the rank-8 class's share of the last quarter beats
        // its share of the first quarter (and vice versa for rank 64)
        let q = 600.0 / 4.0;
        let share8 = |lo: f64, hi: f64| -> f64 {
            let (mut r8, mut all) = (0usize, 0usize);
            for r in &t.requests {
                if r.arrival >= lo && r.arrival < hi {
                    all += 1;
                    if t.adapters.get(r.adapter).rank == 8 {
                        r8 += 1;
                    }
                }
            }
            r8 as f64 / all.max(1) as f64
        };
        let early = share8(0.0, q);
        let late = share8(600.0 - q, 600.0);
        assert!(
            late > early + 0.2,
            "rank-8 share must drift up: early {early} late {late}"
        );
        // deterministic per seed
        let t2 = drift_trace(20, 6.0, 600.0, 1);
        assert_eq!(t.requests.len(), t2.requests.len());
        assert_eq!(t.requests[7], t2.requests[7]);
    }
}
