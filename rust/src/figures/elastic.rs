//! Elastic-capacity figures: the GPUs-under-SLO comparison (the
//! paper's "up to 50% fewer GPUs" claim as a minimum-fleet search per
//! system) and the autoscaler fleet-size timeline on a drifting trace.

use super::helpers::{FigOpts, RESULTS_DIR};
use crate::autoscale::{plan_min_fleet, SloSpec};
use crate::config::{AutoscaleConfig, ClusterConfig};
use crate::sim::{self, SimConfig, SystemKind};
use crate::trace::azure::{self, AzureConfig, RankPopularity};
use crate::trace::production::{self, ProductionConfig};
use crate::trace::Trace;
use crate::util::table::{fmt_secs, Table};

fn planning_trace(opts: &FigOpts, rps: f64) -> Trace {
    production::generate(&ProductionConfig {
        n_adapters: 100,
        n_requests: (rps * opts.scale(600.0)) as usize,
        duration: opts.scale(600.0),
        seed: opts.seed,
        ..Default::default()
    })
    .scale_to_rps(rps)
}

/// GPUs needed under the SLO, per system: the minimum fleet whose
/// fixed-fleet run keeps P95 TTFT within the SLA at the trace's rate.
pub fn gpus_under_slo(opts: &FigOpts) -> std::io::Result<()> {
    let base = ClusterConfig::default();
    let rps = if opts.fast { 16.0 } else { 24.0 };
    let trace = planning_trace(opts, rps);
    let spec = SloSpec::ttft_p95(base.slo.ttft_p95);
    let max_servers = 12;
    let mut table = Table::new(
        &format!(
            "GPUs under SLO — min fleet @ {rps:.0} RPS, p95 TTFT ≤ {}",
            fmt_secs(base.slo.ttft_p95)
        ),
        &["system", "min servers", "gpus", "p95 ttft @min", "vs loraserve"],
    );
    let mut plans = Vec::new();
    for system in SystemKind::all() {
        plans.push(plan_min_fleet(&trace, &base, system, &spec, max_servers));
    }
    let ls_min = plans
        .iter()
        .find(|p| p.system == SystemKind::LoraServe)
        .and_then(|p| p.min_servers);
    for plan in &plans {
        let ratio = match (plan.min_servers, ls_min) {
            (Some(n), Some(l)) if l > 0 => {
                format!("{:.2}x", n as f64 / l as f64)
            }
            _ => "-".into(),
        };
        table.row(vec![
            plan.system.label().to_string(),
            plan.min_servers
                .map(|n| n.to_string())
                .unwrap_or_else(|| format!(">{max_servers}")),
            plan.gpus(base.server.tp)
                .map(|g| g.to_string())
                .unwrap_or_else(|| "-".into()),
            plan.observed_at_min()
                .map(fmt_secs)
                .unwrap_or_else(|| "-".into()),
            ratio,
        ]);
    }
    table.emit(RESULTS_DIR, "gpus_under_slo")
}

/// SLO-aware autoscaler on the shifting-skew trace: fleet-size
/// timeline + GPU-seconds accounting.
pub fn fleet_timeline(opts: &FigOpts) -> std::io::Result<()> {
    let trace = azure::generate(&AzureConfig {
        popularity: RankPopularity::ShiftingSkew,
        rps: 18.0,
        duration: opts.scale(1200.0),
        seed: opts.seed,
        ..Default::default()
    });
    let cluster = ClusterConfig {
        n_servers: 2,
        ..Default::default()
    };
    let acfg = AutoscaleConfig {
        min_servers: 1,
        max_servers: 8,
        ..Default::default()
    };
    let mut rep = sim::run(
        &trace,
        &SimConfig::new(cluster.clone(), SystemKind::LoraServe)
            .with_autoscale(acfg),
    );
    let ttft_p95 = rep.ttft_p95();
    let mut timeline = Table::new(
        "autoscaler fleet timeline (shifting skew, 18 RPS)",
        &["t (s)", "active servers"],
    );
    for &(t, n) in &rep.fleet.timeline {
        timeline.row(vec![format!("{t:.1}"), n.to_string()]);
    }
    timeline.emit(RESULTS_DIR, "fleet_timeline")?;
    let mut summary = Table::new(
        "elastic run summary",
        &["metric", "value"],
    );
    for (k, v) in [
        ("scale-ups", rep.fleet.scale_ups.to_string()),
        ("scale-downs", rep.fleet.scale_downs.to_string()),
        ("peak fleet", rep.fleet.peak_servers().to_string()),
        ("mean fleet", format!("{:.2}", rep.fleet.mean_fleet())),
        ("gpu-seconds", format!("{:.0}", rep.fleet.gpu_seconds)),
        (
            "fixed-fleet gpu-seconds",
            format!(
                "{:.0}",
                (acfg.max_servers * cluster.server.tp) as f64
                    * rep.fleet.duration()
            ),
        ),
        ("slo violation rate", format!("{:.4}", rep.fleet.violation_rate())),
        ("ttft p95", fmt_secs(ttft_p95)),
        ("completed", rep.completed.to_string()),
        ("timeouts", rep.timeouts.to_string()),
    ] {
        summary.row(vec![k.to_string(), v]);
    }
    summary.emit(RESULTS_DIR, "fleet_summary")
}
