//! §III-B workload characterization figures (7, 8, 9, 10, 15, 16) over
//! the synthesized production-like data (DESIGN.md §4 substitution).

use super::helpers::{FigOpts, RESULTS_DIR};
use crate::trace::production::{
    self, fleet_snapshot, raw_adapter_shares, week_rpm_series,
    ProductionConfig,
};
use crate::trace::{azure, characterize};
use crate::util::stats::moving_average;
use crate::util::table::{fmt_f, Table};

/// Fig 7: adapters + memory footprint per base model.
pub fn fig7(opts: &FigOpts) -> std::io::Result<()> {
    let fleet = fleet_snapshot(opts.seed);
    let mut table = Table::new(
        "Fig 7 — adapters and memory footprint per base model",
        &["base model", "adapters", "est. footprint (GB)"],
    );
    for (name, n, gb) in &fleet.models {
        table.row(vec![
            name.to_string(),
            n.to_string(),
            fmt_f(*gb, 1),
        ]);
    }
    table.emit(RESULTS_DIR, "fig7")
}

/// Fig 8: request share per adapter for Model A (top-5 > 70%).
pub fn fig8(opts: &FigOpts) -> std::io::Result<()> {
    let shares = raw_adapter_shares(1000, opts.seed);
    let mut table = Table::new(
        "Fig 8 — adapter request shares, Model A (1000 adapters)",
        &["adapter", "share", "cumulative"],
    );
    let mut cum = 0.0;
    for (i, s) in shares.iter().take(10).enumerate() {
        cum += s;
        table.row(vec![
            format!("#{}", i + 1),
            format!("{:.1}%", s * 100.0),
            format!("{:.1}%", cum * 100.0),
        ]);
    }
    let top5: f64 = shares.iter().take(5).sum();
    let tail_mean: f64 =
        shares[5..].iter().sum::<f64>() / (shares.len() - 5) as f64;
    table.row(vec![
        "top-5 total".into(),
        format!("{:.1}%", top5 * 100.0),
        "-".into(),
    ]);
    table.row(vec![
        "mean of rest".into(),
        format!("{:.3}%", tail_mean * 100.0),
        "-".into(),
    ]);
    table.emit(RESULTS_DIR, "fig8")
}

/// Fig 9: server share per model and per region.
pub fn fig9(opts: &FigOpts) -> std::io::Result<()> {
    let fleet = fleet_snapshot(opts.seed);
    let mut table = Table::new(
        "Fig 9 — share of LLM servers per model (left) and region (right)",
        &["dimension", "name", "share"],
    );
    for (name, s) in &fleet.server_share_by_model {
        table.row(vec![
            "model".into(),
            name.to_string(),
            format!("{:.0}%", s * 100.0),
        ]);
    }
    for (name, s) in &fleet.server_share_by_region {
        table.row(vec![
            "region".into(),
            name.to_string(),
            format!("{:.0}%", s * 100.0),
        ]);
    }
    table.emit(RESULTS_DIR, "fig9")
}

/// Fig 10: weekly requests-per-minute of the top-5 adapters (hourly
/// moving average, 8 sample points per adapter for the table; the CSV
/// holds the full series).
pub fn fig10(opts: &FigOpts) -> std::io::Result<()> {
    let series = week_rpm_series(opts.seed);
    let mut table = Table::new(
        "Fig 10 — weekly RPM per top adapter (hourly MA, day boundaries)",
        &[
            "adapter(shape)", "d0", "d1", "d2", "d3", "d4", "d5", "d6",
        ],
    );
    let mut csv = Table::new(
        "fig10 full series",
        &["adapter", "minute", "rpm_ma60"],
    );
    for (i, (shape, xs)) in series.iter().enumerate() {
        let ma = moving_average(xs, 60);
        let mut row = vec![format!("A{} ({:?})", i + 1, shape)];
        for day in 0..7 {
            let idx = day * 24 * 60 + 12 * 60; // midday sample
            row.push(fmt_f(ma[idx], 0));
        }
        table.row(row);
        for (m, v) in ma.iter().enumerate().step_by(30) {
            csv.row(vec![
                format!("A{}", i + 1),
                m.to_string(),
                fmt_f(*v, 2),
            ]);
        }
    }
    table.emit(RESULTS_DIR, "fig10_summary")?;
    // full series only as CSV (too long for console)
    std::fs::create_dir_all(RESULTS_DIR)?;
    std::fs::write(
        format!("{RESULTS_DIR}/fig10_series.csv"),
        csv.to_csv(),
    )?;
    println!("[written {RESULTS_DIR}/fig10_series.csv]");
    Ok(())
}

/// Fig 15: rank-wise request and token distribution of the production
/// trace.
pub fn fig15(opts: &FigOpts) -> std::io::Result<()> {
    let cfg = ProductionConfig {
        n_adapters: 100,
        n_requests: opts.scale(250_138.0) as usize,
        duration: opts.scale(8.0 * 3600.0),
        seed: opts.seed,
        ..Default::default()
    };
    let trace = production::generate(&cfg);
    let req = characterize::rank_request_shares(&trace);
    let tok = characterize::rank_token_shares(&trace);
    let mut table = Table::new(
        "Fig 15 — rank-wise request (left) and token (right) shares",
        &["rank", "request share", "token share"],
    );
    for ((rank, rs), (_, ts)) in req.iter().zip(tok.iter()) {
        table.row(vec![
            rank.to_string(),
            format!("{:.1}%", rs * 100.0),
            format!("{:.1}%", ts * 100.0),
        ]);
    }
    table.emit(RESULTS_DIR, "fig15")
}

/// Fig 16: the shifting-skew schedule (rank shares over time windows).
pub fn fig16(opts: &FigOpts) -> std::io::Result<()> {
    let cfg = azure::AzureConfig {
        popularity: azure::RankPopularity::ShiftingSkew,
        rps: 40.0,
        duration: opts.scale(1200.0),
        seed: opts.seed,
        ..Default::default()
    };
    let trace = azure::generate(&cfg);
    let wins = characterize::rank_share_over_time(&trace, 6);
    let mut table = Table::new(
        "Fig 16 — shifting skew: rank popularity per time window",
        &["window", "r8", "r16", "r32", "r64", "r128"],
    );
    for (w, shares) in wins.iter().enumerate() {
        let mut row = vec![format!("t{w}")];
        for rank in crate::workload::RANK_CLASSES {
            row.push(format!(
                "{:.0}%",
                shares.get(&rank).copied().unwrap_or(0.0) * 100.0
            ));
        }
        table.row(row);
    }
    table.emit(RESULTS_DIR, "fig16")
}
