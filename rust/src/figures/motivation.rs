//! §III-A motivation figures: rank interference (Fig 1, 3, 4, 5, 6).

use super::helpers::{FigOpts, RESULTS_DIR};
use crate::config::{ClusterConfig, ModelSpec, ServerConfig};
use crate::costmodel::{decode_time, prefill_time};
use crate::sim::{run, SimConfig, SystemKind};
use crate::trace::{azure, LengthModel, Trace};
use crate::util::rng::Pcg32;
use crate::util::table::{fmt_f, fmt_secs, Table};
use crate::workload::{Adapter, AdapterSet, Request};

/// Fig 1: P95 TTFT per adapter when two adapters are co-served on one
/// Llama-7B server. Pairs (8,8) … (8,128); greater rank heterogeneity
/// should inflate the rank-8 adapter's tail latency and variability.
pub fn fig1(opts: &FigOpts) -> std::io::Result<()> {
    let mut table = Table::new(
        "Fig 1 — co-serving two adapters on one server (P95 TTFT, s)",
        &[
            "pair", "rank8 p50", "rank8 p95", "partner p95",
            "rank8 iqr", "rank8 p95 vs (8,8)",
        ],
    );
    let model = ModelSpec::LLAMA_7B;
    let duration = opts.scale(600.0);
    let mut base_p95 = None;
    for partner in [8u32, 16, 32, 64, 128] {
        let adapters = AdapterSet::new(vec![
            Adapter { id: 0, rank: 8, size_bytes: model.adapter_bytes(8) },
            Adapter {
                id: 1,
                rank: partner,
                size_bytes: model.adapter_bytes(partner),
            },
        ]);
        // Poisson arrivals, both adapters equally popular, fixed shape;
        // rate chosen near (not past) single-server capacity so queueing
        // amplifies the interference the way the paper's testbed did.
        let mut rng = Pcg32::with_stream(opts.seed, 0xf1 + partner as u64);
        let rps = 3.5;
        let mut reqs = Vec::new();
        let mut t = 0.0;
        loop {
            t += rng.exponential(rps);
            if t > duration {
                break;
            }
            reqs.push(Request {
                id: 0,
                adapter: (rng.f64() < 0.5) as u32,
                prompt_len: 512,
                output_len: 64,
                arrival: t,
            });
        }
        let trace = Trace::new(&format!("fig1-{partner}"), adapters, reqs);
        let cluster = ClusterConfig {
            n_servers: 1,
            slo: crate::config::SloConfig {
                ttft_p95: 20.0,
                timeout: 600.0,
                ..Default::default()
            },
            ..Default::default()
        };
        let rep = run(
            &trace,
            &SimConfig::new(cluster, SystemKind::SLoraRandom),
        );
        let mut r8 = rep.per_adapter_ttft.get(&0).cloned().unwrap_or_default();
        let mut partner_s =
            rep.per_adapter_ttft.get(&1).cloned().unwrap_or_default();
        let p50 = r8.p50();
        let p95 = r8.p95();
        let iqr = r8.percentile(75.0) - r8.percentile(25.0);
        if partner == 8 {
            base_p95 = Some(p95);
        }
        let rel = p95 / base_p95.unwrap();
        table.row(vec![
            format!("(8,{partner})"),
            fmt_secs(p50),
            fmt_secs(p95),
            fmt_secs(partner_s.p95()),
            fmt_secs(iqr),
            format!("{:.2}x", rel),
        ]);
    }
    table.emit(RESULTS_DIR, "fig1")
}

/// Fig 3: isolated TTFT and TBT vs input size per rank (Llama-7B TP1).
pub fn fig3(_opts: &FigOpts) -> std::io::Result<()> {
    let server = ServerConfig {
        tp: 1,
        ..Default::default()
    };
    let mut ttft = Table::new(
        "Fig 3 (top) — isolated TTFT vs input size, Llama-7B TP1",
        &["input", "r8", "r16", "r32", "r64", "r128", "r128/r8"],
    );
    let mut tbt = Table::new(
        "Fig 3 (bottom) — isolated TBT vs input size (batch 1)",
        &["input", "r8", "r16", "r32", "r64", "r128", "r128/r8"],
    );
    for input in [128u64, 512, 1000, 2000, 4000, 8000] {
        let pf: Vec<f64> = [8u32, 16, 32, 64, 128]
            .iter()
            .map(|&r| prefill_time(&server, input, r))
            .collect();
        let dc: Vec<f64> = [8u32, 16, 32, 64, 128]
            .iter()
            .map(|&r| decode_time(&server, 1, input, r))
            .collect();
        let mut row = vec![input.to_string()];
        row.extend(pf.iter().map(|&x| fmt_secs(x)));
        row.push(format!("{:.2}x", pf[4] / pf[0]));
        ttft.row(row);
        let mut row = vec![input.to_string()];
        row.extend(dc.iter().map(|&x| fmt_secs(x)));
        row.push(format!("{:.2}x", dc[4] / dc[0]));
        tbt.row(row);
    }
    ttft.emit(RESULTS_DIR, "fig3_ttft")?;
    tbt.emit(RESULTS_DIR, "fig3_tbt")
}

/// Fig 4: relative TTFT (vs rank 8) across model sizes, input 2000, TP8.
pub fn fig4(_opts: &FigOpts) -> std::io::Result<()> {
    let mut table = Table::new(
        "Fig 4 — relative TTFT vs model size (input 2000, TP8)",
        &["model", "r8", "r16", "r32", "r64", "r128"],
    );
    for model in [
        ModelSpec::LLAMA_7B,
        ModelSpec::LLAMA_13B,
        ModelSpec::LLAMA_30B,
        ModelSpec::LLAMA_70B,
    ] {
        let server = ServerConfig {
            model,
            tp: 8,
            ..Default::default()
        };
        let base = prefill_time(&server, 2000, 8);
        let mut row = vec![model.name.to_string()];
        for r in [8u32, 16, 32, 64, 128] {
            row.push(format!(
                "{:.2}",
                prefill_time(&server, 2000, r) / base
            ));
        }
        table.row(row);
    }
    table.emit(RESULTS_DIR, "fig4")
}

/// Fig 5: relative TTFT (vs rank 8) across TP degrees, Llama-7B.
pub fn fig5(_opts: &FigOpts) -> std::io::Result<()> {
    let mut table = Table::new(
        "Fig 5 — relative TTFT vs TP (Llama-7B, input 2000)",
        &["tp", "r8", "r16", "r32", "r64", "r128"],
    );
    for tp in [1usize, 2, 4, 8] {
        let server = ServerConfig {
            tp,
            ..Default::default()
        };
        let base = prefill_time(&server, 2000, 8);
        let mut row = vec![format!("TP={tp}")];
        for r in [8u32, 16, 32, 64, 128] {
            row.push(format!(
                "{:.2}",
                prefill_time(&server, 2000, r) / base
            ));
        }
        table.row(row);
    }
    table.emit(RESULTS_DIR, "fig5")
}

/// Fig 6: 4 RPS Poisson, fixed 512/128 shape, single-rank workloads on
/// one server — high ranks blow the 20 s P95 TTFT SLO.
pub fn fig6(opts: &FigOpts) -> std::io::Result<()> {
    let mut table = Table::new(
        "Fig 6 — 4 RPS Poisson per rank (one Llama-7B TP4 server, SLO 20s)",
        &["rank", "p50 ttft", "p95 ttft", "timeouts", "violates slo"],
    );
    let duration = opts.scale(900.0);
    for rank in [8u32, 16, 32, 64, 128] {
        let cfg = azure::AzureConfig {
            arrival: azure::Arrival::Poisson,
            popularity: azure::RankPopularity::Uniform,
            adapters_per_rank: 1,
            rps: 4.0,
            duration,
            lengths: LengthModel::fixed(512, 128),
            seed: opts.seed,
            ..Default::default()
        };
        let mut trace = azure::generate(&cfg);
        // restrict to the single-rank adapter: remap every request to
        // the adapter of `rank`
        let target = trace
            .adapters
            .iter()
            .find(|a| a.rank == rank)
            .unwrap()
            .id;
        for r in trace.requests.iter_mut() {
            r.adapter = target;
        }
        let cluster = ClusterConfig {
            n_servers: 1,
            slo: crate::config::SloConfig {
                ttft_p95: 20.0,
                timeout: 300.0,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut rep = run(
            &trace,
            &SimConfig::new(cluster, SystemKind::SLoraContiguous),
        );
        let p95 = rep.ttft_p95();
        table.row(vec![
            rank.to_string(),
            fmt_secs(rep.ttft.p50()),
            fmt_secs(p95),
            rep.timeouts.to_string(),
            if p95 > 20.0 || rep.completion_rate() < 0.99 {
                "YES".into()
            } else {
                "no".into()
            },
        ]);
    }
    table.emit(RESULTS_DIR, "fig6")
}

/// Operating-point table (§IV-A profiling step).
pub fn tops(_opts: &FigOpts) -> std::io::Result<()> {
    let mut table = Table::new(
        "Operating points — tokens/s per rank under SLO (Llama-7B TP4)",
        &["rank", "tokens/s", "vs r8"],
    );
    let server = ServerConfig::default();
    let ops = crate::costmodel::operating_points(
        &server,
        &crate::workload::RANK_CLASSES,
    );
    let base = ops[&8];
    for r in crate::workload::RANK_CLASSES {
        table.row(vec![
            r.to_string(),
            fmt_f(ops[&r], 0),
            format!("{:.2}x", ops[&r] / base),
        ]);
    }
    table.emit(RESULTS_DIR, "tops")
}
