//! Chrome trace-event JSON sink (Perfetto / `chrome://tracing`
//! viewable) with an optional bounded flight-recorder ring, plus the
//! span-nesting checker the CI smoke uses to validate emitted traces.

use super::{Phase, TraceEvent, PID_CONTROL, TID_PREFILL, TID_REQUESTS};
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Where trace events go. The simulation emits through
/// [`super::Obs`]; sinks only collect and export. `Send` because the
/// sink lives behind the `Arc<Mutex<ObsState>>` handle that servers
/// carry across the sharded engine's scoped-thread boundary.
pub trait TraceSink: std::fmt::Debug + Send {
    fn emit(&mut self, ev: TraceEvent);
    /// Number of retained events.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Export retained events as Chrome trace-event JSON.
    fn export_chrome(&self) -> String;
}

/// Discards everything — the sink behind metrics/attribution-only
/// configurations.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopSink;

impl TraceSink for NoopSink {
    fn emit(&mut self, _ev: TraceEvent) {}
    fn len(&self) -> usize {
        0
    }
    fn export_chrome(&self) -> String {
        String::from("{\"traceEvents\":[]}")
    }
}

/// Collects events in emission order; with `last = Some(n)` it runs as
/// a flight recorder keeping only the most recent `n` events (the
/// number dropped is reported in the export's metadata).
#[derive(Debug, Default)]
pub struct ChromeTraceSink {
    events: VecDeque<TraceEvent>,
    last: Option<usize>,
    dropped: u64,
}

impl ChromeTraceSink {
    pub fn new(last: Option<usize>) -> Self {
        ChromeTraceSink {
            events: VecDeque::new(),
            last,
            dropped: 0,
        }
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

impl TraceSink for ChromeTraceSink {
    fn emit(&mut self, ev: TraceEvent) {
        self.events.push_back(ev);
        if let Some(cap) = self.last {
            while self.events.len() > cap.max(1) {
                self.events.pop_front();
                self.dropped += 1;
            }
        }
    }

    fn len(&self) -> usize {
        self.events.len()
    }

    fn export_chrome(&self) -> String {
        let mut out = String::from("{\"displayTimeUnit\":\"ms\",");
        let _ = std::fmt::Write::write_fmt(
            &mut out,
            format_args!("\"droppedEvents\":{},", self.dropped),
        );
        out.push_str("\"traceEvents\":[");
        let mut first = true;
        let mut push = |j: Json, out: &mut String| {
            if !std::mem::take(&mut first) {
                out.push(',');
            }
            out.push_str(&j.to_string());
        };
        // metadata first: name every process/thread that appears
        let mut pids = BTreeSet::new();
        let mut lanes = BTreeSet::new();
        for ev in &self.events {
            pids.insert(ev.pid);
            lanes.insert((ev.pid, ev.tid));
        }
        for pid in &pids {
            let name = if *pid == PID_CONTROL {
                "control-plane".to_string()
            } else {
                format!("server {}", pid - 1)
            };
            push(meta("process_name", *pid, 0, &name), &mut out);
        }
        for (pid, tid) in &lanes {
            let name = if *pid == PID_CONTROL {
                "decisions".to_string()
            } else {
                match *tid {
                    TID_REQUESTS => "requests".to_string(),
                    TID_PREFILL => "prefill".to_string(),
                    t if t == super::decode_lane(0) => {
                        "decode (no-lora)".to_string()
                    }
                    t => format!("decode r≤{}", 1u64 << (t - 3)),
                }
            };
            push(meta("thread_name", *pid, *tid, &name), &mut out);
        }
        for ev in &self.events {
            push(event_json(ev), &mut out);
        }
        out.push_str("]}");
        out
    }
}

fn meta(name: &str, pid: u32, tid: u32, value: &str) -> Json {
    Json::obj(vec![
        ("ph", "M".into()),
        ("name", name.into()),
        ("pid", pid.into()),
        ("tid", tid.into()),
        ("args", Json::obj(vec![("name", value.into())])),
    ])
}

fn event_json(ev: &TraceEvent) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("name", ev.name.into()),
        ("pid", ev.pid.into()),
        ("tid", ev.tid.into()),
        ("ts", (ev.ts * 1e6).into()),
    ];
    match ev.ph {
        Phase::Span { dur } => {
            pairs.push(("ph", "X".into()));
            pairs.push(("dur", (dur * 1e6).into()));
        }
        Phase::Instant => {
            pairs.push(("ph", "i".into()));
            pairs.push(("s", "t".into()));
        }
        Phase::AsyncBegin { cat, id }
        | Phase::AsyncInstant { cat, id }
        | Phase::AsyncEnd { cat, id } => {
            let ph = match ev.ph {
                Phase::AsyncBegin { .. } => "b",
                Phase::AsyncInstant { .. } => "n",
                _ => "e",
            };
            pairs.push(("ph", ph.into()));
            pairs.push(("cat", cat.into()));
            pairs.push(("id", format!("{id:#x}").into()));
        }
    }
    if let Some(c) = ev.cname {
        pairs.push(("cname", c.into()));
    }
    if !ev.args.is_empty() {
        pairs.push((
            "args",
            Json::obj(
                ev.args.iter().map(|(k, v)| (*k, v.clone())).collect(),
            ),
        ));
    }
    Json::obj(pairs)
}

/// Validate a Chrome trace export: it parses, complete (`"X"`) spans
/// on each `(pid, tid)` track nest strictly (no partial overlap), and
/// every async end has a matching open begin per `(cat, id)`. Used by
/// the `trace-check` CLI subcommand that the CI smoke runs on emitted
/// artifacts.
pub fn check_spans_nest(text: &str) -> Result<(), String> {
    let v = crate::util::json::parse(text)?;
    let events = v
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or("missing traceEvents array")?;
    let num = |ev: &Json, k: &str| -> f64 {
        ev.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0)
    };
    // spans per track, in emission order (event start times are
    // non-decreasing within a track because the DES emits at dispatch
    // time)
    let mut tracks: BTreeMap<(u64, u64), Vec<(f64, f64)>> = BTreeMap::new();
    let mut open_async: BTreeMap<(String, String), i64> = BTreeMap::new();
    const EPS: f64 = 1e-3; // µs
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        match ph {
            "X" => {
                let key = (num(ev, "pid") as u64, num(ev, "tid") as u64);
                let ts = num(ev, "ts");
                let dur = num(ev, "dur");
                if dur < 0.0 {
                    return Err(format!("negative dur at ts={ts}"));
                }
                tracks.entry(key).or_default().push((ts, ts + dur));
            }
            "b" | "e" => {
                let cat = ev
                    .get("cat")
                    .and_then(|c| c.as_str())
                    .ok_or("async event without cat")?
                    .to_string();
                let id = ev
                    .get("id")
                    .and_then(|c| c.as_str())
                    .ok_or("async event without id")?
                    .to_string();
                let n = open_async.entry((cat.clone(), id.clone())).or_insert(0);
                if ph == "b" {
                    *n += 1;
                } else {
                    *n -= 1;
                    if *n < 0 {
                        return Err(format!(
                            "async end without begin: {cat}/{id}"
                        ));
                    }
                }
            }
            _ => {}
        }
    }
    for ((pid, tid), spans) in &tracks {
        // stack of open span end-times; a new span must start after
        // the enclosing span started and must not poke out of it
        let mut stack: Vec<f64> = Vec::new();
        let mut last_start = f64::NEG_INFINITY;
        for &(ts, end) in spans {
            if ts < last_start - EPS {
                return Err(format!(
                    "track {pid}/{tid}: spans out of order at ts={ts}"
                ));
            }
            last_start = ts;
            while let Some(&top) = stack.last() {
                if top <= ts + EPS {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&top) = stack.last() {
                if end > top + EPS {
                    return Err(format!(
                        "track {pid}/{tid}: span [{ts}, {end}] partially \
                         overlaps enclosing span ending at {top}"
                    ));
                }
            }
            stack.push(end);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(ts: f64, dur: f64, pid: u32, tid: u32) -> TraceEvent {
        TraceEvent {
            name: "s",
            ph: Phase::Span { dur },
            ts,
            pid,
            tid,
            cname: None,
            args: vec![],
        }
    }

    #[test]
    fn ring_keeps_exactly_last_n() {
        let mut sink = ChromeTraceSink::new(Some(3));
        for i in 0..10 {
            sink.emit(span(i as f64, 0.5, 0, 0));
        }
        assert_eq!(sink.len(), 3);
        assert_eq!(sink.dropped(), 7);
        let text = sink.export_chrome();
        let v = crate::util::json::parse(&text).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        let xs: Vec<f64> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .map(|e| e.get("ts").unwrap().as_f64().unwrap() / 1e6)
            .collect();
        assert_eq!(xs, vec![7.0, 8.0, 9.0]);
        assert_eq!(
            v.get("droppedEvents").unwrap().as_f64().unwrap() as u64,
            7
        );
    }

    #[test]
    fn export_parses_and_nests() {
        let mut sink = ChromeTraceSink::new(None);
        sink.emit(span(0.0, 10.0, 1, 1));
        sink.emit(span(1.0, 2.0, 1, 1)); // nested inside the first
        sink.emit(span(20.0, 1.0, 1, 1)); // disjoint
        sink.emit(TraceEvent {
            name: "req",
            ph: Phase::AsyncBegin { cat: "req", id: 7 },
            ts: 0.0,
            pid: 1,
            tid: 0,
            cname: None,
            args: vec![("rank", 8u32.into())],
        });
        sink.emit(TraceEvent {
            name: "req",
            ph: Phase::AsyncEnd { cat: "req", id: 7 },
            ts: 5.0,
            pid: 1,
            tid: 0,
            cname: None,
            args: vec![],
        });
        let text = sink.export_chrome();
        check_spans_nest(&text).unwrap();
        // metadata names the tracks
        assert!(text.contains("process_name"));
        assert!(text.contains("server 0"));
    }

    #[test]
    fn checker_rejects_partial_overlap_and_unbalanced_async() {
        let mut sink = ChromeTraceSink::new(None);
        sink.emit(span(0.0, 5.0, 1, 1));
        sink.emit(span(3.0, 5.0, 1, 1)); // pokes out of the first
        assert!(check_spans_nest(&sink.export_chrome()).is_err());

        let mut sink = ChromeTraceSink::new(None);
        sink.emit(TraceEvent {
            name: "m",
            ph: Phase::AsyncEnd { cat: "mig", id: 1 },
            ts: 0.0,
            pid: 0,
            tid: 0,
            cname: None,
            args: vec![],
        });
        assert!(check_spans_nest(&sink.export_chrome()).is_err());
    }
}
