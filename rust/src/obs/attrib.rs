//! Per-request SLO-violation attribution: every completed request's
//! TTFT and E2E latency decomposed into where the time actually went.
//!
//! The decomposition is exact by construction — every interval the
//! request spends between arrival and completion is charged to exactly
//! one component at the moment the engine prices the corresponding
//! iteration, so the summed components reconcile with the measured
//! latencies to float-rounding noise (asserted to 1e-6 s in
//! `tests/obs_tracing.rs`).
//!
//! Component glossary (seconds; `prefill_*` end at the first token,
//! `decode_*`/`preempt_delay` cover first token → completion):
//!
//! | component         | charged when                                      |
//! |-------------------|---------------------------------------------------|
//! | `queue_wait`      | ready-queue residency before prefill admission    |
//! | `fetch_stall`     | RDMA adapter-fetch wait + PCIe page-in time       |
//! | `prefill_service` | own-rank cost of the admitted prefill batch       |
//! | `prefill_skew`    | pad-to-max-rank premium over own-rank cost        |
//! | `prefill_remote`  | remote-attach penalties paid by the prefill batch |
//! | `decode_service`  | own-rank share of member decode steps (+ shared   |
//! |                   | forward-pass base of grouped rounds)              |
//! | `decode_skew`     | rank-padding premium + other sub-batches' kernels |
//! | `decode_launch`   | per-sub-batch kernel launch overheads             |
//! | `decode_remote`   | per-iteration remote-attach penalties             |
//! | `preempt_delay`   | decode stalled behind (preempting or interleaved) |
//! |                   | prefill admissions                                |

use crate::util::json::Json;

/// One request's running decomposition, keyed by the engine-assigned
/// uid (the request's index in the trace).
#[derive(Debug, Clone, Copy, Default)]
pub struct ReqAttrib {
    pub used: bool,
    pub arrival: f64,
    pub server: u32,
    pub rank: u32,
    pub queue_wait: f64,
    pub fetch_stall: f64,
    pub prefill_service: f64,
    pub prefill_skew: f64,
    pub prefill_remote: f64,
    pub decode_service: f64,
    pub decode_skew: f64,
    pub decode_launch: f64,
    pub decode_remote: f64,
    pub preempt_delay: f64,
    /// Measured latencies, filled at completion.
    pub ttft: f64,
    pub e2e: f64,
    pub violated: bool,
    /// Completed after the warmup cutoff (i.e. counted in report
    /// digests).
    pub measured: bool,
    pub done: bool,
}

impl ReqAttrib {
    /// Sum of the TTFT-phase components — reconciles with `ttft`.
    pub fn ttft_sum(&self) -> f64 {
        self.queue_wait
            + self.fetch_stall
            + self.prefill_service
            + self.prefill_skew
            + self.prefill_remote
    }

    /// Sum of all components — reconciles with `e2e`.
    pub fn e2e_sum(&self) -> f64 {
        self.ttft_sum()
            + self.decode_service
            + self.decode_skew
            + self.decode_launch
            + self.decode_remote
            + self.preempt_delay
    }
}

/// Growable uid-indexed table of [`ReqAttrib`] records.
#[derive(Debug, Clone, Default)]
pub struct AttribTable {
    recs: Vec<ReqAttrib>,
}

impl AttribTable {
    pub fn rec(&mut self, uid: u32) -> &mut ReqAttrib {
        let i = uid as usize;
        if i >= self.recs.len() {
            self.recs.resize(i + 1, ReqAttrib::default());
        }
        let r = &mut self.recs[i];
        r.used = true;
        r
    }

    pub fn records(&self) -> &[ReqAttrib] {
        &self.recs
    }

    /// Aggregate the measured completions into per-cohort component
    /// means; `None` when nothing completed past warmup.
    pub fn summarize(&self, ttft_slo: f64) -> Option<AttributionSummary> {
        let measured: Vec<&ReqAttrib> = self
            .recs
            .iter()
            .filter(|r| r.used && r.done && r.measured)
            .collect();
        if measured.is_empty() {
            return None;
        }
        let all = AttribBucket::over(measured.iter().copied());
        let violators = AttribBucket::over(
            measured.iter().copied().filter(|r| r.violated),
        );
        // tail cohort: the top 1% of measured completions by TTFT —
        // its component means explain the p99 end of the distribution
        let mut by_ttft = measured.clone();
        by_ttft.sort_by(|a, b| {
            a.ttft.partial_cmp(&b.ttft).unwrap_or(std::cmp::Ordering::Equal)
        });
        let k = (by_ttft.len() as f64 * 0.01).ceil().max(1.0) as usize;
        let tail = AttribBucket::over(
            by_ttft[by_ttft.len() - k..].iter().copied(),
        );
        Some(AttributionSummary {
            ttft_slo,
            all,
            violators,
            tail,
        })
    }
}

/// Component means over one cohort of completed requests.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AttribBucket {
    pub n: u64,
    pub ttft: f64,
    pub e2e: f64,
    pub queue_wait: f64,
    pub fetch_stall: f64,
    pub prefill_service: f64,
    pub prefill_skew: f64,
    pub prefill_remote: f64,
    pub decode_service: f64,
    pub decode_skew: f64,
    pub decode_launch: f64,
    pub decode_remote: f64,
    pub preempt_delay: f64,
    /// Worst per-request |component sum − measured latency| in the
    /// cohort, over both the TTFT and E2E decompositions.
    pub recon: f64,
}

impl AttribBucket {
    fn over<'a>(recs: impl Iterator<Item = &'a ReqAttrib>) -> AttribBucket {
        let mut b = AttribBucket::default();
        for r in recs {
            b.n += 1;
            b.ttft += r.ttft;
            b.e2e += r.e2e;
            b.queue_wait += r.queue_wait;
            b.fetch_stall += r.fetch_stall;
            b.prefill_service += r.prefill_service;
            b.prefill_skew += r.prefill_skew;
            b.prefill_remote += r.prefill_remote;
            b.decode_service += r.decode_service;
            b.decode_skew += r.decode_skew;
            b.decode_launch += r.decode_launch;
            b.decode_remote += r.decode_remote;
            b.preempt_delay += r.preempt_delay;
            b.recon = b
                .recon
                .max((r.ttft_sum() - r.ttft).abs())
                .max((r.e2e_sum() - r.e2e).abs());
        }
        if b.n > 0 {
            let n = b.n as f64;
            b.ttft /= n;
            b.e2e /= n;
            b.queue_wait /= n;
            b.fetch_stall /= n;
            b.prefill_service /= n;
            b.prefill_skew /= n;
            b.prefill_remote /= n;
            b.decode_service /= n;
            b.decode_skew /= n;
            b.decode_launch /= n;
            b.decode_remote /= n;
            b.preempt_delay /= n;
        }
        b
    }

    /// Combined rank-skew component (prefill padding + decode padding
    /// and sub-batch serialization).
    pub fn skew(&self) -> f64 {
        self.prefill_skew + self.decode_skew
    }

    /// Combined remote-attach component.
    pub fn remote(&self) -> f64 {
        self.prefill_remote + self.decode_remote
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", self.n.into()),
            ("ttft_mean", self.ttft.into()),
            ("e2e_mean", self.e2e.into()),
            ("queue_wait", self.queue_wait.into()),
            ("fetch_stall", self.fetch_stall.into()),
            ("prefill_service", self.prefill_service.into()),
            ("prefill_skew", self.prefill_skew.into()),
            ("prefill_remote", self.prefill_remote.into()),
            ("decode_service", self.decode_service.into()),
            ("decode_skew", self.decode_skew.into()),
            ("decode_launch", self.decode_launch.into()),
            ("decode_remote", self.decode_remote.into()),
            ("preempt_delay", self.preempt_delay.into()),
            ("recon", self.recon.into()),
        ])
    }
}

/// The `attribution` table attached to `SimReport` when the
/// decomposition is enabled: component means for all measured
/// completions, the TTFT-SLO violators, and the top-1%-TTFT tail.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AttributionSummary {
    pub ttft_slo: f64,
    pub all: AttribBucket,
    pub violators: AttribBucket,
    pub tail: AttribBucket,
}

impl AttributionSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ttft_slo", self.ttft_slo.into()),
            ("all", self.all.to_json()),
            ("violators", self.violators.to_json()),
            ("tail", self.tail.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sums_reconcile_and_buckets_select_cohorts() {
        let mut t = AttribTable::default();
        for i in 0..100u32 {
            let r = t.rec(i);
            r.arrival = i as f64;
            r.queue_wait = 0.010;
            r.fetch_stall = 0.002;
            r.prefill_service = 0.020;
            r.prefill_skew = 0.005;
            r.decode_service = 0.030;
            r.decode_launch = 0.001;
            r.preempt_delay = if i == 99 { 0.5 } else { 0.0 };
            r.ttft = r.ttft_sum();
            r.e2e = r.e2e_sum();
            r.violated = r.ttft > 0.030;
            r.measured = i >= 10; // warmup cutoff
            r.done = true;
        }
        let s = t.summarize(0.030).unwrap();
        assert_eq!(s.all.n, 90);
        assert_eq!(s.violators.n, 90); // ttft 37ms > 30ms for everyone
        assert_eq!(s.tail.n, 1);
        assert!(s.all.recon < 1e-12, "recon={}", s.all.recon);
        assert!((s.all.queue_wait - 0.010).abs() < 1e-12);
        // the tail cohort isolates the preempted request
        assert!((s.tail.preempt_delay - 0.5).abs() < 1e-12);
        assert!(s.all.preempt_delay < 0.01);
        // digest round-trips through the json writer
        let j = s.to_json().to_string();
        assert!(j.contains("\"violators\""));
        assert!(crate::util::json::parse(&j).is_ok());
    }

    #[test]
    fn empty_and_unmeasured_tables_summarize_to_none() {
        let t = AttribTable::default();
        assert!(t.summarize(0.1).is_none());
        let mut t = AttribTable::default();
        t.rec(5).done = false; // in flight at end of run
        assert!(t.summarize(0.1).is_none());
    }
}
