//! Counter/gauge registry with deterministic snapshot ordering and
//! Prometheus text exposition. Absorbs the engine's ad-hoc counters:
//! at the end of a run the engine publishes every `SimReport` counter
//! and the fleet/latency gauges here, in addition to the live counters
//! bumped during the run.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
}

impl MetricsRegistry {
    pub fn inc(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn set_counter(&mut self, name: &str, v: u64) {
        self.counters.insert(name.to_string(), v);
    }

    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty()
    }

    /// All series in deterministic (sorted, counters-first) order.
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        self.counters
            .iter()
            .map(|(k, v)| (k.clone(), *v as f64))
            .chain(self.gauges.iter().map(|(k, v)| (k.clone(), *v)))
            .collect()
    }

    /// Prometheus text exposition format (one `# TYPE` line per
    /// series; counters first, then gauges, each alphabetical).
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (k, v) in &self.counters {
            let _ = writeln!(out, "# TYPE {k} counter\n{k} {v}");
        }
        for (k, v) in &self.gauges {
            let _ = writeln!(out, "# TYPE {k} gauge\n{k} {}", fmt_f64(*v));
        }
        out
    }
}

/// Shortest round-trippable float, with Prometheus spellings for the
/// non-finite values.
fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot_is_sorted() {
        let mut m = MetricsRegistry::default();
        m.inc("b_total", 2);
        m.inc("a_total", 1);
        m.inc("b_total", 3);
        m.set_gauge("z_seconds", 0.25);
        m.set_gauge("z_seconds", 0.5); // latest wins
        assert_eq!(m.counter("b_total"), 5);
        assert_eq!(m.gauge("z_seconds"), Some(0.5));
        let names: Vec<String> =
            m.snapshot().into_iter().map(|(k, _)| k).collect();
        assert_eq!(names, vec!["a_total", "b_total", "z_seconds"]);
    }

    #[test]
    fn prometheus_text_is_deterministic() {
        let mut m = MetricsRegistry::default();
        m.inc("sim_completed_total", 42);
        m.set_gauge("sim_ttft_p95_seconds", 0.125);
        m.set_gauge("sim_bad", f64::NAN);
        let text = m.to_prometheus();
        assert_eq!(
            text,
            "# TYPE sim_completed_total counter\n\
             sim_completed_total 42\n\
             # TYPE sim_bad gauge\n\
             sim_bad NaN\n\
             # TYPE sim_ttft_p95_seconds gauge\n\
             sim_ttft_p95_seconds 0.125\n"
        );
        assert_eq!(text, m.clone().to_prometheus());
    }
}
