//! Observability: flight-recorder tracing, per-request SLO-violation
//! attribution, and a counter/gauge metrics registry for the
//! simulation engine.
//!
//! The whole subsystem hangs off one cheaply-cloneable [`Obs`] handle
//! that the engine installs into every [`crate::sim::SimServer`], the
//! adapter pool, and the autoscale controller. `Obs::default()` is
//! *disabled*: every hook early-returns before constructing an event,
//! so the hot path stays zero-cost and report digests are
//! bit-identical to a build without the subsystem (asserted in
//! `tests/obs_tracing.rs`).
//!
//! Track layout of the exported Chrome trace (see [`chrome`]):
//!
//! - `pid 0` — the control plane: trigger checks, rebalances,
//!   autoscale decisions, drains (instants on `tid 0`), plus async
//!   `mig`/`fetch` spans for in-flight RDMA transfers.
//! - `pid 1+s` — server `s`: `tid 0` carries per-request async `req`
//!   spans (arrival → admission → completion), `tid 1` the prefill
//!   lane, and `tid 2+⌈log2 rank⌉` one decode lane per rank class,
//!   colored by class (`cname`).

pub mod attrib;
pub mod chrome;
pub mod metrics;

pub use attrib::{AttribTable, AttributionSummary, ReqAttrib};
pub use chrome::{check_spans_nest, ChromeTraceSink, NoopSink, TraceSink};
pub use metrics::MetricsRegistry;

use crate::util::json::Json;
use std::sync::{Arc, Mutex};

/// Observability knobs on `SimConfig` — all default off, and the
/// engine behaves bit-identically when every knob is off.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ObsConfig {
    /// Record request-lifecycle and control-plane trace events;
    /// exported as Chrome trace-event JSON (`ObsOutput::trace_json`,
    /// `simulate --trace-out`).
    pub trace: bool,
    /// Flight-recorder mode: keep only the last N trace events
    /// (`simulate --trace-last N`).
    pub trace_last: Option<usize>,
    /// Maintain the per-request latency decomposition and attach the
    /// aggregated table to `SimReport::attribution`.
    pub attrib: bool,
    /// Maintain the counter/gauge registry; exported as Prometheus
    /// text (`ObsOutput::metrics_text`, `simulate --metrics-out`).
    pub metrics: bool,
}

impl ObsConfig {
    pub fn enabled(&self) -> bool {
        self.trace || self.attrib || self.metrics
    }
}

/// One trace record. `ts`/`dur` are simulation seconds; the exporter
/// converts to trace-viewer microseconds.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: &'static str,
    pub ph: Phase,
    pub ts: f64,
    pub pid: u32,
    pub tid: u32,
    /// Trace-viewer color name (decode lanes are colored by rank
    /// class).
    pub cname: Option<&'static str>,
    pub args: Vec<(&'static str, Json)>,
}

/// Trace-event phase, mirroring the Chrome trace-event kinds we emit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Phase {
    /// Complete span (`"X"`) with a known duration — iterations and
    /// decode sub-batch steps, whose service time is priced up front.
    Span { dur: f64 },
    /// Thread-scoped instant (`"i"`).
    Instant,
    /// Async begin (`"b"`), paired with [`Phase::AsyncEnd`] by
    /// `(cat, id)`; used for spans that may overlap on one track
    /// (requests in flight, RDMA transfers).
    AsyncBegin { cat: &'static str, id: u64 },
    /// Async instant (`"n"`) — a milestone inside an async span.
    AsyncInstant { cat: &'static str, id: u64 },
    /// Async end (`"e"`).
    AsyncEnd { cat: &'static str, id: u64 },
}

/// Control-plane process id and the server-track helpers.
pub const PID_CONTROL: u32 = 0;

pub fn server_pid(server: usize) -> u32 {
    1 + server as u32
}

/// Server-track thread ids: requests / prefill / per-rank-class decode
/// lanes.
pub const TID_REQUESTS: u32 = 0;
pub const TID_PREFILL: u32 = 1;

/// One decode lane per rank class (class = bit length of the rank, so
/// ranks 5..=8 share a lane, 9..=16 the next, ...).
pub fn decode_lane(max_rank: u32) -> u32 {
    2 + (32 - max_rank.leading_zeros())
}

/// Deterministic per-rank-class trace-viewer color.
pub fn rank_cname(max_rank: u32) -> &'static str {
    const PALETTE: [&str; 6] = [
        "thread_state_running",
        "cq_build_passed",
        "rail_response",
        "thread_state_iowait",
        "cq_build_failed",
        "terrible",
    ];
    PALETTE[(32 - max_rank.leading_zeros()) as usize % PALETTE.len()]
}

/// Shared observability state behind the [`Obs`] handle.
#[derive(Debug)]
pub struct ObsState {
    pub cfg: ObsConfig,
    pub sink: Box<dyn TraceSink>,
    pub metrics: MetricsRegistry,
    pub attrib: AttribTable,
}

/// End-of-run export bundle from `run_observed`.
#[derive(Debug, Clone, Default)]
pub struct ObsOutput {
    /// Chrome trace-event JSON (present when `ObsConfig::trace`).
    pub trace_json: Option<String>,
    /// Prometheus text exposition (present when `ObsConfig::metrics`).
    pub metrics_text: Option<String>,
    /// Per-request attribution records in uid order (present when
    /// `ObsConfig::attrib`).
    pub attrib: Option<Vec<ReqAttrib>>,
}

/// Cheaply-cloneable handle to the shared observability state. The
/// disabled handle (`Obs::default()`) carries `None` and every hook
/// returns before touching any state — that keeps the hot path
/// zero-cost. The enabled handle is `Arc<Mutex<_>>` so servers can
/// cross the sharded engine's scoped-thread boundary; the engine
/// serializes lane flushing whenever observability is on (see
/// `sim/engine.rs`), so the mutex is uncontended and the emission
/// order is deterministic for any shard count.
#[derive(Debug, Clone, Default)]
pub struct Obs {
    inner: Option<Arc<Mutex<ObsState>>>,
}

impl Obs {
    pub fn new(cfg: ObsConfig) -> Obs {
        if !cfg.enabled() {
            return Obs::default();
        }
        let sink: Box<dyn TraceSink> = if cfg.trace {
            Box::new(ChromeTraceSink::new(cfg.trace_last))
        } else {
            Box::new(NoopSink)
        };
        Obs {
            inner: Some(Arc::new(Mutex::new(ObsState {
                cfg,
                sink,
                metrics: MetricsRegistry::default(),
                attrib: AttribTable::default(),
            }))),
        }
    }

    pub fn on(&self) -> bool {
        self.inner.is_some()
    }

    pub fn trace_on(&self) -> bool {
        self.inner
            .as_ref()
            .is_some_and(|s| s.lock().unwrap().cfg.trace)
    }

    pub fn attrib_on(&self) -> bool {
        self.inner
            .as_ref()
            .is_some_and(|s| s.lock().unwrap().cfg.attrib)
    }

    pub fn metrics_on(&self) -> bool {
        self.inner
            .as_ref()
            .is_some_and(|s| s.lock().unwrap().cfg.metrics)
    }

    fn emit(&self, ev: TraceEvent) {
        if let Some(s) = &self.inner {
            let mut s = s.lock().unwrap();
            if s.cfg.trace {
                s.sink.emit(ev);
            }
        }
    }

    pub fn span(
        &self,
        name: &'static str,
        ts: f64,
        dur: f64,
        pid: u32,
        tid: u32,
        cname: Option<&'static str>,
        args: Vec<(&'static str, Json)>,
    ) {
        self.emit(TraceEvent {
            name,
            ph: Phase::Span { dur },
            ts,
            pid,
            tid,
            cname,
            args,
        });
    }

    pub fn instant(
        &self,
        name: &'static str,
        ts: f64,
        pid: u32,
        tid: u32,
        args: Vec<(&'static str, Json)>,
    ) {
        self.emit(TraceEvent {
            name,
            ph: Phase::Instant,
            ts,
            pid,
            tid,
            cname: None,
            args,
        });
    }

    pub fn async_begin(
        &self,
        name: &'static str,
        cat: &'static str,
        id: u64,
        ts: f64,
        pid: u32,
        args: Vec<(&'static str, Json)>,
    ) {
        self.emit(TraceEvent {
            name,
            ph: Phase::AsyncBegin { cat, id },
            ts,
            pid,
            tid: TID_REQUESTS,
            cname: None,
            args,
        });
    }

    pub fn async_instant(
        &self,
        name: &'static str,
        cat: &'static str,
        id: u64,
        ts: f64,
        pid: u32,
        args: Vec<(&'static str, Json)>,
    ) {
        self.emit(TraceEvent {
            name,
            ph: Phase::AsyncInstant { cat, id },
            ts,
            pid,
            tid: TID_REQUESTS,
            cname: None,
            args,
        });
    }

    pub fn async_end(
        &self,
        name: &'static str,
        cat: &'static str,
        id: u64,
        ts: f64,
        pid: u32,
        args: Vec<(&'static str, Json)>,
    ) {
        self.emit(TraceEvent {
            name,
            ph: Phase::AsyncEnd { cat, id },
            ts,
            pid,
            tid: TID_REQUESTS,
            cname: None,
            args,
        });
    }

    /// Bump a monotonically-increasing counter (no-op unless the
    /// metrics registry is enabled).
    pub fn counter_add(&self, name: &'static str, v: u64) {
        if let Some(s) = &self.inner {
            let mut s = s.lock().unwrap();
            if s.cfg.metrics {
                s.metrics.inc(name, v);
            }
        }
    }

    /// Overwrite a counter with its authoritative end-of-run value
    /// (the engine syncs the `SimReport` totals here at `finish`, so
    /// the registry absorbs counters the hot path never bumped live).
    pub fn counter_set(&self, name: &'static str, v: u64) {
        if let Some(s) = &self.inner {
            let mut s = s.lock().unwrap();
            if s.cfg.metrics {
                s.metrics.set_counter(name, v);
            }
        }
    }

    /// Set a gauge to its latest value (no-op unless enabled).
    pub fn gauge_set(&self, name: &'static str, v: f64) {
        if let Some(s) = &self.inner {
            let mut s = s.lock().unwrap();
            if s.cfg.metrics {
                s.metrics.set_gauge(name, v);
            }
        }
    }

    /// Run `f` against the attribution table (no-op unless enabled).
    pub fn with_attrib(&self, f: impl FnOnce(&mut AttribTable)) {
        if let Some(s) = &self.inner {
            let mut s = s.lock().unwrap();
            if s.cfg.attrib {
                f(&mut s.attrib);
            }
        }
    }

    /// Aggregate the attribution table (None when disabled or empty).
    pub fn attribution_summary(
        &self,
        ttft_slo: f64,
    ) -> Option<AttributionSummary> {
        let s = self.inner.as_ref()?;
        let s = s.lock().unwrap();
        if !s.cfg.attrib {
            return None;
        }
        s.attrib.summarize(ttft_slo)
    }

    /// Number of trace events currently retained by the sink.
    pub fn trace_len(&self) -> usize {
        self.inner
            .as_ref()
            .map_or(0, |s| s.lock().unwrap().sink.len())
    }

    /// Export the end-of-run bundle.
    pub fn export(&self) -> ObsOutput {
        let Some(s) = &self.inner else {
            return ObsOutput::default();
        };
        let s = s.lock().unwrap();
        ObsOutput {
            trace_json: s.cfg.trace.then(|| s.sink.export_chrome()),
            metrics_text: s
                .cfg
                .metrics
                .then(|| s.metrics.to_prometheus()),
            attrib: s
                .cfg
                .attrib
                .then(|| s.attrib.records().to_vec()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handle_is_inert() {
        let obs = Obs::default();
        assert!(!obs.on() && !obs.trace_on() && !obs.attrib_on());
        obs.span("x", 0.0, 1.0, 0, 0, None, vec![]);
        obs.counter_add("c", 1);
        obs.with_attrib(|_| panic!("attrib hook ran while disabled"));
        assert_eq!(obs.trace_len(), 0);
        let out = obs.export();
        assert!(out.trace_json.is_none());
        assert!(out.metrics_text.is_none());
        assert!(out.attrib.is_none());
    }

    #[test]
    fn enabled_handle_records_and_exports() {
        let obs = Obs::new(ObsConfig {
            trace: true,
            metrics: true,
            ..Default::default()
        });
        obs.span("prefill", 1.0, 0.5, server_pid(0), TID_PREFILL, None, vec![
            ("tokens", 512u64.into()),
        ]);
        obs.instant("trigger_check", 2.0, PID_CONTROL, 0, vec![]);
        obs.counter_add("sim_arrivals_total", 3);
        assert_eq!(obs.trace_len(), 2);
        let out = obs.export();
        let trace = out.trace_json.unwrap();
        assert!(crate::util::json::parse(&trace).is_ok());
        assert!(out.metrics_text.unwrap().contains("sim_arrivals_total 3"));
    }

    #[test]
    fn decode_lanes_group_by_rank_class() {
        assert_eq!(decode_lane(5), decode_lane(8));
        assert_ne!(decode_lane(8), decode_lane(16));
        assert_ne!(rank_cname(8), rank_cname(64));
        // shared handles see each other's events
        let a = Obs::new(ObsConfig { trace: true, ..Default::default() });
        let b = a.clone();
        b.instant("x", 0.0, 0, 0, vec![]);
        assert_eq!(a.trace_len(), 1);
    }
}
