//! Self-contained substrates: PRNG + distributions, exact statistics,
//! minimal JSON, CLI parsing, table/CSV emission.
//!
//! These exist because the build environment is fully offline — only the
//! `xla` crate's dependency closure is vendored — so the usual crates
//! (rand, serde, clap, criterion) are rebuilt here at the scale this
//! project needs, with their own tests.

pub mod argmin;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
