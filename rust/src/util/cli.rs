//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Subcommands are handled by the caller peeling the first positional.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Flag names that may appear without a value (parser hint).
    known_flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name). `known_flags` lists
    /// options that never take a value (e.g. "--fast").
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        known_flags: &[&str],
    ) -> Result<Args, String> {
        let mut args = Args {
            known_flags: known_flags.iter().map(|s| s.to_string()).collect(),
            ..Default::default()
        };
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // "--" terminator: rest is positional
                    args.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if args.known_flags.iter().any(|f| f == body) {
                    args.flags.push(body.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    args.options
                        .insert(body.to_string(), it.next().unwrap());
                } else {
                    // option with no value and not a known flag: treat
                    // as a flag anyway (lenient)
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env(known_flags: &[&str]) -> Result<Args, String> {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name}={v}: {e}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}={v}: {e}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}={v}: {e}")),
        }
    }

    /// Comma-separated list of usizes, e.g. `--servers 4,8,12`.
    pub fn get_usize_list(
        &self,
        name: &str,
        default: &[usize],
    ) -> Result<Vec<usize>, String> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|e| format!("--{name}={v}: {e}"))
                })
                .collect(),
        }
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str], flags: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn mixed_forms() {
        let a = parse(
            &["figures", "--fig", "17", "--fast", "--rps=30", "extra"],
            &["fast"],
        );
        assert_eq!(a.subcommand(), Some("figures"));
        assert_eq!(a.get("fig"), Some("17"));
        assert_eq!(a.get("rps"), Some("30"));
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["figures", "extra"]);
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--n", "42", "--x", "2.5", "--list", "1,2,3"], &[]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize_list("list", &[]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(parse(&["--n", "abc"], &[]).get_usize("n", 0).is_err());
    }

    #[test]
    fn unknown_option_without_value_is_flag() {
        let a = parse(&["--verbose", "--k", "v"], &[]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("k"), Some("v"));
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["--a", "1", "--", "--not-an-option"], &[]);
        assert_eq!(a.get("a"), Some("1"));
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }
}
