//! Console/markdown table + CSV emission for figure harnesses.
//!
//! Every figure harness produces one `Table`; it is printed to the
//! console as aligned markdown and written to `results/<name>.csv` so
//! EXPERIMENTS.md can reference stable outputs.

use std::fs;
use std::io::Write as _;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.columns.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    /// Render as an aligned markdown table.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> =
            self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = format!("### {}\n\n", self.title);
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:width$} |", c, width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.columns));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&csv_line(&self.columns));
        for row in &self.rows {
            out.push_str(&csv_line(row));
        }
        out
    }

    /// Write CSV to `results/<name>.csv` (creating the dir) and print
    /// the markdown to stdout.
    pub fn emit(&self, results_dir: &str, name: &str) -> std::io::Result<()> {
        println!("{}", self.to_markdown());
        fs::create_dir_all(results_dir)?;
        let path = Path::new(results_dir).join(format!("{name}.csv"));
        let mut f = fs::File::create(&path)?;
        f.write_all(self.to_csv().as_bytes())?;
        println!("[written {}]", path.display());
        Ok(())
    }
}

fn csv_line(cells: &[String]) -> String {
    let mut out = String::new();
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if c.contains(',') || c.contains('"') || c.contains('\n') {
            out.push('"');
            out.push_str(&c.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(c);
        }
    }
    out.push('\n');
    out
}

/// Format seconds for human output: "1.23 ms", "4.5 s".
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return "-".into();
    }
    if s < 1e-3 {
        format!("{:.1} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

pub fn fmt_f(x: f64, digits: usize) -> String {
    if x.is_finite() {
        format!("{x:.digits$}")
    } else {
        "-".into()
    }
}

/// Format byte counts: "2.0 GB" etc.
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let b = b as f64;
    if b >= K * K * K {
        format!("{:.1} GiB", b / (K * K * K))
    } else if b >= K * K {
        format!("{:.1} MiB", b / (K * K))
    } else if b >= K {
        format!("{:.1} KiB", b / K)
    } else {
        format!("{b:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new("t", &["a", "long_col"]);
        t.row(vec!["xx".into(), "1".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a  | long_col |"));
        assert!(md.contains("| xx | 1        |"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_secs(0.5e-6 * 100.0), "50.0 us");
        assert_eq!(fmt_secs(0.002), "2.00 ms");
        assert_eq!(fmt_secs(3.0), "3.00 s");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MiB");
        assert_eq!(fmt_f(f64::NAN, 2), "-");
    }
}
