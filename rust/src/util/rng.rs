//! Deterministic PRNG + the distributions the trace/workload generators
//! need (uniform, exponential, Poisson, Zipf/power-law, normal, gamma).
//!
//! crates.io is unavailable in this build environment, so this is a
//! self-contained PCG-XSH-RR 64/32 implementation (O'Neill 2014) with a
//! SplitMix64 seeder. Determinism matters more than statistical
//! perfection here: every experiment records its seed and replays
//! identically (see EXPERIMENTS.md).

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64 — used to derive well-mixed seeds from small integers.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Independent stream per (seed, stream) pair — used to give each
    /// simulated server / adapter its own generator.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (splitmix64(stream) << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(splitmix64(seed));
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random bits / 2^53.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda`.
    /// Knuth for small lambda, normal approximation above 30.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal(lambda, lambda.sqrt());
            x.max(0.0).round() as u64
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt()
            * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Log-normal with the given underlying mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Pick an index according to `weights` (unnormalized, non-negative).
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weighted_index: all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample from {0..n-1} with P(k) ∝ (k+1)^-alpha (Zipf / power law,
    /// the paper's adapter-popularity model, §V-E and Fig 22).
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        debug_assert!(n > 0);
        // Inverse-CDF over precomputed weights would be faster; the
        // callers that care (trace generation) precompute a
        // PowerLaw table instead. This is the convenience path.
        let weights: Vec<f64> =
            (0..n).map(|k| ((k + 1) as f64).powf(-alpha)).collect();
        self.weighted_index(&weights)
    }
}

#[inline]
fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

/// Precomputed power-law sampler: P(k) ∝ (k+1)^-alpha over n items.
/// Used for adapter popularity (paper: α ∈ {1/3, 1, 3}, Fig 22).
#[derive(Debug, Clone)]
pub struct PowerLaw {
    cdf: Vec<f64>,
}

impl PowerLaw {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += ((k + 1) as f64).powf(-alpha);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        PowerLaw { cdf }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Probability mass of item k.
    pub fn pmf(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg32::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::with_stream(1, 0);
        let mut b = Pcg32::with_stream(1, 1);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut rng = Pcg32::new(9);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg32::new(11);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| rng.exponential(2.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut rng = Pcg32::new(13);
        for lambda in [0.5, 4.0, 80.0] {
            let n = 20_000;
            let sum: u64 = (0..n).map(|_| rng.poisson(lambda)).sum();
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn weighted_index_proportional() {
        let mut rng = Pcg32::new(19);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0u32; 3];
        for _ in 0..40_000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn power_law_matches_pmf() {
        let mut rng = Pcg32::new(23);
        let pl = PowerLaw::new(10, 1.0);
        let mut counts = [0u32; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[pl.sample(&mut rng)] += 1;
        }
        for k in 0..10 {
            let got = counts[k] as f64 / n as f64;
            let want = pl.pmf(k);
            assert!(
                (got - want).abs() < 0.01,
                "k={k} got={got} want={want}"
            );
        }
        // heavier skew concentrates mass on item 0
        let pl3 = PowerLaw::new(10, 3.0);
        assert!(pl3.pmf(0) > pl.pmf(0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(29);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut rng = Pcg32::new(31);
        let mut zero = 0;
        for _ in 0..2000 {
            let k = rng.zipf(8, 2.0);
            assert!(k < 8);
            if k == 0 {
                zero += 1;
            }
        }
        assert!(zero > 1000, "zero={zero}");
    }
}
