//! Latency/throughput statistics: percentile recorders, histograms,
//! moving averages. Exact (sort-based) percentiles — experiment sample
//! counts are bounded (≤ millions), so we keep every sample rather than
//! approximate with a sketch; property tests compare against a naive
//! oracle anyway.

/// Collects raw samples; computes exact order statistics on demand.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        debug_assert!(x.is_finite(), "non-finite sample {x}");
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn extend_from(&mut self, other: &Samples) {
        self.xs.extend_from_slice(&other.xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Exact percentile with linear interpolation (same convention as
    /// numpy.percentile's default). `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        self.ensure_sorted();
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let n = self.xs.len();
        if n == 1 {
            return self.xs[0];
        }
        let rank = p / 100.0 * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.first().copied().unwrap_or(f64::NAN)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.last().copied().unwrap_or(f64::NAN)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let mean = self.mean();
        let var = self.xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / (self.xs.len() - 1) as f64;
        var.sqrt()
    }

    /// Fraction of samples ≤ threshold (SLO attainment).
    pub fn frac_leq(&self, threshold: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().filter(|&&x| x <= threshold).count() as f64
            / self.xs.len() as f64
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }

    /// Box-plot summary (min, p25, p50, p75, max) — Fig 1 style.
    pub fn box_summary(&mut self) -> [f64; 5] {
        [
            self.min(),
            self.percentile(25.0),
            self.percentile(50.0),
            self.percentile(75.0),
            self.max(),
        ]
    }
}

/// Fixed-bucket histogram over [lo, hi) with `n` equal bins + overflow.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    overflow: u64,
    underflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            overflow: 0,
            underflow: 0,
        }
    }

    pub fn record(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let bin = ((x - self.lo) / (self.hi - self.lo)
                * self.counts.len() as f64) as usize;
            let last = self.counts.len() - 1;
            self.counts[bin.min(last)] += 1;
        }
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.overflow + self.underflow
    }

    pub fn bin_edges(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..=self.counts.len())
            .map(|i| self.lo + w * i as f64)
            .collect()
    }
}

/// Moving average over a fixed window — Fig 10's requests-per-minute
/// smoothing.
pub fn moving_average(xs: &[f64], window: usize) -> Vec<f64> {
    assert!(window > 0);
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = 0.0;
    for i in 0..xs.len() {
        acc += xs[i];
        if i >= window {
            acc -= xs[i - window];
        }
        let n = (i + 1).min(window);
        out.push(acc / n as f64);
    }
    out
}

/// Simple least-squares linear fit: returns (slope, intercept).
/// Used by the demand extrapolator (Algorithm 1 step 1).
pub fn linear_fit(ys: &[f64]) -> (f64, f64) {
    let n = ys.len();
    if n == 0 {
        return (0.0, 0.0);
    }
    if n == 1 {
        return (0.0, ys[0]);
    }
    let nf = n as f64;
    let sx = (nf - 1.0) * nf / 2.0;
    let sxx = (nf - 1.0) * nf * (2.0 * nf - 1.0) / 6.0;
    let sy: f64 = ys.iter().sum();
    let sxy: f64 = ys.iter().enumerate().map(|(i, y)| i as f64 * y).sum();
    let denom = nf * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return (0.0, sy / nf);
    }
    let slope = (nf * sxy - sx * sy) / denom;
    let intercept = (sy - slope * sx) / nf;
    (slope, intercept)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn percentile_against_naive_oracle() {
        let mut rng = Pcg32::new(5);
        for trial in 0..20 {
            let n = 1 + rng.below(500) as usize;
            let xs: Vec<f64> =
                (0..n).map(|_| rng.range_f64(-100.0, 100.0)).collect();
            let mut s = Samples::new();
            for &x in &xs {
                s.push(x);
            }
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for p in [0.0, 25.0, 50.0, 95.0, 99.0, 100.0] {
                let rank = p / 100.0 * (n - 1) as f64;
                let (lo, hi) = (rank.floor() as usize, rank.ceil() as usize);
                let frac = rank - lo as f64;
                let want = sorted[lo] * (1.0 - frac) + sorted[hi] * frac;
                let got = s.percentile(p);
                assert!(
                    (got - want).abs() < 1e-9,
                    "trial={trial} p={p} got={got} want={want}"
                );
            }
        }
    }

    #[test]
    fn percentile_small_cases() {
        let mut s = Samples::new();
        assert!(s.p50().is_nan());
        s.push(3.0);
        assert_eq!(s.p50(), 3.0);
        assert_eq!(s.percentile(95.0), 3.0);
        s.push(1.0);
        assert_eq!(s.p50(), 2.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
    }

    #[test]
    fn mean_std_frac() {
        let mut s = Samples::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935).abs() < 1e-6);
        assert!((s.frac_leq(5.0) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.0, 0.5, 9.99, 10.0, -1.0, 5.0] {
            h.record(x);
        }
        assert_eq!(h.counts()[0], 2);
        assert_eq!(h.counts()[9], 1);
        assert_eq!(h.counts()[5], 1);
        assert_eq!(h.total(), 6);
        assert_eq!(h.bin_edges().len(), 11);
    }

    #[test]
    fn moving_average_window() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ma = moving_average(&xs, 2);
        assert_eq!(ma, vec![1.0, 1.5, 2.5, 3.5]);
        let ma1 = moving_average(&xs, 1);
        assert_eq!(ma1, xs.to_vec());
    }

    #[test]
    fn linear_fit_exact_line() {
        let ys: Vec<f64> = (0..10).map(|i| 2.5 * i as f64 + 1.0).collect();
        let (m, b) = linear_fit(&ys);
        assert!((m - 2.5).abs() < 1e-9);
        assert!((b - 1.0).abs() < 1e-9);
        let (m0, b0) = linear_fit(&[7.0]);
        assert_eq!((m0, b0), (0.0, 7.0));
    }

    #[test]
    fn box_summary_ordering() {
        let mut rng = Pcg32::new(77);
        let mut s = Samples::new();
        for _ in 0..100 {
            s.push(rng.f64());
        }
        let b = s.box_summary();
        for w in b.windows(2) {
            assert!(w[0] <= w[1], "{b:?}");
        }
    }
}
