//! Minimal JSON: a value tree, a writer, and a recursive-descent parser.
//!
//! Needed because serde is not available offline. Covers the full JSON
//! grammar minus exotic number forms; good enough for the artifact
//! manifest, golden files, and results emission. Property-tested by
//! round-tripping random value trees.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// obj["a"]["b"][2] style access for tests/loaders.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_json(&mut s, self, 0, true);
        s
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        write_json(&mut s, self, 0, false);
        f.write_str(&s)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

fn write_json(out: &mut String, v: &Json, indent: usize, pretty: bool) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 9e15 {
                let _ = write!(out, "{}", *x as i64);
            } else {
                // shortest round-trippable f64
                let _ = write!(out, "{x}");
            }
        }
        Json::Str(s) => write_escaped(out, s),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                }
                write_json(out, x, indent + 1, pretty);
            }
            if pretty && !xs.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent));
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                }
                write_escaped(out, k);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_json(out, x, indent + 1, pretty);
            }
            if pretty && !m.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent));
            }
            out.push('}');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}",
                b as char,
                self.pos.saturating_sub(1)
            ))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u")? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        s.push(
                            char::from_u32(code).unwrap_or('\u{fffd}'),
                        );
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-decode multi-byte utf-8
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err("truncated utf-8".into());
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|e| e.to_string())?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(xs)),
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::Str("héllo ☃ \"q\"".into());
        let s = v.to_string();
        assert_eq!(parse(&s).unwrap(), v);
        assert_eq!(
            parse("\"\\u2603\"").unwrap(),
            Json::Str("\u{2603}".into())
        );
    }

    fn random_json(rng: &mut Pcg32, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = rng.below(8) as usize;
                Json::Str(
                    (0..n)
                        .map(|_| {
                            char::from_u32(32 + rng.below(90) as u32)
                                .unwrap()
                        })
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect(),
            ),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| {
                        (format!("k{i}"), random_json(rng, depth - 1))
                    })
                    .collect(),
            ),
        }
    }

    #[test]
    fn property_roundtrip_random_trees() {
        let mut rng = Pcg32::new(99);
        for case in 0..200 {
            let v = random_json(&mut rng, 3);
            let compact = v.to_string();
            let pretty = v.to_string_pretty();
            assert_eq!(parse(&compact).unwrap(), v, "case={case} compact");
            assert_eq!(parse(&pretty).unwrap(), v, "case={case} pretty");
        }
    }
}
