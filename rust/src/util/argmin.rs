//! Flat segment tree answering `argmin` over a dense `f64` key array
//! in O(1), with O(log n) point updates — the index behind the
//! control plane's hot paths: least-loaded routing over per-server
//! outstanding work, and the engine's next-due-lane lookup at epoch
//! barriers.
//!
//! Tie-breaking is *left wins*: among equal-key leaves the lowest
//! index is returned, which makes the tree's answer bit-identical to
//! a linear scan using a strict `<` comparison (the pre-index
//! routing loop). Keys must never be NaN; `f64::INFINITY` is the
//! conventional "masked" key (drained server, empty lane) and
//! compares like any other value, so an all-masked tree returns
//! index 0 — exactly what the scan's `best = 0` seed did.

/// Positional argmin index over `n` dense `f64` keys.
///
/// Layout: a classic 1-indexed segment tree over `cap = n.next_power_
/// of_two()` leaves. `node[v]` for internal `v ∈ 1..cap` holds the
/// index of the min-key leaf in `v`'s subtree; leaves are implicit
/// (`node[cap + i] = i`). Padding leaves (`i >= n`) are pinned at
/// `INFINITY` and never updated, so they lose every comparison
/// against a real leaf and an argmin over a non-empty tree is always
/// a valid index `< n`.
#[derive(Debug, Clone)]
pub struct ArgminTree {
    /// number of real leaves
    n: usize,
    /// power-of-two leaf capacity
    cap: usize,
    /// current key per leaf slot (padding slots stay `INFINITY`)
    keys: Vec<f64>,
    /// `node[v]` = argmin leaf index within subtree `v` (size `2*cap`,
    /// slot 0 unused)
    node: Vec<u32>,
}

impl ArgminTree {
    /// Build a tree of `n` leaves, every key `f64::INFINITY` (all
    /// masked). `n = 0` is allowed; `argmin`/`min_key` on an empty
    /// tree return `0` / `INFINITY`.
    pub fn new(n: usize) -> Self {
        let cap = n.next_power_of_two().max(1);
        let mut node = vec![0u32; 2 * cap];
        for i in 0..cap {
            node[cap + i] = i as u32;
        }
        // with all keys equal (INF), left wins everywhere: internal
        // nodes point at their leftmost leaf
        for v in (1..cap).rev() {
            node[v] = node[2 * v];
        }
        ArgminTree {
            n,
            cap,
            keys: vec![f64::INFINITY; cap],
            node,
        }
    }

    /// Number of real leaves.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Current key of leaf `i`.
    #[inline]
    pub fn key(&self, i: usize) -> f64 {
        self.keys[i]
    }

    /// All real-leaf keys (length `n`).
    pub fn keys(&self) -> &[f64] {
        &self.keys[..self.n]
    }

    /// Index of the minimum-key leaf, lowest index among ties. `0`
    /// when the tree is empty.
    #[inline]
    pub fn argmin(&self) -> usize {
        self.node[1] as usize
    }

    /// Key at [`Self::argmin`] (`INFINITY` when empty or all-masked).
    #[inline]
    pub fn min_key(&self) -> f64 {
        self.keys[self.node[1] as usize]
    }

    /// Set leaf `i`'s key and re-derive the O(log n) root path.
    /// `key` must not be NaN (use `INFINITY` to mask a leaf).
    #[inline]
    pub fn update(&mut self, i: usize, key: f64) {
        debug_assert!(i < self.n, "leaf {i} out of range {}", self.n);
        debug_assert!(!key.is_nan(), "NaN keys break argmin ordering");
        self.keys[i] = key;
        let mut v = (self.cap + i) >> 1;
        while v >= 1 {
            let l = self.node[2 * v];
            let r = self.node[2 * v + 1];
            // strict `<` from the right: on ties the left (lower
            // index) child wins, matching a linear scan
            self.node[v] =
                if self.keys[r as usize] < self.keys[l as usize] {
                    r
                } else {
                    l
                };
            v >>= 1;
        }
    }

    /// Reset every real leaf from `f(i)` in one O(n) bottom-up pass
    /// (padding leaves stay masked). Used after bulk mutations where
    /// per-leaf `update` calls would pay O(n log n).
    pub fn rebuild<F: FnMut(usize) -> f64>(&mut self, mut f: F) {
        for i in 0..self.n {
            let k = f(i);
            debug_assert!(!k.is_nan(), "NaN keys break argmin ordering");
            self.keys[i] = k;
        }
        for v in (1..self.cap).rev() {
            let l = self.node[2 * v];
            let r = self.node[2 * v + 1];
            self.node[v] =
                if self.keys[r as usize] < self.keys[l as usize] {
                    r
                } else {
                    l
                };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn scan_argmin(keys: &[f64]) -> usize {
        let mut best = 0;
        for (i, &k) in keys.iter().enumerate().skip(1) {
            if k < keys[best] {
                best = i;
            }
        }
        best
    }

    #[test]
    fn matches_scan_under_random_updates() {
        for n in [1usize, 2, 3, 7, 8, 9, 64, 65, 130] {
            let mut rng = Pcg32::new(n as u64 + 11);
            let mut tree = ArgminTree::new(n);
            let mut keys = vec![f64::INFINITY; n];
            for step in 0..400 {
                let i = (rng.next_u32() as usize) % n;
                // small discrete key set forces frequent ties; an
                // occasional INF exercises masking
                let k = match step % 5 {
                    0 => f64::INFINITY,
                    _ => (rng.next_u32() % 4) as f64,
                };
                keys[i] = k;
                tree.update(i, k);
                assert_eq!(tree.argmin(), scan_argmin(&keys));
                assert_eq!(
                    tree.min_key().to_bits(),
                    keys[scan_argmin(&keys)].to_bits()
                );
            }
        }
    }

    #[test]
    fn all_masked_returns_zero() {
        let tree = ArgminTree::new(12);
        assert_eq!(tree.argmin(), 0);
        assert!(tree.min_key().is_infinite());
    }

    #[test]
    fn ties_pick_lowest_index() {
        let mut tree = ArgminTree::new(5);
        for i in 0..5 {
            tree.update(i, 2.0);
        }
        assert_eq!(tree.argmin(), 0);
        tree.update(3, 1.0);
        tree.update(4, 1.0);
        assert_eq!(tree.argmin(), 3);
        tree.update(1, 1.0);
        assert_eq!(tree.argmin(), 1);
    }

    #[test]
    fn rebuild_matches_scan() {
        let mut rng = Pcg32::new(3);
        let mut tree = ArgminTree::new(33);
        let keys: Vec<f64> =
            (0..33).map(|_| (rng.next_u32() % 6) as f64).collect();
        tree.rebuild(|i| keys[i]);
        assert_eq!(tree.argmin(), scan_argmin(&keys));
        assert_eq!(tree.keys().len(), 33);
    }
}
