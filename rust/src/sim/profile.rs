//! Empirical operating-point profiling (§IV-A: "we profile the servers
//! a priori, to estimate the operating point of each rank under SLO
//! constraints").
//!
//! The analytic points in `costmodel::oppoint` are closed-form
//! approximations; this profiler measures the *actual* max sustainable
//! tokens/sec per rank by bisecting offered load on a single simulated
//! server — matching what the paper's operators would measure on real
//! hardware. Results are cached per (model, tp, rank, batch config).

use super::cluster::{run, SimConfig, SystemKind};
use crate::config::{ClusterConfig, ServerConfig, SloConfig};
use crate::trace::{LengthModel, Trace};
use crate::util::rng::Pcg32;
use crate::workload::{Adapter, AdapterSet, Request};
use std::collections::BTreeMap;
use std::sync::Mutex;

static CACHE: Mutex<BTreeMap<(String, usize, usize, usize, u32), f64>> =
    Mutex::new(BTreeMap::new());

fn single_rank_trace(
    rank: u32,
    rps: f64,
    duration: f64,
    lengths: &LengthModel,
    seed: u64,
) -> Trace {
    let adapters = AdapterSet::new(vec![Adapter {
        id: 0,
        rank,
        size_bytes: crate::config::ModelSpec::LLAMA_7B.adapter_bytes(rank),
    }]);
    let mut rng = Pcg32::with_stream(seed, 0x0bb + rank as u64);
    let mut reqs = Vec::new();
    let mut t = 0.0;
    loop {
        t += rng.exponential(rps);
        if t > duration {
            break;
        }
        let (p, o) = lengths.sample(&mut rng);
        reqs.push(Request {
            id: 0,
            adapter: 0,
            prompt_len: p,
            output_len: o,
            arrival: t,
        });
    }
    Trace::new(&format!("profile-r{rank}"), adapters, reqs)
}

/// Max tokens/sec one server sustains for `rank` with P95 TTFT within
/// `slo` on the standard evaluation request shape.
pub fn empirical_operating_point(
    server: &ServerConfig,
    rank: u32,
    slo: f64,
) -> f64 {
    let key = (
        server.model.name.to_string(),
        server.tp,
        server.max_batch_tokens,
        server.max_batch_size,
        rank,
    );
    if let Some(&v) = CACHE.lock().unwrap().get(&key) {
        return v;
    }
    let lengths = LengthModel::default();
    // mean tokens per request of the profiling shape
    let mean_tokens = {
        let mut rng = Pcg32::new(7);
        let n = 2000;
        let mut sum = 0u64;
        for _ in 0..n {
            let (p, o) = lengths.sample(&mut rng);
            sum += (p + o) as u64;
        }
        sum as f64 / n as f64
    };
    let cluster = ClusterConfig {
        n_servers: 1,
        slo: SloConfig {
            ttft_p95: slo,
            timeout: 10.0 * slo,
            ..Default::default()
        },
        server: *server,
        rebalance_period: 1e9, // static; single adapter anyway
        ..Default::default()
    };
    let meets = |rps: f64| -> bool {
        let trace = single_rank_trace(rank, rps, 240.0, &lengths, 1);
        let mut rep = run(
            &trace,
            &SimConfig::new(cluster.clone(), SystemKind::SLoraContiguous),
        );
        rep.meets_slo(slo)
    };
    let (mut lo, mut hi) = (0.25f64, 512.0f64);
    if !meets(lo) {
        lo = 0.05;
    }
    if meets(hi) {
        // saturation above scan range; cap
        let v = hi * mean_tokens;
        CACHE.lock().unwrap().insert(key, v);
        return v;
    }
    for _ in 0..9 {
        let mid = 0.5 * (lo + hi);
        if meets(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let v = lo * mean_tokens;
    CACHE.lock().unwrap().insert(key, v);
    v
}

/// Profile every rank (cached).
pub fn empirical_operating_points(
    server: &ServerConfig,
    ranks: &[u32],
    slo: f64,
) -> BTreeMap<u32, f64> {
    ranks
        .iter()
        .map(|&r| (r, empirical_operating_point(server, r, slo)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empirical_points_monotone_and_cached() {
        let server = ServerConfig::default();
        let ops = empirical_operating_points(
            &server,
            &[8, 128],
            10.0,
        );
        assert!(
            ops[&8] > ops[&128],
            "r8 {} !> r128 {}",
            ops[&8],
            ops[&128]
        );
        assert!(ops[&128] > 50.0, "r128 op too low: {}", ops[&128]);
        // cache returns identical values on repeat calls
        assert_eq!(
            empirical_operating_point(&server, 8, 10.0),
            ops[&8]
        );
        // fast in aggregate: a cached call must not re-simulate
        let t1 = std::time::Instant::now();
        for _ in 0..100 {
            let _ = empirical_operating_point(&server, 128, 10.0);
        }
        assert!(t1.elapsed().as_millis() < 200);
    }
}
