//! The cluster simulation: arrivals → coordinator routing → per-server
//! continuous batching → completions, with periodic LORASERVE
//! rebalancing and the distributed adapter pool in the loop.

use super::event::EventQueue;
use super::report::SimReport;
use super::server::{SimReq, SimServer};
use crate::config::ClusterConfig;
use crate::coordinator::{DemandTracker, Router, RoutingTable};
use crate::costmodel::{operating_points, CostModel};
use crate::placement::baselines::{ContiguousPlacer, RandomPlacer};
use crate::placement::loraserve::LoraServePlacer;
use crate::placement::{Assignment, PlacementCtx, Placer};
use crate::pool::AdapterPool;
use crate::trace::Trace;
use crate::util::rng::Pcg32;
use crate::workload::{AdapterId, ServerId};
use std::collections::BTreeMap;

/// The four systems of §V-D.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    LoraServe,
    SLoraRandom,
    SLoraContiguous,
    Toppings,
}

impl SystemKind {
    pub fn label(&self) -> &'static str {
        match self {
            SystemKind::LoraServe => "loraserve",
            SystemKind::SLoraRandom => "slora-random",
            SystemKind::SLoraContiguous => "slora-contiguous",
            SystemKind::Toppings => "toppings",
        }
    }

    pub fn all() -> [SystemKind; 4] {
        [
            SystemKind::LoraServe,
            SystemKind::SLoraRandom,
            SystemKind::SLoraContiguous,
            SystemKind::Toppings,
        ]
    }
}

/// Ablation/variant knobs for LORASERVE (DESIGN.md §8).
#[derive(Debug, Clone, Copy, Default)]
pub struct LoraServeOpts {
    /// A2: disable the churn-minimizing permutation step.
    pub skip_permutation: bool,
    /// A3: project demand with last value only (no trend).
    pub last_value_demand: bool,
    /// A4: rank-agnostic placement — all operating points equal, so
    /// budgeting/packing balances pure load.
    pub rank_agnostic: bool,
    /// A5: replicate everything instead of the distributed pool.
    pub full_replication: bool,
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub cluster: ClusterConfig,
    pub system: SystemKind,
    pub opts: LoraServeOpts,
    /// Completions of requests that arrived before this time are
    /// excluded from the latency statistics (steady-state measurement;
    /// the cold-start window before the first rebalance is not what
    /// the paper reports).
    pub warmup: f64,
    /// Hard cap on simulated events (runaway guard).
    pub max_events: u64,
}

impl SimConfig {
    pub fn new(cluster: ClusterConfig, system: SystemKind) -> Self {
        SimConfig {
            cluster,
            system,
            opts: LoraServeOpts::default(),
            warmup: 0.0,
            max_events: 500_000_000,
        }
    }

    pub fn with_warmup(mut self, warmup: f64) -> Self {
        self.warmup = warmup;
        self
    }
}

#[derive(Debug)]
enum Event {
    Arrive(usize),
    IterDone(ServerId),
    FetchDone(ServerId, AdapterId),
    Rebalance,
}

/// Run one trace through one system. Deterministic per (trace, config,
/// seed).
pub fn run(trace: &Trace, cfg: &SimConfig) -> SimReport {
    let n = cfg.cluster.n_servers;
    let cm = CostModel::new(cfg.cluster.server);
    let mut rng = Pcg32::with_stream(cfg.cluster.seed, 0x51u64);
    let ranks = trace.adapters.unique_ranks();
    // LORASERVE consumes *profiled* operating points (§IV-A); the
    // analytic model is only the non-LORASERVE fallback (where the
    // values are unused anyway — static placers ignore demand).
    let mut oppoints = if matches!(cfg.system, SystemKind::LoraServe) {
        super::profile::empirical_operating_points(
            &cfg.cluster.server,
            &ranks,
            cfg.cluster.slo.ttft_p95,
        )
    } else {
        operating_points(&cfg.cluster.server, &ranks)
    };
    if cfg.opts.rank_agnostic {
        let mean: f64 =
            oppoints.values().sum::<f64>() / oppoints.len() as f64;
        for v in oppoints.values_mut() {
            *v = mean;
        }
    }

    // ---- initial placement + router + pool
    let uniform_demand: BTreeMap<AdapterId, f64> = trace
        .adapters
        .iter()
        .map(|a| (a.id, 100.0))
        .collect();
    let mut loraserve_placer = LoraServePlacer {
        skip_permutation: cfg.opts.skip_permutation,
    };
    let mut static_placer: Box<dyn Placer> = match cfg.system {
        SystemKind::SLoraRandom => {
            Box::new(RandomPlacer::new(cfg.cluster.seed))
        }
        _ => Box::new(ContiguousPlacer::new()),
    };

    let initial_ctx = PlacementCtx {
        adapters: &trace.adapters,
        n_servers: n,
        demand_tps: &uniform_demand,
        operating_points: &oppoints,
        prev: None,
    };
    let mut assignment: Assignment = match cfg.system {
        SystemKind::LoraServe => loraserve_placer.place(&initial_ctx),
        SystemKind::SLoraRandom | SystemKind::SLoraContiguous => {
            static_placer.place(&initial_ctx)
        }
        SystemKind::Toppings => {
            // placement is irrelevant; full replication
            let mut a = Assignment::new(trace.adapters.len());
            for ad in trace.adapters.iter() {
                a.add(ad.id, 0, 1.0);
            }
            a
        }
    };
    assignment
        .validate(n)
        .expect("initial placement invalid");

    let replicate = matches!(cfg.system, SystemKind::Toppings)
        || cfg.opts.full_replication;
    let mut pool = if replicate {
        AdapterPool::fully_replicated(n, trace.adapters.len())
    } else {
        let homes: Vec<Vec<ServerId>> = assignment
            .shares
            .iter()
            .map(|ss| ss.iter().map(|(s, _)| *s).collect())
            .collect();
        AdapterPool::new(n, &homes)
    };

    let mut router = match cfg.system {
        SystemKind::Toppings => Router::Toppings { n_servers: n },
        _ => Router::Table(RoutingTable::from_assignment(&assignment)),
    };

    let mut demand =
        DemandTracker::new(cfg.cluster.rebalance_period, 16);
    demand.last_value_only = cfg.opts.last_value_demand;

    let mut servers: Vec<SimServer> =
        (0..n).map(|s| SimServer::new(s, cm)).collect();

    // ---- event loop
    let mut report = SimReport {
        system: cfg.system.label().to_string(),
        trace: trace.name.clone(),
        offered_rps: trace.mean_rps(),
        per_server_ttft: vec![Default::default(); n],
        ..Default::default()
    };
    let mut q: EventQueue<Event> = EventQueue::new();
    for (i, r) in trace.requests.iter().enumerate() {
        q.push(r.arrival, Event::Arrive(i));
    }
    let trace_end = trace.duration();
    let dynamic = matches!(cfg.system, SystemKind::LoraServe);
    if dynamic {
        // Bootstrap: the initial placement is demand-blind (uniform
        // assumption), so the first few rebalances fire early — a
        // cold-start backlog at near-critical utilization otherwise
        // takes many minutes to drain. Production deployments persist
        // demand state across restarts; this approximates that.
        q.push(cfg.cluster.rebalance_period / 4.0, Event::Rebalance);
    }

    let mut outstanding_buf = vec![0.0f64; n];
    let mut events = 0u64;
    while let Some((now, ev)) = q.pop() {
        events += 1;
        if events > cfg.max_events {
            panic!(
                "simulation exceeded {} events (trace {}, system {})",
                cfg.max_events,
                trace.name,
                cfg.system.label()
            );
        }
        match ev {
            Event::Arrive(i) => {
                let req = trace.requests[i];
                demand.record(req.adapter, req.total_tokens());
                // Toppings balances on request *counts* ("requests
                // currently being served and queued", §V-D) — blind to
                // token lengths and ranks; the table policies ignore
                // the signal entirely.
                for (s, srv) in servers.iter().enumerate() {
                    outstanding_buf[s] = match cfg.system {
                        SystemKind::Toppings => srv.pending_count() as f64,
                        _ => srv.outstanding,
                    };
                }
                let target =
                    router.route(req.adapter, &outstanding_buf, &mut rng);
                let rank = trace.adapters.get(req.adapter).rank;
                // Toppings is load-aware but rank-AGNOSTIC (§V-D): its
                // outstanding-work signal prices every request as if it
                // carried no LoRA cost, so high-rank requests are
                // under-weighted — the imbalance the paper critiques.
                let est_rank = match cfg.system {
                    SystemKind::Toppings => 0,
                    _ => rank,
                };
                let sreq = SimReq {
                    req,
                    rank,
                    adapter_bytes: trace.adapters.get(req.adapter).size_bytes,
                    est: SimServer::estimate(&cm, &req, est_rank),
                };
                if pool.is_resident(target, req.adapter) {
                    servers[target].enqueue_ready(sreq);
                } else {
                    servers[target].enqueue_waiting(sreq);
                    if let Some(dt) = pool.start_fetch(
                        target,
                        req.adapter,
                        &trace.adapters,
                        &cfg.cluster.server.gpu,
                    ) {
                        q.push(
                            now + dt,
                            Event::FetchDone(target, req.adapter),
                        );
                    }
                }
                if let Some(dt) = servers[target].start_iteration(now) {
                    q.push(now + dt, Event::IterDone(target));
                }
            }
            Event::IterDone(s) => {
                let completions = servers[s].finish_iteration(now);
                for c in completions {
                    report.completed += 1;
                    report.makespan = report.makespan.max(c.finished_at);
                    if c.req.arrival < cfg.warmup {
                        continue; // simulated, but not measured
                    }
                    report.ttft.push(c.ttft);
                    if c.tbt.is_finite() {
                        report.tbt.push(c.tbt);
                    }
                    report.per_server_ttft[s].push(c.ttft);
                    report
                        .per_adapter_ttft
                        .entry(c.req.adapter)
                        .or_default()
                        .push(c.ttft);
                }
                servers[s].purge_timeouts(now, cfg.cluster.slo.timeout);
                if let Some(dt) = servers[s].start_iteration(now) {
                    q.push(now + dt, Event::IterDone(s));
                }
            }
            Event::FetchDone(s, a) => {
                pool.finish_fetch(s, a);
                servers[s].release_waiting(a);
                if let Some(dt) = servers[s].start_iteration(now) {
                    q.push(now + dt, Event::IterDone(s));
                }
            }
            Event::Rebalance => {
                demand.roll_window();
                let projected = demand.projected_tps();
                let ctx = PlacementCtx {
                    adapters: &trace.adapters,
                    n_servers: n,
                    demand_tps: &projected,
                    operating_points: &oppoints,
                    prev: Some(&assignment),
                };
                let next = loraserve_placer.place(&ctx);
                report.migration_bytes +=
                    next.migration_bytes(&assignment, &trace.adapters);
                router.update_table(RoutingTable::from_assignment(&next));
                if !replicate {
                    let homes: Vec<Vec<ServerId>> = next
                        .shares
                        .iter()
                        .map(|ss| ss.iter().map(|(x, _)| *x).collect())
                        .collect();
                    pool.apply_assignment(&homes);
                }
                assignment = next;
                report.rebalances += 1;
                let next_in = if report.rebalances < 4 {
                    cfg.cluster.rebalance_period / 4.0
                } else {
                    cfg.cluster.rebalance_period
                };
                if now + next_in <= trace_end {
                    q.push(now + next_in, Event::Rebalance);
                }
            }
        }
    }

    debug_assert!(
        pool.check_coverage(trace.adapters.len()).is_ok(),
        "pool lost coverage"
    );
    for (s, srv) in servers.iter().enumerate() {
        report.per_server_busy.push(srv.busy_time);
        report.per_server_max_adapters.push(pool.max_resident(s));
        report.timeouts += srv.timeouts;
        report.gpu_loads += srv.gpu_cache.loads;
        report.gpu_load_bytes += srv.gpu_cache.load_bytes;
        report.per_server_highrank_frac.push(
            srv.iters_highrank as f64 / srv.iters.max(1) as f64,
        );
    }
    report.fetches = pool.total_fetches;
    report.fetch_bytes = pool.total_fetch_bytes;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::trace::azure::{self, AzureConfig};
    use crate::trace::LengthModel;

    fn small_trace(rps: f64, seed: u64) -> Trace {
        azure::generate(&AzureConfig {
            rps,
            duration: 120.0,
            seed,
            lengths: LengthModel::fixed(512, 16),
            ..Default::default()
        })
    }

    fn cluster() -> ClusterConfig {
        ClusterConfig {
            n_servers: 4,
            rebalance_period: 20.0,
            ..Default::default()
        }
    }

    #[test]
    fn all_systems_complete_light_load() {
        let trace = small_trace(4.0, 1);
        for system in SystemKind::all() {
            let mut rep = run(
                &trace,
                &SimConfig::new(cluster(), system),
            );
            let total = rep.completed + rep.timeouts;
            assert_eq!(
                total,
                trace.requests.len() as u64,
                "{}: {total} != {}",
                system.label(),
                trace.requests.len()
            );
            assert!(
                rep.completion_rate() > 0.99,
                "{}: completion {}",
                system.label(),
                rep.completion_rate()
            );
            assert!(rep.ttft_p95() > 0.0);
            assert!(rep.ttft.len() as u64 == rep.completed);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let trace = small_trace(6.0, 2);
        let cfg = SimConfig::new(cluster(), SystemKind::LoraServe);
        let mut r1 = run(&trace, &cfg);
        let mut r2 = run(&trace, &cfg);
        assert_eq!(r1.completed, r2.completed);
        assert_eq!(r1.ttft_p95(), r2.ttft_p95());
        assert_eq!(r1.migration_bytes, r2.migration_bytes);
    }

    #[test]
    fn overload_causes_timeouts_or_queueing() {
        let mut c = cluster();
        c.n_servers = 1;
        c.slo.timeout = 30.0;
        let trace = small_trace(50.0, 3); // way past one server
        let mut rep =
            run(&trace, &SimConfig::new(c, SystemKind::SLoraRandom));
        let p95 = rep.ttft_p95();
        let timeouts = rep.timeouts;
        assert!(
            timeouts > 0 || p95 > 10.0,
            "timeouts={timeouts} p95={p95}"
        );
    }

    #[test]
    fn loraserve_rebalances_and_migrates() {
        let trace = small_trace(8.0, 4);
        let rep = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::LoraServe),
        );
        assert!(rep.rebalances >= 4, "rebalances={}", rep.rebalances);
    }

    #[test]
    fn toppings_replicates_everything() {
        let trace = small_trace(4.0, 5);
        let rep = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::Toppings),
        );
        for s in 0..4 {
            assert_eq!(
                rep.per_server_max_adapters[s],
                trace.adapters.len()
            );
        }
        assert_eq!(rep.fetches, 0);
    }

    #[test]
    fn loraserve_stores_fewer_adapters_than_toppings() {
        let trace = small_trace(8.0, 6);
        let ls = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::LoraServe),
        );
        let tp = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::Toppings),
        );
        let max_ls: usize =
            *ls.per_server_max_adapters.iter().max().unwrap();
        let max_tp: usize =
            *tp.per_server_max_adapters.iter().max().unwrap();
        assert!(
            max_ls < max_tp,
            "loraserve {max_ls} !< toppings {max_tp}"
        );
    }

    #[test]
    fn busy_time_conservation() {
        // server busy time can never exceed the makespan
        let trace = small_trace(6.0, 7);
        let rep = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::LoraServe),
        );
        for (s, &busy) in rep.per_server_busy.iter().enumerate() {
            assert!(
                busy <= rep.makespan * 1.001 + 1.0,
                "server {s} busy {busy} > makespan {}",
                rep.makespan
            );
        }
    }
}
