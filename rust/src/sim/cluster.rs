//! The paper's systems as configuration: `SystemKind` (the four
//! evaluated systems of §V-D), the LORASERVE ablation knobs, and
//! `SimConfig` — plus the thin `run` entry point that composes a
//! [`SystemSpec`](super::engine::SystemSpec) and hands it to the
//! [`SimEngine`](super::engine::SimEngine).
//!
//! The event loop itself lives in `sim/engine.rs`; the fleet lifecycle
//! in `sim/topology.rs`; batch admission policies in `sim/server.rs`.
//! Each canned `SystemKind` is nothing more than a `SystemSpec` value
//! (`SystemKind::spec`) — new systems compose their own spec and call
//! [`run_spec`](super::engine::run_spec) without touching the loop.

use super::engine::{
    LoadSignal, PlacementPolicy, PoolMode, RoutingPolicy, SystemSpec,
};
use super::report::SimReport;
use super::scenario::ScenarioConfig;
use crate::config::{
    AutoscaleConfig, BatchPolicyKind, ClusterConfig, DecodePolicyKind,
    RebalanceConfig, SloFeedbackConfig,
};
use crate::placement::Placer;
use crate::trace::Trace;
use std::sync::{Mutex, OnceLock};

/// The policy bundle a [`SystemSpec`] is composed from — every knob
/// that is orthogonal to *which* system runs: ablation options, batch
/// admission, decode composition, SLO feedback, drift-reactive
/// rebalancing, and the operational scenario (failure injection +
/// regions). One struct instead of five positional parameters, so new
/// knobs stop breaking every `spec()` call site.
///
/// Build one with [`SpecParams::from_config`] (the canonical
/// derivation from a [`SimConfig`]) or from `Default` plus the
/// builder-style setters:
///
/// ```ignore
/// let p = SpecParams::default().batch(BatchPolicyKind::RankAware);
/// let spec = SystemKind::LoraServe.spec(&p);
/// ```
#[derive(Debug, Clone, Copy, Default)]
pub struct SpecParams {
    pub opts: LoraServeOpts,
    pub batch: BatchPolicyKind,
    pub decode: DecodePolicyKind,
    pub slo: SloFeedbackConfig,
    pub rebalance: RebalanceConfig,
    pub scenario: ScenarioConfig,
}

impl SpecParams {
    /// The canonical derivation: every policy knob a `SimConfig`
    /// carries, bundled for `SystemKind::spec` /
    /// `custom_system_spec`.
    pub fn from_config(cfg: &SimConfig) -> Self {
        SpecParams {
            opts: cfg.opts,
            batch: cfg.batch,
            decode: cfg.decode,
            slo: cfg.feedback,
            rebalance: cfg.rebalance,
            scenario: cfg.scenario,
        }
    }

    pub fn opts(mut self, opts: LoraServeOpts) -> Self {
        self.opts = opts;
        self
    }

    pub fn batch(mut self, batch: BatchPolicyKind) -> Self {
        self.batch = batch;
        self
    }

    pub fn decode(mut self, decode: DecodePolicyKind) -> Self {
        self.decode = decode;
        self
    }

    pub fn slo(mut self, slo: SloFeedbackConfig) -> Self {
        self.slo = slo;
        self
    }

    pub fn rebalance(mut self, rebalance: RebalanceConfig) -> Self {
        self.rebalance = rebalance;
        self
    }

    pub fn scenario(mut self, scenario: ScenarioConfig) -> Self {
        self.scenario = scenario;
        self
    }
}

/// The four systems of §V-D.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    LoraServe,
    SLoraRandom,
    SLoraContiguous,
    Toppings,
}

impl SystemKind {
    pub fn label(&self) -> &'static str {
        match self {
            SystemKind::LoraServe => "loraserve",
            SystemKind::SLoraRandom => "slora-random",
            SystemKind::SLoraContiguous => "slora-contiguous",
            SystemKind::Toppings => "toppings",
        }
    }

    pub fn all() -> [SystemKind; 4] {
        [
            SystemKind::LoraServe,
            SystemKind::SLoraRandom,
            SystemKind::SLoraContiguous,
            SystemKind::Toppings,
        ]
    }

    /// The canned [`SystemSpec`] this kind names — the four systems of
    /// §V-D expressed as policy compositions. The ablation knobs fold
    /// in here (they tweak the spec, not the engine).
    pub fn spec(&self, p: &SpecParams) -> SystemSpec {
        // (the Toppings arm below forces Replicated regardless)
        let pool = if p.opts.full_replication {
            PoolMode::Replicated
        } else {
            PoolMode::Distributed
        };
        let base = SystemSpec {
            label: self.label().to_string(),
            placement: PlacementPolicy::Contiguous,
            routing: RoutingPolicy::Table,
            pool,
            batch: p.batch,
            decode: p.decode,
            periodic_rebalance: false,
            empirical_oppoints: false,
            rank_agnostic: p.opts.rank_agnostic,
            last_value_demand: p.opts.last_value_demand,
            load_signal: LoadSignal::ServiceSeconds,
            rank_blind_cost: false,
            slo: p.slo,
            rebalance: p.rebalance,
            scenario: p.scenario,
        };
        match self {
            SystemKind::LoraServe => SystemSpec {
                placement: PlacementPolicy::LoraServe {
                    skip_permutation: p.opts.skip_permutation,
                },
                periodic_rebalance: true,
                empirical_oppoints: true,
                ..base
            },
            SystemKind::SLoraRandom => SystemSpec {
                placement: PlacementPolicy::Random,
                ..base
            },
            SystemKind::SLoraContiguous => base,
            SystemKind::Toppings => SystemSpec {
                placement: PlacementPolicy::ReplicateAll,
                routing: RoutingPolicy::LeastLoaded,
                pool: PoolMode::Replicated,
                load_signal: LoadSignal::RequestCount,
                rank_blind_cost: true,
                ..base
            },
        }
    }
}

/// Ablation/variant knobs for LORASERVE (DESIGN.md §8).
#[derive(Debug, Clone, Copy, Default)]
pub struct LoraServeOpts {
    /// A2: disable the churn-minimizing permutation step.
    pub skip_permutation: bool,
    /// A3: project demand with last value only (no trend).
    pub last_value_demand: bool,
    /// A4: rank-agnostic placement — all operating points equal, so
    /// budgeting/packing balances pure load.
    pub rank_agnostic: bool,
    /// A5: replicate everything instead of the distributed pool.
    pub full_replication: bool,
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub cluster: ClusterConfig,
    pub system: SystemKind,
    pub opts: LoraServeOpts,
    /// Completions of requests that arrived before this time are
    /// excluded from the latency statistics (steady-state measurement;
    /// the cold-start window before the first rebalance is not what
    /// the paper reports).
    pub warmup: f64,
    /// Hard cap on simulated events (runaway guard). Aggregated
    /// across the control queue and every server lane, so the budget
    /// means the same thing at any shard count.
    pub max_events: u64,
    /// Worker threads for the sharded event loop. `1` (the default)
    /// runs fully sequential; any value produces the byte-identical
    /// report digest (epoch-barrier determinism contract — see
    /// `sim/engine.rs` and `tests/sharded_determinism.rs`). Clamped to
    /// the fleet size by the engine.
    pub shards: usize,
    /// Elastic capacity: run the SLO-aware autoscaler with these
    /// knobs. None (the default) keeps the fleet fixed at
    /// `cluster.n_servers` — the paper's original setting.
    pub autoscale: Option<AutoscaleConfig>,
    /// Prefill admission policy of every simulated server. Seeded from
    /// `ClusterConfig::batch_policy` so the CLI/config knob threads
    /// through every consumer (figures, planner, autoscale replay).
    pub batch: BatchPolicyKind,
    /// Decode-set composition policy of every simulated server. Seeded
    /// from `ClusterConfig::decode_policy`, threaded exactly like
    /// `batch`.
    pub decode: DecodePolicyKind,
    /// Scheduler SLO feedback layer. Seeded from
    /// `ClusterConfig::feedback`, threaded exactly like `batch` and
    /// `decode` (so the JSON/CLI knobs reach the capacity planner and
    /// every figure harness unchanged).
    pub feedback: SloFeedbackConfig,
    /// Drift-reactive rebalancing (mode, trigger knobs, remote
    /// attach). Seeded from `ClusterConfig::rebalance`, threaded
    /// exactly like `batch`/`decode`/`feedback`.
    pub rebalance: RebalanceConfig,
    /// Observability: tracing, attribution, and the metrics registry.
    /// All knobs default off, and the engine is bit-identical with
    /// them off (asserted in `tests/obs_tracing.rs`).
    pub obs: crate::obs::ObsConfig,
    /// Operational scenario (failure injection + region pricing).
    /// Inert by default; threaded into the spec like the policy knobs.
    pub scenario: ScenarioConfig,
}

impl SimConfig {
    pub fn new(cluster: ClusterConfig, system: SystemKind) -> Self {
        let batch = cluster.batch_policy;
        let decode = cluster.decode_policy;
        let feedback = cluster.feedback;
        let rebalance = cluster.rebalance;
        SimConfig {
            cluster,
            system,
            opts: LoraServeOpts::default(),
            warmup: 0.0,
            max_events: 500_000_000,
            shards: 1,
            autoscale: None,
            batch,
            decode,
            feedback,
            rebalance,
            obs: crate::obs::ObsConfig::default(),
            scenario: ScenarioConfig::default(),
        }
    }

    pub fn with_warmup(mut self, warmup: f64) -> Self {
        self.warmup = warmup;
        self
    }

    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    pub fn with_autoscale(mut self, autoscale: AutoscaleConfig) -> Self {
        self.autoscale = Some(autoscale);
        self
    }

    /// Edit the policy bundle in one place: derives the current
    /// [`SpecParams`], applies `f`, and writes the result back
    /// (the per-knob `with_*` setter chain it replaced is gone):
    ///
    /// ```ignore
    /// let cfg = SimConfig::new(cluster, SystemKind::LoraServe)
    ///     .with_params(|p| p.batch(batch).rebalance(reb));
    /// ```
    pub fn with_params(
        mut self,
        f: impl FnOnce(SpecParams) -> SpecParams,
    ) -> Self {
        let p = f(SpecParams::from_config(&self));
        self.opts = p.opts;
        self.batch = p.batch;
        self.decode = p.decode;
        self.feedback = p.slo;
        self.rebalance = p.rebalance;
        self.scenario = p.scenario;
        self
    }

    pub fn with_obs(mut self, obs: crate::obs::ObsConfig) -> Self {
        self.obs = obs;
        self
    }
}

/// Run one trace through one canned system. Deterministic per
/// (trace, config, seed). Composes the kind's [`SystemSpec`] and
/// drives the [`SimEngine`](super::engine::SimEngine); custom systems
/// use [`run_spec`](super::engine::run_spec) directly.
pub fn run(trace: &Trace, cfg: &SimConfig) -> SimReport {
    let spec = cfg.system.spec(&SpecParams::from_config(cfg));
    super::engine::run_spec(trace, cfg, &spec)
}

/// [`run`], plus the end-of-run observability bundle (Chrome trace
/// JSON, Prometheus text, per-request attribution records) per
/// `SimConfig::obs`. With every obs knob off this is exactly `run`
/// with an empty bundle.
pub fn run_observed(
    trace: &Trace,
    cfg: &SimConfig,
) -> (SimReport, crate::obs::ObsOutput) {
    let spec = cfg.system.spec(&SpecParams::from_config(cfg));
    super::engine::run_spec_observed(trace, cfg, &spec)
}

// ---------------------------------------------------------------------
// Custom-system registry: placers registered by name, resolvable from
// `--system <name>` (and anywhere else a system is named). The engine
// already accepts `PlacementPolicy::Custom`; this gives it a CLI
// surface.

type PlacerCtor = fn(u64) -> Box<dyn Placer>;

fn custom_registry() -> &'static Mutex<Vec<(&'static str, PlacerCtor)>> {
    static REGISTRY: OnceLock<Mutex<Vec<(&'static str, PlacerCtor)>>> =
        OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

/// Register a custom placer constructor under `name` (keyed by
/// `&'static str`). After registration, `--system <name>` and
/// [`custom_system_spec`] resolve it. Re-registering a name replaces
/// the previous constructor.
pub fn register_custom_system(name: &'static str, build: PlacerCtor) {
    let mut reg = custom_registry().lock().unwrap();
    if let Some(entry) = reg.iter_mut().find(|(n, _)| *n == name) {
        entry.1 = build;
    } else {
        reg.push((name, build));
    }
}

/// Names currently registered with [`register_custom_system`] — the
/// list an unknown-system error reports.
pub fn registered_custom_systems() -> Vec<&'static str> {
    custom_registry().lock().unwrap().iter().map(|(n, _)| *n).collect()
}

/// The [`SystemSpec`] of a registered custom placer: φ-table routing
/// over a distributed pool with periodic demand-driven re-placement
/// (the same operating harness as the placer-backed canned systems),
/// under the given batch/decode policies. `None` if `name` was never
/// registered.
pub fn custom_system_spec(
    name: &str,
    p: &SpecParams,
) -> Option<SystemSpec> {
    let reg = custom_registry().lock().unwrap();
    let &(static_name, build) =
        reg.iter().find(|(n, _)| *n == name)?;
    Some(SystemSpec {
        label: static_name.to_string(),
        placement: PlacementPolicy::Custom(static_name, build),
        routing: RoutingPolicy::Table,
        pool: PoolMode::Distributed,
        batch: p.batch,
        decode: p.decode,
        periodic_rebalance: true,
        empirical_oppoints: false,
        rank_agnostic: false,
        last_value_demand: false,
        load_signal: LoadSignal::ServiceSeconds,
        rank_blind_cost: false,
        slo: p.slo,
        rebalance: p.rebalance,
        scenario: p.scenario,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::trace::azure::{self, AzureConfig};
    use crate::trace::LengthModel;

    fn small_trace(rps: f64, seed: u64) -> Trace {
        azure::generate(&AzureConfig {
            rps,
            duration: 120.0,
            seed,
            lengths: LengthModel::fixed(512, 16),
            ..Default::default()
        })
    }

    fn cluster() -> ClusterConfig {
        ClusterConfig {
            n_servers: 4,
            rebalance_period: 20.0,
            ..Default::default()
        }
    }

    #[test]
    fn all_systems_complete_light_load() {
        let trace = small_trace(4.0, 1);
        for system in SystemKind::all() {
            let mut rep = run(
                &trace,
                &SimConfig::new(cluster(), system),
            );
            let total = rep.completed + rep.timeouts;
            assert_eq!(
                total,
                trace.requests.len() as u64,
                "{}: {total} != {}",
                system.label(),
                trace.requests.len()
            );
            assert!(
                rep.completion_rate() > 0.99,
                "{}: completion {}",
                system.label(),
                rep.completion_rate()
            );
            assert!(rep.ttft_p95() > 0.0);
            assert!(rep.ttft.len() as u64 == rep.completed);
            // fixed fleet: e2e measured alongside ttft, fleet constant
            assert_eq!(rep.e2e.len(), rep.ttft.len());
            assert_eq!(rep.fleet.peak_servers(), 4);
            assert_eq!(rep.fleet.min_servers(), 4);
            assert!(rep.fleet.gpu_seconds > 0.0);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let trace = small_trace(6.0, 2);
        let cfg = SimConfig::new(cluster(), SystemKind::LoraServe);
        let mut r1 = run(&trace, &cfg);
        let mut r2 = run(&trace, &cfg);
        assert_eq!(r1.completed, r2.completed);
        assert_eq!(r1.ttft_p95(), r2.ttft_p95());
        assert_eq!(r1.migration_bytes, r2.migration_bytes);
    }

    #[test]
    fn overload_causes_timeouts_or_queueing() {
        let mut c = cluster();
        c.n_servers = 1;
        c.slo.timeout = 30.0;
        let trace = small_trace(50.0, 3); // way past one server
        let mut rep =
            run(&trace, &SimConfig::new(c, SystemKind::SLoraRandom));
        let p95 = rep.ttft_p95();
        let timeouts = rep.timeouts;
        assert!(
            timeouts > 0 || p95 > 10.0,
            "timeouts={timeouts} p95={p95}"
        );
    }

    #[test]
    fn loraserve_rebalances_and_migrates() {
        let trace = small_trace(8.0, 4);
        let rep = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::LoraServe),
        );
        assert!(rep.rebalances >= 4, "rebalances={}", rep.rebalances);
    }

    #[test]
    fn toppings_replicates_everything() {
        let trace = small_trace(4.0, 5);
        let rep = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::Toppings),
        );
        for s in 0..4 {
            assert_eq!(
                rep.per_server_max_adapters[s],
                trace.adapters.len()
            );
        }
        assert_eq!(rep.fetches, 0);
    }

    #[test]
    fn loraserve_stores_fewer_adapters_than_toppings() {
        let trace = small_trace(8.0, 6);
        let ls = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::LoraServe),
        );
        let tp = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::Toppings),
        );
        let max_ls: usize =
            *ls.per_server_max_adapters.iter().max().unwrap();
        let max_tp: usize =
            *tp.per_server_max_adapters.iter().max().unwrap();
        assert!(
            max_ls < max_tp,
            "loraserve {max_ls} !< toppings {max_tp}"
        );
    }

    #[test]
    fn busy_time_conservation() {
        // server busy time can never exceed the makespan
        let trace = small_trace(6.0, 7);
        let rep = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::LoraServe),
        );
        for (s, &busy) in rep.per_server_busy.iter().enumerate() {
            assert!(
                busy <= rep.makespan * 1.001 + 1.0,
                "server {s} busy {busy} > makespan {}",
                rep.makespan
            );
        }
    }

    #[test]
    fn custom_registry_registers_and_resolves() {
        use crate::placement::baselines::RoundRobinPlacer;
        let params = SpecParams::default();
        assert!(custom_system_spec(
            "definitely-not-registered",
            &params,
        )
        .is_none());
        register_custom_system("rr-test", |_seed| {
            Box::new(RoundRobinPlacer::new())
        });
        assert!(registered_custom_systems().contains(&"rr-test"));
        let spec = custom_system_spec("rr-test", &params)
            .expect("registered name must resolve");
        assert_eq!(spec.label, "rr-test");
        // the spec runs end to end through the composition seam
        let trace = small_trace(4.0, 11);
        let cfg = SimConfig::new(cluster(), SystemKind::LoraServe);
        let rep = crate::sim::run_spec(&trace, &cfg, &spec);
        assert_eq!(
            rep.completed + rep.timeouts,
            trace.requests.len() as u64
        );
        assert_eq!(rep.system, "rr-test");
        // re-registering a name replaces, not duplicates
        register_custom_system("rr-test", |_seed| {
            Box::new(RoundRobinPlacer::new())
        });
        let n = registered_custom_systems()
            .iter()
            .filter(|&&x| x == "rr-test")
            .count();
        assert_eq!(n, 1);
    }

    #[test]
    fn decode_policies_conserve_completions() {
        use crate::config::DecodePolicyKind;
        // decode-heavy light load: every request completes under every
        // decode policy — composition changes latency, never outcomes
        let trace = azure::generate(&AzureConfig {
            rps: 3.0,
            duration: 120.0,
            seed: 21,
            lengths: LengthModel::fixed(256, 48),
            ..Default::default()
        });
        let mut completed = Vec::new();
        for decode in [
            DecodePolicyKind::Unified,
            DecodePolicyKind::RankPartitioned,
            DecodePolicyKind::ClassSubBatch { max_groups: 2 },
            DecodePolicyKind::ClassSubBatchAuto,
        ] {
            let cfg = SimConfig::new(cluster(), SystemKind::SLoraRandom)
                .with_params(|p| p.decode(decode));
            let rep = run(&trace, &cfg);
            assert_eq!(
                rep.completed + rep.timeouts,
                trace.requests.len() as u64,
                "{}: requests lost",
                decode.label()
            );
            assert_eq!(
                rep.timeouts,
                0,
                "{}: light load must not time out",
                decode.label()
            );
            assert_eq!(rep.decode_policy, decode.label());
            // determinism per decode policy
            let rep2 = run(&trace, &cfg);
            assert_eq!(rep.completed, rep2.completed);
            assert_eq!(
                rep.makespan.to_bits(),
                rep2.makespan.to_bits(),
                "{}: non-deterministic",
                decode.label()
            );
            completed.push(rep.completed);
        }
        assert!(
            completed.iter().all(|&c| c == completed[0]),
            "completion counts diverge across decode policies: \
             {completed:?}"
        );
    }

    #[test]
    fn elastic_run_grows_and_accounts_gpu_seconds() {
        let trace = small_trace(25.0, 8);
        let mut c = cluster();
        c.n_servers = 1;
        let acfg = AutoscaleConfig {
            min_servers: 1,
            max_servers: 5,
            decision_period: 10.0,
            cooldown: 15.0,
            provision_delay: 5.0,
            ..Default::default()
        };
        let rep = run(
            &trace,
            &SimConfig::new(c, SystemKind::LoraServe)
                .with_autoscale(acfg),
        );
        assert_eq!(
            rep.completed + rep.timeouts,
            trace.requests.len() as u64
        );
        assert!(rep.fleet.scale_ups >= 1, "no scale-up under burst");
        assert!(rep.fleet.peak_servers() > 1);
        assert!(rep.fleet.peak_servers() <= 5);
        // GPU-seconds bounded by the peak fleet running the whole time
        let bound = (5 * 4) as f64 * rep.fleet.duration() + 1e-6;
        assert!(rep.fleet.gpu_seconds <= bound);
        assert!(rep.fleet.gpu_seconds > 0.0);
    }
}
