//! The cluster simulation: arrivals → coordinator routing → per-server
//! continuous batching → completions, with periodic LORASERVE
//! rebalancing, the distributed adapter pool, and (optionally) the
//! elastic-capacity subsystem in the loop.
//!
//! Elastic mode (`SimConfig::with_autoscale`) adds three topology
//! events to the alphabet: `AutoscaleTick` feeds fleet signals to the
//! `autoscale::ScaleController`; `ServerReady` joins a provisioned
//! server and re-places onto the grown fleet; a `ScaleDown` decision
//! runs the **drain-and-migrate protocol** — the victim leaves the
//! routing table at once, its queued/waiting work is re-routed, its
//! adapters are re-placed onto the survivors, last-copy adapters are
//! RDMA-migrated, and only a fully quiesced, copy-free server retires
//! (`DrainCheck`). The pool coverage invariant holds at every step.

use super::event::{EventQueue, SimEvent};
use super::report::SimReport;
use super::server::{SimReq, SimServer};
use crate::autoscale::{ScaleController, ScaleDecision, ScaleSignals};
use crate::config::{AutoscaleConfig, ClusterConfig, GpuSpec};
use crate::coordinator::{DemandTracker, Router, RoutingTable};
use crate::costmodel::{operating_points, CostModel};
use crate::metrics::FleetMetrics;
use crate::placement::baselines::{ContiguousPlacer, RandomPlacer};
use crate::placement::loraserve::LoraServePlacer;
use crate::placement::{place_onto, Assignment, Placer};
use crate::pool::AdapterPool;
use crate::trace::Trace;
use crate::util::rng::Pcg32;
use crate::workload::{AdapterId, AdapterSet, ServerId};
use std::collections::BTreeMap;

/// The four systems of §V-D.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    LoraServe,
    SLoraRandom,
    SLoraContiguous,
    Toppings,
}

impl SystemKind {
    pub fn label(&self) -> &'static str {
        match self {
            SystemKind::LoraServe => "loraserve",
            SystemKind::SLoraRandom => "slora-random",
            SystemKind::SLoraContiguous => "slora-contiguous",
            SystemKind::Toppings => "toppings",
        }
    }

    pub fn all() -> [SystemKind; 4] {
        [
            SystemKind::LoraServe,
            SystemKind::SLoraRandom,
            SystemKind::SLoraContiguous,
            SystemKind::Toppings,
        ]
    }
}

/// Ablation/variant knobs for LORASERVE (DESIGN.md §8).
#[derive(Debug, Clone, Copy, Default)]
pub struct LoraServeOpts {
    /// A2: disable the churn-minimizing permutation step.
    pub skip_permutation: bool,
    /// A3: project demand with last value only (no trend).
    pub last_value_demand: bool,
    /// A4: rank-agnostic placement — all operating points equal, so
    /// budgeting/packing balances pure load.
    pub rank_agnostic: bool,
    /// A5: replicate everything instead of the distributed pool.
    pub full_replication: bool,
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub cluster: ClusterConfig,
    pub system: SystemKind,
    pub opts: LoraServeOpts,
    /// Completions of requests that arrived before this time are
    /// excluded from the latency statistics (steady-state measurement;
    /// the cold-start window before the first rebalance is not what
    /// the paper reports).
    pub warmup: f64,
    /// Hard cap on simulated events (runaway guard).
    pub max_events: u64,
    /// Elastic capacity: run the SLO-aware autoscaler with these
    /// knobs. None (the default) keeps the fleet fixed at
    /// `cluster.n_servers` — the paper's original setting.
    pub autoscale: Option<AutoscaleConfig>,
}

impl SimConfig {
    pub fn new(cluster: ClusterConfig, system: SystemKind) -> Self {
        SimConfig {
            cluster,
            system,
            opts: LoraServeOpts::default(),
            warmup: 0.0,
            max_events: 500_000_000,
            autoscale: None,
        }
    }

    pub fn with_warmup(mut self, warmup: f64) -> Self {
        self.warmup = warmup;
        self
    }

    pub fn with_autoscale(mut self, autoscale: AutoscaleConfig) -> Self {
        self.autoscale = Some(autoscale);
        self
    }
}

/// Lifecycle of one server slot in the elastic fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SrvState {
    /// Slot exists but was never provisioned (or was retired and can
    /// be re-provisioned).
    Cold,
    /// Scale-up decided; cold start in progress.
    Provisioning,
    /// Routable member of the fleet.
    Active,
    /// Scale-down decided; finishing decodes + migrating last copies.
    Draining,
    /// Fully quiesced and copy-free; reusable by a later scale-up.
    Retired,
}

fn collect_active(state: &[SrvState]) -> Vec<ServerId> {
    state
        .iter()
        .enumerate()
        .filter(|&(_, &st)| st == SrvState::Active)
        .map(|(s, _)| s)
        .collect()
}

/// Servers occupying GPUs: provisioning + active + draining. This is
/// what `FleetMetrics::gpu_seconds` integrates — a draining victim
/// keeps burning its GPUs until it retires.
fn count_billed(state: &[SrvState]) -> usize {
    state
        .iter()
        .filter(|&&st| {
            matches!(
                st,
                SrvState::Provisioning | SrvState::Active | SrvState::Draining
            )
        })
        .count()
}

fn count_provisioning(state: &[SrvState]) -> usize {
    state
        .iter()
        .filter(|&&st| st == SrvState::Provisioning)
        .count()
}

fn homes_of(asg: &Assignment) -> Vec<Vec<ServerId>> {
    asg.shares
        .iter()
        .map(|ss| ss.iter().map(|&(s, _)| s).collect())
        .collect()
}

/// Hand one request to `target`: enqueue (starting an adapter fetch on
/// a pool miss) and kick the server if idle. Shared by fresh arrivals
/// and drain-time re-routing.
#[allow(clippy::too_many_arguments)]
fn deliver(
    target: ServerId,
    sreq: SimReq,
    now: f64,
    servers: &mut [SimServer],
    pool: &mut AdapterPool,
    q: &mut EventQueue<SimEvent>,
    adapters: &AdapterSet,
    gpu: &GpuSpec,
) {
    let a = sreq.req.adapter;
    if pool.is_resident(target, a) {
        servers[target].enqueue_ready(sreq);
    } else {
        servers[target].enqueue_waiting(sreq);
        if let Some(dt) = pool.start_fetch(target, a, adapters, gpu) {
            q.push(now + dt, SimEvent::FetchDone(target, a));
        }
    }
    if let Some(dt) = servers[target].start_iteration(now) {
        q.push(now + dt, SimEvent::IterDone(target));
    }
}

/// Re-place the adapter universe onto `active` for the given system.
/// LORASERVE and the static S-LoRA placers run through `place_onto`
/// (dense virtual cluster + churn matching); Toppings has no placement
/// — its assignment is a marker and the pool is fully replicated.
#[allow(clippy::too_many_arguments)]
fn replace_assignment(
    system: SystemKind,
    ls: &mut LoraServePlacer,
    st: &mut dyn Placer,
    adapters: &AdapterSet,
    active: &[ServerId],
    demand: &BTreeMap<AdapterId, f64>,
    oppoints: &BTreeMap<u32, f64>,
    prev: Option<&Assignment>,
) -> Assignment {
    match system {
        SystemKind::LoraServe => {
            place_onto(ls, adapters, active, demand, oppoints, prev)
        }
        SystemKind::SLoraRandom | SystemKind::SLoraContiguous => {
            place_onto(st, adapters, active, demand, oppoints, prev)
        }
        SystemKind::Toppings => {
            let mut a = Assignment::new(adapters.len());
            let home = active.first().copied().unwrap_or(0);
            for ad in adapters.iter() {
                a.add(ad.id, home, 1.0);
            }
            a
        }
    }
}

/// A draining server retires once it holds no work *and* no adapter
/// copies (so no last copy can ever be lost to a shrink). Retirement
/// ends the server's GPU billing.
fn try_retire(
    s: ServerId,
    now: f64,
    state: &mut [SrvState],
    servers: &[SimServer],
    pool: &AdapterPool,
    fleet: &mut FleetMetrics,
) -> bool {
    if state[s] == SrvState::Draining
        && servers[s].quiesced()
        && pool.resident_count(s) == 0
        && pool.fetching_count(s) == 0
    {
        state[s] = SrvState::Retired;
        fleet.set_fleet(
            now,
            collect_active(state).len(),
            count_billed(state),
        );
        true
    } else {
        false
    }
}

/// Run one trace through one system. Deterministic per (trace, config,
/// seed).
pub fn run(trace: &Trace, cfg: &SimConfig) -> SimReport {
    let n0 = cfg.cluster.n_servers;
    assert!(n0 >= 1, "need at least one server");
    // elastic fleets can grow to max_servers; fixed fleets stay at n0
    let max_n = cfg
        .autoscale
        .map(|a| a.max_servers.max(n0))
        .unwrap_or(n0);
    let cm = CostModel::new(cfg.cluster.server);
    let mut rng = Pcg32::with_stream(cfg.cluster.seed, 0x51u64);
    let ranks = trace.adapters.unique_ranks();
    // LORASERVE consumes *profiled* operating points (§IV-A); the
    // analytic model is only the non-LORASERVE fallback (where the
    // values are unused anyway — static placers ignore demand).
    let mut oppoints = if matches!(cfg.system, SystemKind::LoraServe) {
        super::profile::empirical_operating_points(
            &cfg.cluster.server,
            &ranks,
            cfg.cluster.slo.ttft_p95,
        )
    } else {
        operating_points(&cfg.cluster.server, &ranks)
    };
    if cfg.opts.rank_agnostic {
        let mean: f64 =
            oppoints.values().sum::<f64>() / oppoints.len() as f64;
        for v in oppoints.values_mut() {
            *v = mean;
        }
    }

    // ---- initial placement + router + pool
    let uniform_demand: BTreeMap<AdapterId, f64> = trace
        .adapters
        .iter()
        .map(|a| (a.id, 100.0))
        .collect();
    let mut loraserve_placer = LoraServePlacer {
        skip_permutation: cfg.opts.skip_permutation,
    };
    let mut static_placer: Box<dyn Placer> = match cfg.system {
        SystemKind::SLoraRandom => {
            Box::new(RandomPlacer::new(cfg.cluster.seed))
        }
        _ => Box::new(ContiguousPlacer::new()),
    };

    let mut state: Vec<SrvState> = (0..max_n)
        .map(|s| if s < n0 { SrvState::Active } else { SrvState::Cold })
        .collect();
    let active0: Vec<ServerId> = (0..n0).collect();
    let mut assignment: Assignment = replace_assignment(
        cfg.system,
        &mut loraserve_placer,
        &mut *static_placer,
        &trace.adapters,
        &active0,
        &uniform_demand,
        &oppoints,
        None,
    );
    assignment
        .validate(max_n)
        .expect("initial placement invalid");

    let replicate = matches!(cfg.system, SystemKind::Toppings)
        || cfg.opts.full_replication;
    // Toppings routes per-request (least outstanding work); everything
    // else routes through the φ table and must swap it on every
    // topology change.
    let table_routed = !matches!(cfg.system, SystemKind::Toppings);
    let mut pool = if replicate {
        let initial: Vec<Vec<ServerId>> = (0..trace.adapters.len())
            .map(|_| active0.clone())
            .collect();
        AdapterPool::new(max_n, &initial)
    } else {
        AdapterPool::new(max_n, &homes_of(&assignment))
    };

    let mut router = match cfg.system {
        SystemKind::Toppings => Router::Toppings { n_servers: max_n },
        _ => Router::Table(RoutingTable::from_assignment(&assignment)),
    };

    let mut demand =
        DemandTracker::new(cfg.cluster.rebalance_period, 16);
    demand.last_value_only = cfg.opts.last_value_demand;

    let mut servers: Vec<SimServer> =
        (0..max_n).map(|s| SimServer::new(s, cm)).collect();

    // ---- event loop
    let mut report = SimReport {
        system: cfg.system.label().to_string(),
        trace: trace.name.clone(),
        offered_rps: trace.mean_rps(),
        per_server_ttft: vec![Default::default(); max_n],
        fleet: FleetMetrics::new(cfg.cluster.server.tp, n0),
        ..Default::default()
    };
    let mut q: EventQueue<SimEvent> = EventQueue::new();
    for (i, r) in trace.requests.iter().enumerate() {
        q.push(r.arrival, SimEvent::Arrive(i));
    }
    let trace_end = trace.duration();
    let dynamic = matches!(cfg.system, SystemKind::LoraServe);
    if dynamic {
        // Bootstrap: the initial placement is demand-blind (uniform
        // assumption), so the first few rebalances fire early — a
        // cold-start backlog at near-critical utilization otherwise
        // takes many minutes to drain. Production deployments persist
        // demand state across restarts; this approximates that.
        q.push(cfg.cluster.rebalance_period / 4.0, SimEvent::Rebalance);
    }
    let mut controller: Option<ScaleController> =
        cfg.autoscale.map(ScaleController::new);
    if let Some(a) = cfg.autoscale {
        q.push(a.decision_period, SimEvent::AutoscaleTick);
    }
    // autoscaler signal window: busy-time snapshots + SLO accounting
    let mut busy_snap = vec![0.0f64; max_n];
    let mut last_tick = 0.0f64;
    let mut win_completed = 0u64;
    let mut win_violations = 0u64;

    let mut outstanding_buf = vec![0.0f64; max_n];
    let mut events = 0u64;
    while let Some((now, ev)) = q.pop() {
        events += 1;
        if events > cfg.max_events {
            panic!(
                "simulation exceeded {} events (trace {}, system {})",
                cfg.max_events,
                trace.name,
                cfg.system.label()
            );
        }
        match ev {
            SimEvent::Arrive(i) => {
                let req = trace.requests[i];
                demand.record(req.adapter, req.total_tokens());
                // Toppings balances on request *counts* ("requests
                // currently being served and queued", §V-D) — blind to
                // token lengths and ranks; the table policies ignore
                // the signal entirely. Non-routable (cold, draining,
                // retired) servers are masked out.
                for (s, srv) in servers.iter().enumerate() {
                    outstanding_buf[s] = if state[s] == SrvState::Active {
                        match cfg.system {
                            SystemKind::Toppings => {
                                srv.pending_count() as f64
                            }
                            _ => srv.outstanding,
                        }
                    } else {
                        f64::INFINITY
                    };
                }
                let target =
                    router.route(req.adapter, &outstanding_buf, &mut rng);
                let rank = trace.adapters.get(req.adapter).rank;
                // Toppings is load-aware but rank-AGNOSTIC (§V-D): its
                // outstanding-work signal prices every request as if it
                // carried no LoRA cost, so high-rank requests are
                // under-weighted — the imbalance the paper critiques.
                let est_rank = match cfg.system {
                    SystemKind::Toppings => 0,
                    _ => rank,
                };
                let sreq = SimReq {
                    req,
                    rank,
                    adapter_bytes: trace.adapters.get(req.adapter).size_bytes,
                    est: SimServer::estimate(&cm, &req, est_rank),
                };
                deliver(
                    target,
                    sreq,
                    now,
                    &mut servers,
                    &mut pool,
                    &mut q,
                    &trace.adapters,
                    &cfg.cluster.server.gpu,
                );
            }
            SimEvent::IterDone(s) => {
                let completions = servers[s].finish_iteration(now);
                for c in completions {
                    report.completed += 1;
                    report.makespan = report.makespan.max(c.finished_at);
                    let violated = c.ttft > cfg.cluster.slo.ttft_p95;
                    win_completed += 1;
                    win_violations += violated as u64;
                    if c.req.arrival < cfg.warmup {
                        continue; // simulated, but not measured
                    }
                    report.ttft.push(c.ttft);
                    report.e2e.push(c.finished_at - c.req.arrival);
                    report.fleet.record_completion(violated);
                    if c.tbt.is_finite() {
                        report.tbt.push(c.tbt);
                    }
                    report.per_server_ttft[s].push(c.ttft);
                    report
                        .per_adapter_ttft
                        .entry(c.req.adapter)
                        .or_default()
                        .push(c.ttft);
                }
                servers[s].purge_timeouts(now, cfg.cluster.slo.timeout);
                if let Some(dt) = servers[s].start_iteration(now) {
                    q.push(now + dt, SimEvent::IterDone(s));
                }
                if state[s] == SrvState::Draining {
                    try_retire(
                        s,
                        now,
                        &mut state,
                        &servers,
                        &pool,
                        &mut report.fleet,
                    );
                }
            }
            SimEvent::FetchDone(s, a) => {
                pool.finish_fetch(s, a);
                if state[s] == SrvState::Draining {
                    // a fetch that raced the drain decision: discard
                    // the fresh copy if covered elsewhere, otherwise
                    // it *is* the last copy — migrate it to its new
                    // home before this server can go.
                    if !pool.drop_copy(s, a) {
                        if let Some(&(tgt, _)) =
                            assignment.shares[a as usize].first()
                        {
                            if let Some(dt) = pool.start_fetch(
                                tgt,
                                a,
                                &trace.adapters,
                                &cfg.cluster.server.gpu,
                            ) {
                                q.push(
                                    now + dt,
                                    SimEvent::FetchDone(tgt, a),
                                );
                            }
                        }
                    }
                } else {
                    servers[s].release_waiting(a);
                    if let Some(dt) = servers[s].start_iteration(now) {
                        q.push(now + dt, SimEvent::IterDone(s));
                    }
                }
                // a migration landing anywhere may complete a drain
                for s2 in 0..max_n {
                    if state[s2] == SrvState::Draining {
                        try_retire(
                            s2,
                            now,
                            &mut state,
                            &servers,
                            &pool,
                            &mut report.fleet,
                        );
                    }
                }
            }
            SimEvent::Rebalance => {
                demand.roll_window();
                let projected = demand.projected_tps();
                let active_ids = collect_active(&state);
                let next = replace_assignment(
                    cfg.system,
                    &mut loraserve_placer,
                    &mut *static_placer,
                    &trace.adapters,
                    &active_ids,
                    &projected,
                    &oppoints,
                    Some(&assignment),
                );
                report.migration_bytes +=
                    next.migration_bytes(&assignment, &trace.adapters);
                router.update_table(RoutingTable::from_assignment(&next));
                if !replicate {
                    pool.apply_assignment(&homes_of(&next));
                }
                assignment = next;
                report.rebalances += 1;
                let next_in = if report.rebalances < 4 {
                    cfg.cluster.rebalance_period / 4.0
                } else {
                    cfg.cluster.rebalance_period
                };
                if now + next_in <= trace_end {
                    q.push(now + next_in, SimEvent::Rebalance);
                }
                debug_assert!(
                    pool.check_coverage(trace.adapters.len()).is_ok(),
                    "rebalance lost coverage"
                );
            }
            SimEvent::AutoscaleTick => {
                let (Some(acfg), Some(ctl)) =
                    (cfg.autoscale, controller.as_mut())
                else {
                    continue;
                };
                let active_ids = collect_active(&state);
                let window = (now - last_tick).max(1e-9);
                let mut busy = 0.0;
                for &s in &active_ids {
                    busy += (servers[s].busy_time - busy_snap[s]).max(0.0);
                }
                for (snap, srv) in
                    busy_snap.iter_mut().zip(servers.iter())
                {
                    *snap = srv.busy_time;
                }
                let sig = ScaleSignals {
                    busy_frac: busy
                        / (window * active_ids.len().max(1) as f64),
                    violation_rate: if win_completed > 0 {
                        win_violations as f64 / win_completed as f64
                    } else {
                        0.0
                    },
                    queue_depth: active_ids
                        .iter()
                        .map(|&s| servers[s].pending_count())
                        .sum(),
                    projected_tps: demand.total_projected_tps(),
                };
                win_completed = 0;
                win_violations = 0;
                last_tick = now;
                let cand: Vec<(ServerId, f64)> = active_ids
                    .iter()
                    .map(|&s| (s, servers[s].outstanding))
                    .collect();
                let provisioning = count_provisioning(&state);
                match ctl.decide(now, &sig, &cand, provisioning) {
                    ScaleDecision::Hold => {}
                    ScaleDecision::Up(k) => {
                        for _ in 0..k {
                            let Some(slot) = (0..max_n).find(|&s| {
                                matches!(
                                    state[s],
                                    SrvState::Cold | SrvState::Retired
                                )
                            }) else {
                                break;
                            };
                            state[slot] = SrvState::Provisioning;
                            servers[slot].draining = false;
                            report.fleet.scale_ups += 1;
                            q.push(
                                now + acfg.provision_delay,
                                SimEvent::ServerReady(slot),
                            );
                        }
                        // billing starts at provisioning (cloud
                        // instances bill from launch)
                        report.fleet.set_fleet(
                            now,
                            active_ids.len(),
                            count_billed(&state),
                        );
                    }
                    ScaleDecision::Down(victim) => {
                        // ---- drain-and-migrate protocol
                        state[victim] = SrvState::Draining;
                        servers[victim].draining = true;
                        report.fleet.scale_downs += 1;
                        let survivors = collect_active(&state);
                        // routable drops now; the victim stays billed
                        // until it retires
                        report.fleet.set_fleet(
                            now,
                            survivors.len(),
                            count_billed(&state),
                        );
                        if table_routed {
                            // swap the table: the victim stops
                            // receiving traffic *now*
                            let mut projected = demand.projected_tps();
                            if projected.is_empty() {
                                projected = uniform_demand.clone();
                            }
                            let next = replace_assignment(
                                cfg.system,
                                &mut loraserve_placer,
                                &mut *static_placer,
                                &trace.adapters,
                                &survivors,
                                &projected,
                                &oppoints,
                                Some(&assignment),
                            );
                            if !replicate {
                                report.migration_bytes += next
                                    .migration_bytes(
                                        &assignment,
                                        &trace.adapters,
                                    );
                                // the pool GC keeps any last copy on
                                // the victim alive until its
                                // migration lands
                                pool.apply_assignment(&homes_of(&next));
                            }
                            router.update_table(
                                RoutingTable::from_assignment(&next),
                            );
                            assignment = next;
                        }
                        if replicate {
                            // fully replicated: every copy exists on
                            // the survivors; just release the victim's
                            for a in 0..trace.adapters.len() as AdapterId
                            {
                                pool.drop_copy(victim, a);
                            }
                        } else {
                            // RDMA-migrate the victim's last copies to
                            // their newly assigned homes
                            for a in pool.evacuations(victim) {
                                let Some(&(tgt, _)) =
                                    assignment.shares[a as usize].first()
                                else {
                                    continue;
                                };
                                if let Some(dt) = pool.start_fetch(
                                    tgt,
                                    a,
                                    &trace.adapters,
                                    &cfg.cluster.server.gpu,
                                ) {
                                    q.push(
                                        now + dt,
                                        SimEvent::FetchDone(tgt, a),
                                    );
                                }
                            }
                        }
                        // re-route not-yet-running work through the
                        // swapped table (active decodes finish here)
                        let pending = servers[victim].extract_pending();
                        for sreq in pending {
                            for (s, srv) in servers.iter().enumerate() {
                                outstanding_buf[s] = if state[s]
                                    == SrvState::Active
                                {
                                    match cfg.system {
                                        SystemKind::Toppings => {
                                            srv.pending_count() as f64
                                        }
                                        _ => srv.outstanding,
                                    }
                                } else {
                                    f64::INFINITY
                                };
                            }
                            let target = router.route(
                                sreq.req.adapter,
                                &outstanding_buf,
                                &mut rng,
                            );
                            deliver(
                                target,
                                sreq,
                                now,
                                &mut servers,
                                &mut pool,
                                &mut q,
                                &trace.adapters,
                                &cfg.cluster.server.gpu,
                            );
                        }
                        q.push(now, SimEvent::DrainCheck(victim));
                        debug_assert!(
                            pool.check_coverage(trace.adapters.len())
                                .is_ok(),
                            "drain lost coverage"
                        );
                    }
                }
                if now + acfg.decision_period <= trace_end {
                    q.push(
                        now + acfg.decision_period,
                        SimEvent::AutoscaleTick,
                    );
                }
            }
            SimEvent::ServerReady(s) => {
                if state[s] != SrvState::Provisioning {
                    continue; // stale (slot repurposed)
                }
                state[s] = SrvState::Active;
                let active_ids = collect_active(&state);
                report.fleet.set_fleet(
                    now,
                    active_ids.len(),
                    count_billed(&state),
                );
                if replicate {
                    report.migration_bytes +=
                        pool.replicate_all_to(s, &trace.adapters);
                }
                if table_routed {
                    let mut projected = demand.projected_tps();
                    if projected.is_empty() {
                        projected = uniform_demand.clone();
                    }
                    let next = replace_assignment(
                        cfg.system,
                        &mut loraserve_placer,
                        &mut *static_placer,
                        &trace.adapters,
                        &active_ids,
                        &projected,
                        &oppoints,
                        Some(&assignment),
                    );
                    if !replicate {
                        report.migration_bytes += next
                            .migration_bytes(&assignment, &trace.adapters);
                        pool.apply_assignment(&homes_of(&next));
                    }
                    router.update_table(RoutingTable::from_assignment(
                        &next,
                    ));
                    assignment = next;
                }
                debug_assert!(
                    pool.check_coverage(trace.adapters.len()).is_ok(),
                    "scale-up lost coverage"
                );
            }
            SimEvent::DrainCheck(s) => {
                try_retire(
                    s,
                    now,
                    &mut state,
                    &servers,
                    &pool,
                    &mut report.fleet,
                );
            }
        }
    }

    debug_assert!(
        pool.check_coverage(trace.adapters.len()).is_ok(),
        "pool lost coverage"
    );
    report.fleet.finish(report.makespan.max(trace_end));
    for (s, srv) in servers.iter().enumerate() {
        report.per_server_busy.push(srv.busy_time);
        report.per_server_max_adapters.push(pool.max_resident(s));
        report.timeouts += srv.timeouts;
        report.gpu_loads += srv.gpu_cache.loads;
        report.gpu_load_bytes += srv.gpu_cache.load_bytes;
        report.per_server_highrank_frac.push(
            srv.iters_highrank as f64 / srv.iters.max(1) as f64,
        );
    }
    report.fetches = pool.total_fetches;
    report.fetch_bytes = pool.total_fetch_bytes;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::trace::azure::{self, AzureConfig};
    use crate::trace::LengthModel;

    fn small_trace(rps: f64, seed: u64) -> Trace {
        azure::generate(&AzureConfig {
            rps,
            duration: 120.0,
            seed,
            lengths: LengthModel::fixed(512, 16),
            ..Default::default()
        })
    }

    fn cluster() -> ClusterConfig {
        ClusterConfig {
            n_servers: 4,
            rebalance_period: 20.0,
            ..Default::default()
        }
    }

    #[test]
    fn all_systems_complete_light_load() {
        let trace = small_trace(4.0, 1);
        for system in SystemKind::all() {
            let mut rep = run(
                &trace,
                &SimConfig::new(cluster(), system),
            );
            let total = rep.completed + rep.timeouts;
            assert_eq!(
                total,
                trace.requests.len() as u64,
                "{}: {total} != {}",
                system.label(),
                trace.requests.len()
            );
            assert!(
                rep.completion_rate() > 0.99,
                "{}: completion {}",
                system.label(),
                rep.completion_rate()
            );
            assert!(rep.ttft_p95() > 0.0);
            assert!(rep.ttft.len() as u64 == rep.completed);
            // fixed fleet: e2e measured alongside ttft, fleet constant
            assert_eq!(rep.e2e.len(), rep.ttft.len());
            assert_eq!(rep.fleet.peak_servers(), 4);
            assert_eq!(rep.fleet.min_servers(), 4);
            assert!(rep.fleet.gpu_seconds > 0.0);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let trace = small_trace(6.0, 2);
        let cfg = SimConfig::new(cluster(), SystemKind::LoraServe);
        let mut r1 = run(&trace, &cfg);
        let mut r2 = run(&trace, &cfg);
        assert_eq!(r1.completed, r2.completed);
        assert_eq!(r1.ttft_p95(), r2.ttft_p95());
        assert_eq!(r1.migration_bytes, r2.migration_bytes);
    }

    #[test]
    fn overload_causes_timeouts_or_queueing() {
        let mut c = cluster();
        c.n_servers = 1;
        c.slo.timeout = 30.0;
        let trace = small_trace(50.0, 3); // way past one server
        let mut rep =
            run(&trace, &SimConfig::new(c, SystemKind::SLoraRandom));
        let p95 = rep.ttft_p95();
        let timeouts = rep.timeouts;
        assert!(
            timeouts > 0 || p95 > 10.0,
            "timeouts={timeouts} p95={p95}"
        );
    }

    #[test]
    fn loraserve_rebalances_and_migrates() {
        let trace = small_trace(8.0, 4);
        let rep = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::LoraServe),
        );
        assert!(rep.rebalances >= 4, "rebalances={}", rep.rebalances);
    }

    #[test]
    fn toppings_replicates_everything() {
        let trace = small_trace(4.0, 5);
        let rep = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::Toppings),
        );
        for s in 0..4 {
            assert_eq!(
                rep.per_server_max_adapters[s],
                trace.adapters.len()
            );
        }
        assert_eq!(rep.fetches, 0);
    }

    #[test]
    fn loraserve_stores_fewer_adapters_than_toppings() {
        let trace = small_trace(8.0, 6);
        let ls = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::LoraServe),
        );
        let tp = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::Toppings),
        );
        let max_ls: usize =
            *ls.per_server_max_adapters.iter().max().unwrap();
        let max_tp: usize =
            *tp.per_server_max_adapters.iter().max().unwrap();
        assert!(
            max_ls < max_tp,
            "loraserve {max_ls} !< toppings {max_tp}"
        );
    }

    #[test]
    fn busy_time_conservation() {
        // server busy time can never exceed the makespan
        let trace = small_trace(6.0, 7);
        let rep = run(
            &trace,
            &SimConfig::new(cluster(), SystemKind::LoraServe),
        );
        for (s, &busy) in rep.per_server_busy.iter().enumerate() {
            assert!(
                busy <= rep.makespan * 1.001 + 1.0,
                "server {s} busy {busy} > makespan {}",
                rep.makespan
            );
        }
    }

    #[test]
    fn elastic_run_grows_and_accounts_gpu_seconds() {
        let trace = small_trace(25.0, 8);
        let mut c = cluster();
        c.n_servers = 1;
        let acfg = AutoscaleConfig {
            min_servers: 1,
            max_servers: 5,
            decision_period: 10.0,
            cooldown: 15.0,
            provision_delay: 5.0,
            ..Default::default()
        };
        let rep = run(
            &trace,
            &SimConfig::new(c, SystemKind::LoraServe)
                .with_autoscale(acfg),
        );
        assert_eq!(
            rep.completed + rep.timeouts,
            trace.requests.len() as u64
        );
        assert!(rep.fleet.scale_ups >= 1, "no scale-up under burst");
        assert!(rep.fleet.peak_servers() > 1);
        assert!(rep.fleet.peak_servers() <= 5);
        // GPU-seconds bounded by the peak fleet running the whole time
        let bound = (5 * 4) as f64 * rep.fleet.duration() + 1e-6;
        assert!(rep.fleet.gpu_seconds <= bound);
        assert!(rep.fleet.gpu_seconds > 0.0);
    }
}
