//! The scheduler's SLO feedback layer: per-server rolling TTFT/TBT
//! headroom tracking against configurable targets
//! ([`SloFeedbackConfig`]).
//!
//! The tracker closes the loop the open-loop (PR 3) scheduler left
//! open: instead of reacting only to batch *shape* (rank classes,
//! queue depths), the policies can react to per-request latency
//! *pressure* — the CaraServe/S-LoRA argument that admission control
//! must watch the SLO, not just the batch. Three consumers:
//!
//! * `SimServer::start_iteration` asks [`SloTracker::ttft_pressure`]
//!   whether a queued prefill's projected headroom justifies
//!   preempting the decode round in flight between sub-batch steps;
//! * `ClassSubBatchDecode` asks [`SloTracker::tbt_headroom`] which
//!   rank class is suffering most, and serves it first (the SLO-aware
//!   rotor), falling back to the cyclic rotor on ties;
//! * `RankBucketed` receives [`SloTracker::ttft_headroom_frac`]
//!   through `BatchPolicy::set_slo_pressure` and scales its
//!   bounded-wait starvation guard accordingly (adaptive
//!   `max_wait_iters`).
//!
//! A disabled tracker is simply absent (`SimServer::slo == None`), so
//! the open-loop scheduler stays bit-identical to PR 3.

use crate::config::SloFeedbackConfig;
use crate::workload::AdapterId;
use std::collections::BTreeMap;

/// Rolling-window size of the per-class inter-token-gap estimate.
const TBT_WINDOW: usize = 32;

/// Per-rank-class decode cadence: a ring of recent inter-token gaps
/// plus the time of the class's last decode step (each member of a
/// step produces exactly one token, so step-to-step gaps *are* the
/// class's observed TBT).
#[derive(Debug, Clone, Default)]
struct ClassCadence {
    gaps: Vec<f64>,
    next: usize,
    last_step_at: Option<f64>,
}

/// Rolling TTFT/TBT headroom against the feedback targets. Owned per
/// server (cadence is a per-server signal); purely observational —
/// recording never perturbs simulated time.
#[derive(Debug, Clone)]
pub struct SloTracker {
    pub cfg: SloFeedbackConfig,
    tbt: BTreeMap<u32, ClassCadence>,
    /// Per-tenant cadence inside each rank class
    /// (`rank → adapter → cadence`). The class-level ring alone lets
    /// one noisy tenant hide a starved co-class tenant — the class
    /// keeps stepping (healthy cadence, fresh staleness anchor) while
    /// a particular adapter's own gaps blow the target. Fed by the
    /// member-aware observe/record calls; [`SloTracker::tbt_headroom`]
    /// takes the worst per-adapter value when a class is multi-tenant.
    tbt_adapter: BTreeMap<u32, BTreeMap<AdapterId, ClassCadence>>,
    /// Latest simulated time the tracker has seen (staleness anchor
    /// for classes the rotor has been skipping).
    now: f64,
}

/// Push one inter-step gap into a cadence ring and advance its anchor
/// (shared by the class-level and per-adapter rings).
fn push_gap(e: &mut ClassCadence, now: f64) {
    if let Some(prev) = e.last_step_at {
        let gap = now - prev;
        if gap >= 0.0 {
            if e.gaps.len() < TBT_WINDOW {
                e.gaps.push(gap);
            } else {
                e.gaps[e.next] = gap;
            }
            e.next = (e.next + 1) % TBT_WINDOW;
        }
    }
    e.last_step_at = Some(now);
}

impl SloTracker {
    pub fn new(cfg: SloFeedbackConfig) -> Self {
        SloTracker {
            cfg,
            tbt: BTreeMap::new(),
            tbt_adapter: BTreeMap::new(),
            now: 0.0,
        }
    }

    /// Advance the tracker's clock (monotone).
    pub fn tick(&mut self, now: f64) {
        if now > self.now {
            self.now = now;
        }
    }

    /// Sync the tracker with the classes currently in the active set
    /// (called once per decode composition):
    ///
    /// * **Anchor** first-sighted classes at `now` — a class that
    ///   joins the active set and is then never served would otherwise
    ///   have no `last_step_at`, report full headroom forever, and be
    ///   starved by the worst-first rotor. Anchored, its staleness
    ///   grows from admission until it is worst and gets served.
    /// * **Retire** departed classes — a class whose members all
    ///   completed must not keep its cadence history; if it later
    ///   re-enters, it restarts fresh instead of importing its idle
    ///   gap as a giant "observed TBT" that would hog the rotor.
    pub fn observe_active(&mut self, now: f64, classes: &[u32]) {
        self.tick(now);
        self.tbt.retain(|rank, _| classes.contains(rank));
        for &rank in classes {
            let e = self.tbt.entry(rank).or_default();
            if e.last_step_at.is_none() {
                e.last_step_at = Some(now);
            }
        }
    }

    /// Member-aware [`SloTracker::observe_active`]: anchors/retires
    /// the class rings from the distinct ranks present *and* keeps the
    /// per-tenant rings in sync — a tenant that joins the active set
    /// and is then never stepped accrues its own staleness, and a
    /// tenant whose requests all completed loses its cadence history
    /// exactly like a departed class does.
    pub fn observe_active_members(
        &mut self,
        now: f64,
        members: &[(u32, AdapterId)],
    ) {
        let mut classes: Vec<u32> = Vec::new();
        for &(rank, _) in members {
            if !classes.contains(&rank) {
                classes.push(rank);
            }
        }
        self.observe_active(now, &classes);
        self.tbt_adapter.retain(|rank, per| {
            per.retain(|ad, _| members.contains(&(*rank, *ad)));
            !per.is_empty()
        });
        for &(rank, ad) in members {
            let e = self
                .tbt_adapter
                .entry(rank)
                .or_default()
                .entry(ad)
                .or_default();
            if e.last_step_at.is_none() {
                e.last_step_at = Some(now);
            }
        }
    }

    /// Record one decode step finishing at `now` for every rank class
    /// with a member in the step: the gap since the class's previous
    /// step is its newest inter-token-gap sample.
    pub fn record_decode_step(
        &mut self,
        now: f64,
        classes: impl IntoIterator<Item = u32>,
    ) {
        self.tick(now);
        for rank in classes {
            push_gap(self.tbt.entry(rank).or_default(), now);
        }
    }

    /// Member-aware [`SloTracker::record_decode_step`]: updates the
    /// class rings (distinct ranks, identical to the class-only call)
    /// *and* each stepped tenant's own cadence ring. `members` must be
    /// deduplicated per (rank, adapter).
    pub fn record_decode_step_members(
        &mut self,
        now: f64,
        members: &[(u32, AdapterId)],
    ) {
        let mut classes: Vec<u32> = Vec::new();
        for &(rank, _) in members {
            if !classes.contains(&rank) {
                classes.push(rank);
            }
        }
        self.record_decode_step(now, classes);
        for &(rank, ad) in members {
            push_gap(
                self.tbt_adapter
                    .entry(rank)
                    .or_default()
                    .entry(ad)
                    .or_default(),
                now,
            );
        }
    }

    /// Rolling mean inter-token gap of a class (None until the class
    /// has stepped at least twice).
    pub fn observed_tbt(&self, rank: u32) -> Option<f64> {
        let e = self.tbt.get(&rank)?;
        if e.gaps.is_empty() {
            return None;
        }
        Some(e.gaps.iter().sum::<f64>() / e.gaps.len() as f64)
    }

    /// Headroom of one cadence ring: target minus the rolling observed
    /// gap, floored by staleness (a ring that hasn't stepped since
    /// `last_step_at` is *at least* `now − last_step_at` slow, however
    /// healthy its history looks — otherwise a skipped class/tenant
    /// would keep reporting its old, good cadence and starve).
    fn headroom_of(&self, e: &ClassCadence) -> f64 {
        let mut gap: f64 = 0.0;
        if !e.gaps.is_empty() {
            gap = e.gaps.iter().sum::<f64>() / e.gaps.len() as f64;
        }
        if let Some(last) = e.last_step_at {
            gap = gap.max(self.now - last);
        }
        if gap <= 0.0 {
            return self.cfg.tbt_target;
        }
        self.cfg.tbt_target - gap
    }

    /// TBT headroom of a rank class (see [`SloTracker::headroom_of`]
    /// for the per-ring formula). Classes with no observations report
    /// full headroom: the tracker has no evidence of pressure, so
    /// all-fresh classes tie. When the class is multi-tenant, the
    /// *worst per-adapter* headroom wins — the class-level ring
    /// averages tenants, so a noisy tenant stepping often would
    /// otherwise hide a starved co-class tenant from the rotor.
    pub fn tbt_headroom(&self, rank: u32) -> f64 {
        let class = match self.tbt.get(&rank) {
            None => return self.cfg.tbt_target,
            Some(e) => self.headroom_of(e),
        };
        match self.tbt_adapter.get(&rank) {
            Some(per) if per.len() >= 2 => per
                .values()
                .map(|e| self.headroom_of(e))
                .fold(class, f64::min),
            _ => class,
        }
    }

    /// Worst rolling TBT headroom over every tracked class (and every
    /// tenant inside multi-tenant classes) — the server-level SLO
    /// pressure signal the drift-reactive rebalance trigger consumes.
    /// `None` until at least one class has been observed.
    pub fn worst_tbt_headroom(&self) -> Option<f64> {
        let mut worst: Option<f64> = None;
        for &rank in self.tbt.keys() {
            let h = self.tbt_headroom(rank);
            worst = Some(worst.map_or(h, |w: f64| w.min(h)));
        }
        worst
    }

    /// TTFT pressure: the queue head has already waited `waited`
    /// seconds and would wait `projected` more (e.g. the in-flight
    /// decode round's remaining sub-batch steps) before its prefill
    /// could start. Pressure once the projected slack drops below
    /// `pressure_theta ×` the target.
    pub fn ttft_pressure(&self, waited: f64, projected: f64) -> bool {
        let t = self.cfg.ttft_target;
        t - waited - projected < self.cfg.pressure_theta * t
    }

    /// Remaining TTFT-headroom fraction of a request that has waited
    /// `waited` seconds, in [0, 1]: 1 = just arrived, 0 = target
    /// already blown. Drives the adaptive `RankBucketed` wait bound.
    pub fn ttft_headroom_frac(&self, waited: f64) -> f64 {
        ((self.cfg.ttft_target - waited) / self.cfg.ttft_target)
            .clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SloFeedbackConfig {
        SloFeedbackConfig {
            enabled: true,
            ttft_target: 1.0,
            tbt_target: 0.1,
            preempt_decode: true,
            pressure_theta: 0.5,
        }
    }

    #[test]
    fn tbt_headroom_tracks_cadence_and_staleness() {
        let mut t = SloTracker::new(cfg());
        // unobserved classes report full headroom — an all-fresh tie
        assert_eq!(t.tbt_headroom(8), 0.1);
        assert_eq!(t.tbt_headroom(128), 0.1);
        // class 8 steps every 20 ms, class 128 every 80 ms
        for i in 0..10 {
            t.record_decode_step(0.02 * (i + 1) as f64, [8u32]);
        }
        for i in 0..3 {
            t.record_decode_step(0.08 * (i + 1) as f64, [128u32]);
        }
        t.tick(0.24);
        assert!((t.observed_tbt(8).unwrap() - 0.02).abs() < 1e-12);
        assert!((t.observed_tbt(128).unwrap() - 0.08).abs() < 1e-12);
        // the slower class has the worse headroom
        assert!(t.tbt_headroom(128) < t.tbt_headroom(8));
        // staleness floor: class 8 skipped until t=0.5 looks 0.3 slow
        t.tick(0.5);
        let h = t.tbt_headroom(8);
        assert!((h - (0.1 - 0.3)).abs() < 1e-12, "{h}");
    }

    /// A class that joins the active set but never gets served must
    /// not hide behind "no observations = full headroom": once
    /// anchored by `observe_active`, its staleness grows until it is
    /// the worst class — the rotor cannot starve it. And a class that
    /// drains out of the active set loses its cadence history, so a
    /// later re-entry starts fresh instead of importing its idle gap.
    #[test]
    fn observe_active_anchors_and_retires_classes() {
        let mut t = SloTracker::new(cfg());
        // class 8 decodes steadily every 20 ms
        for i in 0..6 {
            t.record_decode_step(0.02 * (i + 1) as f64, [8u32]);
        }
        // class 64 becomes active at t=0.12 and is never served
        t.observe_active(0.12, &[8, 64]);
        // immediately after anchoring the fresh class still looks
        // healthy (no evidence either way)
        assert_eq!(t.tbt_headroom(64), 0.1);
        // but by t=0.4 its staleness (0.28) beats class 8's (mean
        // 0.02, staleness 0.28 too — both stale here, so step class 8
        // once more to refresh it)
        t.record_decode_step(0.4, [8u32]);
        t.observe_active(0.4, &[8, 64]);
        assert!(
            t.tbt_headroom(64) < t.tbt_headroom(8),
            "unserved class must decay below a freshly served one: \
             64 -> {}, 8 -> {}",
            t.tbt_headroom(64),
            t.tbt_headroom(8)
        );
        // re-observing does not reset an existing anchor
        assert!(t.tbt_headroom(64) < 0.1 - 0.27);
        // class 8 drains out of the active set: its history retires,
        // and a re-entry at t=1.0 restarts at full headroom instead of
        // importing the 0.6 s idle gap as observed TBT
        t.observe_active(0.7, &[64]);
        t.observe_active(1.0, &[8, 64]);
        assert_eq!(t.tbt_headroom(8), 0.1);
        t.record_decode_step(1.02, [8u32]);
        let g = t.observed_tbt(8).unwrap();
        assert!(
            (g - 0.02).abs() < 1e-12,
            "re-entry gap must be anchor→step, not the 0.6 s idle \
             gap: {g}"
        );
    }

    /// The multi-tenant fix: a class whose ring keeps stepping (one
    /// busy tenant) must not hide a co-class tenant that never steps —
    /// the worst per-adapter headroom wins. Single-tenant classes keep
    /// reporting exactly the class-level value.
    #[test]
    fn per_adapter_headroom_catches_starved_co_tenant() {
        let mut t = SloTracker::new(cfg());
        // tenants 1 and 2 share rank class 8; only tenant 1 steps
        t.observe_active_members(0.0, &[(8, 1), (8, 2)]);
        for i in 0..10 {
            t.record_decode_step_members(
                0.02 * (i + 1) as f64,
                &[(8, 1)],
            );
        }
        // class-level view: healthy 20 ms cadence, fresh anchor
        let class_only = t.headroom_of(t.tbt.get(&8).unwrap());
        assert!(class_only > 0.0, "{class_only}");
        // tenant 2 has been starved for 0.2 s: the class headroom must
        // reflect the worst tenant, not the class average
        let h = t.tbt_headroom(8);
        assert!(
            (h - (0.1 - 0.2)).abs() < 1e-12,
            "want tenant 2's staleness (-0.1), got {h}"
        );
        assert_eq!(t.worst_tbt_headroom(), Some(h));
        // once tenant 2 drains out of the active set, the class is
        // single-tenant again and reports the class-level value
        t.observe_active_members(0.2, &[(8, 1)]);
        assert_eq!(t.tbt_headroom(8), class_only);
        // empty tracker has no worst signal
        assert_eq!(SloTracker::new(cfg()).worst_tbt_headroom(), None);
    }

    /// Member-aware recording feeds the class rings exactly like the
    /// class-only call (same distinct ranks), so single-tenant
    /// behavior — and the rotor driven by it — is unchanged.
    #[test]
    fn member_calls_match_class_calls_for_single_tenants() {
        let mut a = SloTracker::new(cfg());
        let mut b = SloTracker::new(cfg());
        for i in 0..8 {
            let now = 0.03 * (i + 1) as f64;
            a.record_decode_step(now, [8u32, 64]);
            b.record_decode_step_members(
                now,
                &[(8, 1), (64, 2)],
            );
        }
        for rank in [8u32, 64] {
            assert_eq!(
                a.tbt_headroom(rank).to_bits(),
                b.tbt_headroom(rank).to_bits(),
                "rank {rank}"
            );
        }
    }

    #[test]
    fn rolling_window_bounds_memory() {
        let mut t = SloTracker::new(cfg());
        for i in 0..(3 * TBT_WINDOW) {
            t.record_decode_step(0.01 * (i + 1) as f64, [8u32]);
        }
        // still a finite mean of the last window, not the full history
        assert!((t.observed_tbt(8).unwrap() - 0.01).abs() < 1e-12);
    }

    #[test]
    fn ttft_pressure_and_headroom_frac() {
        let t = SloTracker::new(cfg());
        // target 1.0, theta 0.5: pressure once waited+projected > 0.5
        assert!(!t.ttft_pressure(0.1, 0.1));
        assert!(t.ttft_pressure(0.4, 0.2));
        assert!(t.ttft_pressure(0.6, 0.0));
        assert_eq!(t.ttft_headroom_frac(0.0), 1.0);
        assert!((t.ttft_headroom_frac(0.25) - 0.75).abs() < 1e-12);
        assert_eq!(t.ttft_headroom_frac(2.0), 0.0);
    }

    #[test]
    fn clock_is_monotone() {
        let mut t = SloTracker::new(cfg());
        t.tick(5.0);
        t.tick(1.0); // ignored
        t.record_decode_step(5.0, [8u32]);
        t.record_decode_step(5.5, [8u32]);
        assert!((t.observed_tbt(8).unwrap() - 0.5).abs() < 1e-12);
    }
}
