//! Simulated LLM inference server: admission queue + iteration-level
//! continuous batching with prefill priority (the vLLM/S-LoRA-style
//! engine the paper's cluster is made of).
//!
//! The rank-interference mechanism is first-class here: every
//! iteration's service time is computed with the **maximum adapter rank
//! present in that batch** (`costmodel::prefill_time`/`decode_time`),
//! exactly the pad-to-max-rank behaviour of the BGMV/MBGMV kernels.
//!
//! Both phases of generation are policy-composed via [`BatchPolicy`]:
//!
//! * **Prefill admission** (`admit`): [`Fifo`] reproduces the classic
//!   arrival-order admission bit for bit, while [`RankBucketed`] and
//!   [`RankCap`] are rank-aware compositions (the CaraServe-style
//!   scheduler half of the design space) that trade a little queueing
//!   for rank-homogeneous batches.
//! * **Decode composition** (`compose_decode`): the active set is
//!   decoded as a [`DecodePlan`] — a round of one or more sub-batch
//!   steps, each with its own service time and `busy_until`. The
//!   default (unified) plan is one whole-set step at the set's max
//!   rank, the pre-refactor behavior bit for bit; the
//!   [`RankPartitionedDecode`] and [`ClassSubBatchDecode`] decorators
//!   split the round into per-rank-class steps (SGMV-style grouped
//!   kernels), so a rank-8 tenant stops paying a co-resident rank-128
//!   tenant's operating point for its whole decode tail.
//!
//! On top of both sits the **SLO feedback layer**
//! ([`super::slo::SloTracker`], optional per server): decode rounds
//! become preemptible between sub-batch steps under TTFT pressure,
//! `ClassSubBatchDecode`'s rotor serves the rank class with the worst
//! rolling TBT headroom first, and `RankBucketed`'s bounded-wait guard
//! adapts to the queue head's remaining TTFT headroom. Servers without
//! a tracker run the open-loop scheduler unchanged.

use super::slo::SloTracker;
use crate::config::{
    BatchPolicyKind, ClassSelect, DecodePolicyKind, SloFeedbackConfig,
};
use crate::costmodel::calib::HBM_PAGE_BYTES;
use crate::costmodel::CostModel;
use crate::obs::{self, Obs};
use crate::pool::hbm::HbmPool;
use crate::workload::{AdapterId, Request};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// A request resident on a server.
#[derive(Debug, Clone, Copy)]
pub struct SimReq {
    pub req: Request,
    /// Engine-assigned request uid (its index in the trace) — the
    /// stable identity observability keys on (`Request::id` can repeat
    /// across traces). Behavior-neutral: nothing on the timing path
    /// reads it.
    pub uid: u32,
    pub rank: u32,
    /// Adapter weight bytes (GPU paging cost on a cache miss).
    pub adapter_bytes: u64,
    /// Routed-time service estimate (for Toppings' outstanding-work).
    pub est: f64,
    /// Served by remote attach: the adapter's weights stay in a peer
    /// server's HBM and every iteration touching this request streams
    /// its slices over GPUDirect RDMA
    /// (`CostModel::remote_attach_penalty`) instead of paging a local
    /// copy — the routing moved without the bytes. Set by the engine
    /// on delivery; always false outside remote-attach pools.
    pub remote: bool,
}

/// One decode sub-batch: the active sequences (by their per-server
/// `ActiveReq::seq` id) that step together, paying their group's
/// maximum rank. Every sub-batch of a multi-group round pays the
/// per-sub-batch kernel-launch overhead (`CostModel::decode_class`);
/// single-group rounds are billed through the legacy unified formula.
#[derive(Debug, Clone)]
pub struct DecodeGroup {
    pub seqs: Vec<u64>,
}

/// A decode round composed by policy: one or more disjoint sub-batch
/// steps over the active set. The round is atomic — all its steps run
/// (each with its own service time and `busy_until`) before the next
/// prefill admission check.
#[derive(Debug, Clone, Default)]
pub struct DecodePlan {
    pub groups: Vec<DecodeGroup>,
}

impl DecodePlan {
    /// The unified (pre-refactor) plan: one whole-set step, no launch
    /// overhead.
    pub fn unified(active: &[ActiveReq]) -> DecodePlan {
        DecodePlan::unified_pooled(active, &mut Vec::new())
    }

    /// [`unified`](DecodePlan::unified), drawing the membership vector
    /// from `pool` so the hot path never allocates.
    pub fn unified_pooled(
        active: &[ActiveReq],
        pool: &mut Vec<Vec<u64>>,
    ) -> DecodePlan {
        if active.is_empty() {
            return DecodePlan::default();
        }
        let mut seqs = pool.pop().unwrap_or_default();
        seqs.clear();
        seqs.extend(active.iter().map(|a| a.seq));
        DecodePlan {
            groups: vec![DecodeGroup { seqs }],
        }
    }

    pub fn total_members(&self) -> usize {
        self.groups.iter().map(|g| g.seqs.len()).sum()
    }
}

/// Group the active set by exact rank class, ascending rank. The
/// building block of the rank-aware decode compositions. Class
/// vectors come from `pool` (recycled step-membership buffers).
fn classes_of(
    active: &[ActiveReq],
    pool: &mut Vec<Vec<u64>>,
) -> BTreeMap<u32, Vec<u64>> {
    let mut classes: BTreeMap<u32, Vec<u64>> = BTreeMap::new();
    for a in active {
        classes
            .entry(a.sreq.rank)
            .or_insert_with(|| {
                let mut v = pool.pop().unwrap_or_default();
                v.clear();
                v
            })
            .push(a.seq);
    }
    classes
}

/// Batch composition policy for *both* phases of generation.
///
/// **Prefill admission** (`admit_into`): given the ready queue (FIFO
/// by arrival), decide which requests enter this iteration's prefill
/// batch, appending them to `out` (empty on entry — the server hands
/// each policy a recycled buffer, so steady-state admission allocates
/// nothing). Implementations remove admitted requests from `queue`
/// (preserving the relative order of everything left behind) and must
/// respect `slots` (free decode slots) and `max_tokens` (iteration
/// token budget; the first admitted request is exempt so oversized
/// prompts still run alone).
///
/// **Decode composition** (`compose_decode_pooled`): given the active
/// set, produce the [`DecodePlan`] for the next decode round. Groups
/// must be disjoint, non-empty, and cover at most `slots` sequences in
/// total. Membership vectors are drawn from `pool` (the server
/// recycles them when steps finish), so steady-state composition
/// allocates nothing either. The default is the unified whole-set
/// plan (the pre-refactor decode, bit for bit). `slo` is the server's
/// SLO feedback tracker (None = open loop); SLO-aware compositions may
/// consult its rolling per-class TBT headroom but must behave
/// identically to their open-loop selves when it is absent.
///
/// `Send` because servers (each owning its policy) cross the sharded
/// engine's scoped-thread boundary between epoch barriers.
pub trait BatchPolicy: std::fmt::Debug + Send {
    fn name(&self) -> &'static str;

    fn admit_into(
        &mut self,
        queue: &mut VecDeque<SimReq>,
        slots: usize,
        max_tokens: u64,
        out: &mut Vec<SimReq>,
    );

    /// Allocating convenience wrapper around
    /// [`admit_into`](BatchPolicy::admit_into) for tests and one-off
    /// callers; the simulation hot path passes a recycled buffer
    /// instead.
    fn admit(
        &mut self,
        queue: &mut VecDeque<SimReq>,
        slots: usize,
        max_tokens: u64,
    ) -> Vec<SimReq> {
        let mut out = Vec::new();
        self.admit_into(queue, slots, max_tokens, &mut out);
        out
    }

    fn compose_decode_pooled(
        &mut self,
        active: &[ActiveReq],
        slots: usize,
        _cm: &CostModel,
        _slo: Option<&SloTracker>,
        pool: &mut Vec<Vec<u64>>,
    ) -> DecodePlan {
        let _ = slots; // the whole-set plan can never exceed slots
        DecodePlan::unified_pooled(active, pool)
    }

    /// Allocating convenience wrapper around
    /// [`compose_decode_pooled`](BatchPolicy::compose_decode_pooled)
    /// for tests and one-off callers.
    fn compose_decode(
        &mut self,
        active: &[ActiveReq],
        slots: usize,
        cm: &CostModel,
        slo: Option<&SloTracker>,
    ) -> DecodePlan {
        self.compose_decode_pooled(active, slots, cm, slo, &mut Vec::new())
    }

    /// SLO feedback hook: before each admission the server reports the
    /// queue head's remaining TTFT-headroom fraction (1 = fresh, 0 =
    /// target blown), letting stateful policies adapt — RankBucketed
    /// shrinks its bounded-wait starvation guard as headroom drains
    /// (adaptive `max_wait_iters`). Never called on open-loop servers,
    /// so ignoring it (the default) preserves open-loop behavior.
    fn set_slo_pressure(&mut self, _headroom_frac: f64) {}
}

/// Build the policy instance a server owns (policies carry per-server
/// state such as starvation counters and fairness rotors, so each
/// server gets its own). The prefill policy comes from `batch`; the
/// decode policy wraps it as a decorator (`decode`), so one object
/// composes both phases. `oppoints` (rank → tokens/s under SLO) scores
/// cost-weighted class selection — pass the same map the rest of the
/// system plans with (the engine passes its trace-derived, possibly
/// empirical/flattened operating points, so selection and
/// placement/planning never disagree).
pub fn build_policy(
    batch: BatchPolicyKind,
    decode: DecodePolicyKind,
    oppoints: &BTreeMap<u32, f64>,
) -> Box<dyn BatchPolicy> {
    let base: Box<dyn BatchPolicy> = match batch {
        BatchPolicyKind::Fifo => Box::new(Fifo),
        BatchPolicyKind::RankBucketed {
            max_wait_iters,
            select,
        } => match select {
            ClassSelect::LargestQueue => {
                Box::new(RankBucketed::new(max_wait_iters))
            }
            ClassSelect::CostWeighted => {
                Box::new(RankBucketed::cost_weighted(
                    max_wait_iters,
                    oppoints.clone(),
                ))
            }
        },
        BatchPolicyKind::RankCap { factor } => {
            Box::new(RankCap::new(factor))
        }
    };
    match decode {
        DecodePolicyKind::Unified => base,
        DecodePolicyKind::RankPartitioned => {
            Box::new(RankPartitionedDecode::new(base))
        }
        DecodePolicyKind::ClassSubBatch { max_groups } => Box::new(
            ClassSubBatchDecode::new(base, max_groups.max(1) as usize),
        ),
        DecodePolicyKind::ClassSubBatchAuto => {
            Box::new(ClassSubBatchDecode::adaptive(base))
        }
    }
}

/// Strict arrival order — the S-LoRA/vLLM admission loop, unchanged:
/// take from the front while slots remain and the token budget holds.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fifo;

impl BatchPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn admit_into(
        &mut self,
        queue: &mut VecDeque<SimReq>,
        slots: usize,
        max_tokens: u64,
        out: &mut Vec<SimReq>,
    ) {
        let start = out.len();
        let mut tokens = 0u64;
        while let Some(head) = queue.front() {
            if out.len() - start >= slots {
                break;
            }
            let t = head.req.prompt_len as u64;
            if out.len() > start && tokens + t > max_tokens {
                break;
            }
            tokens += t;
            out.push(queue.pop_front().unwrap());
        }
    }
}

/// One rank class per prefill iteration: the chosen class's requests
/// are admitted in arrival order; every other class waits. By default
/// the class with the most queued requests wins (ties go to the class
/// whose oldest request arrived first); with cost-weighted selection
/// ([`RankBucketed::cost_weighted`]) the class with the most queued
/// *work* — queued prompt tokens ÷ the class's operating point — wins
/// instead, so a short queue of expensive high-rank prompts can
/// outrank a long queue of cheap ones. Either way, whenever the
/// queue's head request has been passed over `max_wait_iters`
/// consecutive prefill iterations, its class is forced — the
/// bounded-wait starvation guard. Because admission scans from the
/// front, a forced class always admits the head, so no request waits
/// at the head for more than `max_wait_iters` admitting iterations.
#[derive(Debug, Clone)]
pub struct RankBucketed {
    pub max_wait_iters: u32,
    /// Consecutive admitting iterations the current head request has
    /// been passed over.
    waited: u32,
    /// Last reported TTFT-headroom fraction of the queue head (SLO
    /// feedback; stays 1.0 — the open-loop constant bound — on
    /// servers without a tracker).
    pressure: f64,
    /// Cost-weighted class selection: rank → operating point (tokens/s
    /// under SLO). Empty = largest-queued-class selection (the
    /// original behavior). Ranks missing from the map (the engine
    /// keys it by the trace's ranks, so normally none) score with the
    /// map's minimum operating point — unknown means assume expensive,
    /// never a runaway 1.0-denominator score.
    oppoints: BTreeMap<u32, f64>,
    /// Reused drain buffer: admission swaps the queue's storage out,
    /// then refills it with everything not admitted — steady-state
    /// both deques keep their capacity and admission allocates
    /// nothing.
    scratch: VecDeque<SimReq>,
}

impl RankBucketed {
    pub fn new(max_wait_iters: u32) -> Self {
        RankBucketed {
            max_wait_iters,
            waited: 0,
            pressure: 1.0,
            oppoints: BTreeMap::new(),
            scratch: VecDeque::new(),
        }
    }

    /// Cost-weighted class selection against the given per-rank
    /// operating points (`ClassSelect::CostWeighted`).
    pub fn cost_weighted(
        max_wait_iters: u32,
        oppoints: BTreeMap<u32, f64>,
    ) -> Self {
        RankBucketed {
            max_wait_iters,
            waited: 0,
            pressure: 1.0,
            oppoints,
            scratch: VecDeque::new(),
        }
    }

    /// Effective bounded-wait guard: the configured `max_wait_iters`
    /// scaled by the queue head's remaining TTFT-headroom fraction —
    /// the adaptive `max_wait_iters` of the SLO feedback layer. With
    /// full headroom (or no feedback: `pressure` stays 1.0) the bound
    /// is exactly the configured constant; as the head's headroom
    /// drains the bound shrinks toward 0, forcing the head class
    /// through before its TTFT target blows.
    fn effective_wait_bound(&self) -> u32 {
        (self.max_wait_iters as f64 * self.pressure).floor() as u32
    }
}

impl BatchPolicy for RankBucketed {
    fn name(&self) -> &'static str {
        "rank-bucketed"
    }

    fn set_slo_pressure(&mut self, headroom_frac: f64) {
        self.pressure = headroom_frac.clamp(0.0, 1.0);
    }

    fn admit_into(
        &mut self,
        queue: &mut VecDeque<SimReq>,
        slots: usize,
        max_tokens: u64,
        out: &mut Vec<SimReq>,
    ) {
        if queue.is_empty() || slots == 0 {
            return;
        }
        let front_rank = queue.front().unwrap().rank;
        let chosen = if self.waited >= self.effective_wait_bound() {
            front_rank
        } else {
            // highest-scoring class; ties to the oldest head. The
            // score is the queued request count (largest-queue) or
            // queued tokens ÷ operating point (cost-weighted).
            let mut stats: BTreeMap<u32, (usize, usize, u64)> =
                Default::default();
            for (i, r) in queue.iter().enumerate() {
                let e = stats.entry(r.rank).or_insert((0, i, 0));
                e.0 += 1;
                e.2 += r.req.prompt_len as u64;
            }
            let mut best = (f64::NEG_INFINITY, usize::MAX, 0u32);
            for (&rank, &(count, first, tokens)) in &stats {
                let score = if self.oppoints.is_empty() {
                    count as f64
                } else {
                    let op = self
                        .oppoints
                        .get(&rank)
                        .copied()
                        .unwrap_or_else(|| {
                            // unknown rank: assume the most expensive
                            // class we know about
                            self.oppoints
                                .values()
                                .copied()
                                .fold(f64::INFINITY, f64::min)
                        })
                        .max(1e-9);
                    tokens as f64 / op
                };
                if score > best.0 || (score == best.0 && first < best.1) {
                    best = (score, first, rank);
                }
            }
            best.2
        };
        let start = out.len();
        let mut tokens = 0u64;
        debug_assert!(self.scratch.is_empty());
        std::mem::swap(queue, &mut self.scratch);
        let mut stop = false;
        for r in self.scratch.drain(..) {
            if stop || out.len() - start >= slots || r.rank != chosen {
                queue.push_back(r);
                continue;
            }
            let t = r.req.prompt_len as u64;
            if out.len() > start && tokens + t > max_tokens {
                // budget full: stop admitting to keep FIFO order
                // within the class
                queue.push_back(r);
                stop = true;
                continue;
            }
            tokens += t;
            out.push(r);
        }
        if out.len() > start {
            if chosen == front_rank {
                self.waited = 0; // the head was admitted
            } else {
                self.waited += 1;
            }
        }
    }
}

/// Arrival order with a rank ceiling: the head request is always
/// admitted and sets the ceiling at `factor ×` its rank; later
/// requests whose rank exceeds the ceiling are skipped (they stay
/// queued, in order) instead of dragging the whole batch up to their
/// rank. Nothing starves — a skipped request reaches the head in FIFO
/// time and is then admitted unconditionally.
#[derive(Debug, Clone)]
pub struct RankCap {
    pub factor: u32,
    /// Reused drain buffer (same swap-and-refill pattern as
    /// `RankBucketed`).
    scratch: VecDeque<SimReq>,
}

impl RankCap {
    pub fn new(factor: u32) -> Self {
        assert!(factor >= 1, "rank-cap factor must be >= 1");
        RankCap {
            factor,
            scratch: VecDeque::new(),
        }
    }
}

impl BatchPolicy for RankCap {
    fn name(&self) -> &'static str {
        "rank-cap"
    }

    fn admit_into(
        &mut self,
        queue: &mut VecDeque<SimReq>,
        slots: usize,
        max_tokens: u64,
        out: &mut Vec<SimReq>,
    ) {
        if queue.is_empty() || slots == 0 {
            return;
        }
        let start = out.len();
        let mut tokens = 0u64;
        let mut cap = 0u32;
        debug_assert!(self.scratch.is_empty());
        std::mem::swap(queue, &mut self.scratch);
        let mut stop = false;
        for r in self.scratch.drain(..) {
            if stop || out.len() - start >= slots {
                queue.push_back(r);
                continue;
            }
            if out.len() == start {
                cap = r.rank.saturating_mul(self.factor);
                tokens += r.req.prompt_len as u64;
                out.push(r);
                continue;
            }
            if r.rank > cap {
                queue.push_back(r); // rank-skipped; keep scanning
                continue;
            }
            let t = r.req.prompt_len as u64;
            if tokens + t > max_tokens {
                queue.push_back(r);
                stop = true;
                continue;
            }
            tokens += t;
            out.push(r);
        }
    }
}

/// Rank-partitioned decode decorator: prefill admission delegates to
/// the wrapped policy; every decode round runs one sub-batch step per
/// rank class present in the active set (ascending rank), so each
/// class pays only its own operating point — the SGMV-style grouped
/// kernel, at the cost of one launch overhead per sub-batch whenever
/// the round has more than one class.
#[derive(Debug)]
pub struct RankPartitionedDecode {
    inner: Box<dyn BatchPolicy>,
}

impl RankPartitionedDecode {
    pub fn new(inner: Box<dyn BatchPolicy>) -> Self {
        RankPartitionedDecode { inner }
    }
}

impl BatchPolicy for RankPartitionedDecode {
    fn name(&self) -> &'static str {
        "rank-partitioned"
    }

    fn admit_into(
        &mut self,
        queue: &mut VecDeque<SimReq>,
        slots: usize,
        max_tokens: u64,
        out: &mut Vec<SimReq>,
    ) {
        self.inner.admit_into(queue, slots, max_tokens, out);
    }

    fn set_slo_pressure(&mut self, headroom_frac: f64) {
        self.inner.set_slo_pressure(headroom_frac);
    }

    fn compose_decode_pooled(
        &mut self,
        active: &[ActiveReq],
        _slots: usize,
        _cm: &CostModel,
        _slo: Option<&SloTracker>,
        pool: &mut Vec<Vec<u64>>,
    ) -> DecodePlan {
        DecodePlan {
            groups: classes_of(active, pool)
                .into_values()
                .map(|seqs| DecodeGroup { seqs })
                .collect(),
        }
    }
}

/// The SLO-aware rotor's class pick: the `take` classes with the worst
/// (lowest) rolling TBT headroom go first, ties broken by ascending
/// rank. Returns None — fall back to the cyclic fairness rotor — when
/// no tracker is installed or every class reports the same headroom
/// (an all-fresh tracker, or genuinely tied cadences: with no signal
/// to act on, count-fair rotation is the right default and keeps the
/// ⌈C/G⌉ − 1 skip bound).
fn slo_pick(
    slo: Option<&SloTracker>,
    ranks: &[u32],
    take: usize,
) -> Option<Vec<u32>> {
    let slo = slo?;
    let hs: Vec<f64> =
        ranks.iter().map(|&r| slo.tbt_headroom(r)).collect();
    let (lo, hi) = hs
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &h| {
            (lo.min(h), hi.max(h))
        });
    if hi - lo <= 1e-12 {
        return None; // headrooms tie: cyclic fairness
    }
    let mut order: Vec<(f64, u32)> =
        hs.into_iter().zip(ranks.iter().copied()).collect();
    order.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
    });
    Some(order.into_iter().take(take).map(|(_, r)| r).collect())
}

/// Break-even (adaptive `max_groups`) composition: every class whose
/// recovered padding beats one extra sub-batch launch
/// (`CostModel::decode_split_gain` > 0) decodes as its own group; the
/// rest fold into the maximum-rank group, where staying padded is
/// cheaper than another kernel launch. Every member decodes every
/// round; the plan collapses to unified when no split pays and to
/// rank-partitioned when every split does.
fn breakeven_plan(
    cm: &CostModel,
    mut classes: BTreeMap<u32, Vec<u64>>,
    pool: &mut Vec<Vec<u64>>,
) -> DecodePlan {
    let Some(&max_rank) = classes.keys().next_back() else {
        return DecodePlan::default();
    };
    let mut merged = classes.remove(&max_rank).unwrap_or_default();
    let mut groups: Vec<DecodeGroup> = Vec::new();
    for (rank, mut seqs) in classes {
        if cm.decode_split_gain(seqs.len(), rank, max_rank) > 0.0 {
            groups.push(DecodeGroup { seqs });
        } else {
            merged.append(&mut seqs);
            pool.push(seqs);
        }
    }
    groups.push(DecodeGroup { seqs: merged });
    DecodePlan { groups }
}

/// Class-sub-batch decode decorator: like [`RankPartitionedDecode`]
/// but at most `max_groups` classes decode per round, bounding kernel
/// launches when many rank classes are co-resident.
///
/// Which classes go each round: with SLO feedback, the classes with
/// the worst rolling TBT headroom first (the SLO-aware rotor — serve
/// whoever is suffering); on headroom ties or open loop, a cyclic
/// fairness rotor, so a non-empty class is never skipped for more than
/// ⌈classes/max_groups⌉ − 1 consecutive rounds. The [`adaptive`]
/// variant (`class-subbatch:auto`) derives the grouping from the
/// launch-overhead/padding break-even instead of a fixed bound — see
/// [`breakeven_plan`].
///
/// [`adaptive`]: ClassSubBatchDecode::adaptive
#[derive(Debug)]
pub struct ClassSubBatchDecode {
    inner: Box<dyn BatchPolicy>,
    /// Fixed per-round group bound; None = adaptive break-even
    /// composition.
    max_groups: Option<usize>,
    /// Rank of the last class the cyclic rotor served; the next
    /// tie/open-loop round starts from the first class strictly above
    /// it (cyclic).
    rotor: u32,
}

impl ClassSubBatchDecode {
    pub fn new(inner: Box<dyn BatchPolicy>, max_groups: usize) -> Self {
        assert!(max_groups >= 1, "class-subbatch needs max_groups >= 1");
        ClassSubBatchDecode {
            inner,
            max_groups: Some(max_groups),
            rotor: 0,
        }
    }

    /// Adaptive `max_groups` from the launch-overhead/padding
    /// break-even in the cost model (`class-subbatch:auto`).
    pub fn adaptive(inner: Box<dyn BatchPolicy>) -> Self {
        ClassSubBatchDecode {
            inner,
            max_groups: None,
            rotor: 0,
        }
    }
}

impl BatchPolicy for ClassSubBatchDecode {
    fn name(&self) -> &'static str {
        "class-subbatch"
    }

    fn admit_into(
        &mut self,
        queue: &mut VecDeque<SimReq>,
        slots: usize,
        max_tokens: u64,
        out: &mut Vec<SimReq>,
    ) {
        self.inner.admit_into(queue, slots, max_tokens, out);
    }

    fn set_slo_pressure(&mut self, headroom_frac: f64) {
        self.inner.set_slo_pressure(headroom_frac);
    }

    fn compose_decode_pooled(
        &mut self,
        active: &[ActiveReq],
        _slots: usize,
        cm: &CostModel,
        slo: Option<&SloTracker>,
        pool: &mut Vec<Vec<u64>>,
    ) -> DecodePlan {
        let mut classes = classes_of(active, pool);
        let Some(max_groups) = self.max_groups else {
            return breakeven_plan(cm, classes, pool);
        };
        if classes.len() > max_groups {
            let ranks: Vec<u32> = classes.keys().copied().collect();
            let take: Vec<u32> = match slo_pick(slo, &ranks, max_groups)
            {
                Some(worst_first) => worst_first,
                None => {
                    // cyclic rotor: serve the next `max_groups`
                    // classes in ascending-rank order, starting just
                    // above the last rank served (wrapping), and
                    // remember where we stopped
                    let start = ranks
                        .iter()
                        .position(|&r| r > self.rotor)
                        .unwrap_or(0);
                    let t: Vec<u32> = (0..max_groups)
                        .map(|k| ranks[(start + k) % ranks.len()])
                        .collect();
                    self.rotor = *t.last().unwrap();
                    t
                }
            };
            classes.retain(|r, _| take.contains(r));
        } else if let Some(&last) = classes.keys().next_back() {
            self.rotor = last;
        }
        DecodePlan {
            groups: classes
                .into_values()
                .map(|seqs| DecodeGroup { seqs })
                .collect(),
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct ActiveReq {
    pub sreq: SimReq,
    /// Tokens produced so far (>= 1 once prefilled).
    pub produced: u32,
    pub first_token_at: f64,
    /// Per-server activation sequence number — the stable id decode
    /// plans reference members by (request ids can repeat across
    /// traces; this never does within a server).
    pub seq: u64,
}

/// What the server is currently executing.
#[derive(Debug, Clone)]
pub enum Iteration {
    Idle,
    Prefill {
        batch: Vec<SimReq>,
    },
    /// One decode sub-batch step: the member `seq` ids of the running
    /// group (the whole active set under the unified plan).
    Decode {
        seqs: Vec<u64>,
    },
}

/// Outcome of one finished request.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub req: Request,
    /// Engine-assigned request uid (see [`SimReq::uid`]).
    pub uid: u32,
    /// Adapter rank of the request (per-rank-class attribution).
    pub rank: u32,
    pub server: usize,
    pub ttft: f64,
    /// Mean time between tokens (NaN for single-token outputs).
    pub tbt: f64,
    pub finished_at: f64,
}

#[derive(Debug)]
pub struct SimServer {
    pub id: usize,
    pub cm: CostModel,
    /// Ready-to-prefill FIFO.
    pub queue: VecDeque<SimReq>,
    /// Requests waiting for their adapter to be fetched, with the
    /// time each started waiting (feeds the fetch-stall counter and
    /// the attribution table).
    pub waiting_fetch: Vec<(SimReq, f64)>,
    pub active: Vec<ActiveReq>,
    pub running: Iteration,
    /// Outstanding-work estimate in seconds (Toppings' signal).
    pub outstanding: f64,
    /// Drain state: no new work is routed here; active decodes finish
    /// and last-copy adapters migrate before the server retires.
    pub draining: bool,
    /// Unified paged HBM pool: adapter slices and (when bounded) KV
    /// footprints carved from one page budget. Unbounded by default —
    /// the legacy S-LoRA byte-LRU adapter cache bit for bit.
    pub hbm: HbmPool,
    pub busy_until: f64,
    pub busy_time: f64,
    /// Per-server TTFT samples (queueing+prefill, Fig 18 top).
    pub ttft_samples: Vec<f64>,
    pub timeouts: u64,
    /// Mixing diagnostics: iterations total / iterations whose batch
    /// max rank was >= 64 (the interference tax indicator).
    pub iters: u64,
    pub iters_highrank: u64,
    /// Prefill-composition diagnostics (per batch policy): prefill
    /// iterations, prefill iterations mixing ≥2 distinct ranks, and
    /// Σ (batch_max_rank − rank) × prompt_tokens — the volume of
    /// pad-to-max-rank work the kernels burn on mixed batches.
    pub prefill_iters: u64,
    pub mixed_prefill_iters: u64,
    pub pad_rank_tokens: u64,
    /// Decode-composition diagnostics (per decode policy): sub-batch
    /// steps run, steps whose group mixed ≥2 distinct ranks (only the
    /// unified plan produces these), and Σ (group_max_rank − rank) per
    /// member per step — the pad-to-max-rank work the decode kernels
    /// burn on mixed groups (each member produces one token per step,
    /// so the unit is rank·tokens, comparable to `pad_rank_tokens`).
    pub decode_steps: u64,
    pub mixed_decode_steps: u64,
    pub decode_pad_rank: u64,
    /// Sub-batch steps by the rank class the step *paid* (its group
    /// max rank) — the per-class decode-iteration mix.
    pub decode_steps_by_class: BTreeMap<u32, u64>,
    /// Batch composition policy, both phases (owned per server:
    /// policies carry starvation-guard and fairness-rotor state).
    pub policy: Box<dyn BatchPolicy>,
    /// SLO feedback layer (None = open loop, the PR 3 scheduler bit
    /// for bit): rolling TTFT/TBT headroom that drives decode-round
    /// preemption, the SLO-aware rotor, and adaptive admission waits.
    pub slo: Option<SloTracker>,
    /// Decode rounds cut short by TTFT pressure (a queued prefill
    /// preempted the remaining sub-batch steps).
    pub preemptions: u64,
    /// (arrival, TTFT) of requests admitted by a batch that ran under
    /// TTFT pressure (preempting or pressure-flagged admissions) — the
    /// "TTFT under pressure" distribution the feedback layer defends.
    /// The arrival rides along so the engine can apply the same warmup
    /// cutoff as every other latency stream.
    pub ttft_under_pressure: Vec<(f64, f64)>,
    /// The running prefill was admitted under TTFT pressure.
    prefill_under_pressure: bool,
    /// Seconds requests spent blocked on adapter fetches, accumulated
    /// as they leave `waiting_fetch` — one of the two queue-pressure
    /// signals the drift trigger's optional third OR-term reads
    /// (`RebalanceConfig::queue_signal`).
    pub fetch_stall_s: f64,
    /// Observability handle (disabled by default: every hook is a
    /// no-op and the server is bit-identical to an unobserved one).
    pub obs: Obs,
    /// Remaining sub-batch steps of the decode round in flight, priced
    /// and profiled once at composition (membership cannot change
    /// until a group's own step runs, so the stats stay exact). The
    /// round is atomic in open loop: these run before the next prefill
    /// admission. Under SLO feedback a queued prefill may preempt
    /// between steps — the remainder is discarded whole (never run
    /// stale) and re-planned on the next decode composition.
    pending_decode: VecDeque<PricedStep>,
    /// Next `ActiveReq::seq` to hand out.
    next_seq: u64,
    /// Recycled prefill-batch buffers: admission fills one, the
    /// finished prefill returns it — steady-state the iteration loop
    /// allocates nothing.
    batch_pool: Vec<Vec<SimReq>>,
    /// Recycled decode-membership buffers, threaded through
    /// `compose_decode_pooled` and returned when steps finish (or are
    /// preempted).
    seq_pool: Vec<Vec<u64>>,
    /// Admission-time pinned-adapter set, reused across iterations.
    pinned_scratch: BTreeSet<AdapterId>,
    /// Distinct-remote-adapter scan scratch, reused across iterations.
    remote_seen_scratch: Vec<AdapterId>,
    /// `release_waiting` arrival-order scratch.
    released_scratch: Vec<SimReq>,
}

/// One pre-priced decode sub-batch step: the group's membership plus
/// the stats and service time computed at round composition, so the
/// per-step hot path never rescans the active set.
#[derive(Debug, Clone)]
struct PricedStep {
    seqs: Vec<u64>,
    time: f64,
    members: usize,
    max_rank: u32,
    rank_sum: u64,
    mixed: bool,
    /// Price breakdown for attribution — only computed when the
    /// observability layer asks for it (None on the unobserved path).
    price: Option<StepPrice>,
}

/// Where one priced step's service time came from, recorded at
/// composition so per-member attribution can split `time` into
/// service / skew / launch / remote without re-deriving the formulas.
#[derive(Debug, Clone, Copy)]
struct StepPrice {
    /// Shared forward-pass base carried by this step (first step of a
    /// multi-group round; 0 elsewhere).
    base: f64,
    /// Per-sub-batch kernel launch overhead included in `time`.
    launch: f64,
    /// Remote-attach penalties included in `time`.
    remote: f64,
    /// KV residency of the group (own-rank repricing input).
    cached: u64,
    multi: bool,
}

impl SimServer {
    /// FIFO-admitting server (the classic engine).
    pub fn new(id: usize, cm: CostModel) -> Self {
        Self::with_policy(id, cm, Box::new(Fifo))
    }

    pub fn with_policy(
        id: usize,
        cm: CostModel,
        policy: Box<dyn BatchPolicy>,
    ) -> Self {
        SimServer {
            id,
            cm,
            queue: VecDeque::new(),
            waiting_fetch: Vec::new(),
            active: Vec::new(),
            running: Iteration::Idle,
            outstanding: 0.0,
            draining: false,
            hbm: HbmPool::new(
                cm.server.gpu_adapter_cache_bytes,
                cm.server.hbm_pages as u64,
                HBM_PAGE_BYTES,
                cm.server.evict_policy,
                cm.server.model.kv_bytes_per_token(),
            ),
            busy_until: 0.0,
            busy_time: 0.0,
            ttft_samples: Vec::new(),
            timeouts: 0,
            iters: 0,
            iters_highrank: 0,
            prefill_iters: 0,
            mixed_prefill_iters: 0,
            pad_rank_tokens: 0,
            decode_steps: 0,
            mixed_decode_steps: 0,
            decode_pad_rank: 0,
            decode_steps_by_class: BTreeMap::new(),
            policy,
            slo: None,
            preemptions: 0,
            ttft_under_pressure: Vec::new(),
            prefill_under_pressure: false,
            fetch_stall_s: 0.0,
            obs: Obs::default(),
            pending_decode: VecDeque::new(),
            next_seq: 0,
            batch_pool: Vec::new(),
            seq_pool: Vec::new(),
            pinned_scratch: BTreeSet::new(),
            remote_seen_scratch: Vec::new(),
            released_scratch: Vec::new(),
        }
    }

    /// Install the SLO feedback tracker (no-op when the config leaves
    /// the layer disabled, keeping the server open-loop).
    pub fn enable_slo(&mut self, cfg: SloFeedbackConfig) {
        if cfg.enabled {
            self.slo = Some(SloTracker::new(cfg));
        }
    }

    pub fn is_idle(&self) -> bool {
        matches!(self.running, Iteration::Idle)
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    /// Requests queued, waiting, or decoding on this server — the
    /// count-granularity load signal the Toppings router inspects.
    pub fn pending_count(&self) -> usize {
        self.queue.len() + self.waiting_fetch.len() + self.active.len()
    }

    /// Estimated service seconds a request adds to this server.
    pub fn estimate(cm: &CostModel, req: &Request, rank: u32) -> f64 {
        let prefill = cm.prefill(req.prompt_len as u64, rank);
        // decode share: assume a typical batch of half max_batch_size
        let b = (cm.server.max_batch_size / 2).max(1);
        let step = cm.decode(b, b as u64 * 640, rank);
        prefill + step / b as f64 * req.output_len as f64
    }

    pub fn enqueue_ready(&mut self, sreq: SimReq) {
        self.outstanding += sreq.est;
        self.queue.push_back(sreq);
    }

    pub fn enqueue_waiting(&mut self, sreq: SimReq, now: f64) {
        self.outstanding += sreq.est;
        self.waiting_fetch.push((sreq, now));
    }

    /// An adapter just became locally resident (a fetch or migration
    /// landed): requests that were being served by remote attach
    /// switch to the local copy from their next iteration on, instead
    /// of paying the per-iteration RDMA penalty for their whole
    /// lifetime. (Steps of a decode round already priced keep their
    /// priced time — rounds are atomic.)
    pub fn mark_local(&mut self, adapter: AdapterId) {
        for r in self.queue.iter_mut() {
            if r.req.adapter == adapter {
                r.remote = false;
            }
        }
        for (r, _) in self.waiting_fetch.iter_mut() {
            if r.req.adapter == adapter {
                r.remote = false;
            }
        }
        for a in self.active.iter_mut() {
            if a.sreq.req.adapter == adapter {
                a.sreq.remote = false;
            }
        }
    }

    /// Move requests whose adapter just became resident into the ready
    /// queue (ordered by arrival to preserve FIFO fairness), charging
    /// the time they spent blocked to the fetch-stall counter.
    pub fn release_waiting(&mut self, adapter: AdapterId, now: f64) {
        let released = &mut self.released_scratch;
        released.clear();
        let stall = &mut self.fetch_stall_s;
        let obs = &self.obs;
        self.waiting_fetch.retain(|(r, since)| {
            if r.req.adapter == adapter {
                *stall += now - since;
                obs.with_attrib(|t| {
                    t.rec(r.uid).fetch_stall += now - since;
                });
                released.push(*r);
                false
            } else {
                true
            }
        });
        released.sort_by(|a, b| {
            a.req.arrival.partial_cmp(&b.req.arrival).unwrap()
        });
        for r in released.drain(..) {
            self.queue.push_back(r);
        }
    }

    /// Pull every not-yet-running request off this server (drain
    /// protocol step 1: queued + waiting-for-fetch work gets re-routed
    /// through the swapped table), restoring the outstanding-work
    /// estimate. Sorted by arrival so re-delivery preserves FIFO
    /// fairness. Active (already prefilled) sequences stay and finish
    /// here.
    pub fn extract_pending(&mut self) -> Vec<SimReq> {
        let mut out: Vec<SimReq> = self.queue.drain(..).collect();
        out.extend(self.waiting_fetch.drain(..).map(|(r, _)| r));
        for r in &out {
            self.outstanding -= r.est;
        }
        out.sort_by(|a, b| {
            a.req.arrival.partial_cmp(&b.req.arrival).unwrap()
        });
        out
    }

    /// Hard-stop for failure injection: unlike the graceful
    /// `extract_pending`, *everything* comes off — queued requests,
    /// fetch-blocked requests (their block time is still charged to
    /// the fetch-stall counter), the running iteration's prefill
    /// batch, and every active (mid-decode) sequence. The scheduler
    /// state resets to empty/idle; the engine decides whether the
    /// returned requests requeue on survivors or fail. Sorted by
    /// arrival so re-delivery preserves FIFO fairness.
    pub fn crash_reset(&mut self, now: f64) -> Vec<SimReq> {
        let mut out: Vec<SimReq> = self.queue.drain(..).collect();
        let waiting: Vec<(SimReq, f64)> =
            self.waiting_fetch.drain(..).collect();
        for (r, since) in waiting {
            self.fetch_stall_s += now - since;
            self.obs.with_attrib(|t| {
                t.rec(r.uid).fetch_stall += now - since;
            });
            out.push(r);
        }
        if let Iteration::Prefill { batch } =
            std::mem::replace(&mut self.running, Iteration::Idle)
        {
            out.extend(batch);
        }
        out.extend(self.active.drain(..).map(|a| a.sreq));
        self.pending_decode.clear();
        self.outstanding = 0.0;
        self.busy_until = now;
        self.prefill_under_pressure = false;
        out.sort_by(|a, b| {
            a.req.arrival.partial_cmp(&b.req.arrival).unwrap()
        });
        out
    }

    /// True once a draining server holds no work at all — the compute
    /// half of the retire condition (the pool half is that it holds no
    /// last-copy adapters).
    pub fn quiesced(&self) -> bool {
        self.queue.is_empty()
            && self.waiting_fetch.is_empty()
            && self.active.is_empty()
            && self.is_idle()
    }

    /// Drop queued requests older than `timeout` (frontend gives up).
    ///
    /// The ready queue is FIFO by arrival, so expired requests cluster
    /// at the front: a front-only scan is O(dropped) instead of the
    /// O(queue-depth) full retain this used to be — which dominated
    /// 90% of simulation time under backlog (EXPERIMENTS.md §Perf).
    /// Requests re-queued out of order by `release_waiting` are at
    /// worst dropped a little late, when they reach the front.
    pub fn purge_timeouts(&mut self, now: f64, timeout: f64) -> u64 {
        let mut dropped = 0;
        while let Some(front) = self.queue.front() {
            if now - front.req.arrival > timeout {
                let r = self.queue.pop_front().unwrap();
                self.outstanding -= r.est;
                dropped += 1;
            } else {
                break;
            }
        }
        // the waiting-fetch list is short (adapters in flight); keep
        // the exact scan but skip it when empty
        if !self.waiting_fetch.is_empty() {
            let outstanding = &mut self.outstanding;
            let stall = &mut self.fetch_stall_s;
            self.waiting_fetch.retain(|(r, since)| {
                if now - r.req.arrival > timeout {
                    *outstanding -= r.est;
                    *stall += now - since;
                    dropped += 1;
                    false
                } else {
                    true
                }
            });
        }
        self.timeouts += dropped;
        dropped
    }

    /// SLO feedback: should the decode round in flight yield to a
    /// queued prefill? Only when preemption is enabled, a prefill is
    /// queued with a free decode slot to land in, and the tracker
    /// projects the queue head's TTFT headroom below the pressure
    /// threshold if the round's remaining sub-batch steps were allowed
    /// to run first.
    fn should_preempt_round(&self, now: f64) -> bool {
        let Some(slo) = &self.slo else {
            return false;
        };
        if !slo.cfg.preempt_decode
            || self.active.len() >= self.cm.server.max_batch_size
        {
            return false;
        }
        let Some(head) = self.queue.front() else {
            return false;
        };
        let remaining: f64 =
            self.pending_decode.iter().map(|s| s.time).sum();
        // Projected TTFT is wait *plus* the prefill the head will ride
        // in: its first token lands only after that batch runs, not
        // when it merely reaches the front. The head rarely prefills
        // alone — a simultaneous burst co-admits into one batch priced
        // at the batch's *total* tokens and *max* rank, so project the
        // greedy FIFO batch over the head's co-arrived neighbours
        // (slot- and token-budget-limited, first request exempt like
        // admission itself). Pricing only the head's own prompt made
        // the projection under-fire on bursts — the head's wait looked
        // fine while its batch was several prompts (or a higher rank
        // class) wide (regression-tested below).
        let slots = self
            .cm
            .server
            .max_batch_size
            .saturating_sub(self.active.len());
        let budget = self.cm.server.max_batch_tokens as u64;
        let mut tokens = 0u64;
        let mut max_rank = 0u32;
        let mut n = 0usize;
        for r in &self.queue {
            let t = r.req.prompt_len as u64;
            if n > 0
                && (n >= slots
                    || tokens + t > budget
                    || r.req.arrival > head.req.arrival + 1e-9)
            {
                break;
            }
            tokens += t;
            max_rank = max_rank.max(r.rank);
            n += 1;
        }
        let own = self.cm.prefill(tokens, max_rank);
        slo.ttft_pressure(now - head.req.arrival, remaining + own)
    }

    /// Start the next iteration if idle and work exists. Returns the
    /// iteration's service time (caller schedules IterationDone).
    ///
    /// Prefill-prioritized iteration-level scheduling: the owned
    /// [`BatchPolicy`] admits a prefill batch (token budget + slot
    /// limited) if any request is queued, otherwise the policy
    /// composes a [`DecodePlan`] over the active set and its sub-batch
    /// steps run one per iteration (the whole set in one step under
    /// the unified default). A decode round in flight finishes all its
    /// steps before the next prefill admission check — unless the SLO
    /// feedback layer preempts it: under TTFT pressure with a prefill
    /// queued, the remaining steps are dropped and the round is
    /// re-planned after the admission. Conservation holds because
    /// un-stepped members stay in the active set and simply rejoin the
    /// next composed round (they re-pay the shared forward-pass base
    /// there — the real cost of preemption).
    pub fn start_iteration(&mut self, now: f64) -> Option<f64> {
        if !self.is_idle() {
            return None;
        }
        if let Some(t) = &mut self.slo {
            t.tick(now);
        }
        // decode-round continuation: remaining sub-batch steps run
        // before any new admission (the plan is atomic in open loop)
        let mut preempted = false;
        if !self.pending_decode.is_empty() {
            if self.should_preempt_round(now) {
                let dropped = self.pending_decode.len();
                self.recycle_pending();
                self.preemptions += 1;
                preempted = true;
                if self.obs.trace_on() {
                    self.obs.instant(
                        "preempt",
                        now,
                        obs::server_pid(self.id),
                        obs::TID_REQUESTS,
                        vec![("dropped_steps", dropped.into())],
                    );
                }
                self.obs.counter_add("sim_decode_preemptions_total", 1);
            } else if let Some(t) = self.start_pending_decode(now) {
                return Some(t);
            }
        }
        // admit prefills (policy-selected composition)
        let slots = self
            .cm
            .server
            .max_batch_size
            .saturating_sub(self.active.len());
        let mut under_pressure = preempted;
        if let (Some(slo), Some(head)) = (&self.slo, self.queue.front())
        {
            // adaptive admission: report the head's remaining TTFT
            // headroom so stateful policies (RankBucketed's
            // bounded-wait guard) can tighten under pressure
            let waited = now - head.req.arrival;
            under_pressure =
                under_pressure || slo.ttft_pressure(waited, 0.0);
            let frac = slo.ttft_headroom_frac(waited);
            self.policy.set_slo_pressure(frac);
        }
        let mut batch = self.batch_pool.pop().unwrap_or_default();
        batch.clear();
        // Bounded HBM: refresh the pool's KV footprint (every active
        // sequence holds prompt + produced tokens of cache) so the
        // admission budget below reflects the pages in-flight work
        // already owns, and hand the slo-aware evictor the adapters
        // with live demand here. Unbounded pools skip all of this and
        // admit on the configured budget — the legacy path bit for bit.
        if self.hbm.bounded() {
            let kv: u64 = self
                .active
                .iter()
                .map(|a| {
                    a.sreq.req.prompt_len as u64 + a.produced as u64
                })
                .sum();
            self.hbm.set_kv_tokens(kv);
            if self.hbm.wants_protected() {
                self.hbm.set_protected(
                    self.active
                        .iter()
                        .map(|a| a.sreq.req.adapter)
                        .chain(
                            self.queue.iter().map(|r| r.req.adapter),
                        ),
                );
            }
        }
        let budget = self
            .hbm
            .admissible_tokens(self.cm.server.max_batch_tokens as u64);
        self.policy.admit_into(
            &mut self.queue,
            slots,
            budget,
            &mut batch,
        );
        if !batch.is_empty() {
            self.prefill_under_pressure = under_pressure;
            let tokens: u64 =
                batch.iter().map(|r| r.req.prompt_len as u64).sum();
            let max_rank =
                batch.iter().map(|r| r.rank).max().unwrap_or(0);
            self.prefill_iters += 1;
            if batch.iter().any(|r| r.rank != batch[0].rank) {
                self.mixed_prefill_iters += 1;
            }
            self.pad_rank_tokens += batch
                .iter()
                .map(|r| {
                    u64::from(max_rank - r.rank)
                        * r.req.prompt_len as u64
                })
                .sum::<u64>();
            // page this batch's adapters into the GPU pool (S-LoRA
            // unified paging); active sequences' adapters are pinned.
            // Remotely-attached adapters never enter the local cache —
            // each pays the per-iteration RDMA penalty instead (once
            // per distinct adapter: its slices stream once per
            // iteration however many requests share it).
            self.pinned_scratch.clear();
            self.pinned_scratch.extend(
                self.active
                    .iter()
                    .map(|a| a.sreq.req.adapter)
                    .chain(batch.iter().map(|r| r.req.adapter)),
            );
            let mut load_time = 0.0;
            let pcie = self.cm.server.gpu.pcie_bw;
            self.remote_seen_scratch.clear();
            // page-in vs remote split tracked for attribution only —
            // `load_time` keeps its exact accumulation order so the
            // timing stays bit-identical
            let mut page_t = 0.0;
            let mut remote_t = 0.0;
            for r in &batch {
                if r.remote {
                    if !self.remote_seen_scratch.contains(&r.req.adapter)
                    {
                        self.remote_seen_scratch.push(r.req.adapter);
                        let pen = self.cm.remote_attach_penalty();
                        load_time += pen;
                        remote_t += pen;
                    }
                } else {
                    let lt = self.hbm.touch(
                        r.req.adapter,
                        r.adapter_bytes,
                        pcie,
                        &self.pinned_scratch,
                    );
                    load_time += lt;
                    page_t += lt;
                }
            }
            let time = self.cm.prefill(tokens, max_rank) + load_time;
            self.iters += 1;
            self.iters_highrank += (max_rank >= 64) as u64;
            if self.obs.on() {
                self.observe_prefill(
                    now, time, tokens, max_rank, page_t, remote_t, &batch,
                );
            }
            self.running = Iteration::Prefill { batch };
            self.busy_until = now + time;
            self.busy_time += time;
            return Some(time);
        }
        self.batch_pool.push(batch);
        if !self.active.is_empty() {
            if self.slo.is_some() {
                // anchor every active class *and tenant* in the
                // tracker so a class (or a co-class tenant) the rotor
                // has been skipping accrues staleness from admission,
                // not from its (never-happening) first step
                let mut members: Vec<(u32, AdapterId)> = Vec::new();
                for a in &self.active {
                    let m = (a.sreq.rank, a.sreq.req.adapter);
                    if !members.contains(&m) {
                        members.push(m);
                    }
                }
                if let Some(slo) = &mut self.slo {
                    slo.observe_active_members(now, &members);
                }
            }
            let plan = self.policy.compose_decode_pooled(
                &self.active,
                self.cm.server.max_batch_size,
                &self.cm,
                self.slo.as_ref(),
                &mut self.seq_pool,
            );
            debug_assert!(
                plan.total_members() <= self.cm.server.max_batch_size,
                "decode plan exceeds slots"
            );
            self.price_decode_round(plan);
            if self.pending_decode.is_empty() {
                // A malformed custom plan (empty, or only empty
                // groups) must not stall a server with live decodes —
                // nothing else would ever re-arm it and its requests
                // would silently never complete. Fall back to the
                // unified whole-set round.
                debug_assert!(false, "decode plan left active set unserved");
                let plan = DecodePlan::unified(&self.active);
                self.price_decode_round(plan);
            }
            if let Some(t) = self.start_pending_decode(now) {
                return Some(t);
            }
        }
        None
    }

    /// Per-member stats of one group's `seqs` (must be sorted — the
    /// pricing path sorts every group once) against the current active
    /// set: (members, cached tokens, max rank, Σ rank, mixed?,
    /// distinct remote adapters). Runs once per group at round
    /// composition — the per-step hot path reuses the stored result.
    fn group_stats(
        &self,
        seqs: &[u64],
    ) -> (usize, u64, u32, u64, bool, usize) {
        let mut b = 0usize;
        let mut cached = 0u64;
        let mut max_rank = 0u32;
        let mut rank_sum = 0u64;
        let mut mixed = false;
        let mut remote_seen: Vec<AdapterId> = Vec::new();
        // membership: whole-set groups (the unified default) hit the
        // O(n) fast path; sub-batches binary-search their sorted seqs
        let whole_set = seqs.len() == self.active.len();
        for a in &self.active {
            if !whole_set && seqs.binary_search(&a.seq).is_err() {
                continue;
            }
            if b > 0 && a.sreq.rank != max_rank {
                mixed = true;
            }
            b += 1;
            cached += a.sreq.req.prompt_len as u64 + a.produced as u64;
            rank_sum += u64::from(a.sreq.rank);
            max_rank = max_rank.max(a.sreq.rank);
            if a.sreq.remote
                && !remote_seen.contains(&a.sreq.req.adapter)
            {
                remote_seen.push(a.sreq.req.adapter);
            }
        }
        (b, cached, max_rank, rank_sum, mixed, remote_seen.len())
    }

    /// Price a composed decode round into per-step service times and
    /// stats.
    ///
    /// A single-group round is billed through the legacy whole-batch
    /// formula (`cm.decode`) — bit-identical to the pre-refactor
    /// decode for the unified plan. A multi-group (SGMV-style) round
    /// shares one forward pass: its *first* step carries the
    /// weight-streaming/KV/overhead base of the entire round's
    /// membership (`cm.decode_base`), and every step adds only its own
    /// class's grouped LoRA kernel plus the per-sub-batch launch
    /// overhead (`cm.decode_class`). Members of later groups cannot
    /// change before their step runs (groups are disjoint, only a
    /// group's own step completes its members, and the round blocks
    /// prefill admission), so pricing at composition time is exact.
    /// Fills `pending_decode` in place (reusing its storage round
    /// over round); the caller guarantees it is empty on entry.
    fn price_decode_round(&mut self, plan: DecodePlan) {
        debug_assert!(self.pending_decode.is_empty());
        // profile the groups that actually run (empty groups dropped
        // first, so a [real, empty] plan is priced as a single-group
        // round, not a mispriced multi-group one)
        type Profiled = (Vec<u64>, usize, u64, u32, u64, bool, usize);
        let mut profiled: Vec<Profiled> =
            Vec::with_capacity(plan.groups.len());
        let mut b_total = 0usize;
        let mut cached_total = 0u64;
        for group in plan.groups {
            // sorted once here so every later membership check (stats,
            // token production) can binary-search instead of scanning
            let mut seqs = group.seqs;
            seqs.sort_unstable();
            let (b, cached, max_rank, rank_sum, mixed, remote) =
                self.group_stats(&seqs);
            if b == 0 {
                // empty group: nothing to run
                seqs.clear();
                self.seq_pool.push(seqs);
                continue;
            }
            b_total += b;
            cached_total += cached;
            profiled.push((
                seqs, b, cached, max_rank, rank_sum, mixed, remote,
            ));
        }
        let multi = profiled.len() > 1;
        let want_price = self.obs.attrib_on();
        for (i, (seqs, b, cached, max_rank, rank_sum, mixed, remote)) in
            profiled.into_iter().enumerate()
        {
            let mut time = if multi {
                self.cm.decode_class(b, max_rank, true)
            } else {
                self.cm.decode(b, cached, max_rank)
            };
            if multi && i == 0 {
                // the round's shared forward-pass base lands on its
                // first step
                time += self.cm.decode_base(b_total, cached_total);
            }
            if remote > 0 {
                // each remotely-attached adapter streams its slices
                // over RDMA once per step it participates in
                time +=
                    remote as f64 * self.cm.remote_attach_penalty();
            }
            let price = want_price.then(|| StepPrice {
                base: if multi && i == 0 {
                    self.cm.decode_base(b_total, cached_total)
                } else {
                    0.0
                },
                launch: if multi {
                    self.cm.server.decode_launch_overhead
                } else {
                    0.0
                },
                remote: remote as f64 * self.cm.remote_attach_penalty(),
                cached,
                multi,
            });
            self.pending_decode.push_back(PricedStep {
                seqs,
                time,
                members: b,
                max_rank,
                rank_sum,
                mixed,
                price,
            });
        }
    }

    /// Drop any un-run steps of the round in flight, returning their
    /// membership buffers to the pool.
    fn recycle_pending(&mut self) {
        while let Some(mut s) = self.pending_decode.pop_front() {
            s.seqs.clear();
            self.seq_pool.push(s.seqs);
        }
    }

    /// Run the next sub-batch step of the decode round in flight, if
    /// any.
    fn start_pending_decode(&mut self, now: f64) -> Option<f64> {
        let step = self.pending_decode.pop_front()?;
        debug_assert_eq!(
            self.group_stats(&step.seqs).0,
            step.members,
            "decode-round membership changed between composition and \
             its step"
        );
        self.iters += 1;
        self.iters_highrank += (step.max_rank >= 64) as u64;
        self.decode_steps += 1;
        self.mixed_decode_steps += step.mixed as u64;
        // Σ (group_max − rank) over members, one token each
        self.decode_pad_rank +=
            u64::from(step.max_rank) * step.members as u64 - step.rank_sum;
        *self
            .decode_steps_by_class
            .entry(step.max_rank)
            .or_insert(0) += 1;
        if self.obs.on() {
            self.observe_decode_step(now, &step);
        }
        self.running = Iteration::Decode { seqs: step.seqs };
        self.busy_until = now + step.time;
        self.busy_time += step.time;
        Some(step.time)
    }

    /// Observability for one admitted prefill batch: the iteration
    /// span, per-request admission milestones, and the exact latency
    /// decomposition. Queue wait is computed residually at admission
    /// (everything since arrival not already charged to fetch stall);
    /// the batch's page-in/remote load and its pad-to-max-rank premium
    /// are charged to every member — each member really does wait for
    /// the whole batch.
    fn observe_prefill(
        &mut self,
        now: f64,
        time: f64,
        tokens: u64,
        max_rank: u32,
        page_t: f64,
        remote_t: f64,
        batch: &[SimReq],
    ) {
        let pid = obs::server_pid(self.id);
        if self.obs.trace_on() {
            self.obs.span(
                "prefill",
                now,
                time,
                pid,
                obs::TID_PREFILL,
                Some(obs::rank_cname(max_rank)),
                vec![
                    ("batch", batch.len().into()),
                    ("tokens", tokens.into()),
                    ("max_rank", max_rank.into()),
                    ("load_ms", ((page_t + remote_t) * 1e3).into()),
                ],
            );
            for r in batch {
                self.obs.async_instant(
                    "admitted",
                    "req",
                    r.uid as u64,
                    now,
                    pid,
                    vec![],
                );
            }
        }
        self.obs.counter_add("sim_prefill_iters_total", 1);
        self.obs.counter_add("sim_prefill_tokens_total", tokens);
        if self.obs.attrib_on() {
            let cm = self.cm;
            let compute = cm.prefill(tokens, max_rank);
            let active_uids: Vec<u32> =
                self.active.iter().map(|a| a.sreq.uid).collect();
            self.obs.with_attrib(|t| {
                for r in batch {
                    let rec = t.rec(r.uid);
                    rec.queue_wait =
                        now - r.req.arrival - rec.fetch_stall;
                    rec.fetch_stall += page_t;
                    let own = cm.prefill(tokens, r.rank);
                    rec.prefill_service = own;
                    rec.prefill_skew = compute - own;
                    rec.prefill_remote = remote_t;
                }
                // every already-active decode stalls behind this
                // (preempting or interleaved) prefill
                for &uid in &active_uids {
                    t.rec(uid).preempt_delay += time;
                }
            });
        }
    }

    /// Observability for one decode sub-batch step: the rank-class
    /// lane span plus the per-member split of the step's priced time
    /// into service / skew / launch / remote. Non-members of the step
    /// (other sub-batches of the round) are charged the step's
    /// serialization: the shared base still advances their forward
    /// pass (service); the class kernel, launch, and remote penalties
    /// stall them (skew/launch/remote).
    fn observe_decode_step(&self, now: f64, step: &PricedStep) {
        if self.obs.trace_on() {
            self.obs.span(
                "decode",
                now,
                step.time,
                obs::server_pid(self.id),
                obs::decode_lane(step.max_rank),
                Some(obs::rank_cname(step.max_rank)),
                vec![
                    ("b", step.members.into()),
                    ("max_rank", step.max_rank.into()),
                    ("mixed", step.mixed.into()),
                ],
            );
        }
        self.obs.counter_add("sim_decode_steps_total", 1);
        let Some(p) = step.price else {
            return;
        };
        let cm = self.cm;
        let whole = step.seqs.len() == self.active.len();
        let charges: Vec<(u32, bool, u32)> = self
            .active
            .iter()
            .map(|a| {
                let member =
                    whole || step.seqs.binary_search(&a.seq).is_ok();
                (a.sreq.uid, member, a.sreq.rank)
            })
            .collect();
        let (b, max_rank, time) =
            (step.members, step.max_rank, step.time);
        self.obs.with_attrib(|t| {
            for (uid, member, rank) in charges {
                let rec = t.rec(uid);
                if member {
                    if p.multi {
                        let own = cm.decode_class(b, rank, false);
                        let at_max =
                            cm.decode_class(b, max_rank, false);
                        rec.decode_service += own + p.base;
                        rec.decode_skew += at_max - own;
                        rec.decode_launch += p.launch;
                    } else {
                        let own = cm.decode(b, p.cached, rank);
                        rec.decode_service += own;
                        rec.decode_skew += time - p.remote - own;
                    }
                    rec.decode_remote += p.remote;
                } else {
                    rec.decode_service += p.base;
                    rec.decode_skew +=
                        time - p.base - p.launch - p.remote;
                    rec.decode_launch += p.launch;
                    rec.decode_remote += p.remote;
                }
            }
        });
    }

    /// Finish the running iteration; returns completed requests.
    /// Allocating wrapper around `finish_iteration_into` for tests
    /// and one-off callers — the engine's hot path passes a recycled
    /// buffer instead.
    pub fn finish_iteration(&mut self, now: f64) -> Vec<Completion> {
        let mut done = Vec::new();
        self.finish_iteration_into(now, &mut done);
        done
    }

    /// Finish the running iteration, appending completed requests to
    /// `done` (not cleared here — the caller owns the buffer).
    pub fn finish_iteration_into(
        &mut self,
        now: f64,
        done: &mut Vec<Completion>,
    ) {
        match std::mem::replace(&mut self.running, Iteration::Idle) {
            Iteration::Idle => {}
            Iteration::Prefill { mut batch } => {
                let pressured = std::mem::replace(
                    &mut self.prefill_under_pressure,
                    false,
                );
                for sreq in batch.drain(..) {
                    let ttft = now - sreq.req.arrival;
                    self.ttft_samples.push(ttft);
                    if pressured {
                        self.ttft_under_pressure
                            .push((sreq.req.arrival, ttft));
                    }
                    if sreq.req.output_len <= 1 {
                        self.outstanding -= sreq.est;
                        done.push(Completion {
                            req: sreq.req,
                            uid: sreq.uid,
                            rank: sreq.rank,
                            server: self.id,
                            ttft,
                            tbt: f64::NAN,
                            finished_at: now,
                        });
                    } else {
                        let seq = self.next_seq;
                        self.next_seq += 1;
                        self.active.push(ActiveReq {
                            sreq,
                            produced: 1,
                            first_token_at: now,
                            seq,
                        });
                    }
                }
                self.batch_pool.push(batch);
            }
            Iteration::Decode { mut seqs } => {
                let id = self.id;
                let outstanding = &mut self.outstanding;
                // SLO feedback: collect the step's distinct (rank,
                // adapter) members so the tracker can update each
                // class's — and each tenant's — decode cadence (pure
                // observation, no timing effect)
                let track = self.slo.is_some();
                let mut stepped: Vec<(u32, AdapterId)> = Vec::new();
                // whole-set steps (the unified default) skip the
                // per-member membership check entirely; sub-batch
                // steps binary-search their (priced-time-sorted) seqs
                let whole_set = seqs.len() == self.active.len();
                self.active.retain_mut(|a| {
                    if !whole_set && seqs.binary_search(&a.seq).is_err() {
                        return true; // not in this sub-batch step
                    }
                    if track {
                        let m = (a.sreq.rank, a.sreq.req.adapter);
                        if !stepped.contains(&m) {
                            stepped.push(m);
                        }
                    }
                    a.produced += 1;
                    if a.produced >= a.sreq.req.output_len {
                        *outstanding -= a.sreq.est;
                        done.push(Completion {
                            req: a.sreq.req,
                            uid: a.sreq.uid,
                            rank: a.sreq.rank,
                            server: id,
                            ttft: a.first_token_at - a.sreq.req.arrival,
                            tbt: (now - a.first_token_at)
                                / (a.sreq.req.output_len - 1).max(1) as f64,
                            finished_at: now,
                        });
                        false
                    } else {
                        true
                    }
                });
                if let Some(slo) = &mut self.slo {
                    slo.record_decode_step_members(now, &stepped);
                }
                seqs.clear();
                self.seq_pool.push(seqs);
                if self.active.is_empty() {
                    // nothing left for any remaining (stale) steps
                    self.recycle_pending();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServerConfig;

    fn server() -> SimServer {
        SimServer::new(0, CostModel::new(ServerConfig::default()))
    }

    fn req(arrival: f64, adapter: AdapterId, prompt: u32, output: u32) -> SimReq {
        let r = Request {
            id: 0,
            adapter,
            prompt_len: prompt,
            output_len: output,
            arrival,
        };
        SimReq {
            req: r,
            uid: 0,
            rank: 8,
            adapter_bytes: 17 << 20,
            est: 0.1,
            remote: false,
        }
    }

    #[test]
    fn prefill_then_decode_lifecycle() {
        let mut s = server();
        s.enqueue_ready(req(0.0, 0, 100, 3));
        let t1 = s.start_iteration(0.0).unwrap();
        assert!(t1 > 0.0);
        let done = s.finish_iteration(t1);
        assert!(done.is_empty());
        assert_eq!(s.active.len(), 1);
        assert_eq!(s.ttft_samples.len(), 1);
        // two decode steps to finish output_len=3
        let t2 = s.start_iteration(t1).unwrap();
        assert!(s.finish_iteration(t1 + t2).is_empty());
        let t3 = s.start_iteration(t1 + t2).unwrap();
        let done = s.finish_iteration(t1 + t2 + t3);
        assert_eq!(done.len(), 1);
        let c = done[0];
        assert!((c.ttft - t1).abs() < 1e-12);
        assert!((c.tbt - (t2 + t3) / 2.0).abs() < 1e-12);
        assert!(!s.has_work());
        assert!(s.outstanding.abs() < 1e-9);
    }

    #[test]
    fn single_token_output_completes_at_prefill() {
        let mut s = server();
        s.enqueue_ready(req(0.0, 0, 50, 1));
        let t = s.start_iteration(0.0).unwrap();
        let done = s.finish_iteration(t);
        assert_eq!(done.len(), 1);
        assert!(done[0].tbt.is_nan());
        assert!(s.active.is_empty());
    }

    #[test]
    fn batch_respects_token_budget() {
        let mut s = server();
        let budget = s.cm.server.max_batch_tokens as u32;
        s.enqueue_ready(req(0.0, 0, budget - 10, 2));
        s.enqueue_ready(req(0.0, 1, 100, 2));
        s.start_iteration(0.0).unwrap();
        if let Iteration::Prefill { batch } = &s.running {
            assert_eq!(batch.len(), 1, "second prompt must not fit");
        } else {
            panic!("expected prefill");
        }
        assert_eq!(s.queue.len(), 1);
    }

    #[test]
    fn oversized_prompt_still_admitted_alone() {
        let mut s = server();
        let budget = s.cm.server.max_batch_tokens as u32;
        s.enqueue_ready(req(0.0, 0, budget * 2, 2));
        assert!(s.start_iteration(0.0).is_some());
    }

    #[test]
    fn mixed_rank_batch_pays_max_rank() {
        let mut s = server();
        let mut lo = req(0.0, 0, 500, 2);
        lo.rank = 8;
        let mut hi = req(0.0, 1, 500, 2);
        hi.rank = 128;
        // homogeneous low-rank batch
        let mut s1 = server();
        s1.enqueue_ready(lo);
        s1.enqueue_ready({
            let mut x = lo;
            x.req.adapter = 2;
            x
        });
        let t_lo = s1.start_iteration(0.0).unwrap();
        // mixed batch of the same token count
        s.enqueue_ready(lo);
        s.enqueue_ready(hi);
        let t_mixed = s.start_iteration(0.0).unwrap();
        assert!(
            t_mixed > t_lo * 1.2,
            "mixed {t_mixed} vs homogeneous {t_lo}"
        );
    }

    #[test]
    fn waiting_fetch_released_in_arrival_order() {
        let mut s = server();
        s.enqueue_waiting(req(2.0, 5, 10, 1), 2.0);
        s.enqueue_waiting(req(1.0, 5, 10, 1), 1.0);
        s.enqueue_waiting(req(1.5, 6, 10, 1), 1.5);
        s.release_waiting(5, 3.0);
        assert_eq!(s.queue.len(), 2);
        assert_eq!(s.queue[0].req.arrival, 1.0);
        assert_eq!(s.waiting_fetch.len(), 1);
        // stall accounting: (3−2) + (3−1) seconds left the wait list
        assert!((s.fetch_stall_s - 3.0).abs() < 1e-12);
    }

    #[test]
    fn purge_timeouts_counts_and_restores_outstanding() {
        let mut s = server();
        s.enqueue_ready(req(0.0, 0, 10, 1));
        s.enqueue_waiting(req(0.5, 1, 10, 1), 0.5);
        let before = s.outstanding;
        assert!(before > 0.0);
        let dropped = s.purge_timeouts(100.0, 10.0);
        assert_eq!(dropped, 2);
        assert_eq!(s.timeouts, 2);
        assert!(s.outstanding.abs() < 1e-9);
        assert_eq!(s.purge_timeouts(100.0, 1000.0), 0);
    }

    #[test]
    fn extract_pending_drains_queues_in_arrival_order() {
        let mut s = server();
        s.enqueue_ready(req(2.0, 0, 10, 1));
        s.enqueue_waiting(req(1.0, 1, 10, 1), 1.0);
        s.enqueue_ready(req(3.0, 2, 10, 1));
        assert!(s.outstanding > 0.0);
        let pending = s.extract_pending();
        assert_eq!(pending.len(), 3);
        assert_eq!(pending[0].req.arrival, 1.0);
        assert_eq!(pending[2].req.arrival, 3.0);
        assert!(s.outstanding.abs() < 1e-9);
        assert!(s.quiesced());
    }

    #[test]
    fn quiesced_tracks_active_work() {
        let mut s = server();
        assert!(s.quiesced());
        s.enqueue_ready(req(0.0, 0, 10, 3));
        assert!(!s.quiesced());
        let t = s.start_iteration(0.0).unwrap();
        s.finish_iteration(t);
        // one active decode sequence keeps the server busy
        assert!(!s.quiesced());
        let t2 = s.start_iteration(t).unwrap();
        s.finish_iteration(t + t2);
        let t3 = s.start_iteration(t + t2).unwrap();
        s.finish_iteration(t + t2 + t3);
        assert!(s.quiesced());
    }

    fn ranked(arrival: f64, adapter: AdapterId, rank: u32) -> SimReq {
        let mut r = req(arrival, adapter, 100, 1);
        r.rank = rank;
        r
    }

    /// Regression (ROADMAP follow-up): the decode-preemption
    /// projection must include the queued head's *own* prefill time.
    /// This pins an operating point where waited + remaining-round
    /// time alone sits under the pressure threshold — the old
    /// projection declines to preempt — but adding the head's prefill
    /// blows it, so the fixed projection preempts.
    #[test]
    fn preemption_projection_includes_prefill_service_time() {
        let cm = CostModel::new(ServerConfig::default());
        // after the round's first (rank-8) step runs, the remaining
        // step is the lone rank-128 sub-batch
        let rem = cm.decode_class(1, 128, true);
        let own = cm.prefill(2000, 8);
        // θ = 0.5 and target T = 2·rem + own puts the pressure
        // boundary (projected > T/2 = rem + own/2) strictly between
        // the old projection (rem) and the fixed one (rem + own)
        let slo_cfg = SloFeedbackConfig {
            enabled: true,
            ttft_target: 2.0 * rem + own,
            tbt_target: 0.2,
            preempt_decode: true,
            pressure_theta: 0.5,
        };
        let probe = SloTracker::new(slo_cfg);
        assert!(
            !probe.ttft_pressure(0.0, rem),
            "old projection (queue wait only) must under-fire here"
        );
        assert!(probe.ttft_pressure(0.0, rem + own));

        let mut s = SimServer::with_policy(
            0,
            cm,
            Box::new(RankPartitionedDecode::new(Box::new(Fifo))),
        );
        s.enable_slo(slo_cfg);
        let mut lo = req(0.0, 0, 100, 3);
        lo.rank = 8;
        let mut hi = req(0.0, 1, 100, 3);
        hi.rank = 128;
        s.enqueue_ready(lo);
        s.enqueue_ready(hi);
        let t1 = s.start_iteration(0.0).unwrap(); // mixed prefill
        assert!(s.finish_iteration(t1).is_empty());
        let d1 = s.start_iteration(t1).unwrap(); // round step 1 (rank 8)
        s.finish_iteration(t1 + d1);
        // a big prefill arrives exactly now: waited = 0 at the check
        let mut head = req(t1 + d1, 2, 2000, 1);
        head.rank = 8;
        s.enqueue_ready(head);
        let _ = s.start_iteration(t1 + d1).unwrap();
        assert_eq!(
            s.preemptions, 1,
            "fixed projection must preempt the remaining rank-128 step"
        );
        assert!(
            matches!(s.running, Iteration::Prefill { .. }),
            "the preempting admission runs the head's prefill"
        );
    }

    /// Regression: the preemption projection must price the *batch*
    /// the queue head will ride in, not just the head's own prompt. A
    /// simultaneous burst co-admits into one prefill priced at the
    /// batch's total tokens (and max rank); pricing the head alone
    /// under-fires by the width of its co-arrived neighbours.
    #[test]
    fn preemption_projection_prices_coqueued_burst() {
        let cm = CostModel::new(ServerConfig::default());
        let rem = cm.decode_class(1, 128, true);
        // one 700-token prompt looks harmless; a simultaneous burst of
        // three co-admits into a 2100-token batch that does not
        let single = cm.prefill(700, 8);
        let burst = cm.prefill(2100, 8);
        let slo_cfg = SloFeedbackConfig {
            enabled: true,
            // boundary (θ=0.5): projected > rem + single strictly
            // separates head-only (rem + single) from the burst
            // projection (rem + burst)
            ttft_target: 2.0 * (rem + single),
            tbt_target: 0.2,
            preempt_decode: true,
            pressure_theta: 0.5,
        };
        let probe = SloTracker::new(slo_cfg);
        assert!(
            !probe.ttft_pressure(0.0, rem + single),
            "head-only projection must under-fire here"
        );
        assert!(probe.ttft_pressure(0.0, rem + burst));

        let run = |n_burst: usize| {
            let mut s = SimServer::with_policy(
                0,
                cm,
                Box::new(RankPartitionedDecode::new(Box::new(Fifo))),
            );
            s.enable_slo(slo_cfg);
            let mut lo = req(0.0, 0, 100, 3);
            lo.rank = 8;
            let mut hi = req(0.0, 1, 100, 3);
            hi.rank = 128;
            s.enqueue_ready(lo);
            s.enqueue_ready(hi);
            let t1 = s.start_iteration(0.0).unwrap();
            assert!(s.finish_iteration(t1).is_empty());
            let d1 = s.start_iteration(t1).unwrap(); // round step 1
            s.finish_iteration(t1 + d1);
            // the burst arrives together, exactly at the check
            for k in 0..n_burst {
                let mut r = req(t1 + d1, 2 + k as AdapterId, 700, 1);
                r.rank = 8;
                s.enqueue_ready(r);
            }
            let _ = s.start_iteration(t1 + d1).unwrap();
            s.preemptions
        };
        assert_eq!(run(1), 0, "a lone 700-token head must not preempt");
        assert_eq!(run(3), 1, "the co-queued burst must preempt");
    }

    /// When a copy lands locally, `mark_local` flips the remote flag
    /// on that adapter's queued, waiting, and active requests — other
    /// adapters' requests keep theirs.
    #[test]
    fn mark_local_clears_remote_flags() {
        let mut s = server();
        let mut c = req(0.0, 7, 100, 3);
        c.remote = true;
        s.enqueue_ready(c);
        let t = s.start_iteration(0.0).unwrap();
        s.finish_iteration(t); // c decoding, still remote
        assert!(s.active[0].sreq.remote);
        let mut a = req(t, 7, 100, 1);
        a.remote = true;
        s.enqueue_ready(a);
        let mut b = req(t, 8, 100, 1);
        b.remote = true;
        s.enqueue_waiting(b, t);
        s.mark_local(7);
        assert!(!s.active[0].sreq.remote);
        assert!(!s.queue[0].remote);
        assert!(
            s.waiting_fetch[0].0.remote,
            "other adapters keep the flag"
        );
    }

    /// Remote-attach pricing: a remotely-served adapter pays the
    /// per-iteration RDMA penalty on its prefill (instead of a GPU
    /// cache page-in) and on every decode step touching it — once per
    /// distinct adapter, however many requests share it.
    #[test]
    fn remote_attach_pays_per_iteration_penalty() {
        let penalty =
            CostModel::new(ServerConfig::default()).remote_attach_penalty();
        // two requests sharing one remote adapter vs the same pair
        // served locally from a warm cache
        let serve = |remote: bool| -> (f64, f64) {
            let mut s = server();
            for i in 0..2 {
                let mut r = req(0.0, 7, 100, 3);
                r.req.id = i;
                r.remote = remote;
                s.enqueue_ready(r);
            }
            if !remote {
                // warm the cache so the local path pays no page-in
                // (remote adapters never enter the cache at all)
                let pinned = std::collections::BTreeSet::new();
                s.hbm.touch(
                    7,
                    17 << 20,
                    s.cm.server.gpu.pcie_bw,
                    &pinned,
                );
            }
            let tp = s.start_iteration(0.0).unwrap();
            s.finish_iteration(tp);
            let td = s.start_iteration(tp).unwrap();
            (tp, td)
        };
        let (tp_local, td_local) = serve(false);
        let (tp_remote, td_remote) = serve(true);
        assert!(
            (tp_remote - tp_local - penalty).abs() < 1e-12,
            "prefill: one penalty for one distinct remote adapter \
             (local {tp_local}, remote {tp_remote})"
        );
        assert!(
            (td_remote - td_local - penalty).abs() < 1e-12,
            "decode step: one penalty per distinct remote adapter \
             (local {td_local}, remote {td_remote})"
        );
    }

    #[test]
    fn rank_bucketed_admits_single_class() {
        let mut pol = RankBucketed::new(8);
        let mut q: VecDeque<SimReq> = VecDeque::new();
        q.push_back(ranked(0.0, 0, 8));
        q.push_back(ranked(1.0, 1, 128));
        q.push_back(ranked(2.0, 2, 128));
        q.push_back(ranked(3.0, 3, 8));
        // largest class wins the iteration; the batch is homogeneous
        let batch = pol.admit(&mut q, 8, 10_000);
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|r| r.rank == batch[0].rank));
        // the other class stays queued, in order
        assert_eq!(q.len(), 2);
        let leftover: Vec<u32> = q.iter().map(|r| r.rank).collect();
        assert!(leftover.iter().all(|&r| r != batch[0].rank));
        let second = pol.admit(&mut q, 8, 10_000);
        assert_eq!(second.len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn rank_bucketed_starvation_guard_forces_head() {
        let bound = 2;
        let mut pol = RankBucketed::new(bound);
        let mut q: VecDeque<SimReq> = VecDeque::new();
        q.push_back(ranked(0.0, 0, 8)); // lone low-rank head
        for i in 0..3 {
            q.push_back(ranked(1.0 + i as f64, 10 + i, 128));
        }
        for round in 0..bound {
            let batch = pol.admit(&mut q, 8, 10_000);
            assert!(
                batch.iter().all(|r| r.rank == 128),
                "round {round}: majority class must win"
            );
            assert_eq!(q.front().unwrap().rank, 8, "head must remain");
            for i in 0..3 {
                q.push_back(ranked(10.0 + i as f64, 20 + i, 128));
            }
        }
        // head has now been passed over `bound` times: forced through
        let batch = pol.admit(&mut q, 8, 10_000);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].rank, 8);
        assert!(q.iter().all(|r| r.rank == 128));
    }

    #[test]
    fn rank_cap_skips_high_ranks_but_never_the_head() {
        let mut pol = RankCap::new(2);
        let mut q: VecDeque<SimReq> = VecDeque::new();
        q.push_back(ranked(0.0, 0, 8));
        q.push_back(ranked(1.0, 1, 128));
        q.push_back(ranked(2.0, 2, 16)); // within 2 × head rank
        q.push_back(ranked(3.0, 3, 32)); // beyond the cap
        let batch = pol.admit(&mut q, 8, 10_000);
        let ranks: Vec<u32> = batch.iter().map(|r| r.rank).collect();
        assert_eq!(ranks, vec![8, 16]);
        // skipped requests kept their order; the 128 now heads the
        // queue and is admitted unconditionally next round
        let leftover: Vec<u32> = q.iter().map(|r| r.rank).collect();
        assert_eq!(leftover, vec![128, 32]);
        let batch = pol.admit(&mut q, 8, 10_000);
        assert_eq!(batch.len(), 2, "128 admits 32 under its cap");
        assert_eq!(batch[0].rank, 128);
    }

    #[test]
    fn policies_respect_slots_and_token_budget() {
        let ops = crate::costmodel::operating_points(
            &ServerConfig::default(),
            &crate::workload::RANK_CLASSES,
        );
        for kind in [
            BatchPolicyKind::Fifo,
            BatchPolicyKind::RankBucketed {
                max_wait_iters: 4,
                select: ClassSelect::LargestQueue,
            },
            BatchPolicyKind::RankBucketed {
                max_wait_iters: 4,
                select: ClassSelect::CostWeighted,
            },
            BatchPolicyKind::RankCap { factor: 2 },
        ] {
            let mut pol =
                build_policy(kind, DecodePolicyKind::Unified, &ops);
            let mut q: VecDeque<SimReq> = VecDeque::new();
            for i in 0..6 {
                q.push_back(req(i as f64, i, 100, 1));
            }
            let batch = pol.admit(&mut q, 3, 10_000);
            assert_eq!(batch.len(), 3, "{kind:?}: slot limit");
            assert_eq!(q.len(), 3);
            // token budget: second request does not fit
            let mut q2: VecDeque<SimReq> = VecDeque::new();
            q2.push_back(req(0.0, 0, 190, 1));
            q2.push_back(req(1.0, 1, 20, 1));
            let batch = pol.admit(&mut q2, 8, 200);
            assert_eq!(batch.len(), 1, "{kind:?}: token budget");
            // oversized head still admitted alone
            let mut q3: VecDeque<SimReq> = VecDeque::new();
            q3.push_back(req(0.0, 0, 500, 1));
            let batch = pol.admit(&mut q3, 8, 200);
            assert_eq!(batch.len(), 1, "{kind:?}: oversized head");
            // zero slots admit nothing
            let mut q4: VecDeque<SimReq> = VecDeque::new();
            q4.push_back(req(0.0, 0, 10, 1));
            assert!(pol.admit(&mut q4, 0, 200).is_empty());
            assert_eq!(q4.len(), 1);
        }
    }

    #[test]
    fn mixing_metrics_track_padding_tax() {
        let mut s = server();
        let mut lo = req(0.0, 0, 500, 1);
        lo.rank = 8;
        let mut hi = req(0.0, 1, 500, 1);
        hi.rank = 128;
        s.enqueue_ready(lo);
        s.enqueue_ready(hi);
        let t = s.start_iteration(0.0).unwrap();
        assert_eq!(s.prefill_iters, 1);
        assert_eq!(s.mixed_prefill_iters, 1);
        assert_eq!(s.pad_rank_tokens, (128 - 8) as u64 * 500);
        s.finish_iteration(t);
        // a homogeneous batch adds no padding
        let mut s2 = server();
        s2.enqueue_ready(lo);
        s2.enqueue_ready({
            let mut x = lo;
            x.req.adapter = 2;
            x
        });
        s2.start_iteration(0.0).unwrap();
        assert_eq!(s2.prefill_iters, 1);
        assert_eq!(s2.mixed_prefill_iters, 0);
        assert_eq!(s2.pad_rank_tokens, 0);
    }

    #[test]
    fn decode_only_when_no_prefill_queued() {
        let mut s = server();
        s.enqueue_ready(req(0.0, 0, 10, 5));
        let t = s.start_iteration(0.0).unwrap();
        s.finish_iteration(t);
        // now one active decode; enqueue a new prefill — prefill wins
        s.enqueue_ready(req(t, 1, 10, 2));
        s.start_iteration(t).unwrap();
        assert!(matches!(s.running, Iteration::Prefill { .. }));
    }

    /// Unified decode parity at the unit level: the sub-batch step of
    /// the single-group plan bills exactly the pre-refactor whole-set
    /// formula `cm.decode(b, cached, max_rank)`, bit for bit.
    #[test]
    fn unified_decode_step_matches_legacy_formula() {
        let mut s = server();
        let mut lo = req(0.0, 0, 100, 3);
        lo.rank = 8;
        let mut hi = req(0.0, 1, 300, 3);
        hi.rank = 128;
        s.enqueue_ready(lo);
        s.enqueue_ready(hi);
        let t1 = s.start_iteration(0.0).unwrap();
        s.finish_iteration(t1);
        assert_eq!(s.active.len(), 2);
        let t2 = s.start_iteration(t1).unwrap();
        assert!(matches!(s.running, Iteration::Decode { .. }));
        // cached = Σ prompt + produced(=1); whole set pays max rank
        let want = s.cm.decode(2, (100 + 1) + (300 + 1), 128);
        assert_eq!(t2.to_bits(), want.to_bits());
        assert_eq!(s.decode_steps, 1);
        assert_eq!(s.mixed_decode_steps, 1);
        assert_eq!(s.decode_pad_rank, (128 - 8) as u64);
        assert_eq!(s.decode_steps_by_class.get(&128), Some(&1));
    }

    /// A mixed active set under RankPartitioned decodes as one
    /// homogeneous sub-batch step per rank class, each billed at its
    /// own rank plus the launch overhead, with per-class completion
    /// times.
    #[test]
    fn rank_partitioned_decode_runs_per_class_steps() {
        let cm = CostModel::new(ServerConfig::default());
        let mut s = SimServer::with_policy(
            0,
            cm,
            build_policy(
                BatchPolicyKind::Fifo,
                DecodePolicyKind::RankPartitioned,
                &BTreeMap::new(),
            ),
        );
        let mut lo = req(0.0, 0, 100, 2);
        lo.rank = 8;
        let mut hi = req(0.0, 1, 100, 2);
        hi.rank = 128;
        s.enqueue_ready(lo);
        s.enqueue_ready(hi);
        let t1 = s.start_iteration(0.0).unwrap();
        s.finish_iteration(t1);
        // decode round of two class steps sharing one forward pass:
        // step 1 = rank-8 class — it carries the round's base (the
        // whole membership's weights/KV/overheads) plus its own
        // grouped kernel and launch overhead
        let t2 = s.start_iteration(t1).unwrap();
        let want_lo = s.cm.decode_class(1, 8, true)
            + s.cm.decode_base(2, 202);
        assert_eq!(t2.to_bits(), want_lo.to_bits());
        let done = s.finish_iteration(t1 + t2);
        assert_eq!(done.len(), 1, "rank-8 member finishes first");
        assert_eq!(done[0].rank, 8);
        // step 2 = rank-128 class: only its own kernel + launch, still
        // the same round (no prefill in between even if one were
        // queued — the round is atomic)
        let t3 = s.start_iteration(t1 + t2).unwrap();
        let want_hi = s.cm.decode_class(1, 128, true);
        assert_eq!(t3.to_bits(), want_hi.to_bits());
        let done = s.finish_iteration(t1 + t2 + t3);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].rank, 128);
        assert!(s.quiesced());
        assert_eq!(s.decode_steps, 2);
        assert_eq!(s.mixed_decode_steps, 0, "groups are homogeneous");
        assert_eq!(s.decode_pad_rank, 0);
        assert_eq!(s.decode_steps_by_class.get(&8), Some(&1));
        assert_eq!(s.decode_steps_by_class.get(&128), Some(&1));
        // the round pays strictly less than unified + its two launch
        // overheads: the rank-8 member's recovered padding is real
        // (with bigger low-rank groups the round beats unified
        // outright — see costmodel::grouped_decode_cost_split)
        let launch = s.cm.server.decode_launch_overhead;
        assert!(t2 + t3 < s.cm.decode(2, 202, 128) + 2.0 * launch);
    }

    fn active_set(ranks: &[u32]) -> Vec<ActiveReq> {
        ranks
            .iter()
            .enumerate()
            .map(|(i, &rank)| ActiveReq {
                sreq: {
                    let mut r = req(0.0, i as AdapterId, 64, 8);
                    r.rank = rank;
                    r
                },
                produced: 1,
                first_token_at: 0.0,
                seq: i as u64,
            })
            .collect()
    }

    #[test]
    fn class_subbatch_rotor_serves_all_classes() {
        let cm = CostModel::new(ServerConfig::default());
        let mut pol = ClassSubBatchDecode::new(Box::new(Fifo), 2);
        let active = active_set(&[8, 8, 16, 32, 64, 128, 128]);
        // 5 classes, 2 per round: every class must be served at least
        // once within ceil(5/2) = 3 consecutive rounds
        let mut served: std::collections::BTreeSet<u32> =
            Default::default();
        for round in 0..3 {
            let plan = pol.compose_decode(&active, 24, &cm, None);
            assert!(plan.groups.len() <= 2, "round {round}");
            for g in &plan.groups {
                assert!(!g.seqs.is_empty());
                let rank = active
                    .iter()
                    .find(|a| a.seq == g.seqs[0])
                    .unwrap()
                    .sreq
                    .rank;
                // homogeneous: every member has the group's rank
                for &sq in &g.seqs {
                    let a =
                        active.iter().find(|a| a.seq == sq).unwrap();
                    assert_eq!(a.sreq.rank, rank);
                }
                served.insert(rank);
            }
        }
        assert_eq!(
            served.into_iter().collect::<Vec<_>>(),
            vec![8, 16, 32, 64, 128],
            "rotor starved a class"
        );
        // few classes: behaves like rank-partitioned, no rotor skips
        let small = active_set(&[8, 128]);
        let plan = pol.compose_decode(&small, 24, &cm, None);
        assert_eq!(plan.groups.len(), 2);
        assert_eq!(plan.total_members(), 2);
    }

    /// The SLO-aware rotor serves the class with the worst rolling TBT
    /// headroom first; with no signal (fresh tracker) it falls back to
    /// the cyclic rotor.
    #[test]
    fn slo_rotor_serves_worst_headroom_first() {
        use crate::config::SloFeedbackConfig;
        let cm = CostModel::new(ServerConfig::default());
        let fcfg = SloFeedbackConfig {
            enabled: true,
            ttft_target: 1.0,
            tbt_target: 0.1,
            preempt_decode: false,
            pressure_theta: 0.5,
        };
        let active = active_set(&[8, 8, 64, 128]);
        let mut pol = ClassSubBatchDecode::new(Box::new(Fifo), 1);
        // fresh tracker: all headrooms tie at the target -> cyclic
        // rotor, ascending from rank 8
        let fresh = SloTracker::new(fcfg);
        let plan = pol.compose_decode(&active, 24, &cm, Some(&fresh));
        assert_eq!(plan.groups.len(), 1);
        let first = plan.groups[0].seqs[0];
        assert_eq!(
            active.iter().find(|a| a.seq == first).unwrap().sreq.rank,
            8
        );
        // rank 64 decoding far slower than the others: it must win the
        // next round even though the cyclic rotor would pick rank 64's
        // successor
        let mut hot = SloTracker::new(fcfg);
        for i in 0..4 {
            let t = 0.02 * (i + 1) as f64;
            hot.record_decode_step(t, [8u32, 128u32]);
        }
        hot.record_decode_step(0.02, [64u32]);
        hot.record_decode_step(0.30, [64u32]); // 280 ms gap
        let plan = pol.compose_decode(&active, 24, &cm, Some(&hot));
        assert_eq!(plan.groups.len(), 1);
        let first = plan.groups[0].seqs[0];
        assert_eq!(
            active.iter().find(|a| a.seq == first).unwrap().sreq.rank,
            64,
            "worst-TBT-headroom class must be served first"
        );
    }

    /// Adaptive (break-even) composition: big padded classes split
    /// out, tiny ones fold into the max-rank group, and the plan
    /// always covers the whole active set.
    #[test]
    fn class_subbatch_auto_breakeven_plan() {
        let cm = CostModel::new(ServerConfig::default());
        let mut pol = ClassSubBatchDecode::adaptive(Box::new(Fifo));
        // 12 rank-8 members recover far more padding than one launch;
        // a single rank-64 member cannot pay for its own kernel launch
        let mut ranks = vec![8u32; 12];
        ranks.push(64);
        ranks.extend([128, 128]);
        let active = active_set(&ranks);
        let plan = pol.compose_decode(&active, 24, &cm, None);
        assert_eq!(plan.total_members(), active.len(), "covers everyone");
        assert_eq!(plan.groups.len(), 2, "{plan:?}");
        // the split group is the rank-8 dozen; the merged group holds
        // the stray 64 padded up with the 128s
        assert_eq!(plan.groups[0].seqs.len(), 12);
        assert_eq!(plan.groups[1].seqs.len(), 3);
        // homogeneous active set: collapses to the unified plan
        let uni = active_set(&[128, 128, 128]);
        let plan = pol.compose_decode(&uni, 24, &cm, None);
        assert_eq!(plan.groups.len(), 1);
        assert_eq!(plan.total_members(), 3);
    }

    fn slo_server(preempt: bool, ttft_target: f64) -> SimServer {
        use crate::config::SloFeedbackConfig;
        let cm = CostModel::new(ServerConfig::default());
        let mut s = SimServer::with_policy(
            0,
            cm,
            build_policy(
                BatchPolicyKind::Fifo,
                DecodePolicyKind::RankPartitioned,
                &BTreeMap::new(),
            ),
        );
        s.enable_slo(SloFeedbackConfig {
            enabled: true,
            ttft_target,
            tbt_target: 0.1,
            preempt_decode: preempt,
            pressure_theta: 0.9,
        });
        s
    }

    /// Preemption: a prefill arriving mid-round is admitted at the
    /// next sub-batch step boundary under TTFT pressure; the dropped
    /// steps re-plan, and every request still completes (conservation).
    #[test]
    fn preemption_admits_prefill_between_steps_and_conserves() {
        let mut s = slo_server(true, 0.05);
        let mut lo = req(0.0, 0, 100, 4);
        lo.rank = 8;
        let mut hi = req(0.0, 1, 100, 4);
        hi.rank = 128;
        s.enqueue_ready(lo);
        s.enqueue_ready(hi);
        let t1 = s.start_iteration(0.0).unwrap();
        s.finish_iteration(t1);
        assert_eq!(s.active.len(), 2);
        // decode round of two steps starts; a prefill arrives mid-round
        let t2 = s.start_iteration(t1).unwrap();
        assert!(matches!(s.running, Iteration::Decode { .. }));
        let mut late = req(t1, 2, 100, 1);
        late.rank = 8;
        s.enqueue_ready(late);
        s.finish_iteration(t1 + t2);
        // next start: the remaining rank-128 step is preempted (waited
        // + remaining >> (1-theta)*50ms) and the prefill runs instead
        let t3 = s.start_iteration(t1 + t2).unwrap();
        assert!(
            matches!(s.running, Iteration::Prefill { .. }),
            "pressure must preempt the round: {:?}",
            s.running
        );
        assert_eq!(s.preemptions, 1);
        let done = s.finish_iteration(t1 + t2 + t3);
        assert_eq!(done.len(), 1, "single-token prefill completes");
        assert_eq!(s.ttft_under_pressure.len(), 1);
        // drive to quiescence: everyone (incl. the preempted member's
        // re-planned steps) finishes — nothing lost, no empty steps
        let mut now = t1 + t2 + t3;
        let mut completed = done.len();
        for _ in 0..64 {
            match s.start_iteration(now) {
                Some(dt) => {
                    now += dt;
                    completed += s.finish_iteration(now).len();
                }
                None => break,
            }
        }
        assert_eq!(completed, 3, "conservation across preempted rounds");
        assert!(s.quiesced());
    }

    /// Preemption off (or no pressure): rounds stay atomic — the PR 3
    /// contract, bit for bit.
    #[test]
    fn preemption_off_keeps_rounds_atomic() {
        let mut s = slo_server(false, 0.05);
        let mut lo = req(0.0, 0, 100, 4);
        lo.rank = 8;
        let mut hi = req(0.0, 1, 100, 4);
        hi.rank = 128;
        s.enqueue_ready(lo);
        s.enqueue_ready(hi);
        let t1 = s.start_iteration(0.0).unwrap();
        s.finish_iteration(t1);
        let t2 = s.start_iteration(t1).unwrap();
        let mut late = req(t1, 2, 100, 1);
        late.rank = 8;
        s.enqueue_ready(late);
        s.finish_iteration(t1 + t2);
        let _t3 = s.start_iteration(t1 + t2).unwrap();
        assert!(
            matches!(s.running, Iteration::Decode { .. }),
            "round must finish before the prefill without preemption"
        );
        assert_eq!(s.preemptions, 0);
    }

    /// Adaptive max_wait_iters: with the head's TTFT headroom gone,
    /// RankBucketed's guard drops to zero and the head class is forced
    /// immediately; with full headroom the configured bound applies.
    #[test]
    fn rank_bucketed_adaptive_wait_bound() {
        let mut pol = RankBucketed::new(8);
        let mut q: VecDeque<SimReq> = VecDeque::new();
        q.push_back(ranked(0.0, 0, 8)); // lone head
        q.push_back(ranked(1.0, 1, 128));
        q.push_back(ranked(2.0, 2, 128));
        // no headroom left: the guard collapses, head class forced
        pol.set_slo_pressure(0.0);
        let batch = pol.admit(&mut q, 8, 10_000);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].rank, 8, "zero headroom forces the head");
        // full headroom restored: majority class wins again
        pol.set_slo_pressure(1.0);
        q.push_back(ranked(3.0, 3, 8));
        let batch = pol.admit(&mut q, 8, 10_000);
        assert!(batch.iter().all(|r| r.rank == 128));
    }

    #[test]
    fn cost_weighted_class_selection_prefers_expensive_backlog() {
        // three cheap rank-8 prompts vs two rank-128 prompts of the
        // same length: largest-queue picks 8, cost-weighted picks 128
        // (200 tokens / op 100 = 2.0 > 300 tokens / op 1000 = 0.3)
        let fill = |q: &mut VecDeque<SimReq>| {
            q.clear();
            q.push_back(ranked(0.0, 0, 8));
            q.push_back(ranked(1.0, 1, 128));
            q.push_back(ranked(2.0, 2, 8));
            q.push_back(ranked(3.0, 3, 128));
            q.push_back(ranked(4.0, 4, 8));
        };
        let mut q: VecDeque<SimReq> = VecDeque::new();
        fill(&mut q);
        let mut largest = RankBucketed::new(8);
        let batch = largest.admit(&mut q, 8, 10_000);
        assert!(batch.iter().all(|r| r.rank == 8));
        assert_eq!(batch.len(), 3);
        let mut ops: BTreeMap<u32, f64> = BTreeMap::new();
        ops.insert(8, 1000.0);
        ops.insert(128, 100.0);
        fill(&mut q);
        let mut cost = RankBucketed::cost_weighted(8, ops);
        let batch = cost.admit(&mut q, 8, 10_000);
        assert!(batch.iter().all(|r| r.rank == 128), "{batch:?}");
        assert_eq!(batch.len(), 2);
        // the starvation guard still forces the head eventually
        let mut q2: VecDeque<SimReq> = VecDeque::new();
        let mut forced = RankBucketed::cost_weighted(1, {
            let mut m = BTreeMap::new();
            m.insert(8u32, 1000.0);
            m.insert(128u32, 100.0);
            m
        });
        q2.push_back(ranked(0.0, 0, 8)); // lone cheap head
        q2.push_back(ranked(1.0, 1, 128));
        let b1 = forced.admit(&mut q2, 8, 10_000);
        assert!(b1.iter().all(|r| r.rank == 128));
        q2.push_back(ranked(2.0, 2, 128));
        let b2 = forced.admit(&mut q2, 8, 10_000);
        assert_eq!(b2[0].rank, 8, "guard must force the head class");
    }
}
