//! Simulated LLM inference server: admission queue + iteration-level
//! continuous batching with prefill priority (the vLLM/S-LoRA-style
//! engine the paper's cluster is made of).
//!
//! The rank-interference mechanism is first-class here: every
//! iteration's service time is computed with the **maximum adapter rank
//! present in that batch** (`costmodel::prefill_time`/`decode_time`),
//! exactly the pad-to-max-rank behaviour of the BGMV/MBGMV kernels.
//!
//! *What* enters a batch is pluggable via [`BatchPolicy`]: [`Fifo`]
//! reproduces the classic arrival-order admission bit for bit, while
//! [`RankBucketed`] and [`RankCap`] are rank-aware compositions (the
//! CaraServe-style scheduler half of the design space) that trade a
//! little queueing for rank-homogeneous batches.

use crate::config::BatchPolicyKind;
use crate::costmodel::CostModel;
use crate::workload::{AdapterId, Request};
use std::collections::VecDeque;

/// A request resident on a server.
#[derive(Debug, Clone, Copy)]
pub struct SimReq {
    pub req: Request,
    pub rank: u32,
    /// Adapter weight bytes (GPU paging cost on a cache miss).
    pub adapter_bytes: u64,
    /// Routed-time service estimate (for Toppings' outstanding-work).
    pub est: f64,
}

/// S-LoRA-style GPU adapter cache: active adapter slices live in a
/// fixed HBM pool; a batch whose adapter is not resident pages it in
/// from host memory over PCIe before the iteration can run. LRU
/// eviction, with adapters of currently-active sequences pinned.
#[derive(Debug, Default)]
pub struct GpuAdapterCache {
    budget: u64,
    used: u64,
    /// adapter -> (bytes, last-use tick)
    entries: std::collections::BTreeMap<AdapterId, (u64, u64)>,
    tick: u64,
    pub loads: u64,
    pub load_bytes: u64,
}

impl GpuAdapterCache {
    pub fn new(budget: u64) -> Self {
        GpuAdapterCache {
            budget,
            ..Default::default()
        }
    }

    /// Ensure `adapter` is resident; returns the PCIe paging time
    /// (0 on hit). `pinned` adapters are never evicted.
    pub fn touch(
        &mut self,
        adapter: AdapterId,
        bytes: u64,
        pcie_bw: f64,
        pinned: &std::collections::BTreeSet<AdapterId>,
    ) -> f64 {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&adapter) {
            e.1 = self.tick;
            return 0.0;
        }
        // evict LRU until it fits (pinned entries skipped)
        while self.used + bytes > self.budget && !self.entries.is_empty()
        {
            let victim = self
                .entries
                .iter()
                .filter(|(a, _)| !pinned.contains(a))
                .min_by_key(|(_, (_, t))| *t)
                .map(|(a, _)| *a);
            match victim {
                Some(a) => {
                    let (b, _) = self.entries.remove(&a).unwrap();
                    self.used -= b;
                }
                None => break, // everything pinned; overcommit
            }
        }
        self.entries.insert(adapter, (bytes, self.tick));
        self.used += bytes;
        self.loads += 1;
        self.load_bytes += bytes;
        100e-6 + bytes as f64 / pcie_bw
    }

    pub fn resident(&self, adapter: AdapterId) -> bool {
        self.entries.contains_key(&adapter)
    }
}

/// Prefill admission: given the ready queue (FIFO by arrival), decide
/// which requests enter this iteration's prefill batch. Implementations
/// remove admitted requests from `queue` (preserving the relative order
/// of everything left behind) and must respect `slots` (free decode
/// slots) and `max_tokens` (iteration token budget; the first admitted
/// request is exempt so oversized prompts still run alone).
pub trait BatchPolicy: std::fmt::Debug {
    fn name(&self) -> &'static str;

    fn admit(
        &mut self,
        queue: &mut VecDeque<SimReq>,
        slots: usize,
        max_tokens: u64,
    ) -> Vec<SimReq>;
}

/// Build the policy instance a server owns (policies carry per-server
/// state such as starvation counters, so each server gets its own).
pub fn build_policy(kind: BatchPolicyKind) -> Box<dyn BatchPolicy> {
    match kind {
        BatchPolicyKind::Fifo => Box::new(Fifo),
        BatchPolicyKind::RankBucketed { max_wait_iters } => {
            Box::new(RankBucketed::new(max_wait_iters))
        }
        BatchPolicyKind::RankCap { factor } => {
            Box::new(RankCap::new(factor))
        }
    }
}

/// Strict arrival order — the S-LoRA/vLLM admission loop, unchanged:
/// take from the front while slots remain and the token budget holds.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fifo;

impl BatchPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn admit(
        &mut self,
        queue: &mut VecDeque<SimReq>,
        slots: usize,
        max_tokens: u64,
    ) -> Vec<SimReq> {
        let mut batch: Vec<SimReq> = Vec::new();
        let mut tokens = 0u64;
        while let Some(head) = queue.front() {
            if batch.len() >= slots {
                break;
            }
            let t = head.req.prompt_len as u64;
            if !batch.is_empty() && tokens + t > max_tokens {
                break;
            }
            tokens += t;
            batch.push(queue.pop_front().unwrap());
        }
        batch
    }
}

/// One rank class per prefill iteration: the chosen class's requests
/// are admitted in arrival order; every other class waits. The class
/// with the most queued requests wins (ties go to the class whose
/// oldest request arrived first), except that whenever the queue's
/// head request has been passed over `max_wait_iters` consecutive
/// prefill iterations, its class is forced — the bounded-wait
/// starvation guard. Because admission scans from the front, a forced
/// class always admits the head, so no request waits at the head for
/// more than `max_wait_iters` admitting iterations.
#[derive(Debug, Clone, Copy)]
pub struct RankBucketed {
    pub max_wait_iters: u32,
    /// Consecutive admitting iterations the current head request has
    /// been passed over.
    waited: u32,
}

impl RankBucketed {
    pub fn new(max_wait_iters: u32) -> Self {
        RankBucketed {
            max_wait_iters,
            waited: 0,
        }
    }
}

impl BatchPolicy for RankBucketed {
    fn name(&self) -> &'static str {
        "rank-bucketed"
    }

    fn admit(
        &mut self,
        queue: &mut VecDeque<SimReq>,
        slots: usize,
        max_tokens: u64,
    ) -> Vec<SimReq> {
        if queue.is_empty() || slots == 0 {
            return Vec::new();
        }
        let front_rank = queue.front().unwrap().rank;
        let chosen = if self.waited >= self.max_wait_iters {
            front_rank
        } else {
            // largest queued class; ties to the oldest head
            let mut counts: std::collections::BTreeMap<u32, (usize, usize)> =
                Default::default();
            for (i, r) in queue.iter().enumerate() {
                counts.entry(r.rank).or_insert((0, i)).0 += 1;
            }
            let mut best = (0usize, usize::MAX, 0u32);
            for (&rank, &(count, first)) in &counts {
                if count > best.0 || (count == best.0 && first < best.1) {
                    best = (count, first, rank);
                }
            }
            best.2
        };
        let mut batch: Vec<SimReq> = Vec::new();
        let mut tokens = 0u64;
        let mut kept: VecDeque<SimReq> =
            VecDeque::with_capacity(queue.len());
        let mut stop = false;
        for r in queue.drain(..) {
            if stop || batch.len() >= slots || r.rank != chosen {
                kept.push_back(r);
                continue;
            }
            let t = r.req.prompt_len as u64;
            if !batch.is_empty() && tokens + t > max_tokens {
                // budget full: stop admitting to keep FIFO order
                // within the class
                kept.push_back(r);
                stop = true;
                continue;
            }
            tokens += t;
            batch.push(r);
        }
        *queue = kept;
        if !batch.is_empty() {
            if chosen == front_rank {
                self.waited = 0; // the head was admitted
            } else {
                self.waited += 1;
            }
        }
        batch
    }
}

/// Arrival order with a rank ceiling: the head request is always
/// admitted and sets the ceiling at `factor ×` its rank; later
/// requests whose rank exceeds the ceiling are skipped (they stay
/// queued, in order) instead of dragging the whole batch up to their
/// rank. Nothing starves — a skipped request reaches the head in FIFO
/// time and is then admitted unconditionally.
#[derive(Debug, Clone, Copy)]
pub struct RankCap {
    pub factor: u32,
}

impl RankCap {
    pub fn new(factor: u32) -> Self {
        assert!(factor >= 1, "rank-cap factor must be >= 1");
        RankCap { factor }
    }
}

impl BatchPolicy for RankCap {
    fn name(&self) -> &'static str {
        "rank-cap"
    }

    fn admit(
        &mut self,
        queue: &mut VecDeque<SimReq>,
        slots: usize,
        max_tokens: u64,
    ) -> Vec<SimReq> {
        if queue.is_empty() || slots == 0 {
            return Vec::new();
        }
        let mut batch: Vec<SimReq> = Vec::new();
        let mut tokens = 0u64;
        let mut cap = 0u32;
        let mut kept: VecDeque<SimReq> =
            VecDeque::with_capacity(queue.len());
        let mut stop = false;
        for r in queue.drain(..) {
            if stop || batch.len() >= slots {
                kept.push_back(r);
                continue;
            }
            if batch.is_empty() {
                cap = r.rank.saturating_mul(self.factor);
                tokens += r.req.prompt_len as u64;
                batch.push(r);
                continue;
            }
            if r.rank > cap {
                kept.push_back(r); // rank-skipped; keep scanning
                continue;
            }
            let t = r.req.prompt_len as u64;
            if tokens + t > max_tokens {
                kept.push_back(r);
                stop = true;
                continue;
            }
            tokens += t;
            batch.push(r);
        }
        *queue = kept;
        batch
    }
}

#[derive(Debug, Clone, Copy)]
pub struct ActiveReq {
    pub sreq: SimReq,
    /// Tokens produced so far (>= 1 once prefilled).
    pub produced: u32,
    pub first_token_at: f64,
}

/// What the server is currently executing.
#[derive(Debug, Clone)]
pub enum Iteration {
    Idle,
    Prefill { batch: Vec<SimReq> },
    Decode,
}

/// Outcome of one finished request.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub req: Request,
    pub server: usize,
    pub ttft: f64,
    /// Mean time between tokens (NaN for single-token outputs).
    pub tbt: f64,
    pub finished_at: f64,
}

#[derive(Debug)]
pub struct SimServer {
    pub id: usize,
    pub cm: CostModel,
    /// Ready-to-prefill FIFO.
    pub queue: VecDeque<SimReq>,
    /// Requests waiting for their adapter to be fetched.
    pub waiting_fetch: Vec<SimReq>,
    pub active: Vec<ActiveReq>,
    pub running: Iteration,
    /// Outstanding-work estimate in seconds (Toppings' signal).
    pub outstanding: f64,
    /// Drain state: no new work is routed here; active decodes finish
    /// and last-copy adapters migrate before the server retires.
    pub draining: bool,
    pub gpu_cache: GpuAdapterCache,
    pub busy_until: f64,
    pub busy_time: f64,
    /// Per-server TTFT samples (queueing+prefill, Fig 18 top).
    pub ttft_samples: Vec<f64>,
    pub timeouts: u64,
    /// Mixing diagnostics: iterations total / iterations whose batch
    /// max rank was >= 64 (the interference tax indicator).
    pub iters: u64,
    pub iters_highrank: u64,
    /// Prefill-composition diagnostics (per batch policy): prefill
    /// iterations, prefill iterations mixing ≥2 distinct ranks, and
    /// Σ (batch_max_rank − rank) × prompt_tokens — the volume of
    /// pad-to-max-rank work the kernels burn on mixed batches.
    pub prefill_iters: u64,
    pub mixed_prefill_iters: u64,
    pub pad_rank_tokens: u64,
    /// Prefill admission policy (owned per server: policies carry
    /// starvation-guard state).
    pub policy: Box<dyn BatchPolicy>,
}

impl SimServer {
    /// FIFO-admitting server (the classic engine).
    pub fn new(id: usize, cm: CostModel) -> Self {
        Self::with_policy(id, cm, Box::new(Fifo))
    }

    pub fn with_policy(
        id: usize,
        cm: CostModel,
        policy: Box<dyn BatchPolicy>,
    ) -> Self {
        SimServer {
            id,
            cm,
            queue: VecDeque::new(),
            waiting_fetch: Vec::new(),
            active: Vec::new(),
            running: Iteration::Idle,
            outstanding: 0.0,
            draining: false,
            gpu_cache: GpuAdapterCache::new(
                cm.server.gpu_adapter_cache_bytes,
            ),
            busy_until: 0.0,
            busy_time: 0.0,
            ttft_samples: Vec::new(),
            timeouts: 0,
            iters: 0,
            iters_highrank: 0,
            prefill_iters: 0,
            mixed_prefill_iters: 0,
            pad_rank_tokens: 0,
            policy,
        }
    }

    pub fn is_idle(&self) -> bool {
        matches!(self.running, Iteration::Idle)
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    /// Requests queued, waiting, or decoding on this server — the
    /// count-granularity load signal the Toppings router inspects.
    pub fn pending_count(&self) -> usize {
        self.queue.len() + self.waiting_fetch.len() + self.active.len()
    }

    /// Estimated service seconds a request adds to this server.
    pub fn estimate(cm: &CostModel, req: &Request, rank: u32) -> f64 {
        let prefill = cm.prefill(req.prompt_len as u64, rank);
        // decode share: assume a typical batch of half max_batch_size
        let b = (cm.server.max_batch_size / 2).max(1);
        let step = cm.decode(b, b as u64 * 640, rank);
        prefill + step / b as f64 * req.output_len as f64
    }

    pub fn enqueue_ready(&mut self, sreq: SimReq) {
        self.outstanding += sreq.est;
        self.queue.push_back(sreq);
    }

    pub fn enqueue_waiting(&mut self, sreq: SimReq) {
        self.outstanding += sreq.est;
        self.waiting_fetch.push(sreq);
    }

    /// Move requests whose adapter just became resident into the ready
    /// queue (ordered by arrival to preserve FIFO fairness).
    pub fn release_waiting(&mut self, adapter: AdapterId) {
        let mut released: Vec<SimReq> = Vec::new();
        self.waiting_fetch.retain(|r| {
            if r.req.adapter == adapter {
                released.push(*r);
                false
            } else {
                true
            }
        });
        released.sort_by(|a, b| {
            a.req.arrival.partial_cmp(&b.req.arrival).unwrap()
        });
        for r in released {
            self.queue.push_back(r);
        }
    }

    /// Pull every not-yet-running request off this server (drain
    /// protocol step 1: queued + waiting-for-fetch work gets re-routed
    /// through the swapped table), restoring the outstanding-work
    /// estimate. Sorted by arrival so re-delivery preserves FIFO
    /// fairness. Active (already prefilled) sequences stay and finish
    /// here.
    pub fn extract_pending(&mut self) -> Vec<SimReq> {
        let mut out: Vec<SimReq> = self.queue.drain(..).collect();
        out.extend(self.waiting_fetch.drain(..));
        for r in &out {
            self.outstanding -= r.est;
        }
        out.sort_by(|a, b| {
            a.req.arrival.partial_cmp(&b.req.arrival).unwrap()
        });
        out
    }

    /// True once a draining server holds no work at all — the compute
    /// half of the retire condition (the pool half is that it holds no
    /// last-copy adapters).
    pub fn quiesced(&self) -> bool {
        self.queue.is_empty()
            && self.waiting_fetch.is_empty()
            && self.active.is_empty()
            && self.is_idle()
    }

    /// Drop queued requests older than `timeout` (frontend gives up).
    ///
    /// The ready queue is FIFO by arrival, so expired requests cluster
    /// at the front: a front-only scan is O(dropped) instead of the
    /// O(queue-depth) full retain this used to be — which dominated
    /// 90% of simulation time under backlog (EXPERIMENTS.md §Perf).
    /// Requests re-queued out of order by `release_waiting` are at
    /// worst dropped a little late, when they reach the front.
    pub fn purge_timeouts(&mut self, now: f64, timeout: f64) -> u64 {
        let mut dropped = 0;
        while let Some(front) = self.queue.front() {
            if now - front.req.arrival > timeout {
                let r = self.queue.pop_front().unwrap();
                self.outstanding -= r.est;
                dropped += 1;
            } else {
                break;
            }
        }
        // the waiting-fetch list is short (adapters in flight); keep
        // the exact scan but skip it when empty
        if !self.waiting_fetch.is_empty() {
            let outstanding = &mut self.outstanding;
            self.waiting_fetch.retain(|r| {
                if now - r.req.arrival > timeout {
                    *outstanding -= r.est;
                    dropped += 1;
                    false
                } else {
                    true
                }
            });
        }
        self.timeouts += dropped;
        dropped
    }

    /// Start the next iteration if idle and work exists. Returns the
    /// iteration's service time (caller schedules IterationDone).
    ///
    /// Prefill-prioritized iteration-level scheduling: the owned
    /// [`BatchPolicy`] admits a prefill batch (token budget + slot
    /// limited) if any request is queued, otherwise one decode step
    /// runs over all active sequences.
    pub fn start_iteration(&mut self, now: f64) -> Option<f64> {
        if !self.is_idle() {
            return None;
        }
        // admit prefills (policy-selected composition)
        let slots = self
            .cm
            .server
            .max_batch_size
            .saturating_sub(self.active.len());
        let batch = self.policy.admit(
            &mut self.queue,
            slots,
            self.cm.server.max_batch_tokens as u64,
        );
        if !batch.is_empty() {
            let tokens: u64 =
                batch.iter().map(|r| r.req.prompt_len as u64).sum();
            let max_rank =
                batch.iter().map(|r| r.rank).max().unwrap_or(0);
            self.prefill_iters += 1;
            if batch.iter().any(|r| r.rank != batch[0].rank) {
                self.mixed_prefill_iters += 1;
            }
            self.pad_rank_tokens += batch
                .iter()
                .map(|r| {
                    u64::from(max_rank - r.rank)
                        * r.req.prompt_len as u64
                })
                .sum::<u64>();
            // page this batch's adapters into the GPU pool (S-LoRA
            // unified paging); active sequences' adapters are pinned
            let pinned: std::collections::BTreeSet<AdapterId> = self
                .active
                .iter()
                .map(|a| a.sreq.req.adapter)
                .chain(batch.iter().map(|r| r.req.adapter))
                .collect();
            let mut load_time = 0.0;
            let pcie = self.cm.server.gpu.pcie_bw;
            for r in &batch {
                load_time += self.gpu_cache.touch(
                    r.req.adapter,
                    r.adapter_bytes,
                    pcie,
                    &pinned,
                );
            }
            let time = self.cm.prefill(tokens, max_rank) + load_time;
            self.iters += 1;
            self.iters_highrank += (max_rank >= 64) as u64;
            self.running = Iteration::Prefill { batch };
            self.busy_until = now + time;
            self.busy_time += time;
            return Some(time);
        }
        if !self.active.is_empty() {
            let b = self.active.len();
            let cached: u64 = self
                .active
                .iter()
                .map(|a| {
                    a.sreq.req.prompt_len as u64 + a.produced as u64
                })
                .sum();
            let max_rank =
                self.active.iter().map(|a| a.sreq.rank).max().unwrap();
            let time = self.cm.decode(b, cached, max_rank);
            self.iters += 1;
            self.iters_highrank += (max_rank >= 64) as u64;
            self.running = Iteration::Decode;
            self.busy_until = now + time;
            self.busy_time += time;
            return Some(time);
        }
        None
    }

    /// Finish the running iteration; returns completed requests.
    pub fn finish_iteration(&mut self, now: f64) -> Vec<Completion> {
        let mut done = Vec::new();
        match std::mem::replace(&mut self.running, Iteration::Idle) {
            Iteration::Idle => {}
            Iteration::Prefill { batch } => {
                for sreq in batch {
                    let ttft = now - sreq.req.arrival;
                    self.ttft_samples.push(ttft);
                    if sreq.req.output_len <= 1 {
                        self.outstanding -= sreq.est;
                        done.push(Completion {
                            req: sreq.req,
                            server: self.id,
                            ttft,
                            tbt: f64::NAN,
                            finished_at: now,
                        });
                    } else {
                        self.active.push(ActiveReq {
                            sreq,
                            produced: 1,
                            first_token_at: now,
                        });
                    }
                }
            }
            Iteration::Decode => {
                let id = self.id;
                let outstanding = &mut self.outstanding;
                self.active.retain_mut(|a| {
                    a.produced += 1;
                    if a.produced >= a.sreq.req.output_len {
                        *outstanding -= a.sreq.est;
                        done.push(Completion {
                            req: a.sreq.req,
                            server: id,
                            ttft: a.first_token_at - a.sreq.req.arrival,
                            tbt: (now - a.first_token_at)
                                / (a.sreq.req.output_len - 1).max(1) as f64,
                            finished_at: now,
                        });
                        false
                    } else {
                        true
                    }
                });
            }
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServerConfig;

    fn server() -> SimServer {
        SimServer::new(0, CostModel::new(ServerConfig::default()))
    }

    fn req(arrival: f64, adapter: AdapterId, prompt: u32, output: u32) -> SimReq {
        let r = Request {
            id: 0,
            adapter,
            prompt_len: prompt,
            output_len: output,
            arrival,
        };
        SimReq {
            req: r,
            rank: 8,
            adapter_bytes: 17 << 20,
            est: 0.1,
        }
    }

    #[test]
    fn prefill_then_decode_lifecycle() {
        let mut s = server();
        s.enqueue_ready(req(0.0, 0, 100, 3));
        let t1 = s.start_iteration(0.0).unwrap();
        assert!(t1 > 0.0);
        let done = s.finish_iteration(t1);
        assert!(done.is_empty());
        assert_eq!(s.active.len(), 1);
        assert_eq!(s.ttft_samples.len(), 1);
        // two decode steps to finish output_len=3
        let t2 = s.start_iteration(t1).unwrap();
        assert!(s.finish_iteration(t1 + t2).is_empty());
        let t3 = s.start_iteration(t1 + t2).unwrap();
        let done = s.finish_iteration(t1 + t2 + t3);
        assert_eq!(done.len(), 1);
        let c = done[0];
        assert!((c.ttft - t1).abs() < 1e-12);
        assert!((c.tbt - (t2 + t3) / 2.0).abs() < 1e-12);
        assert!(!s.has_work());
        assert!(s.outstanding.abs() < 1e-9);
    }

    #[test]
    fn single_token_output_completes_at_prefill() {
        let mut s = server();
        s.enqueue_ready(req(0.0, 0, 50, 1));
        let t = s.start_iteration(0.0).unwrap();
        let done = s.finish_iteration(t);
        assert_eq!(done.len(), 1);
        assert!(done[0].tbt.is_nan());
        assert!(s.active.is_empty());
    }

    #[test]
    fn batch_respects_token_budget() {
        let mut s = server();
        let budget = s.cm.server.max_batch_tokens as u32;
        s.enqueue_ready(req(0.0, 0, budget - 10, 2));
        s.enqueue_ready(req(0.0, 1, 100, 2));
        s.start_iteration(0.0).unwrap();
        if let Iteration::Prefill { batch } = &s.running {
            assert_eq!(batch.len(), 1, "second prompt must not fit");
        } else {
            panic!("expected prefill");
        }
        assert_eq!(s.queue.len(), 1);
    }

    #[test]
    fn oversized_prompt_still_admitted_alone() {
        let mut s = server();
        let budget = s.cm.server.max_batch_tokens as u32;
        s.enqueue_ready(req(0.0, 0, budget * 2, 2));
        assert!(s.start_iteration(0.0).is_some());
    }

    #[test]
    fn mixed_rank_batch_pays_max_rank() {
        let mut s = server();
        let mut lo = req(0.0, 0, 500, 2);
        lo.rank = 8;
        let mut hi = req(0.0, 1, 500, 2);
        hi.rank = 128;
        // homogeneous low-rank batch
        let mut s1 = server();
        s1.enqueue_ready(lo);
        s1.enqueue_ready({
            let mut x = lo;
            x.req.adapter = 2;
            x
        });
        let t_lo = s1.start_iteration(0.0).unwrap();
        // mixed batch of the same token count
        s.enqueue_ready(lo);
        s.enqueue_ready(hi);
        let t_mixed = s.start_iteration(0.0).unwrap();
        assert!(
            t_mixed > t_lo * 1.2,
            "mixed {t_mixed} vs homogeneous {t_lo}"
        );
    }

    #[test]
    fn waiting_fetch_released_in_arrival_order() {
        let mut s = server();
        s.enqueue_waiting(req(2.0, 5, 10, 1));
        s.enqueue_waiting(req(1.0, 5, 10, 1));
        s.enqueue_waiting(req(1.5, 6, 10, 1));
        s.release_waiting(5);
        assert_eq!(s.queue.len(), 2);
        assert_eq!(s.queue[0].req.arrival, 1.0);
        assert_eq!(s.waiting_fetch.len(), 1);
    }

    #[test]
    fn purge_timeouts_counts_and_restores_outstanding() {
        let mut s = server();
        s.enqueue_ready(req(0.0, 0, 10, 1));
        s.enqueue_waiting(req(0.5, 1, 10, 1));
        let before = s.outstanding;
        assert!(before > 0.0);
        let dropped = s.purge_timeouts(100.0, 10.0);
        assert_eq!(dropped, 2);
        assert_eq!(s.timeouts, 2);
        assert!(s.outstanding.abs() < 1e-9);
        assert_eq!(s.purge_timeouts(100.0, 1000.0), 0);
    }

    #[test]
    fn extract_pending_drains_queues_in_arrival_order() {
        let mut s = server();
        s.enqueue_ready(req(2.0, 0, 10, 1));
        s.enqueue_waiting(req(1.0, 1, 10, 1));
        s.enqueue_ready(req(3.0, 2, 10, 1));
        assert!(s.outstanding > 0.0);
        let pending = s.extract_pending();
        assert_eq!(pending.len(), 3);
        assert_eq!(pending[0].req.arrival, 1.0);
        assert_eq!(pending[2].req.arrival, 3.0);
        assert!(s.outstanding.abs() < 1e-9);
        assert!(s.quiesced());
    }

    #[test]
    fn quiesced_tracks_active_work() {
        let mut s = server();
        assert!(s.quiesced());
        s.enqueue_ready(req(0.0, 0, 10, 3));
        assert!(!s.quiesced());
        let t = s.start_iteration(0.0).unwrap();
        s.finish_iteration(t);
        // one active decode sequence keeps the server busy
        assert!(!s.quiesced());
        let t2 = s.start_iteration(t).unwrap();
        s.finish_iteration(t + t2);
        let t3 = s.start_iteration(t + t2).unwrap();
        s.finish_iteration(t + t2 + t3);
        assert!(s.quiesced());
    }

    fn ranked(arrival: f64, adapter: AdapterId, rank: u32) -> SimReq {
        let mut r = req(arrival, adapter, 100, 1);
        r.rank = rank;
        r
    }

    #[test]
    fn rank_bucketed_admits_single_class() {
        let mut pol = RankBucketed::new(8);
        let mut q: VecDeque<SimReq> = VecDeque::new();
        q.push_back(ranked(0.0, 0, 8));
        q.push_back(ranked(1.0, 1, 128));
        q.push_back(ranked(2.0, 2, 128));
        q.push_back(ranked(3.0, 3, 8));
        // largest class wins the iteration; the batch is homogeneous
        let batch = pol.admit(&mut q, 8, 10_000);
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|r| r.rank == batch[0].rank));
        // the other class stays queued, in order
        assert_eq!(q.len(), 2);
        let leftover: Vec<u32> = q.iter().map(|r| r.rank).collect();
        assert!(leftover.iter().all(|&r| r != batch[0].rank));
        let second = pol.admit(&mut q, 8, 10_000);
        assert_eq!(second.len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn rank_bucketed_starvation_guard_forces_head() {
        let bound = 2;
        let mut pol = RankBucketed::new(bound);
        let mut q: VecDeque<SimReq> = VecDeque::new();
        q.push_back(ranked(0.0, 0, 8)); // lone low-rank head
        for i in 0..3 {
            q.push_back(ranked(1.0 + i as f64, 10 + i, 128));
        }
        for round in 0..bound {
            let batch = pol.admit(&mut q, 8, 10_000);
            assert!(
                batch.iter().all(|r| r.rank == 128),
                "round {round}: majority class must win"
            );
            assert_eq!(q.front().unwrap().rank, 8, "head must remain");
            for i in 0..3 {
                q.push_back(ranked(10.0 + i as f64, 20 + i, 128));
            }
        }
        // head has now been passed over `bound` times: forced through
        let batch = pol.admit(&mut q, 8, 10_000);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].rank, 8);
        assert!(q.iter().all(|r| r.rank == 128));
    }

    #[test]
    fn rank_cap_skips_high_ranks_but_never_the_head() {
        let mut pol = RankCap::new(2);
        let mut q: VecDeque<SimReq> = VecDeque::new();
        q.push_back(ranked(0.0, 0, 8));
        q.push_back(ranked(1.0, 1, 128));
        q.push_back(ranked(2.0, 2, 16)); // within 2 × head rank
        q.push_back(ranked(3.0, 3, 32)); // beyond the cap
        let batch = pol.admit(&mut q, 8, 10_000);
        let ranks: Vec<u32> = batch.iter().map(|r| r.rank).collect();
        assert_eq!(ranks, vec![8, 16]);
        // skipped requests kept their order; the 128 now heads the
        // queue and is admitted unconditionally next round
        let leftover: Vec<u32> = q.iter().map(|r| r.rank).collect();
        assert_eq!(leftover, vec![128, 32]);
        let batch = pol.admit(&mut q, 8, 10_000);
        assert_eq!(batch.len(), 2, "128 admits 32 under its cap");
        assert_eq!(batch[0].rank, 128);
    }

    #[test]
    fn policies_respect_slots_and_token_budget() {
        for kind in [
            BatchPolicyKind::Fifo,
            BatchPolicyKind::RankBucketed { max_wait_iters: 4 },
            BatchPolicyKind::RankCap { factor: 2 },
        ] {
            let mut pol = build_policy(kind);
            let mut q: VecDeque<SimReq> = VecDeque::new();
            for i in 0..6 {
                q.push_back(req(i as f64, i, 100, 1));
            }
            let batch = pol.admit(&mut q, 3, 10_000);
            assert_eq!(batch.len(), 3, "{kind:?}: slot limit");
            assert_eq!(q.len(), 3);
            // token budget: second request does not fit
            let mut q2: VecDeque<SimReq> = VecDeque::new();
            q2.push_back(req(0.0, 0, 190, 1));
            q2.push_back(req(1.0, 1, 20, 1));
            let batch = pol.admit(&mut q2, 8, 200);
            assert_eq!(batch.len(), 1, "{kind:?}: token budget");
            // oversized head still admitted alone
            let mut q3: VecDeque<SimReq> = VecDeque::new();
            q3.push_back(req(0.0, 0, 500, 1));
            let batch = pol.admit(&mut q3, 8, 200);
            assert_eq!(batch.len(), 1, "{kind:?}: oversized head");
            // zero slots admit nothing
            let mut q4: VecDeque<SimReq> = VecDeque::new();
            q4.push_back(req(0.0, 0, 10, 1));
            assert!(pol.admit(&mut q4, 0, 200).is_empty());
            assert_eq!(q4.len(), 1);
        }
    }

    #[test]
    fn mixing_metrics_track_padding_tax() {
        let mut s = server();
        let mut lo = req(0.0, 0, 500, 1);
        lo.rank = 8;
        let mut hi = req(0.0, 1, 500, 1);
        hi.rank = 128;
        s.enqueue_ready(lo);
        s.enqueue_ready(hi);
        let t = s.start_iteration(0.0).unwrap();
        assert_eq!(s.prefill_iters, 1);
        assert_eq!(s.mixed_prefill_iters, 1);
        assert_eq!(s.pad_rank_tokens, (128 - 8) as u64 * 500);
        s.finish_iteration(t);
        // a homogeneous batch adds no padding
        let mut s2 = server();
        s2.enqueue_ready(lo);
        s2.enqueue_ready({
            let mut x = lo;
            x.req.adapter = 2;
            x
        });
        s2.start_iteration(0.0).unwrap();
        assert_eq!(s2.prefill_iters, 1);
        assert_eq!(s2.mixed_prefill_iters, 0);
        assert_eq!(s2.pad_rank_tokens, 0);
    }

    #[test]
    fn decode_only_when_no_prefill_queued() {
        let mut s = server();
        s.enqueue_ready(req(0.0, 0, 10, 5));
        let t = s.start_iteration(0.0).unwrap();
        s.finish_iteration(t);
        // now one active decode; enqueue a new prefill — prefill wins
        s.enqueue_ready(req(t, 1, 10, 2));
        s.start_iteration(t).unwrap();
        assert!(matches!(s.running, Iteration::Prefill { .. }));
    }
}
