//! Simulated LLM inference server: admission queue + iteration-level
//! continuous batching with prefill priority (the vLLM/S-LoRA-style
//! engine the paper's cluster is made of).
//!
//! The rank-interference mechanism is first-class here: every
//! iteration's service time is computed with the **maximum adapter rank
//! present in that batch** (`costmodel::prefill_time`/`decode_time`),
//! exactly the pad-to-max-rank behaviour of the BGMV/MBGMV kernels.

use crate::costmodel::CostModel;
use crate::workload::{AdapterId, Request};
use std::collections::VecDeque;

/// A request resident on a server.
#[derive(Debug, Clone, Copy)]
pub struct SimReq {
    pub req: Request,
    pub rank: u32,
    /// Adapter weight bytes (GPU paging cost on a cache miss).
    pub adapter_bytes: u64,
    /// Routed-time service estimate (for Toppings' outstanding-work).
    pub est: f64,
}

/// S-LoRA-style GPU adapter cache: active adapter slices live in a
/// fixed HBM pool; a batch whose adapter is not resident pages it in
/// from host memory over PCIe before the iteration can run. LRU
/// eviction, with adapters of currently-active sequences pinned.
#[derive(Debug, Default)]
pub struct GpuAdapterCache {
    budget: u64,
    used: u64,
    /// adapter -> (bytes, last-use tick)
    entries: std::collections::BTreeMap<AdapterId, (u64, u64)>,
    tick: u64,
    pub loads: u64,
    pub load_bytes: u64,
}

impl GpuAdapterCache {
    pub fn new(budget: u64) -> Self {
        GpuAdapterCache {
            budget,
            ..Default::default()
        }
    }

    /// Ensure `adapter` is resident; returns the PCIe paging time
    /// (0 on hit). `pinned` adapters are never evicted.
    pub fn touch(
        &mut self,
        adapter: AdapterId,
        bytes: u64,
        pcie_bw: f64,
        pinned: &std::collections::BTreeSet<AdapterId>,
    ) -> f64 {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&adapter) {
            e.1 = self.tick;
            return 0.0;
        }
        // evict LRU until it fits (pinned entries skipped)
        while self.used + bytes > self.budget && !self.entries.is_empty()
        {
            let victim = self
                .entries
                .iter()
                .filter(|(a, _)| !pinned.contains(a))
                .min_by_key(|(_, (_, t))| *t)
                .map(|(a, _)| *a);
            match victim {
                Some(a) => {
                    let (b, _) = self.entries.remove(&a).unwrap();
                    self.used -= b;
                }
                None => break, // everything pinned; overcommit
            }
        }
        self.entries.insert(adapter, (bytes, self.tick));
        self.used += bytes;
        self.loads += 1;
        self.load_bytes += bytes;
        100e-6 + bytes as f64 / pcie_bw
    }

    pub fn resident(&self, adapter: AdapterId) -> bool {
        self.entries.contains_key(&adapter)
    }
}

#[derive(Debug, Clone, Copy)]
pub struct ActiveReq {
    pub sreq: SimReq,
    /// Tokens produced so far (>= 1 once prefilled).
    pub produced: u32,
    pub first_token_at: f64,
}

/// What the server is currently executing.
#[derive(Debug, Clone)]
pub enum Iteration {
    Idle,
    Prefill { batch: Vec<SimReq> },
    Decode,
}

/// Outcome of one finished request.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub req: Request,
    pub server: usize,
    pub ttft: f64,
    /// Mean time between tokens (NaN for single-token outputs).
    pub tbt: f64,
    pub finished_at: f64,
}

#[derive(Debug)]
pub struct SimServer {
    pub id: usize,
    pub cm: CostModel,
    /// Ready-to-prefill FIFO.
    pub queue: VecDeque<SimReq>,
    /// Requests waiting for their adapter to be fetched.
    pub waiting_fetch: Vec<SimReq>,
    pub active: Vec<ActiveReq>,
    pub running: Iteration,
    /// Outstanding-work estimate in seconds (Toppings' signal).
    pub outstanding: f64,
    /// Drain state: no new work is routed here; active decodes finish
    /// and last-copy adapters migrate before the server retires.
    pub draining: bool,
    pub gpu_cache: GpuAdapterCache,
    pub busy_until: f64,
    pub busy_time: f64,
    /// Per-server TTFT samples (queueing+prefill, Fig 18 top).
    pub ttft_samples: Vec<f64>,
    pub timeouts: u64,
    /// Mixing diagnostics: iterations total / iterations whose batch
    /// max rank was >= 64 (the interference tax indicator).
    pub iters: u64,
    pub iters_highrank: u64,
}

impl SimServer {
    pub fn new(id: usize, cm: CostModel) -> Self {
        SimServer {
            id,
            cm,
            queue: VecDeque::new(),
            waiting_fetch: Vec::new(),
            active: Vec::new(),
            running: Iteration::Idle,
            outstanding: 0.0,
            draining: false,
            gpu_cache: GpuAdapterCache::new(
                cm.server.gpu_adapter_cache_bytes,
            ),
            busy_until: 0.0,
            busy_time: 0.0,
            ttft_samples: Vec::new(),
            timeouts: 0,
            iters: 0,
            iters_highrank: 0,
        }
    }

    pub fn is_idle(&self) -> bool {
        matches!(self.running, Iteration::Idle)
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    /// Requests queued, waiting, or decoding on this server — the
    /// count-granularity load signal the Toppings router inspects.
    pub fn pending_count(&self) -> usize {
        self.queue.len() + self.waiting_fetch.len() + self.active.len()
    }

    /// Estimated service seconds a request adds to this server.
    pub fn estimate(cm: &CostModel, req: &Request, rank: u32) -> f64 {
        let prefill = cm.prefill(req.prompt_len as u64, rank);
        // decode share: assume a typical batch of half max_batch_size
        let b = (cm.server.max_batch_size / 2).max(1);
        let step = cm.decode(b, b as u64 * 640, rank);
        prefill + step / b as f64 * req.output_len as f64
    }

    pub fn enqueue_ready(&mut self, sreq: SimReq) {
        self.outstanding += sreq.est;
        self.queue.push_back(sreq);
    }

    pub fn enqueue_waiting(&mut self, sreq: SimReq) {
        self.outstanding += sreq.est;
        self.waiting_fetch.push(sreq);
    }

    /// Move requests whose adapter just became resident into the ready
    /// queue (ordered by arrival to preserve FIFO fairness).
    pub fn release_waiting(&mut self, adapter: AdapterId) {
        let mut released: Vec<SimReq> = Vec::new();
        self.waiting_fetch.retain(|r| {
            if r.req.adapter == adapter {
                released.push(*r);
                false
            } else {
                true
            }
        });
        released.sort_by(|a, b| {
            a.req.arrival.partial_cmp(&b.req.arrival).unwrap()
        });
        for r in released {
            self.queue.push_back(r);
        }
    }

    /// Pull every not-yet-running request off this server (drain
    /// protocol step 1: queued + waiting-for-fetch work gets re-routed
    /// through the swapped table), restoring the outstanding-work
    /// estimate. Sorted by arrival so re-delivery preserves FIFO
    /// fairness. Active (already prefilled) sequences stay and finish
    /// here.
    pub fn extract_pending(&mut self) -> Vec<SimReq> {
        let mut out: Vec<SimReq> = self.queue.drain(..).collect();
        out.extend(self.waiting_fetch.drain(..));
        for r in &out {
            self.outstanding -= r.est;
        }
        out.sort_by(|a, b| {
            a.req.arrival.partial_cmp(&b.req.arrival).unwrap()
        });
        out
    }

    /// True once a draining server holds no work at all — the compute
    /// half of the retire condition (the pool half is that it holds no
    /// last-copy adapters).
    pub fn quiesced(&self) -> bool {
        self.queue.is_empty()
            && self.waiting_fetch.is_empty()
            && self.active.is_empty()
            && self.is_idle()
    }

    /// Drop queued requests older than `timeout` (frontend gives up).
    ///
    /// The ready queue is FIFO by arrival, so expired requests cluster
    /// at the front: a front-only scan is O(dropped) instead of the
    /// O(queue-depth) full retain this used to be — which dominated
    /// 90% of simulation time under backlog (EXPERIMENTS.md §Perf).
    /// Requests re-queued out of order by `release_waiting` are at
    /// worst dropped a little late, when they reach the front.
    pub fn purge_timeouts(&mut self, now: f64, timeout: f64) -> u64 {
        let mut dropped = 0;
        while let Some(front) = self.queue.front() {
            if now - front.req.arrival > timeout {
                let r = self.queue.pop_front().unwrap();
                self.outstanding -= r.est;
                dropped += 1;
            } else {
                break;
            }
        }
        // the waiting-fetch list is short (adapters in flight); keep
        // the exact scan but skip it when empty
        if !self.waiting_fetch.is_empty() {
            let outstanding = &mut self.outstanding;
            self.waiting_fetch.retain(|r| {
                if now - r.req.arrival > timeout {
                    *outstanding -= r.est;
                    dropped += 1;
                    false
                } else {
                    true
                }
            });
        }
        self.timeouts += dropped;
        dropped
    }

    /// Start the next iteration if idle and work exists. Returns the
    /// iteration's service time (caller schedules IterationDone).
    ///
    /// Policy: prefill-prioritized iteration-level scheduling — admit a
    /// prefill batch (token budget + slot limited) if any request is
    /// queued, otherwise run one decode step over all active sequences.
    pub fn start_iteration(&mut self, now: f64) -> Option<f64> {
        if !self.is_idle() {
            return None;
        }
        // admit prefills
        let mut batch: Vec<SimReq> = Vec::new();
        let mut tokens = 0u64;
        let slots = self
            .cm
            .server
            .max_batch_size
            .saturating_sub(self.active.len());
        while let Some(head) = self.queue.front() {
            if batch.len() >= slots {
                break;
            }
            let t = head.req.prompt_len as u64;
            if !batch.is_empty()
                && tokens + t > self.cm.server.max_batch_tokens as u64
            {
                break;
            }
            tokens += t;
            batch.push(self.queue.pop_front().unwrap());
        }
        if !batch.is_empty() {
            let max_rank =
                batch.iter().map(|r| r.rank).max().unwrap_or(0);
            // page this batch's adapters into the GPU pool (S-LoRA
            // unified paging); active sequences' adapters are pinned
            let pinned: std::collections::BTreeSet<AdapterId> = self
                .active
                .iter()
                .map(|a| a.sreq.req.adapter)
                .chain(batch.iter().map(|r| r.req.adapter))
                .collect();
            let mut load_time = 0.0;
            let pcie = self.cm.server.gpu.pcie_bw;
            for r in &batch {
                load_time += self.gpu_cache.touch(
                    r.req.adapter,
                    r.adapter_bytes,
                    pcie,
                    &pinned,
                );
            }
            let time = self.cm.prefill(tokens, max_rank) + load_time;
            self.iters += 1;
            self.iters_highrank += (max_rank >= 64) as u64;
            self.running = Iteration::Prefill { batch };
            self.busy_until = now + time;
            self.busy_time += time;
            return Some(time);
        }
        if !self.active.is_empty() {
            let b = self.active.len();
            let cached: u64 = self
                .active
                .iter()
                .map(|a| {
                    a.sreq.req.prompt_len as u64 + a.produced as u64
                })
                .sum();
            let max_rank =
                self.active.iter().map(|a| a.sreq.rank).max().unwrap();
            let time = self.cm.decode(b, cached, max_rank);
            self.iters += 1;
            self.iters_highrank += (max_rank >= 64) as u64;
            self.running = Iteration::Decode;
            self.busy_until = now + time;
            self.busy_time += time;
            return Some(time);
        }
        None
    }

    /// Finish the running iteration; returns completed requests.
    pub fn finish_iteration(&mut self, now: f64) -> Vec<Completion> {
        let mut done = Vec::new();
        match std::mem::replace(&mut self.running, Iteration::Idle) {
            Iteration::Idle => {}
            Iteration::Prefill { batch } => {
                for sreq in batch {
                    let ttft = now - sreq.req.arrival;
                    self.ttft_samples.push(ttft);
                    if sreq.req.output_len <= 1 {
                        self.outstanding -= sreq.est;
                        done.push(Completion {
                            req: sreq.req,
                            server: self.id,
                            ttft,
                            tbt: f64::NAN,
                            finished_at: now,
                        });
                    } else {
                        self.active.push(ActiveReq {
                            sreq,
                            produced: 1,
                            first_token_at: now,
                        });
                    }
                }
            }
            Iteration::Decode => {
                let id = self.id;
                let outstanding = &mut self.outstanding;
                self.active.retain_mut(|a| {
                    a.produced += 1;
                    if a.produced >= a.sreq.req.output_len {
                        *outstanding -= a.sreq.est;
                        done.push(Completion {
                            req: a.sreq.req,
                            server: id,
                            ttft: a.first_token_at - a.sreq.req.arrival,
                            tbt: (now - a.first_token_at)
                                / (a.sreq.req.output_len - 1).max(1) as f64,
                            finished_at: now,
                        });
                        false
                    } else {
                        true
                    }
                });
            }
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServerConfig;

    fn server() -> SimServer {
        SimServer::new(0, CostModel::new(ServerConfig::default()))
    }

    fn req(arrival: f64, adapter: AdapterId, prompt: u32, output: u32) -> SimReq {
        let r = Request {
            id: 0,
            adapter,
            prompt_len: prompt,
            output_len: output,
            arrival,
        };
        SimReq {
            req: r,
            rank: 8,
            adapter_bytes: 17 << 20,
            est: 0.1,
        }
    }

    #[test]
    fn prefill_then_decode_lifecycle() {
        let mut s = server();
        s.enqueue_ready(req(0.0, 0, 100, 3));
        let t1 = s.start_iteration(0.0).unwrap();
        assert!(t1 > 0.0);
        let done = s.finish_iteration(t1);
        assert!(done.is_empty());
        assert_eq!(s.active.len(), 1);
        assert_eq!(s.ttft_samples.len(), 1);
        // two decode steps to finish output_len=3
        let t2 = s.start_iteration(t1).unwrap();
        assert!(s.finish_iteration(t1 + t2).is_empty());
        let t3 = s.start_iteration(t1 + t2).unwrap();
        let done = s.finish_iteration(t1 + t2 + t3);
        assert_eq!(done.len(), 1);
        let c = done[0];
        assert!((c.ttft - t1).abs() < 1e-12);
        assert!((c.tbt - (t2 + t3) / 2.0).abs() < 1e-12);
        assert!(!s.has_work());
        assert!(s.outstanding.abs() < 1e-9);
    }

    #[test]
    fn single_token_output_completes_at_prefill() {
        let mut s = server();
        s.enqueue_ready(req(0.0, 0, 50, 1));
        let t = s.start_iteration(0.0).unwrap();
        let done = s.finish_iteration(t);
        assert_eq!(done.len(), 1);
        assert!(done[0].tbt.is_nan());
        assert!(s.active.is_empty());
    }

    #[test]
    fn batch_respects_token_budget() {
        let mut s = server();
        let budget = s.cm.server.max_batch_tokens as u32;
        s.enqueue_ready(req(0.0, 0, budget - 10, 2));
        s.enqueue_ready(req(0.0, 1, 100, 2));
        s.start_iteration(0.0).unwrap();
        if let Iteration::Prefill { batch } = &s.running {
            assert_eq!(batch.len(), 1, "second prompt must not fit");
        } else {
            panic!("expected prefill");
        }
        assert_eq!(s.queue.len(), 1);
    }

    #[test]
    fn oversized_prompt_still_admitted_alone() {
        let mut s = server();
        let budget = s.cm.server.max_batch_tokens as u32;
        s.enqueue_ready(req(0.0, 0, budget * 2, 2));
        assert!(s.start_iteration(0.0).is_some());
    }

    #[test]
    fn mixed_rank_batch_pays_max_rank() {
        let mut s = server();
        let mut lo = req(0.0, 0, 500, 2);
        lo.rank = 8;
        let mut hi = req(0.0, 1, 500, 2);
        hi.rank = 128;
        // homogeneous low-rank batch
        let mut s1 = server();
        s1.enqueue_ready(lo);
        s1.enqueue_ready({
            let mut x = lo;
            x.req.adapter = 2;
            x
        });
        let t_lo = s1.start_iteration(0.0).unwrap();
        // mixed batch of the same token count
        s.enqueue_ready(lo);
        s.enqueue_ready(hi);
        let t_mixed = s.start_iteration(0.0).unwrap();
        assert!(
            t_mixed > t_lo * 1.2,
            "mixed {t_mixed} vs homogeneous {t_lo}"
        );
    }

    #[test]
    fn waiting_fetch_released_in_arrival_order() {
        let mut s = server();
        s.enqueue_waiting(req(2.0, 5, 10, 1));
        s.enqueue_waiting(req(1.0, 5, 10, 1));
        s.enqueue_waiting(req(1.5, 6, 10, 1));
        s.release_waiting(5);
        assert_eq!(s.queue.len(), 2);
        assert_eq!(s.queue[0].req.arrival, 1.0);
        assert_eq!(s.waiting_fetch.len(), 1);
    }

    #[test]
    fn purge_timeouts_counts_and_restores_outstanding() {
        let mut s = server();
        s.enqueue_ready(req(0.0, 0, 10, 1));
        s.enqueue_waiting(req(0.5, 1, 10, 1));
        let before = s.outstanding;
        assert!(before > 0.0);
        let dropped = s.purge_timeouts(100.0, 10.0);
        assert_eq!(dropped, 2);
        assert_eq!(s.timeouts, 2);
        assert!(s.outstanding.abs() < 1e-9);
        assert_eq!(s.purge_timeouts(100.0, 1000.0), 0);
    }

    #[test]
    fn extract_pending_drains_queues_in_arrival_order() {
        let mut s = server();
        s.enqueue_ready(req(2.0, 0, 10, 1));
        s.enqueue_waiting(req(1.0, 1, 10, 1));
        s.enqueue_ready(req(3.0, 2, 10, 1));
        assert!(s.outstanding > 0.0);
        let pending = s.extract_pending();
        assert_eq!(pending.len(), 3);
        assert_eq!(pending[0].req.arrival, 1.0);
        assert_eq!(pending[2].req.arrival, 3.0);
        assert!(s.outstanding.abs() < 1e-9);
        assert!(s.quiesced());
    }

    #[test]
    fn quiesced_tracks_active_work() {
        let mut s = server();
        assert!(s.quiesced());
        s.enqueue_ready(req(0.0, 0, 10, 3));
        assert!(!s.quiesced());
        let t = s.start_iteration(0.0).unwrap();
        s.finish_iteration(t);
        // one active decode sequence keeps the server busy
        assert!(!s.quiesced());
        let t2 = s.start_iteration(t).unwrap();
        s.finish_iteration(t + t2);
        let t3 = s.start_iteration(t + t2).unwrap();
        s.finish_iteration(t + t2 + t3);
        assert!(s.quiesced());
    }

    #[test]
    fn decode_only_when_no_prefill_queued() {
        let mut s = server();
        s.enqueue_ready(req(0.0, 0, 10, 5));
        let t = s.start_iteration(0.0).unwrap();
        s.finish_iteration(t);
        // now one active decode; enqueue a new prefill — prefill wins
        s.enqueue_ready(req(t, 1, 10, 2));
        s.start_iteration(t).unwrap();
        assert!(matches!(s.running, Iteration::Prefill { .. }));
    }
}
