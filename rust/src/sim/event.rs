//! Discrete-event machinery: the simulator's event alphabet and a
//! deterministic time-ordered event heap.
//!
//! The heap is generic over the event payload so the sharded engine
//! can reuse it both for the coordinator's control queue
//! (`EventQueue<SimEvent>`) and for each server lane's private heap
//! (`EventQueue<LaneEvent>`). Ordering is a single packed
//! `(time_bits, seq)` `u128` key compare: for non-negative finite
//! `f64` times the IEEE-754 bit pattern is order-preserving, so one
//! integer compare replaces the old two-field float-then-int chain.

use crate::workload::{AdapterId, ServerId};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Control-plane events — everything the coordinator handles
/// sequentially at epoch barriers: the request path's routing and
/// fetch landings, rebalance/migration, the autoscaler, and drain.
/// Server-local iteration completions (`IterDone`) are *not* here:
/// they live in each server lane's private heap
/// (`engine::LaneEvent`), which is what makes lanes advance in
/// parallel between barriers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimEvent {
    /// Request `trace.requests[i]` reaches the coordinator.
    Arrive(usize),
    /// An RDMA adapter fetch lands on its destination server.
    FetchDone(ServerId, AdapterId),
    /// Periodic LORASERVE re-placement (Algorithm 1 time step).
    Rebalance,
    /// Drift-reactive trigger evaluation (`--rebalance-mode
    /// triggered|hybrid`): roll the demand window, read the
    /// load-imbalance / SLO-headroom signals, and fire an incremental
    /// rebalance when the `RebalanceTrigger` says so.
    TriggerCheck,
    /// Autoscaler signal-evaluation tick (`AutoscaleConfig`
    /// `decision_period`).
    AutoscaleTick,
    /// A provisioned server finishes cold start and joins the fleet.
    ServerReady(ServerId),
    /// Re-check whether a draining server has fully quiesced
    /// (drain-and-migrate protocol).
    DrainCheck(ServerId),
    /// A batched drain-time RDMA migration lands on its destination
    /// server. The engine resolves the adapter group by the carried
    /// batch id (one event per destination, not per adapter).
    MigrationDone(ServerId, u32),
    /// Scenario failure injection: the seeded MTBF process kills one
    /// active server (victim chosen at fire time from the live fleet).
    /// A coordinator-epoch event — all lanes flush before it lands.
    ServerCrash,
    /// A crashed server comes back (MTTR elapsed) and rejoins the
    /// fleet empty-handed.
    ServerRecover(ServerId),
}

/// Events are ordered by time, then by insertion sequence (FIFO among
/// simultaneous events) — this makes runs bit-reproducible. Both are
/// packed into one `u128` (`time.to_bits() << 64 | seq`) so the heap's
/// sift compares are a single integer compare. Valid because sim time
/// is non-negative and finite (asserted on push): for such doubles the
/// raw bit pattern orders exactly like the float.
#[derive(Debug)]
struct Scheduled<E> {
    key: u128,
    event: E,
}

#[inline]
fn pack(time: f64, seq: u64) -> u128 {
    ((time.to_bits() as u128) << 64) | seq as u128
}

#[inline]
fn unpack_time(key: u128) -> f64 {
    f64::from_bits((key >> 64) as u64)
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first
        other.key.cmp(&self.key)
    }
}

#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// A queue pre-sized for `n` events (e.g. the trace's request
    /// count), so the bootstrap `push` storm never re-grows the heap.
    pub fn with_capacity(n: usize) -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(n),
            seq: 0,
            now: 0.0,
        }
    }

    /// Grow the backing heap to hold at least `additional` more events
    /// without reallocating.
    pub fn reserve(&mut self, additional: usize) {
        self.heap.reserve(additional);
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn push(&mut self, time: f64, event: E) {
        debug_assert!(
            time >= self.now - 1e-9,
            "scheduling into the past: {time} < {}",
            self.now
        );
        debug_assert!(
            time.is_finite() && time >= 0.0,
            "event time must be finite and non-negative (bit-packed \
             ordering): {time}"
        );
        self.heap.push(Scheduled {
            key: pack(time.max(self.now), self.seq),
            event,
        });
        self.seq += 1;
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|s| {
            let time = unpack_time(s.key);
            debug_assert!(time >= self.now - 1e-9);
            self.now = time;
            (time, s.event)
        })
    }

    /// Timestamp of the earliest pending event (the clock does not
    /// advance). The sharded engine's lane flush loops on this:
    /// `while peek_time() <= horizon { pop() }` (inclusive — a
    /// same-timestamp delivery must land before the control event
    /// that reads it).
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|s| unpack_time(s.key))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drop every pending event without rewinding the clock or the
    /// sequence counter (a crashed server's lane wipe: scheduled
    /// deliveries and iteration completions die with the server, but
    /// determinism requires `now`/`seq` to keep their history).
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.now(), 2.0);
        q.push(2.5, "d");
        assert_eq!(q.pop().unwrap(), (2.5, "d"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn fifo_among_ties() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clear_keeps_clock_and_seq() {
        let mut q = EventQueue::new();
        q.push(1.0, "a");
        q.push(5.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.now(), 1.0, "clear must not rewind the clock");
        q.push(2.0, "c");
        assert_eq!(q.pop().unwrap(), (2.0, "c"));
    }

    #[test]
    fn property_random_order_is_sorted() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(9);
        let mut q = EventQueue::with_capacity(1000);
        for i in 0..1000 {
            q.push(rng.f64() * 100.0, i);
        }
        let mut last = 0.0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
    }

    #[test]
    fn packed_key_orders_like_float_then_seq() {
        // the u128 pack is order-isomorphic to (time, seq) for the
        // domain the queue accepts (finite, non-negative times)
        let times = [0.0, 1e-300, 0.5, 1.0, 1.0000000000000002, 3e5];
        for w in times.windows(2) {
            assert!(pack(w[0], u64::MAX) < pack(w[1], 0));
        }
        assert!(pack(2.0, 0) < pack(2.0, 1));
    }
}
