//! Discrete-event machinery: the simulator's event alphabet and a
//! deterministic time-ordered event heap.

use crate::workload::{AdapterId, ServerId};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Everything that can happen in the cluster simulation — the request
/// path (arrive/iterate/fetch), the control plane (rebalance), and the
/// elastic-capacity subsystem's topology-change events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimEvent {
    /// Request `trace.requests[i]` reaches the coordinator.
    Arrive(usize),
    /// A server finishes its running prefill/decode iteration.
    IterDone(ServerId),
    /// An RDMA adapter fetch lands on its destination server.
    FetchDone(ServerId, AdapterId),
    /// Periodic LORASERVE re-placement (Algorithm 1 time step).
    Rebalance,
    /// Drift-reactive trigger evaluation (`--rebalance-mode
    /// triggered|hybrid`): roll the demand window, read the
    /// load-imbalance / SLO-headroom signals, and fire an incremental
    /// rebalance when the `RebalanceTrigger` says so.
    TriggerCheck,
    /// Autoscaler signal-evaluation tick (`AutoscaleConfig`
    /// `decision_period`).
    AutoscaleTick,
    /// A provisioned server finishes cold start and joins the fleet.
    ServerReady(ServerId),
    /// Re-check whether a draining server has fully quiesced
    /// (drain-and-migrate protocol).
    DrainCheck(ServerId),
    /// A batched drain-time RDMA migration lands on its destination
    /// server. The engine resolves the adapter group by the carried
    /// batch id (one event per destination, not per adapter).
    MigrationDone(ServerId, u32),
}

/// Events are ordered by time, then by insertion sequence (FIFO among
/// simultaneous events) — this makes runs bit-reproducible.
#[derive(Debug)]
struct Scheduled<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn push(&mut self, time: f64, event: E) {
        debug_assert!(
            time >= self.now - 1e-9,
            "scheduling into the past: {time} < {}",
            self.now
        );
        debug_assert!(time.is_finite());
        self.heap.push(Scheduled {
            time: time.max(self.now),
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|s| {
            debug_assert!(s.time >= self.now - 1e-9);
            self.now = s.time;
            (s.time, s.event)
        })
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.now(), 2.0);
        q.push(2.5, "d");
        assert_eq!(q.pop().unwrap(), (2.5, "d"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_among_ties() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn property_random_order_is_sorted() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(9);
        let mut q = EventQueue::new();
        for i in 0..1000 {
            q.push(rng.f64() * 100.0, i);
        }
        let mut last = 0.0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
    }
}
