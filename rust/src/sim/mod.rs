//! Discrete-event cluster simulator.
//!
//! Reproduces the paper's testbed dynamics (queueing, continuous
//! batching, rank interference, adapter fetches, rebalancing) at paper
//! scale, with per-batch service times from `costmodel`. The *real*
//! PJRT-backed mini-cluster lives in `server/`; both share the
//! coordinator/placement/pool code.

pub mod cluster;
pub mod engine;
pub mod event;
pub mod profile;
pub mod rebalance;
pub mod report;
pub mod scenario;
pub mod server;
pub mod slo;
pub mod topology;

pub use cluster::{
    custom_system_spec, register_custom_system,
    registered_custom_systems, run, run_observed, LoraServeOpts,
    SimConfig, SpecParams, SystemKind,
};
pub use engine::{
    run_spec, run_spec_observed, LoadSignal, PlacementPolicy, PoolMode,
    RoutingPolicy, SimEngine, SystemSpec,
};
pub use rebalance::{
    imbalance_ratio, plan_incremental, IncrementalPlan, RebalanceTrigger,
    UtilCache,
};
pub use report::SimReport;
pub use server::{BatchPolicy, DecodeGroup, DecodePlan};
pub use slo::SloTracker;
