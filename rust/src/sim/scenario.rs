//! Operational scenario runtime knobs: failure injection + regions.
//!
//! `ScenarioConfig` is the runtime half of the production scenario
//! pack (the trace half lives in `trace::scenario`). It travels inside
//! `SpecParams`/`SystemSpec`, so every engine run carries it; all
//! defaults are inert — a default `ScenarioConfig` leaves the engine
//! byte-identical to the pre-scenario code paths.
//!
//! * `FailureConfig` drives the seeded MTBF crash process: the engine
//!   pre-seeds `ServerCrash` control events from a dedicated RNG
//!   stream, each crash hard-stops an active server (state `Crashed`,
//!   in-flight requests requeued or failed, every adapter copy lost,
//!   last copies re-fetched from host memory) and schedules a
//!   `ServerRecover` an exponential MTTR later.
//! * `RegionConfig` tags servers with a region (`id % n_regions`) and
//!   prices inter-region RDMA distinctly from intra-region in the
//!   fetch cost model (derated bandwidth + extra fabric latency).
//!
//! A `--scenario file.json` bundles both with an optional
//! `trace::scenario::ScenarioTraceConfig` under `"trace"`.

use crate::trace::scenario::ScenarioTraceConfig;
use crate::util::json::{self, Json};

/// Seeded MTBF/MTTR failure-injection process. Inert by default.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureConfig {
    pub enabled: bool,
    /// Mean time between failures (s) — fleet-wide exponential
    /// inter-crash gaps.
    pub mtbf: f64,
    /// Mean time to recovery (s) — exponential per-crash downtime.
    pub mttr: f64,
    /// No crash fires before this time (lets warmup settle).
    pub start: f64,
    /// Hard cap on injected crashes per run.
    pub max_crashes: u32,
    /// `true`: a crashed server's in-flight requests are re-routed to
    /// survivors (conservation: completed + timeouts = arrived).
    /// `false`: they fail outright and are counted in
    /// `SimReport::crash_failed`.
    pub requeue: bool,
}

impl Default for FailureConfig {
    fn default() -> Self {
        FailureConfig {
            enabled: false,
            mtbf: 600.0,
            mttr: 60.0,
            start: 60.0,
            max_crashes: 4,
            requeue: true,
        }
    }
}

/// Region topology: server `s` lives in region `s % n_regions`.
/// `n_regions <= 1` disables region-aware pricing entirely.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RegionConfig {
    pub n_regions: usize,
    /// Inter-region RDMA bandwidth as a fraction of the intra-region
    /// NIC-bound path (WAN/fabric oversubscription).
    pub inter_bw_factor: f64,
    /// Extra one-way latency (s) an inter-region transfer pays on top
    /// of the RDMA setup cost.
    pub inter_latency: f64,
}

impl Default for RegionConfig {
    fn default() -> Self {
        RegionConfig {
            n_regions: 1,
            inter_bw_factor: 0.25,
            inter_latency: 750e-6,
        }
    }
}

impl RegionConfig {
    /// Region tag of a server id.
    pub fn region_of(&self, server: usize) -> usize {
        server % self.n_regions.max(1)
    }

    /// Whether two servers sit in different regions (always false when
    /// regions are disabled).
    pub fn crosses(&self, a: usize, b: usize) -> bool {
        self.n_regions > 1 && self.region_of(a) != self.region_of(b)
    }
}

/// Runtime scenario knobs carried by `SystemSpec`. Default is inert.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ScenarioConfig {
    pub failures: FailureConfig,
    pub regions: RegionConfig,
}

impl ScenarioConfig {
    /// Overlay `"failures"` / `"regions"` JSON sections on the inert
    /// defaults. Missing keys keep defaults; present keys are
    /// validated.
    pub fn from_json(v: &Json) -> Result<ScenarioConfig, String> {
        let mut cfg = ScenarioConfig::default();
        if let Some(f) = v.get("failures") {
            if let Some(x) = f.get("enabled").and_then(Json::as_bool) {
                cfg.failures.enabled = x;
            }
            if let Some(x) = f.get("mtbf").and_then(Json::as_f64) {
                if x <= 0.0 {
                    return Err(format!(
                        "failures.mtbf must be > 0, got {x}"
                    ));
                }
                cfg.failures.mtbf = x;
            }
            if let Some(x) = f.get("mttr").and_then(Json::as_f64) {
                if x <= 0.0 {
                    return Err(format!(
                        "failures.mttr must be > 0, got {x}"
                    ));
                }
                cfg.failures.mttr = x;
            }
            if let Some(x) = f.get("start").and_then(Json::as_f64) {
                if x < 0.0 {
                    return Err(format!(
                        "failures.start must be >= 0, got {x}"
                    ));
                }
                cfg.failures.start = x;
            }
            if let Some(x) =
                f.get("max_crashes").and_then(Json::as_usize)
            {
                cfg.failures.max_crashes = x as u32;
            }
            if let Some(s) = f.get("on_crash").and_then(Json::as_str) {
                cfg.failures.requeue = match s {
                    "requeue" => true,
                    "fail" => false,
                    other => {
                        return Err(format!(
                            "failures.on_crash must be \
                             'requeue' or 'fail', got '{other}'"
                        ))
                    }
                };
            }
        }
        if let Some(r) = v.get("regions") {
            if let Some(x) = r.get("n_regions").and_then(Json::as_usize)
            {
                if x == 0 {
                    return Err(
                        "regions.n_regions must be >= 1".into()
                    );
                }
                cfg.regions.n_regions = x;
            }
            if let Some(x) =
                r.get("inter_bw_factor").and_then(Json::as_f64)
            {
                if !(x > 0.0 && x <= 1.0) {
                    return Err(format!(
                        "regions.inter_bw_factor must be in (0, 1], \
                         got {x}"
                    ));
                }
                cfg.regions.inter_bw_factor = x;
            }
            if let Some(x) =
                r.get("inter_latency").and_then(Json::as_f64)
            {
                if x < 0.0 {
                    return Err(format!(
                        "regions.inter_latency must be >= 0, got {x}"
                    ));
                }
                cfg.regions.inter_latency = x;
            }
        }
        Ok(cfg)
    }
}

/// A full `--scenario` file: a name, optional trace-generation knobs,
/// and the runtime failure/region knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    /// When present, the CLI synthesizes the workload from
    /// `trace::scenario::generate` instead of the `--trace` choice.
    pub trace: Option<ScenarioTraceConfig>,
    pub runtime: ScenarioConfig,
}

impl Scenario {
    pub fn from_json(v: &Json) -> Result<Scenario, String> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("scenario")
            .to_string();
        let trace = match v.get("trace") {
            Some(t) => Some(ScenarioTraceConfig::from_json(t)?),
            None => None,
        };
        Ok(Scenario {
            name,
            trace,
            runtime: ScenarioConfig::from_json(v)?,
        })
    }

    pub fn from_file(path: &str) -> Result<Scenario, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path}: {e}"))?;
        let v = json::parse(&text)
            .map_err(|e| format!("parse {path}: {e}"))?;
        Scenario::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_inert() {
        let cfg = ScenarioConfig::default();
        assert!(!cfg.failures.enabled);
        assert_eq!(cfg.regions.n_regions, 1);
        assert!(!cfg.regions.crosses(0, 5));
    }

    #[test]
    fn region_tags_and_crossing() {
        let r = RegionConfig {
            n_regions: 3,
            ..RegionConfig::default()
        };
        assert_eq!(r.region_of(0), 0);
        assert_eq!(r.region_of(4), 1);
        assert!(r.crosses(0, 1));
        assert!(!r.crosses(0, 3));
    }

    #[test]
    fn json_roundtrip_and_validation() {
        let v = json::parse(
            r#"{
                "name": "res",
                "failures": {"enabled": true, "mtbf": 120.0,
                             "mttr": 30.0, "max_crashes": 2,
                             "on_crash": "fail"},
                "regions": {"n_regions": 2, "inter_bw_factor": 0.5},
                "trace": {"n_adapters": 32, "rps": 20.0}
            }"#,
        )
        .unwrap();
        let s = Scenario::from_json(&v).unwrap();
        assert_eq!(s.name, "res");
        assert!(s.runtime.failures.enabled);
        assert_eq!(s.runtime.failures.mtbf, 120.0);
        assert!(!s.runtime.failures.requeue);
        assert_eq!(s.runtime.regions.n_regions, 2);
        let t = s.trace.expect("trace section");
        assert_eq!(t.n_adapters, 32);

        for bad in [
            r#"{"failures": {"mtbf": 0}}"#,
            r#"{"failures": {"on_crash": "explode"}}"#,
            r#"{"regions": {"n_regions": 0}}"#,
            r#"{"regions": {"inter_bw_factor": 2.0}}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(
                ScenarioConfig::from_json(&v).is_err(),
                "{bad} should be rejected"
            );
        }
    }
}
