//! Simulation outcome: the metrics every figure harness consumes.

use crate::metrics::FleetMetrics;
use crate::util::stats::Samples;
use crate::workload::AdapterId;
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub system: String,
    pub trace: String,
    /// End-to-end time to first token (queueing + fetch + prefill).
    pub ttft: Samples,
    /// Mean time between tokens per request.
    pub tbt: Samples,
    /// End-to-end request latency (arrival → last token) — the E2E
    /// SLO the capacity planner can constrain.
    pub e2e: Samples,
    pub completed: u64,
    pub timeouts: u64,
    /// Time of the last completion.
    pub makespan: f64,
    pub offered_rps: f64,
    pub per_server_ttft: Vec<Samples>,
    pub per_adapter_ttft: BTreeMap<AdapterId, Samples>,
    pub per_server_busy: Vec<f64>,
    pub per_server_max_adapters: Vec<usize>,
    pub migration_bytes: u64,
    pub fetches: u64,
    pub fetch_bytes: u64,
    /// Host->GPU adapter pagings (S-LoRA unified-paging misses).
    pub gpu_loads: u64,
    pub gpu_load_bytes: u64,
    /// Fraction of iterations whose batch contained rank >= 64 work.
    pub per_server_highrank_frac: Vec<f64>,
    /// Cluster-wide iteration counts behind the high-rank fraction:
    /// every prefill/decode iteration, and those whose batch paid the
    /// rank ≥ 64 padding tax.
    pub iters: u64,
    pub iters_highrank: u64,
    /// Prefill-composition (batch scheduling) diagnostics: prefill
    /// iterations, prefill iterations mixing ≥ 2 distinct ranks, and
    /// Σ (batch_max_rank − rank) × prompt_tokens of pad-to-max-rank
    /// kernel work.
    pub prefill_iters: u64,
    pub mixed_prefill_iters: u64,
    pub pad_rank_tokens: u64,
    /// Decode-composition diagnostics: sub-batch steps run, steps
    /// whose group mixed ≥ 2 distinct ranks (only unified decode
    /// produces these), and Σ (group_max_rank − rank) per member per
    /// step — the pad-to-max-rank decode work a rank-aware decode
    /// policy recovers (unit rank·tokens, comparable to
    /// `pad_rank_tokens`).
    pub decode_steps: u64,
    pub mixed_decode_steps: u64,
    pub decode_pad_rank: u64,
    /// Decode sub-batch steps by the rank class each step *paid* (its
    /// group's max rank) — the per-class decode-iteration mix.
    pub decode_steps_by_class: BTreeMap<u32, u64>,
    /// Mean time-between-tokens samples keyed by the request's adapter
    /// rank class — the per-class TBT attribution decode-aware
    /// scheduling is judged on.
    pub tbt_by_class: BTreeMap<u32, Samples>,
    /// Decode rounds cut short by the SLO feedback layer (a queued
    /// prefill preempted the remaining sub-batch steps under TTFT
    /// pressure).
    pub decode_preemptions: u64,
    /// Per-completion TTFT headroom vs the feedback target
    /// (`ttft_target − ttft`; negative = target blown). Empty when the
    /// feedback layer is off.
    pub ttft_headroom: Samples,
    /// Per-completion TBT headroom vs the feedback target.
    pub tbt_headroom: Samples,
    /// TTFT of requests admitted while their server was under TTFT
    /// pressure (including preempting admissions) — the
    /// "TTFT under pressure" percentiles the feedback loop defends.
    pub ttft_under_pressure: Samples,
    /// Label of the batch policy the servers admitted with.
    pub batch_policy: String,
    /// Label of the decode-set composition policy the servers ran.
    pub decode_policy: String,
    pub rebalances: u64,
    /// Simulated times of every re-placement (periodic and triggered)
    /// — what `figures::helpers::steady_warmup` derives the
    /// steady-state cutoff from now that rebalances may be
    /// trigger-driven.
    pub rebalance_times: Vec<f64>,
    /// Trigger-signal evaluations (`--rebalance-mode
    /// triggered|hybrid`'s TriggerCheck events).
    pub trigger_checks: u64,
    /// Rebalances fired by the drift trigger (also counted in
    /// `rebalances`).
    pub triggered_rebalances: u64,
    /// Adapter copies the incremental planner migrated (projected
    /// gain beat the RDMA cost).
    pub incremental_moves: u64,
    /// Proposed copies the incremental planner rejected as
    /// not-worth-the-bytes churn.
    pub rejected_moves: u64,
    /// Remote-attach promotions (`RebalanceConfig::promote_hot`):
    /// adapters whose sustained remote-serving traffic earned them a
    /// materialized copy on the serving server.
    pub promotions: u64,
    /// Remote-attach serving episodes: a request entering remote
    /// service (adapter left in a peer's HBM, per-iteration RDMA
    /// penalty instead of a migration). A request re-routed while
    /// already remote counts once; one that turned local and later
    /// misses again starts a new episode.
    pub remote_served: u64,
    /// Failure injection (scenario pack): crashes fired and recoveries
    /// completed by the seeded MTBF process.
    pub crashes: u64,
    pub recoveries: u64,
    /// In-flight requests lost to a crash under `on_crash = "fail"`
    /// (conservation: completed + timeouts + crash_failed = arrived).
    pub crash_failed: u64,
    /// In-flight requests a crash re-routed to surviving servers
    /// (each restarts from scratch; TTFT still measured from arrival).
    pub crash_requeued: u64,
    /// Adapter fetches served from the host/registry tier because a
    /// crash destroyed the last GPU-side copy.
    pub host_fetches: u64,
    /// Total simulated events processed: control-queue events plus
    /// every server lane's delivery/iteration events. Shard-invariant
    /// by the epoch-barrier determinism contract, so it is part of the
    /// digest; also the denominator of the `bench` subcommand's
    /// events/sec figure.
    pub events: u64,
    /// Fleet accounting (GPU-seconds, scale events, size timeline,
    /// SLO-violation rate). For fixed-fleet runs the timeline is the
    /// constant `n_servers`.
    pub fleet: FleetMetrics,
    /// Per-request SLO-violation attribution summary (component means
    /// for all/violator/tail cohorts), present only when the run was
    /// observed with `ObsConfig::attrib` — absent, the digest is
    /// byte-identical to an unobserved run.
    pub attribution: Option<crate::obs::AttributionSummary>,
    /// Fleet-total seconds requests spent blocked on adapter fetches
    /// (the queue-pressure stall signal, summed at finish). Not part
    /// of the digest — floats summed over servers would make the
    /// digest sensitive to representation details the scalar counters
    /// avoid; it exists for programmatic comparisons (the memory
    /// economy tests read it).
    pub fetch_stall_s: f64,
    /// Unified-HBM page economy (evictions, peaks), present only when
    /// the pool is bounded (`ServerConfig::hbm_pages > 0`) — absent,
    /// the digest is byte-identical to a pre-refactor run.
    pub hbm: Option<crate::pool::hbm::HbmStats>,
}

impl SimReport {
    /// Completed-request throughput over the active window.
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / self.makespan
    }

    /// Fraction of *offered* requests that completed (1 - drop rate).
    pub fn completion_rate(&self) -> f64 {
        let offered = self.completed + self.timeouts;
        if offered == 0 {
            return f64::NAN;
        }
        self.completed as f64 / offered as f64
    }

    /// The paper's SLA check: P95 TTFT within the SLO and (almost) no
    /// timeouts.
    pub fn meets_slo(&mut self, ttft_p95_slo: f64) -> bool {
        self.completed > 0
            && self.ttft.p95() <= ttft_p95_slo
            && self.completion_rate() >= 0.99
    }

    /// Share of iterations whose batch paid the high-rank (≥ 64)
    /// padding tax — the interference indicator the `sched` ablation
    /// compares across batch policies.
    pub fn highrank_iter_share(&self) -> f64 {
        if self.iters == 0 {
            return 0.0;
        }
        self.iters_highrank as f64 / self.iters as f64
    }

    /// Share of prefill iterations that mixed ≥ 2 distinct ranks.
    pub fn mixed_prefill_share(&self) -> f64 {
        if self.prefill_iters == 0 {
            return 0.0;
        }
        self.mixed_prefill_iters as f64 / self.prefill_iters as f64
    }

    /// Share of decode sub-batch steps billed at a high (≥ 64) rank —
    /// the decode-side interference indicator the `sched` ablation
    /// compares across decode policies.
    pub fn highrank_decode_share(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        let hi: u64 = self
            .decode_steps_by_class
            .iter()
            .filter(|(&class, _)| class >= 64)
            .map(|(_, &n)| n)
            .sum();
        hi as f64 / self.decode_steps as f64
    }

    /// Share of decode sub-batch steps that mixed ≥ 2 distinct ranks.
    pub fn mixed_decode_share(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.mixed_decode_steps as f64 / self.decode_steps as f64
    }

    /// P99 mean-TBT of one rank class (NaN if the class completed
    /// nothing measurable).
    pub fn tbt_p99_class(&mut self, rank: u32) -> f64 {
        match self.tbt_by_class.get_mut(&rank) {
            Some(s) if !s.is_empty() => s.p99(),
            _ => f64::NAN,
        }
    }

    /// P99 TTFT of requests admitted under TTFT pressure (NaN if the
    /// feedback layer never flagged an admission).
    pub fn ttft_under_pressure_p99(&mut self) -> f64 {
        if self.ttft_under_pressure.is_empty() {
            return f64::NAN;
        }
        self.ttft_under_pressure.p99()
    }

    /// Deterministic JSON digest of the run: every scalar counter plus
    /// full-precision percentile/sum digests of each sample stream,
    /// serialized through `util::json` (proper string escaping,
    /// shortest-roundtrip floats; non-finite values quoted as strings
    /// since bare NaN is not JSON). Two runs of the same (trace,
    /// config, seed) must produce byte-identical output — the CI
    /// determinism gate `cmp`s exactly this.
    pub fn to_json_string(&mut self) -> String {
        use crate::util::json::Json;
        fn num(x: f64) -> Json {
            if x.is_finite() {
                Json::Num(x)
            } else {
                Json::Str(format!("{x}"))
            }
        }
        fn digest(s: &mut Samples) -> Json {
            Json::obj(vec![
                ("n", Json::from(s.len())),
                ("sum", num(s.sum())),
                ("p50", num(s.p50())),
                ("p95", num(s.p95())),
                ("p99", num(s.p99())),
            ])
        }
        let mut pairs = vec![
            ("system", Json::from(self.system.as_str())),
            ("trace", Json::from(self.trace.as_str())),
            ("batch_policy", Json::from(self.batch_policy.as_str())),
            ("decode_policy", Json::from(self.decode_policy.as_str())),
            ("completed", Json::from(self.completed)),
            ("timeouts", Json::from(self.timeouts)),
            ("events", Json::from(self.events)),
            ("makespan", num(self.makespan)),
            ("offered_rps", num(self.offered_rps)),
            ("iters", Json::from(self.iters)),
            ("iters_highrank", Json::from(self.iters_highrank)),
            ("prefill_iters", Json::from(self.prefill_iters)),
            (
                "mixed_prefill_iters",
                Json::from(self.mixed_prefill_iters),
            ),
            ("pad_rank_tokens", Json::from(self.pad_rank_tokens)),
            ("decode_steps", Json::from(self.decode_steps)),
            ("mixed_decode_steps", Json::from(self.mixed_decode_steps)),
            ("decode_pad_rank", Json::from(self.decode_pad_rank)),
            ("decode_preemptions", Json::from(self.decode_preemptions)),
            ("migration_bytes", Json::from(self.migration_bytes)),
            ("fetches", Json::from(self.fetches)),
            ("fetch_bytes", Json::from(self.fetch_bytes)),
            ("gpu_loads", Json::from(self.gpu_loads)),
            ("gpu_load_bytes", Json::from(self.gpu_load_bytes)),
            ("rebalances", Json::from(self.rebalances)),
            ("trigger_checks", Json::from(self.trigger_checks)),
            (
                "triggered_rebalances",
                Json::from(self.triggered_rebalances),
            ),
            ("incremental_moves", Json::from(self.incremental_moves)),
            ("rejected_moves", Json::from(self.rejected_moves)),
            ("promotions", Json::from(self.promotions)),
            ("remote_served", Json::from(self.remote_served)),
            ("crashes", Json::from(self.crashes)),
            ("recoveries", Json::from(self.recoveries)),
            ("crash_failed", Json::from(self.crash_failed)),
            ("crash_requeued", Json::from(self.crash_requeued)),
            ("host_fetches", Json::from(self.host_fetches)),
            ("ttft", digest(&mut self.ttft)),
            ("tbt", digest(&mut self.tbt)),
            ("e2e", digest(&mut self.e2e)),
            ("ttft_headroom", digest(&mut self.ttft_headroom)),
            ("tbt_headroom", digest(&mut self.tbt_headroom)),
            (
                "ttft_under_pressure",
                digest(&mut self.ttft_under_pressure),
            ),
        ];
        if let Some(a) = &self.attribution {
            pairs.push(("attribution", a.to_json()));
        }
        if let Some(h) = &self.hbm {
            pairs.push(("hbm", h.to_json()));
        }
        Json::obj(pairs).to_string()
    }

    pub fn ttft_p95(&mut self) -> f64 {
        self.ttft.p95()
    }

    pub fn tbt_p95(&mut self) -> f64 {
        self.tbt.p95()
    }

    pub fn e2e_p95(&mut self) -> f64 {
        self.e2e.p95()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_and_slo() {
        let mut r = SimReport {
            completed: 99,
            timeouts: 1,
            makespan: 10.0,
            ..Default::default()
        };
        for i in 0..100 {
            r.ttft.push(i as f64 / 100.0);
            r.tbt.push(0.01);
        }
        assert!((r.throughput_rps() - 9.9).abs() < 1e-9);
        assert!((r.completion_rate() - 0.99).abs() < 1e-9);
        assert!(r.meets_slo(1.0));
        assert!(!r.meets_slo(0.5));
    }

    #[test]
    fn empty_report_fails_slo() {
        let mut r = SimReport::default();
        assert!(!r.meets_slo(10.0));
        assert!(r.completion_rate().is_nan());
        assert_eq!(r.throughput_rps(), 0.0);
    }

    #[test]
    fn json_digest_is_deterministic_and_complete() {
        let mut r = SimReport {
            system: "loraserve".into(),
            completed: 10,
            makespan: 12.5,
            decode_preemptions: 3,
            triggered_rebalances: 2,
            incremental_moves: 5,
            remote_served: 7,
            crashes: 2,
            recoveries: 1,
            crash_requeued: 11,
            ..Default::default()
        };
        for i in 0..10 {
            r.ttft.push(0.01 * i as f64);
            r.ttft_under_pressure.push(0.02 * i as f64);
        }
        let a = r.to_json_string();
        let b = r.to_json_string();
        assert_eq!(a, b, "digest must be stable across calls");
        for key in [
            "\"completed\":10",
            "\"decode_preemptions\":3",
            "\"triggered_rebalances\":2",
            "\"incremental_moves\":5",
            "\"remote_served\":7",
            "\"crashes\":2",
            "\"recoveries\":1",
            "\"crash_requeued\":11",
            "\"crash_failed\":0",
            "\"host_fetches\":0",
            "\"makespan\":12.5",
            "\"ttft\":{",
            "\"ttft_under_pressure\":{",
        ] {
            assert!(a.contains(key), "missing {key} in {a}");
        }
        // empty streams digest as NaN strings, still valid + stable
        let mut empty = SimReport::default();
        let d = empty.to_json_string();
        assert!(d.contains("\"NaN\""));
        assert!(empty.ttft_under_pressure_p99().is_nan());
        // the hbm block appears only for bounded-pool runs — an absent
        // pool must leave the digest without the key (the unbounded
        // bit-parity contract), and fetch_stall_s never enters it
        assert!(!a.contains("\"hbm\""));
        assert!(!a.contains("fetch_stall"));
        r.hbm = Some(crate::pool::hbm::HbmStats {
            total_pages: 64,
            policy: "lru".into(),
            evictions: 3,
            ..Default::default()
        });
        let h = r.to_json_string();
        assert!(h.contains("\"hbm\":{"));
        assert!(h.contains("\"total_pages\":64"));
        assert!(h.contains("\"evictions\":3"));
    }

    #[test]
    fn decode_shares_and_per_class_tbt() {
        let mut r = SimReport::default();
        assert_eq!(r.highrank_decode_share(), 0.0);
        assert_eq!(r.mixed_decode_share(), 0.0);
        assert!(r.tbt_p99_class(8).is_nan());
        r.decode_steps = 10;
        r.mixed_decode_steps = 4;
        r.decode_steps_by_class.insert(8, 3);
        r.decode_steps_by_class.insert(64, 5);
        r.decode_steps_by_class.insert(128, 2);
        assert!((r.highrank_decode_share() - 0.7).abs() < 1e-12);
        assert!((r.mixed_decode_share() - 0.4).abs() < 1e-12);
        for i in 0..100 {
            r.tbt_by_class
                .entry(8)
                .or_default()
                .push(i as f64 / 100.0);
        }
        let p99 = r.tbt_p99_class(8);
        assert!(p99 > 0.9 && p99 <= 1.0, "{p99}");
        assert!(r.tbt_p99_class(128).is_nan());
    }
}
