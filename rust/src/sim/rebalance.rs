//! Drift-reactive rebalancing: the trigger and the incremental
//! migration planner behind `--rebalance-mode triggered|hybrid`.
//!
//! The paper claims *workload-aware dynamic* placement, but the PR 4
//! engine rebalanced on an open-loop timer: a full re-place every
//! `rebalance_period`, applied wholesale. This module closes the
//! sense→decide→act loop:
//!
//! * [`RebalanceTrigger`] — a Schmitt trigger over the projected
//!   per-server load-imbalance ratio ([`imbalance_ratio`], computed
//!   from the `DemandTracker` projections under the *current*
//!   assignment) plus the SLO feedback layer's rolling TBT headroom.
//!   Hysteresis (fire at `imbalance_threshold`, re-arm below
//!   `1 + hysteresis × (threshold − 1)`) and a min-interval guard keep
//!   it from thrashing on signal noise.
//! * [`plan_incremental`] — diffs the current [`Assignment`] against
//!   the placer's fresh proposal and applies only the moves whose
//!   projected queued-token relief at the destination beats their RDMA
//!   migration cost (`costmodel::fetch_time` over the bytes moved).
//!   Rejected moves either stay home (the status quo wins) or — under
//!   `remote_attach` — move their *routing* without moving any bytes:
//!   the adapter keeps living in its old home's HBM and the new home
//!   serves it over GPUDirect RDMA at a per-iteration penalty
//!   (`CostModel::remote_attach_penalty`).
//!
//! Periodic mode never calls into this module, so the default engine
//! stays the PR 4 open-loop rebalancer bit for bit.

use crate::config::{GpuSpec, RebalanceConfig};
use crate::costmodel::{fetch_time, FetchSource};
use crate::placement::Assignment;
use crate::workload::{AdapterId, AdapterSet, ServerId};
use std::collections::BTreeMap;

/// Projected per-server load-imbalance ratio: max utilization ÷ mean
/// utilization over the *active* servers, with utilization of server s
/// = Σ φ·demand/oppoint over its assigned adapters (the same
/// rank-aware pricing the placer budgets with). 1.0 = perfectly
/// balanced (or an idle cluster, where there is nothing to react to).
pub fn imbalance_ratio(
    assignment: &Assignment,
    n_servers: usize,
    active: &[ServerId],
    adapters: &AdapterSet,
    demand: &BTreeMap<AdapterId, f64>,
    oppoints: &BTreeMap<u32, f64>,
) -> f64 {
    if active.is_empty() {
        return 1.0;
    }
    let utils =
        assignment.server_utils(n_servers, adapters, demand, oppoints);
    let mut max = 0.0f64;
    let mut sum = 0.0f64;
    for &s in active {
        max = max.max(utils[s]);
        sum += utils[s];
    }
    let mean = sum / active.len() as f64;
    if mean <= 1e-9 {
        1.0
    } else {
        max / mean
    }
}

/// Delta-maintained per-server utilization vector: the incremental
/// replacement for calling [`Assignment::server_utils`] — an
/// O(adapters × copies) full recompute — at every trigger check.
///
/// The cache pins the assignment's copy sets in server-major form
/// (`by_server`, rebuilt on every assignment swap) plus the demand
/// projections it last priced (`dem`). A refresh diffs the new
/// projections against `dem` bitwise and recomputes *only* the
/// servers hosting a changed adapter — each recomputed server folds
/// its terms in ascending adapter order with the exact
/// `φ · demand / oppoint` expression `server_utils` uses, so a cached
/// vector is bit-identical to the full recompute at every check
/// (enforced by a debug assertion in the engine).
#[derive(Debug, Clone)]
pub struct UtilCache {
    /// cached utilization per server (dense, `n_servers` long)
    utils: Vec<f64>,
    /// demand projection each adapter was last priced at
    dem: Vec<f64>,
    /// `oppoints[rank_a]` per adapter (`1.0` for unprofiled ranks —
    /// `server_utils`' `unwrap_or`), fixed for the run
    op: Vec<f64>,
    /// copy sets server-major: `(adapter, φ)` in ascending adapter
    /// order, mirroring `Assignment::shares`
    by_server: Vec<Vec<(AdapterId, f64)>>,
    dirty: Vec<bool>,
    dirty_list: Vec<ServerId>,
}

impl UtilCache {
    pub fn new(
        n_servers: usize,
        adapters: &AdapterSet,
        oppoints: &BTreeMap<u32, f64>,
    ) -> Self {
        let op = adapters
            .iter()
            .map(|a| oppoints.get(&a.rank).copied().unwrap_or(1.0))
            .collect();
        UtilCache {
            utils: vec![0.0; n_servers],
            dem: vec![0.0; adapters.len()],
            op,
            by_server: vec![Vec::new(); n_servers],
            dirty: vec![false; n_servers],
            dirty_list: Vec::new(),
        }
    }

    fn recompute(&mut self, s: ServerId) {
        let mut u = 0.0f64;
        // ascending adapter order: the exact accumulation order (and
        // term) of `server_utils`, so the sum is bit-identical
        for &(a, phi) in &self.by_server[s] {
            u += phi * self.dem[a as usize] / self.op[a as usize];
        }
        self.utils[s] = u;
    }

    /// Re-pin the copy sets after an assignment swap (wholesale
    /// rebalance, incremental plan landing, drain re-place) and
    /// recompute every server — O(adapters × copies), the same cost
    /// the swap's planner just paid.
    pub fn rebuild(&mut self, asg: &Assignment) {
        for v in &mut self.by_server {
            v.clear();
        }
        for (a, ss) in asg.shares.iter().enumerate() {
            for &(s, phi) in ss {
                self.by_server[s].push((a as AdapterId, phi));
            }
        }
        for s in 0..self.utils.len() {
            self.recompute(s);
        }
        for f in &mut self.dirty {
            *f = false;
        }
        self.dirty_list.clear();
    }

    /// Fold a fresh demand roll in: price the adapters whose
    /// projection changed (bitwise) and recompute only their hosts.
    /// `known`/`proj` are the tracker's dense projection view
    /// ([`crate::coordinator::DemandTracker::known_ids`] /
    /// `projections`); ids absent from `known` keep projecting 0.
    pub fn refresh(
        &mut self,
        asg: &Assignment,
        known: &[AdapterId],
        proj: &[f64],
    ) {
        for &id in known {
            let i = id as usize;
            let p = proj[i];
            if p.to_bits() == self.dem[i].to_bits() {
                continue;
            }
            self.dem[i] = p;
            for &(s, _) in &asg.shares[i] {
                if !self.dirty[s] {
                    self.dirty[s] = true;
                    self.dirty_list.push(s);
                }
            }
        }
        if self.dirty_list.is_empty() {
            return;
        }
        let list = std::mem::take(&mut self.dirty_list);
        for &s in &list {
            self.recompute(s);
            self.dirty[s] = false;
        }
        self.dirty_list = list;
        self.dirty_list.clear();
    }

    /// The cached utilization vector (valid after
    /// `rebuild`/`refresh`).
    pub fn utils(&self) -> &[f64] {
        &self.utils
    }

    /// [`imbalance_ratio`] served from the cache: the identical
    /// max/mean fold over the active servers, minus the
    /// `server_utils` recompute.
    pub fn imbalance(&self, active: &[ServerId]) -> f64 {
        if active.is_empty() {
            return 1.0;
        }
        let mut max = 0.0f64;
        let mut sum = 0.0f64;
        for &s in active {
            max = max.max(self.utils[s]);
            sum += self.utils[s];
        }
        let mean = sum / active.len() as f64;
        if mean <= 1e-9 {
            1.0
        } else {
            max / mean
        }
    }
}

/// Schmitt trigger with a min-interval guard over the rebalance
/// signals. `evaluate` is called once per `trigger_check_period`; it
/// returns true when a rebalance should fire *now*.
///
/// Hysteresis semantics: the trigger fires on a rising edge — the
/// signal crossing `imbalance_threshold` (or the SLO feedback layer
/// reporting a blown TBT headroom) while armed — and then latches
/// until the signal cools below the exit threshold with no SLO
/// pressure, so a signal hovering at the threshold produces exactly
/// one rebalance, not one per check. Because the imbalance ratio is
/// floored at 1.0 (a balanced cluster), the hysteresis fraction
/// applies to the *excess over 1*: exit = 1 + hysteresis ×
/// (threshold − 1) — a plain `threshold × hysteresis` could sit below
/// 1.0 and never re-arm. `min_interval` additionally paces fires so a
/// rebalance gets time to take effect before it can be judged
/// insufficient.
#[derive(Debug, Clone)]
pub struct RebalanceTrigger {
    cfg: RebalanceConfig,
    armed: bool,
    last_fire: f64,
    /// Total fires (mirrors `SimReport::triggered_rebalances`).
    pub fires: u64,
}

impl RebalanceTrigger {
    pub fn new(cfg: RebalanceConfig) -> Self {
        RebalanceTrigger {
            cfg,
            armed: true,
            last_fire: f64::NEG_INFINITY,
            fires: 0,
        }
    }

    /// Feed one observation of the signals; true = fire a rebalance.
    /// `queue_pressed` is the opt-in third OR-term
    /// (`RebalanceConfig::queue_signal`): queue depth or fetch-stall
    /// pressure, treated exactly like SLO pressure — it fires while
    /// armed and holds the latch until it clears. `mem_pressed` is the
    /// opt-in fourth OR-term (`RebalanceConfig::memory_signal`): a
    /// bounded unified HBM pool running at hot page occupancy, with
    /// identical fire-and-latch semantics.
    pub fn evaluate(
        &mut self,
        now: f64,
        imbalance: f64,
        slo_pressed: bool,
        queue_pressed: bool,
        mem_pressed: bool,
    ) -> bool {
        let pressed = slo_pressed || queue_pressed || mem_pressed;
        let hot =
            imbalance >= self.cfg.imbalance_threshold || pressed;
        let exit = 1.0
            + self.cfg.hysteresis
                * (self.cfg.imbalance_threshold - 1.0);
        let cold = imbalance < exit && !pressed;
        if cold {
            self.armed = true;
        }
        if hot
            && self.armed
            && now - self.last_fire >= self.cfg.min_interval
        {
            self.armed = false;
            self.last_fire = now;
            self.fires += 1;
            return true;
        }
        false
    }
}

/// Outcome of diffing the current assignment against a placer
/// proposal: the assignment to route by, where copies should actually
/// live, and the transfers to start eagerly.
#[derive(Debug)]
pub struct IncrementalPlan {
    /// The routing truth the φ table is rebuilt from: the proposal's
    /// entry minus the rejected destinations (their φ mass re-spread
    /// over the survivors) — or the full proposal under remote attach
    /// (rejected destinations serve remotely), or the previous entry
    /// when nothing was accepted.
    pub assignment: Assignment,
    /// Desired residency per adapter for `AdapterPool::
    /// apply_assignment` — the homes that hold (or are about to
    /// receive) an actual copy. Remote-attach routing entries without
    /// a copy are deliberately absent here.
    pub residency: Vec<Vec<ServerId>>,
    /// Accepted copies to RDMA eagerly, grouped per destination (one
    /// batched transfer each, like the drain protocol).
    pub transfers: BTreeMap<ServerId, Vec<AdapterId>>,
    /// Bytes of the accepted copies (the migration the plan decided to
    /// pay for).
    pub migrated_bytes: u64,
    pub moves_applied: u64,
    pub moves_rejected: u64,
}

/// Diff `prev` → `proposal` and keep only the moves that pay.
///
/// A "move" is a copy of adapter `a` appearing on a server it wasn't
/// on; every destination is judged *on its own*. A destination that
/// already holds a copy (`has_copy` — resident, or in flight from an
/// earlier on-demand miss fetch) is a free routing improvement and is
/// always accepted. A destination needing a copy must buy its own
/// transfer: its projected queued-token relief — the utilization
/// share moved (φ·demand/oppoint) times how much more loaded the
/// source is than the destination under the *previous* assignment,
/// integrated over `horizon` seconds (the span the new placement is
/// expected to serve) — must beat the RDMA cost of the adapter's
/// bytes (`fetch_time(RemoteRdma)`). The rejected destinations' φ
/// mass re-spreads proportionally over the surviving homes (or, under
/// `remote_attach`, stays routed and is served remotely). Moves whose
/// old home is leaving the active set are forced through wholesale —
/// there is no status quo to keep.
#[allow(clippy::too_many_arguments)]
pub fn plan_incremental(
    prev: &Assignment,
    proposal: &Assignment,
    adapters: &AdapterSet,
    n_servers: usize,
    active: &[ServerId],
    demand: &BTreeMap<AdapterId, f64>,
    oppoints: &BTreeMap<u32, f64>,
    gpu: &GpuSpec,
    horizon: f64,
    remote_attach: bool,
    has_copy: &dyn Fn(ServerId, AdapterId) -> bool,
) -> IncrementalPlan {
    let n_adapters = proposal.shares.len();
    let utils =
        prev.server_utils(n_servers, adapters, demand, oppoints);
    // O(1) activity membership: the per-adapter `active.contains`
    // probe was an O(adapters × fleet) scan at big fleets
    let mut is_active = vec![false; n_servers];
    for &s in active {
        is_active[s] = true;
    }
    let mut plan = IncrementalPlan {
        assignment: Assignment::new(n_adapters),
        residency: vec![Vec::new(); n_adapters],
        transfers: BTreeMap::new(),
        migrated_bytes: 0,
        moves_applied: 0,
        moves_rejected: 0,
    };
    for a in 0..n_adapters as AdapterId {
        let old: Vec<ServerId> = prev
            .shares
            .get(a as usize)
            .map(|ss| ss.iter().map(|&(s, _)| s).collect())
            .unwrap_or_default();
        let new_entry = &proposal.shares[a as usize];
        let added: Vec<(ServerId, f64)> = new_entry
            .iter()
            .copied()
            .filter(|&(s, _)| !old.contains(&s))
            .collect();
        // φ-share shifts among existing homes move no bytes: accept
        // wholesale. Homes leaving the active set force the whole
        // proposal through — the status quo is not keepable.
        let forced = old.iter().any(|&s| !is_active[s]);
        if added.is_empty() || forced {
            for &(s, phi) in new_entry {
                plan.assignment.add(a, s, phi);
            }
            plan.residency[a as usize] =
                new_entry.iter().map(|&(s, _)| s).collect();
            let need: Vec<ServerId> = added
                .iter()
                .map(|&(s, _)| s)
                .filter(|&s| !has_copy(s, a))
                .collect();
            if !need.is_empty() {
                plan.migrated_bytes +=
                    adapters.get(a).size_bytes * need.len() as u64;
                plan.moves_applied += need.len() as u64;
                for &d in &need {
                    plan.transfers.entry(d).or_default().push(a);
                }
            }
            continue;
        }
        // judge each destination on its own merits
        let info = adapters.get(a);
        let per_copy =
            fetch_time(gpu, FetchSource::RemoteRdma, info.size_bytes);
        let dem = demand.get(&a).copied().unwrap_or(0.0);
        let op = oppoints
            .get(&info.rank)
            .copied()
            .unwrap_or(1.0)
            .max(1e-9);
        // relief is measured against the most loaded current home
        // (the server the move actually decongests)
        let u_src =
            old.iter().map(|&s| utils[s]).fold(0.0f64, f64::max);
        let mut accepted: Vec<ServerId> = Vec::new();
        let mut need: Vec<ServerId> = Vec::new();
        let mut rejected: Vec<ServerId> = Vec::new();
        for &(d, phi) in &added {
            if has_copy(d, a) {
                accepted.push(d); // free routing improvement
                continue;
            }
            let w = phi * dem / op;
            let gain = w * (u_src - utils[d]).max(0.0) * horizon;
            if gain > per_copy {
                accepted.push(d);
                need.push(d);
            } else {
                rejected.push(d);
            }
        }
        plan.migrated_bytes += info.size_bytes * need.len() as u64;
        plan.moves_applied += need.len() as u64;
        plan.moves_rejected += rejected.len() as u64;
        for &d in &need {
            plan.transfers.entry(d).or_default().push(a);
        }
        if remote_attach {
            // rejected destinations keep their routing share and serve
            // the adapter out of a peer's HBM over RDMA — no bytes
            for &(s, phi) in new_entry {
                plan.assignment.add(a, s, phi);
            }
            plan.residency[a as usize] = new_entry
                .iter()
                .map(|&(s, _)| s)
                .filter(|s| !rejected.contains(s))
                .collect();
            if plan.residency[a as usize].is_empty() {
                // every proposed home was rejected: the copies stay
                // exactly where they are
                plan.residency[a as usize] = old;
            }
        } else if accepted.is_empty() {
            // nothing pays anywhere: the status quo stays
            for &(s, phi) in &prev.shares[a as usize] {
                plan.assignment.add(a, s, phi);
            }
            plan.residency[a as usize] = old;
        } else {
            // keep the proposal's surviving homes; the rejected
            // destinations' φ mass re-spreads proportionally
            let chosen: Vec<(ServerId, f64)> = new_entry
                .iter()
                .copied()
                .filter(|(s, _)| !rejected.contains(s))
                .collect();
            let total: f64 =
                chosen.iter().map(|&(_, phi)| phi).sum();
            for &(s, phi) in &chosen {
                plan.assignment.add(a, s, phi / total);
            }
            plan.residency[a as usize] =
                chosen.iter().map(|&(s, _)| s).collect();
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelSpec, RebalanceMode, ServerConfig};
    use crate::costmodel::operating_points;
    use crate::util::rng::Pcg32;
    use crate::workload::RANK_CLASSES;

    fn cfg() -> RebalanceConfig {
        RebalanceConfig {
            mode: RebalanceMode::Triggered,
            check_period: 15.0,
            imbalance_threshold: 1.5,
            hysteresis: 0.8,
            min_interval: 30.0,
            remote_attach: false,
            ..Default::default()
        }
    }

    /// Property: a stable signal — bounded noise strictly below the
    /// fire threshold — never fires, for any of a family of seeds.
    #[test]
    fn stable_signal_fires_zero() {
        for seed in 0..16u64 {
            let mut rng = Pcg32::new(seed);
            let mut t = RebalanceTrigger::new(cfg());
            for step in 0..400 {
                // ratio wanders in [1.0, 1.4): under the 1.5 threshold
                let sig = 1.0 + 0.4 * rng.f64();
                assert!(
                    !t.evaluate(15.0 * step as f64, sig, false, false, false),
                    "seed {seed} step {step}: fired on stable signal"
                );
            }
            assert_eq!(t.fires, 0);
        }
    }

    /// Property: a step change fires exactly one burst — one fire at
    /// the edge, then the latch holds while the signal stays high, and
    /// nothing refires after the (simulated) fix brings it back down.
    #[test]
    fn step_change_fires_one_burst() {
        for seed in 0..16u64 {
            let mut rng = Pcg32::new(100 + seed);
            let mut t = RebalanceTrigger::new(cfg());
            let mut fired_at: Vec<usize> = Vec::new();
            for step in 0..400 {
                // low until step 100; high (hovering around 2.0) until
                // step 120 — a fix landing 20 checks later; low after
                let sig = if (100..120).contains(&step) {
                    1.8 + 0.4 * rng.f64()
                } else {
                    1.0 + 0.2 * rng.f64()
                };
                if t.evaluate(15.0 * step as f64, sig, false, false, false) {
                    fired_at.push(step);
                }
            }
            assert_eq!(
                fired_at,
                vec![100],
                "seed {seed}: want exactly one fire at the edge"
            );
        }
    }

    /// The latch re-arms below the exit threshold, so a second
    /// genuine episode fires again — and the min-interval guard paces
    /// back-to-back episodes.
    #[test]
    fn rearms_after_cooling_and_paces_by_min_interval() {
        let mut t = RebalanceTrigger::new(cfg());
        assert!(t.evaluate(0.0, 2.0, false, false, false));
        // still hot: latched
        assert!(!t.evaluate(15.0, 2.0, false, false, false));
        // hovering between exit (1 + 0.8 × 0.5 = 1.4) and enter
        // (1.5): stays latched
        assert!(!t.evaluate(30.0, 1.45, false, false, false));
        // cools below the exit threshold: re-arms silently
        assert!(!t.evaluate(45.0, 1.1, false, false, false));
        // second episode 60 s after the first fire: refires
        assert!(t.evaluate(60.0, 1.6, false, false, false));
        assert_eq!(t.fires, 2);
        // immediate third episode is paced out by min_interval even
        // after cooling
        assert!(!t.evaluate(70.0, 1.0, false, false, false));
        assert!(!t.evaluate(80.0, 3.0, false, false, false), "min-interval guard");
        assert!(t.evaluate(95.0, 3.0, false, false, false));
    }

    /// SLO pressure fires the trigger on its own, and holds the latch
    /// like a hot imbalance signal does.
    #[test]
    fn slo_pressure_fires_and_latches() {
        let mut t = RebalanceTrigger::new(cfg());
        assert!(t.evaluate(0.0, 1.0, true, false, false));
        assert!(!t.evaluate(40.0, 1.0, true, false, false), "latched under pressure");
        // pressure clears with a cold ratio: re-arm, then refire
        assert!(!t.evaluate(55.0, 1.0, false, false, false));
        assert!(t.evaluate(70.0, 1.0, true, false, false));
        assert_eq!(t.fires, 2);
    }

    /// Memory pressure (the bounded-HBM fourth OR-term) fires and
    /// latches exactly like the SLO and queue signals.
    #[test]
    fn memory_pressure_fires_and_latches() {
        let mut t = RebalanceTrigger::new(cfg());
        assert!(t.evaluate(0.0, 1.0, false, false, true));
        assert!(
            !t.evaluate(40.0, 1.0, false, false, true),
            "latched under memory pressure"
        );
        // occupancy drops with a cold ratio: re-arm, then refire
        assert!(!t.evaluate(55.0, 1.0, false, false, false));
        assert!(t.evaluate(70.0, 1.0, false, false, true));
        assert_eq!(t.fires, 2);
    }

    fn ctx() -> (AdapterSet, BTreeMap<AdapterId, f64>, BTreeMap<u32, f64>)
    {
        let adapters = AdapterSet::uniform_per_rank(
            4,
            &[8, 64],
            &ModelSpec::LLAMA_7B,
        );
        let oppoints =
            operating_points(&ServerConfig::default(), &RANK_CLASSES);
        let mut demand = BTreeMap::new();
        for a in adapters.iter() {
            demand.insert(a.id, 100.0);
        }
        (adapters, demand, oppoints)
    }

    #[test]
    fn imbalance_ratio_flags_skewed_assignments() {
        let (adapters, demand, oppoints) = ctx();
        let active = [0usize, 1];
        // balanced: one rank-8 and one rank-64 adapter per server
        let mut even = Assignment::new(4);
        even.add(0, 0, 1.0);
        even.add(2, 0, 1.0);
        even.add(1, 1, 1.0);
        even.add(3, 1, 1.0);
        // skewed: everything piles onto server 0
        let mut skew = Assignment::new(4);
        for a in 0..4 {
            skew.add(a, 0, 1.0);
        }
        let r_even = imbalance_ratio(
            &even, 2, &active, &adapters, &demand, &oppoints,
        );
        let r_skew = imbalance_ratio(
            &skew, 2, &active, &adapters, &demand, &oppoints,
        );
        assert!((r_even - 1.0).abs() < 1e-9, "even {r_even}");
        assert!((r_skew - 2.0).abs() < 1e-9, "skew {r_skew}");
        // an idle cluster reads balanced
        let none: BTreeMap<AdapterId, f64> = BTreeMap::new();
        assert_eq!(
            imbalance_ratio(
                &even, 2, &active, &adapters, &none, &oppoints
            ),
            1.0
        );
    }

    #[test]
    fn incremental_plan_accepts_paying_moves_and_rejects_churn() {
        let (adapters, mut demand, oppoints) = ctx();
        let gpu = crate::config::GpuSpec::A100_40G;
        let active = [0usize, 1];
        // everything on server 0; adapter 0 is hot, adapter 1 is idle
        let mut prev = Assignment::new(4);
        for a in 0..4 {
            prev.add(a, 0, 1.0);
        }
        demand.insert(0, 4000.0);
        demand.insert(1, 0.0);
        // proposal moves the hot adapter 0 *and* the idle adapter 1 to
        // the empty server 1
        let mut proposal = prev.clone();
        proposal.shares[0] = vec![(1, 1.0)];
        proposal.shares[1] = vec![(1, 1.0)];
        let plan = plan_incremental(
            &prev, &proposal, &adapters, 2, &active, &demand,
            &oppoints, &gpu, 60.0, false, &|_, _| false,
        );
        // the hot move pays (seconds of queued-token relief vs a ~ms
        // transfer); the idle move is pure churn and stays home
        assert_eq!(plan.moves_applied, 1);
        assert_eq!(plan.moves_rejected, 1);
        assert_eq!(plan.assignment.servers_of(0), &[(1, 1.0)]);
        assert_eq!(plan.assignment.servers_of(1), &[(0, 1.0)]);
        assert_eq!(plan.residency[0], vec![1]);
        assert_eq!(plan.residency[1], vec![0]);
        assert_eq!(plan.transfers[&1], vec![0]);
        assert_eq!(
            plan.migrated_bytes,
            adapters.get(0).size_bytes
        );
        plan.assignment.validate(2).unwrap();
        // remote attach: the rejected move still moves its *routing*
        let plan_ra = plan_incremental(
            &prev, &proposal, &adapters, 2, &active, &demand,
            &oppoints, &gpu, 60.0, true, &|_, _| false,
        );
        assert_eq!(plan_ra.assignment.servers_of(1), &[(1, 1.0)]);
        assert_eq!(plan_ra.residency[1], vec![0], "no copy moved");
        assert_eq!(plan_ra.migrated_bytes, plan.migrated_bytes);
        plan_ra.assignment.validate(2).unwrap();
        // a destination already holding a resident copy (left behind
        // by an earlier on-demand miss fetch) makes the move free: the
        // otherwise-rejected idle move is accepted with no bytes, no
        // transfer, and no move counted
        let plan_free = plan_incremental(
            &prev,
            &proposal,
            &adapters,
            2,
            &active,
            &demand,
            &oppoints,
            &gpu,
            60.0,
            false,
            &|s, a| s == 1 && a == 1,
        );
        assert_eq!(plan_free.assignment.servers_of(1), &[(1, 1.0)]);
        assert_eq!(plan_free.residency[1], vec![1]);
        assert_eq!(plan_free.moves_rejected, 0);
        assert_eq!(plan_free.moves_applied, 1, "only the hot copy");
        assert_eq!(plan_free.migrated_bytes, plan.migrated_bytes);
        assert_eq!(plan_free.transfers[&1], vec![0]);
    }

    /// Destinations are judged individually: a paying destination in
    /// the same proposal entry as a useless one is kept while the
    /// useless one is dropped, its φ mass re-spreading over the
    /// survivors — a free destination can neither subsidize a useless
    /// copy nor be dragged down with one.
    #[test]
    fn per_destination_judgement_splits_mixed_bundles() {
        let (adapters, mut demand, oppoints) = ctx();
        let gpu = crate::config::GpuSpec::A100_40G;
        let active = [0usize, 1, 2];
        let mut prev = Assignment::new(4);
        prev.add(0, 0, 1.0);
        prev.add(1, 0, 1.0);
        prev.add(2, 2, 1.0);
        prev.add(3, 2, 1.0);
        for a in 0..4 {
            demand.insert(a, 4000.0);
        }
        // proposal splits adapter 0 onto server 1 (idle — the move
        // pays) and server 2 (rank-64 load makes it *more* loaded
        // than the source — zero relief)
        let mut proposal = prev.clone();
        proposal.shares[0] = vec![(1, 0.5), (2, 0.5)];
        let plan = plan_incremental(
            &prev, &proposal, &adapters, 3, &active, &demand,
            &oppoints, &gpu, 60.0, false, &|_, _| false,
        );
        assert_eq!(plan.moves_applied, 1);
        assert_eq!(plan.moves_rejected, 1);
        // the surviving home takes the rejected destination's share
        assert_eq!(plan.assignment.servers_of(0), &[(1, 1.0)]);
        assert_eq!(plan.residency[0], vec![1]);
        assert_eq!(plan.transfers[&1], vec![0]);
        plan.assignment.validate(3).unwrap();
    }

    #[test]
    fn incremental_plan_forces_moves_off_inactive_homes() {
        let (adapters, demand, oppoints) = ctx();
        let gpu = crate::config::GpuSpec::A100_40G;
        // server 0 is leaving the fleet: only server 1 stays active
        let active = [1usize];
        let mut prev = Assignment::new(4);
        for a in 0..4 {
            prev.add(a, 0, 1.0);
        }
        let mut proposal = Assignment::new(4);
        for a in 0..4 {
            proposal.add(a, 1, 1.0);
        }
        let plan = plan_incremental(
            &prev, &proposal, &adapters, 2, &active, &demand,
            &oppoints, &gpu, 60.0, false, &|_, _| false,
        );
        assert_eq!(plan.moves_applied, 4, "all moves forced");
        assert_eq!(plan.moves_rejected, 0);
        for a in 0..4u32 {
            assert_eq!(plan.assignment.servers_of(a), &[(1usize, 1.0)]);
        }
    }

    /// The delta-maintained utilization cache must track the full
    /// `server_utils` recompute bit for bit through randomized demand
    /// drift and assignment swaps.
    #[test]
    fn util_cache_matches_full_recompute_bitwise() {
        let (adapters, _, oppoints) = ctx();
        let n_servers = 3;
        let mut rng = Pcg32::new(17);
        let mut asg = Assignment::new(adapters.len());
        for a in 0..adapters.len() as AdapterId {
            asg.add(a, (rng.next_u32() as usize) % n_servers, 1.0);
        }
        let mut cache = UtilCache::new(n_servers, &adapters, &oppoints);
        cache.rebuild(&asg);
        let mut known: Vec<AdapterId> =
            (0..adapters.len() as AdapterId).collect();
        known.sort_unstable();
        let mut proj = vec![0.0f64; adapters.len()];
        for step in 0..60 {
            // drift a couple of projections (sometimes to the same
            // bits — the refresh must skip those cleanly)
            for _ in 0..2 {
                let id = (rng.next_u32() as usize) % adapters.len();
                proj[id] = (rng.next_u32() % 3) as f64 * 50.0;
            }
            if step % 10 == 9 {
                // an assignment swap: move one adapter, rebuild
                let a = (rng.next_u32() as usize) % adapters.len();
                asg.shares[a] =
                    vec![((rng.next_u32() as usize) % n_servers, 1.0)];
                cache.rebuild(&asg);
            }
            cache.refresh(&asg, &known, &proj);
            let demand: BTreeMap<AdapterId, f64> = known
                .iter()
                .map(|&id| (id, proj[id as usize]))
                .collect();
            let full = asg.server_utils(
                n_servers, &adapters, &demand, &oppoints,
            );
            for s in 0..n_servers {
                assert_eq!(
                    cache.utils()[s].to_bits(),
                    full[s].to_bits(),
                    "server {s} diverged at step {step}"
                );
            }
            let active = [0usize, 1, 2];
            assert_eq!(
                cache.imbalance(&active).to_bits(),
                imbalance_ratio(
                    &asg, n_servers, &active, &adapters, &demand,
                    &oppoints
                )
                .to_bits()
            );
        }
    }

    /// An identical proposal is a no-op plan: nothing moves, nothing
    /// is rejected, the assignment survives byte for byte.
    #[test]
    fn identical_proposal_is_noop() {
        let (adapters, demand, oppoints) = ctx();
        let gpu = crate::config::GpuSpec::A100_40G;
        let active = [0usize, 1];
        let mut prev = Assignment::new(4);
        prev.add(0, 0, 0.5);
        prev.add(0, 1, 0.5);
        prev.add(1, 0, 1.0);
        prev.add(2, 1, 1.0);
        prev.add(3, 1, 1.0);
        let plan = plan_incremental(
            &prev,
            &prev.clone(),
            &adapters,
            2,
            &active,
            &demand,
            &oppoints,
            &gpu,
            60.0,
            false,
            &|_, _| false,
        );
        assert_eq!(plan.moves_applied, 0);
        assert_eq!(plan.moves_rejected, 0);
        assert_eq!(plan.migrated_bytes, 0);
        assert!(plan.transfers.is_empty());
        assert_eq!(plan.assignment, prev);
    }
}
