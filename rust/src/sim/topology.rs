//! Fleet topology: the lifecycle of every server slot in an elastic
//! cluster, factored out of the event loop so the engine handlers can
//! ask one object "who is routable / billed / free" instead of
//! re-deriving it from a raw state vector.

use crate::metrics::FleetMetrics;
use crate::pool::AdapterPool;
use crate::workload::ServerId;

use super::server::SimServer;

/// Lifecycle of one server slot in the elastic fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SrvState {
    /// Slot exists but was never provisioned (or was retired and can
    /// be re-provisioned).
    Cold,
    /// Scale-up decided; cold start in progress.
    Provisioning,
    /// Routable member of the fleet.
    Active,
    /// Scale-down decided; finishing decodes + migrating last copies.
    Draining,
    /// Fully quiesced and copy-free; reusable by a later scale-up.
    Retired,
    /// Hardware failure (scenario failure injection): unroutable, not
    /// billed, every adapter copy lost. Unlike `Retired`, the slot is
    /// reserved for the pending `ServerRecover` and is NOT a free slot
    /// the autoscaler may claim.
    Crashed,
}

/// The slot-state vector of the (possibly elastic) fleet, with
/// maintained class counters so the per-event reads (`billed`,
/// `provisioning`, `n_active`) are O(1) instead of O(fleet) scans on
/// the engine's barrier path. Fixed-fleet runs simply keep every slot
/// `Active` forever.
#[derive(Debug, Clone)]
pub struct FleetTopology {
    state: Vec<SrvState>,
    n_active: usize,
    n_billed: usize,
    n_provisioning: usize,
}

/// Does this state occupy (and bill for) GPUs? Provisioning + active +
/// draining: a draining victim keeps burning its GPUs until it
/// retires.
fn bills(st: SrvState) -> bool {
    matches!(
        st,
        SrvState::Provisioning | SrvState::Active | SrvState::Draining
    )
}

impl FleetTopology {
    /// Slots `0..n0` start active; `n0..max_n` are cold spares for the
    /// autoscaler.
    pub fn new(n0: usize, max_n: usize) -> Self {
        FleetTopology {
            state: (0..max_n)
                .map(|s| {
                    if s < n0 {
                        SrvState::Active
                    } else {
                        SrvState::Cold
                    }
                })
                .collect(),
            n_active: n0.min(max_n),
            n_billed: n0.min(max_n),
            n_provisioning: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.state.len()
    }

    pub fn is_empty(&self) -> bool {
        self.state.is_empty()
    }

    pub fn state(&self, s: ServerId) -> SrvState {
        self.state[s]
    }

    pub fn set(&mut self, s: ServerId, st: SrvState) {
        let old = self.state[s];
        self.n_active -= (old == SrvState::Active) as usize;
        self.n_billed -= bills(old) as usize;
        self.n_provisioning -=
            (old == SrvState::Provisioning) as usize;
        self.state[s] = st;
        self.n_active += (st == SrvState::Active) as usize;
        self.n_billed += bills(st) as usize;
        self.n_provisioning += (st == SrvState::Provisioning) as usize;
    }

    /// Routable members of the fleet, in id order.
    pub fn active(&self) -> Vec<ServerId> {
        self.state
            .iter()
            .enumerate()
            .filter(|&(_, &st)| st == SrvState::Active)
            .map(|(s, _)| s)
            .collect()
    }

    /// Number of routable servers (O(1); `active()` allocates).
    pub fn n_active(&self) -> usize {
        self.n_active
    }

    /// Servers occupying GPUs: provisioning + active + draining. This
    /// is what `FleetMetrics::gpu_seconds` integrates.
    pub fn billed(&self) -> usize {
        self.n_billed
    }

    pub fn provisioning(&self) -> usize {
        self.n_provisioning
    }

    /// Lowest-id slot a scale-up can (re)provision.
    pub fn free_slot(&self) -> Option<ServerId> {
        (0..self.state.len()).find(|&s| {
            matches!(self.state[s], SrvState::Cold | SrvState::Retired)
        })
    }
}

/// A draining server retires once it holds no work *and* no adapter
/// copies (so no last copy can ever be lost to a shrink). Retirement
/// ends the server's GPU billing.
pub(crate) fn try_retire(
    s: ServerId,
    now: f64,
    topo: &mut FleetTopology,
    servers: &[SimServer],
    pool: &AdapterPool,
    fleet: &mut FleetMetrics,
) -> bool {
    if topo.state(s) == SrvState::Draining
        && servers[s].quiesced()
        && pool.resident_count(s) == 0
        && pool.fetching_count(s) == 0
    {
        topo.set(s, SrvState::Retired);
        fleet.set_fleet(now, topo.active().len(), topo.billed());
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_counts() {
        let mut t = FleetTopology::new(2, 5);
        assert_eq!(t.len(), 5);
        assert_eq!(t.active(), vec![0, 1]);
        assert_eq!(t.billed(), 2);
        assert_eq!(t.provisioning(), 0);
        assert_eq!(t.free_slot(), Some(2));
        t.set(2, SrvState::Provisioning);
        assert_eq!(t.billed(), 3);
        assert_eq!(t.provisioning(), 1);
        assert_eq!(t.free_slot(), Some(3));
        t.set(2, SrvState::Active);
        assert_eq!(t.active(), vec![0, 1, 2]);
        t.set(0, SrvState::Draining);
        assert_eq!(t.active(), vec![1, 2]);
        assert_eq!(t.billed(), 3, "draining still bills");
        t.set(0, SrvState::Retired);
        assert_eq!(t.billed(), 2);
        assert_eq!(t.free_slot(), Some(0), "retired slots are reusable");
    }

    #[test]
    fn crashed_is_unbilled_and_not_a_free_slot() {
        let mut t = FleetTopology::new(2, 2);
        t.set(1, SrvState::Crashed);
        assert_eq!(t.active(), vec![0]);
        assert_eq!(t.billed(), 1, "a dead server stops billing");
        assert_eq!(
            t.free_slot(),
            None,
            "the slot is reserved for recovery"
        );
        t.set(1, SrvState::Active);
        assert_eq!(t.active(), vec![0, 1]);
        assert_eq!(t.billed(), 2);
    }
}
