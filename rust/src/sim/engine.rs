//! The composable simulation engine.
//!
//! `sim/cluster.rs::run` used to be one ~580-line event loop hard-wired
//! to the closed four-variant `SystemKind` enum. It is now a
//! [`SimEngine`]: an explicit [`EngineState`], one handler method per
//! [`SimEvent`] variant, and a [`SystemSpec`] that *composes* a system
//! from orthogonal policies —
//!
//! * [`PlacementPolicy`] — which placer produces the adapter→server
//!   assignment (Algorithm 1, the static S-LoRA baselines, full
//!   replication, or a registered custom placer);
//! * [`RoutingPolicy`] — the probabilistic φ table vs request-level
//!   least-loaded routing;
//! * [`PoolMode`] — distributed adapter pool vs full replication;
//! * [`crate::config::BatchPolicyKind`] — the per-server prefill
//!   admission policy (the scheduler half of the design space);
//! * [`crate::config::DecodePolicyKind`] — the per-server decode-set
//!   composition (unified max-rank decode vs SGMV-style per-rank-class
//!   sub-batch steps), making the scheduler seam symmetric across both
//!   phases of generation;
//!
//! plus the smaller behavioral switches (periodic rebalancing,
//! empirical vs analytic operating points, the load signal the router
//! inspects, rank-blind cost estimates). The four paper systems are
//! canned specs (`SystemKind::spec`); new systems are new
//! `SystemSpec` values and never touch the loop. With
//! `BatchPolicyKind::Fifo` the engine reproduces the pre-refactor
//! simulator bit for bit (asserted by `tests/sched_policies.rs`).
//!
//! # Sharded execution
//!
//! The event loop is split in two. Every *coupling* event — routing
//! (`Arrive`), fetch/migration landings, rebalance, trigger checks,
//! autoscaling, drain — stays on the coordinator's control queue and
//! runs sequentially in deterministic `(time, seq)` order. Everything
//! *server-local* — request deliveries and iteration completions —
//! lives in a per-server [`Lane`] with its own private heap. Between
//! control events the lanes are independent, so the coordinator
//! advances them to each control event's timestamp (an *epoch
//! barrier*) either inline or, with `SimConfig::shards > 1`, on
//! `std::thread::scope` worker threads. Each lane's computation is
//! identical no matter which thread runs it, completions are absorbed
//! in fixed lane-index order at each barrier, and the control schedule
//! never depends on the shard count — so the same seed produces a
//! byte-identical report digest sequential or sharded, with any shard
//! count (asserted by `tests/sharded_determinism.rs` and the CI
//! determinism gate).

use super::cluster::SimConfig;
use super::event::{EventQueue, SimEvent};
use super::rebalance::{
    plan_incremental, RebalanceTrigger, UtilCache,
};
use super::report::SimReport;
use super::server::{build_policy, Completion, SimReq, SimServer};
use super::topology::{try_retire, FleetTopology, SrvState};
use crate::config::RebalanceMode;
use crate::autoscale::{ScaleController, ScaleDecision, ScaleSignals};
use crate::coordinator::{DemandTracker, Router, RoutingTable};
use crate::costmodel::{operating_points, CostModel};
use crate::metrics::FleetMetrics;
use crate::obs::{self, Obs, ObsOutput};
use crate::placement::baselines::{ContiguousPlacer, RandomPlacer};
use crate::placement::loraserve::LoraServePlacer;
use crate::placement::{place_onto, Assignment, Placer};
use crate::pool::AdapterPool;
use crate::trace::Trace;
use crate::util::argmin::ArgminTree;
use crate::util::rng::Pcg32;
use crate::workload::{AdapterId, AdapterSet, ServerId};
use std::collections::BTreeMap;

/// How a system produces its adapter→server assignment.
#[derive(Debug, Clone)]
pub enum PlacementPolicy {
    /// Algorithm 1 (rank- and demand-aware, churn-minimized).
    LoraServe { skip_permutation: bool },
    /// S-LoRA Random: one uniformly random home per adapter.
    Random,
    /// S-LoRA Contiguous: rank-sorted contiguous chunks.
    Contiguous,
    /// No placer at all: a marker assignment (everything on the first
    /// active server) that routing never consults — pair with
    /// `PoolMode::Replicated` + `RoutingPolicy::LeastLoaded` for the
    /// Toppings baseline.
    ReplicateAll,
    /// Registration point for new placers: (name, constructor from the
    /// cluster seed). New systems plug in here without touching the
    /// engine.
    Custom(&'static str, fn(u64) -> Box<dyn Placer>),
}

impl PlacementPolicy {
    fn build(&self, seed: u64) -> Option<Box<dyn Placer>> {
        match self {
            PlacementPolicy::LoraServe { skip_permutation } => {
                Some(Box::new(LoraServePlacer {
                    skip_permutation: *skip_permutation,
                }))
            }
            PlacementPolicy::Random => {
                Some(Box::new(RandomPlacer::new(seed)))
            }
            PlacementPolicy::Contiguous => {
                Some(Box::new(ContiguousPlacer::new()))
            }
            PlacementPolicy::ReplicateAll => None,
            PlacementPolicy::Custom(_, build) => Some(build(seed)),
        }
    }
}

/// How requests pick a server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// The φ routing table of Fig 11, swapped on every placement or
    /// topology change.
    Table,
    /// Request-level least-loaded routing over all active servers
    /// (the Toppings baseline; requires a replicated pool).
    LeastLoaded,
}

/// Where adapter copies live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolMode {
    /// Each server holds only its assigned adapters; misses fetch over
    /// RDMA (§IV-B).
    Distributed,
    /// Every adapter resident on every active server.
    Replicated,
}

/// The load signal a `RoutingPolicy::LeastLoaded` router inspects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadSignal {
    /// Estimated outstanding service seconds (rank-priced work).
    ServiceSeconds,
    /// Plain request counts ("requests being served and queued",
    /// §V-D) — blind to token lengths and ranks.
    RequestCount,
}

/// A fully composed system: what `SimKind` used to hard-wire, as data.
#[derive(Debug, Clone)]
pub struct SystemSpec {
    /// Label reported in `SimReport::system`.
    pub label: String,
    pub placement: PlacementPolicy,
    pub routing: RoutingPolicy,
    pub pool: PoolMode,
    pub batch: crate::config::BatchPolicyKind,
    /// Per-server decode-set composition (the decode half of the
    /// scheduler seam, symmetric with `batch`).
    pub decode: crate::config::DecodePolicyKind,
    /// Re-place from projected demand at all (Algorithm 1's time
    /// step). Static placements skip this entirely; rebalancing
    /// systems pick *when* and *how* via `rebalance` (open-loop timer,
    /// drift-reactive trigger, or both).
    pub periodic_rebalance: bool,
    /// Drift-reactive rebalance control: mode (periodic | triggered |
    /// hybrid), trigger thresholds/hysteresis, and the remote-attach
    /// pool behavior. Only consulted when `periodic_rebalance` is set
    /// (except `remote_attach`, which any distributed-pool system may
    /// use); the `Periodic` default reproduces the PR 4 engine bit for
    /// bit.
    pub rebalance: crate::config::RebalanceConfig,
    /// Profiled operating points (§IV-A) instead of the analytic model.
    pub empirical_oppoints: bool,
    /// Ablation A4: flatten operating points to their mean so
    /// budgeting balances pure load.
    pub rank_agnostic: bool,
    /// Ablation A3: project demand with the last value only.
    pub last_value_demand: bool,
    pub load_signal: LoadSignal,
    /// Price every request as rank 0 in the outstanding-work estimate
    /// (Toppings' rank-agnostic signal, the imbalance §V-D critiques).
    pub rank_blind_cost: bool,
    /// Scheduler SLO feedback layer (per-server headroom tracker,
    /// preemptible decode rounds, SLO-aware rotor, adaptive waits).
    /// Disabled by default; with it disabled the engine is the PR 3
    /// open-loop engine bit for bit.
    pub slo: crate::config::SloFeedbackConfig,
    /// Production-scenario runtime knobs: the seeded MTBF failure
    /// process (`ServerCrash`/`ServerRecover` control events) and
    /// region-aware RDMA pricing. The default is inert — with it the
    /// engine is the pre-scenario code path bit for bit.
    pub scenario: super::scenario::ScenarioConfig,
}

/// Run one trace through one composed system. Deterministic per
/// (trace, config, spec, seed).
pub fn run_spec(
    trace: &Trace,
    cfg: &SimConfig,
    spec: &SystemSpec,
) -> SimReport {
    SimEngine::new(trace, cfg, spec).run()
}

/// [`run_spec`], plus the end-of-run observability bundle (trace JSON,
/// Prometheus text, attribution records) per `SimConfig::obs`.
pub fn run_spec_observed(
    trace: &Trace,
    cfg: &SimConfig,
    spec: &SystemSpec,
) -> (SimReport, ObsOutput) {
    SimEngine::new(trace, cfg, spec).run_observed()
}

/// Async-span id for one (server, adapter) RDMA fetch (cat `fetch`).
fn fetch_id(s: ServerId, a: AdapterId) -> u64 {
    ((s as u64) << 32) | a as u64
}

fn homes_of(asg: &Assignment) -> Vec<Vec<ServerId>> {
    asg.shares
        .iter()
        .map(|ss| ss.iter().map(|&(s, _)| s).collect())
        .collect()
}

/// Re-place the adapter universe onto `active`. Placer-backed systems
/// run through `place_onto` (dense virtual cluster + churn matching);
/// `ReplicateAll` has no placement — its assignment is a marker and
/// the pool is fully replicated.
fn compute_assignment(
    placer: Option<&mut Box<dyn Placer>>,
    adapters: &AdapterSet,
    active: &[ServerId],
    demand: &BTreeMap<AdapterId, f64>,
    oppoints: &BTreeMap<u32, f64>,
    prev: Option<&Assignment>,
) -> Assignment {
    match placer {
        Some(p) => {
            place_onto(&mut **p, adapters, active, demand, oppoints, prev)
        }
        None => {
            let mut a = Assignment::new(adapters.len());
            let home = active.first().copied().unwrap_or(0);
            for ad in adapters.iter() {
                a.add(ad.id, home, 1.0);
            }
            a
        }
    }
}

/// Server-local events, private to one server's lane heap. Lanes
/// advance independently between epoch barriers; everything that
/// couples servers (routing, fetches, rebalance, autoscaling, drain)
/// stays on the coordinator's control queue.
#[derive(Debug, Clone, Copy)]
enum LaneEvent {
    /// A routed request lands on this server. `ready` was decided at
    /// control time (the pool is coordinator state): resident or
    /// remote-attached adapters enqueue runnable; a pool miss parks in
    /// the fetch-wait queue until the control-plane `FetchDone` lands.
    Deliver { sreq: SimReq, ready: bool },
    /// The server's in-flight iteration completes.
    IterDone,
}

/// One server's shard of the event loop: a private heap of
/// [`LaneEvent`]s, the completions produced since the last barrier
/// (absorbed into the report in lane-index order — fixed regardless of
/// shard count, so the digest never depends on it), and the lane's
/// event counter (aggregated into the `max_events` runaway backstop).
struct Lane {
    heap: EventQueue<LaneEvent>,
    outbox: Vec<Completion>,
    events: u64,
    /// Set by `flush_lane` when the lane pops at least one event.
    /// After a parallel flush the coordinator sweeps these to find
    /// which lanes to absorb and which router loads went stale
    /// (the inline path tracks both incrementally instead).
    touched: bool,
}

/// Below this many pending lane events a parallel flush costs more in
/// thread spawn than it saves — run inline.
const PARALLEL_FLUSH_MIN: usize = 256;

/// Advance one lane to `horizon` (inclusive — a same-timestamp
/// delivery must land before the control event that reads it).
/// Runs on worker threads, so it must never panic for the runaway
/// backstop: `std::thread::scope` would replace the payload with
/// "a scoped thread panicked". Instead the lane stops at `cap` and
/// the coordinator's aggregate budget check fires on the control
/// thread with the real message.
fn flush_lane(
    srv: &mut SimServer,
    lane: &mut Lane,
    horizon: f64,
    timeout: f64,
    cap: u64,
) {
    loop {
        let Some(t) = lane.heap.peek_time() else { break };
        if t > horizon || lane.events >= cap {
            break;
        }
        let Some((t, ev)) = lane.heap.pop() else { break };
        lane.events += 1;
        lane.touched = true;
        match ev {
            LaneEvent::Deliver { sreq, ready } => {
                if ready {
                    srv.enqueue_ready(sreq);
                } else {
                    srv.enqueue_waiting(sreq, t);
                }
            }
            LaneEvent::IterDone => {
                srv.finish_iteration_into(t, &mut lane.outbox);
                srv.purge_timeouts(t, timeout);
            }
        }
        if let Some(dt) = srv.start_iteration(t) {
            lane.heap.push(t + dt, LaneEvent::IterDone);
        }
    }
}

/// Every mutable piece of a running simulation, explicit in one place:
/// each event handler reads and writes exactly these fields.
pub(crate) struct EngineState {
    pub rng: Pcg32,
    pub topo: FleetTopology,
    pub servers: Vec<SimServer>,
    pub pool: AdapterPool,
    pub router: Router,
    pub assignment: Assignment,
    pub demand: DemandTracker,
    pub q: EventQueue<SimEvent>,
    pub report: SimReport,
    pub controller: Option<ScaleController>,
    /// Autoscaler signal window: busy-time snapshots + SLO accounting.
    pub busy_snap: Vec<f64>,
    pub last_tick: f64,
    pub win_completed: u64,
    pub win_violations: u64,
    /// In-flight batched drain migrations; `SimEvent::MigrationDone`
    /// carries an index into this list.
    pub migrations: Vec<Vec<AdapterId>>,
    /// Drift-reactive rebalance trigger (None in periodic mode, where
    /// the engine is the PR 4 open-loop rebalancer bit for bit).
    pub trigger: Option<RebalanceTrigger>,
    /// Control-queue events processed.
    pub events: u64,
    /// Per-server event lanes, indexed like `servers` (the sharded
    /// half of the event loop).
    lanes: Vec<Lane>,
    /// Σ `lanes[s].events`, maintained incrementally so the
    /// `max_events` backstop check on the control path stays O(1).
    lane_events: u64,
    /// Σ `lanes[s].heap.len()`, maintained by `lane_push` and the
    /// flush paths: the inline/parallel flush decision and the
    /// nothing-pending early-out read it without scanning lanes.
    lane_backlog: usize,
    /// Argmin index over each lane's next event time (∞ = empty
    /// lane). An inline barrier flush visits only lanes with an
    /// event due by the horizon instead of scanning the whole fleet.
    lane_times: ArgminTree,
    /// Lanes that popped at least one event since the last
    /// completion merge; merged in sorted-index order so the digest
    /// matches the old scan-everything merge bit for bit.
    flushed_lanes: Vec<ServerId>,
    /// A parallel flush ran since the last merge: sweep all lanes
    /// (the per-lane list is only maintained on the inline path).
    flushed_all: bool,
    /// Least-loaded routing only: servers whose load signal changed
    /// since the router's argmin tree was last refreshed (dirty
    /// list + dedup flags). Empty for table-routed systems.
    router_dirty: Vec<ServerId>,
    router_dirty_flag: Vec<bool>,
    /// Delta-maintained per-server utilization (triggered/hybrid
    /// rebalance modes): refreshed from projection deltas at each
    /// trigger check instead of the O(adapters × copies) full
    /// `server_utils` recompute.
    pub util_cache: Option<UtilCache>,
}

/// The discrete-event cluster simulation: arrivals → routing →
/// per-server continuous batching → completions, with periodic
/// re-placement, the distributed adapter pool, and (optionally) the
/// elastic-capacity subsystem in the loop.
pub struct SimEngine<'a> {
    trace: &'a Trace,
    cfg: &'a SimConfig,
    spec: &'a SystemSpec,
    cm: CostModel,
    oppoints: BTreeMap<u32, f64>,
    /// Demand-weighted per-server capacity (tokens/s on the trace's
    /// rank mix; harmonic mean of per-class operating points weighted
    /// by token share) — the fleet-capacity yardstick the predictive
    /// autoscaler sizes scale-ups against.
    server_capacity_tps: f64,
    uniform_demand: BTreeMap<AdapterId, f64>,
    placer: Option<Box<dyn Placer>>,
    max_n: usize,
    trace_end: f64,
    replicate: bool,
    table_routed: bool,
    /// Worker-thread count for parallel lane flushes (clamped to
    /// `[1, max_n]`; 1 = fully inline). Never observable in results:
    /// it only picks who executes identical per-lane work.
    shards: usize,
    /// Serve pool misses out of a peer's HBM over RDMA instead of
    /// fetching a copy (`RebalanceConfig::remote_attach`; only
    /// meaningful for distributed pools).
    remote_attach: bool,
    /// Observability handle (tracing + metrics + attribution), shared
    /// with every server. Disabled (`Obs::default`) unless
    /// `SimConfig::obs` enables something, in which case every hook
    /// below is still behind an `obs.on()` / `trace_on()` guard.
    obs: Obs,
    /// Fleet-wide fetch-stall seconds at the previous trigger check —
    /// the baseline the queue-pressure signal's windowed delta is
    /// measured from (`RebalanceConfig::queue_signal`).
    stall_snap: f64,
    /// Remote-attach hotness window: (adapter, server) → remote
    /// deliveries since the last trigger check. Only maintained when
    /// `RebalanceConfig::promote_hot` > 0.
    remote_hot: BTreeMap<(AdapterId, ServerId), u64>,
    /// Seeded failure process (stream 0xfa11, independent of routing
    /// and workload streams); `None` unless the scenario enables
    /// failure injection. Draw order per crash is fixed — victim,
    /// MTTR, next inter-crash gap — so the schedule never depends on
    /// shard count.
    failure_rng: Option<Pcg32>,
    /// Crashes injected so far (`FailureConfig::max_crashes` cap).
    crashes_done: u32,
    /// Bounded unified HBM pools (`ServerConfig::hbm_pages > 0`):
    /// servers can evict adapter pages under KV pressure, and the
    /// engine drains their eviction lists at every epoch barrier.
    /// False (the default) skips the drain entirely — the unbounded
    /// path is the pre-refactor engine bit for bit.
    hbm_bounded: bool,
    st: EngineState,
}

impl<'a> SimEngine<'a> {
    pub fn new(
        trace: &'a Trace,
        cfg: &'a SimConfig,
        spec: &'a SystemSpec,
    ) -> Self {
        let n0 = cfg.cluster.n_servers;
        assert!(n0 >= 1, "need at least one server");
        // elastic fleets can grow to max_servers; fixed fleets stay
        // at n0
        let max_n = cfg
            .autoscale
            .map(|a| a.max_servers.max(n0))
            .unwrap_or(n0);
        let cm = CostModel::new(cfg.cluster.server);
        let rng = Pcg32::with_stream(cfg.cluster.seed, 0x51u64);
        let ranks = trace.adapters.unique_ranks();
        let mut oppoints = if spec.empirical_oppoints {
            super::profile::empirical_operating_points(
                &cfg.cluster.server,
                &ranks,
                cfg.cluster.slo.ttft_p95,
            )
        } else {
            operating_points(&cfg.cluster.server, &ranks)
        };
        if spec.rank_agnostic {
            let mean: f64 =
                oppoints.values().sum::<f64>() / oppoints.len() as f64;
            for v in oppoints.values_mut() {
                *v = mean;
            }
        }
        // Demand-weighted per-server capacity: tokens/s one server
        // sustains on the trace's *actual* rank mix — the
        // token-share-weighted harmonic mean of the per-class
        // operating points (service time adds, so capacities combine
        // harmonically). An unweighted mean over the classes would
        // systematically mis-size predictive scale-ups on skewed-rank
        // mixes (e.g. 85% rank-8 traffic priced at the rank-128 rate).
        let server_capacity_tps = {
            let mut tok_by_rank: BTreeMap<u32, f64> = BTreeMap::new();
            for r in &trace.requests {
                *tok_by_rank
                    .entry(trace.adapters.get(r.adapter).rank)
                    .or_insert(0.0) += r.total_tokens() as f64;
            }
            let total: f64 = tok_by_rank.values().sum();
            // ranks missing from oppoints (none today: the map is
            // keyed by the trace's unique_ranks) price as the most
            // expensive known class — same conservative fallback as
            // cost-weighted class selection, never a 1.0-denominator
            // that would collapse the capacity estimate
            let min_op = oppoints
                .values()
                .copied()
                .fold(f64::INFINITY, f64::min);
            let denom: f64 = tok_by_rank
                .iter()
                .map(|(rank, t)| {
                    t / oppoints
                        .get(rank)
                        .copied()
                        .unwrap_or(min_op)
                        .max(1e-9)
                })
                .sum();
            if total > 0.0 && denom > 0.0 {
                total / denom
            } else if oppoints.is_empty() {
                0.0
            } else {
                oppoints.values().sum::<f64>() / oppoints.len() as f64
            }
        };

        // ---- initial placement + router + pool
        let uniform_demand: BTreeMap<AdapterId, f64> = trace
            .adapters
            .iter()
            .map(|a| (a.id, 100.0))
            .collect();
        let mut placer = spec.placement.build(cfg.cluster.seed);
        let topo = FleetTopology::new(n0, max_n);
        let active0: Vec<ServerId> = (0..n0).collect();
        let assignment = compute_assignment(
            placer.as_mut(),
            &trace.adapters,
            &active0,
            &uniform_demand,
            &oppoints,
            None,
        );
        assignment
            .validate(max_n)
            .expect("initial placement invalid");

        let replicate = spec.pool == PoolMode::Replicated;
        // Least-loaded routing is per-request; everything else routes
        // through the φ table and must swap it on every topology
        // change.
        let table_routed = spec.routing == RoutingPolicy::Table;
        let mut pool = if replicate {
            let initial: Vec<Vec<ServerId>> = (0..trace.adapters.len())
                .map(|_| active0.clone())
                .collect();
            AdapterPool::new(max_n, &initial)
        } else {
            AdapterPool::new(max_n, &homes_of(&assignment))
        };
        let regions = spec.scenario.regions;
        if regions.n_regions > 1 {
            pool.set_regions(
                regions.n_regions,
                regions.inter_bw_factor,
                regions.inter_latency,
            );
        }
        let failures = spec.scenario.failures;
        let mut failure_rng = failures.enabled.then(|| {
            // a crash can strand the *last* copy of an adapter on the
            // dead server: re-fetches fall back to the host/registry
            // tier instead of tripping the coverage panic
            pool.set_host_fallback(true);
            Pcg32::with_stream(cfg.cluster.seed, 0xfa11)
        });

        let router = match spec.routing {
            RoutingPolicy::Table => {
                Router::Table(RoutingTable::from_assignment(&assignment))
            }
            RoutingPolicy::LeastLoaded => Router::toppings(max_n),
        };

        // The demand tracker's window must match whoever rolls it: the
        // periodic Rebalance tick (periodic mode — unchanged) or the
        // TriggerCheck cadence (triggered/hybrid, where the trigger
        // rolls every check so its projections track drift at the
        // check period, not the — possibly never-elapsing — rebalance
        // period).
        let reactive = spec.periodic_rebalance
            && spec.rebalance.mode != RebalanceMode::Periodic;
        let demand_window = if reactive {
            spec.rebalance.check_period
        } else {
            cfg.cluster.rebalance_period
        };
        let mut demand = DemandTracker::new(demand_window, 16);
        demand.last_value_only = spec.last_value_demand;

        let obs = Obs::new(cfg.obs);
        let servers: Vec<SimServer> = (0..max_n)
            .map(|s| {
                let mut srv = SimServer::with_policy(
                    s,
                    cm,
                    // cost-weighted class selection scores with the
                    // same (possibly empirical/flattened) operating
                    // points the placer and planner use
                    build_policy(spec.batch, spec.decode, &oppoints),
                );
                // SLO feedback is per-server state (rolling headroom
                // windows), installed only when the layer is enabled
                srv.enable_slo(spec.slo);
                // shared observability handle (disabled = zero-cost)
                srv.obs = obs.clone();
                srv
            })
            .collect();

        let report = SimReport {
            system: spec.label.clone(),
            trace: trace.name.clone(),
            offered_rps: trace.mean_rps(),
            batch_policy: spec.batch.label(),
            decode_policy: spec.decode.label(),
            per_server_ttft: vec![Default::default(); max_n],
            fleet: FleetMetrics::new(cfg.cluster.server.tp, n0),
            ..Default::default()
        };
        // pre-size for the bootstrap storm: one Arrive per trace
        // request, plus headroom for the periodic control events
        let mut q: EventQueue<SimEvent> =
            EventQueue::with_capacity(trace.requests.len() + 16);
        for (i, r) in trace.requests.iter().enumerate() {
            q.push(r.arrival, SimEvent::Arrive(i));
        }
        let trace_end = trace.duration();
        if spec.periodic_rebalance {
            if spec.rebalance.mode != RebalanceMode::Triggered {
                // Bootstrap: the initial placement is demand-blind
                // (uniform assumption), so the first few rebalances
                // fire early — a cold-start backlog at near-critical
                // utilization otherwise takes many minutes to drain.
                // Production deployments persist demand state across
                // restarts; this approximates that.
                q.push(
                    cfg.cluster.rebalance_period / 4.0,
                    SimEvent::Rebalance,
                );
            }
            if reactive {
                // triggered/hybrid: evaluate the drift signals every
                // check period (the trigger itself decides whether the
                // cold-start imbalance warrants the first re-place)
                q.push(
                    spec.rebalance.check_period,
                    SimEvent::TriggerCheck,
                );
            }
        }
        let trigger =
            reactive.then(|| RebalanceTrigger::new(spec.rebalance));
        // Delta-maintained utilization vector for the trigger's
        // imbalance reads; re-pinned on every assignment swap.
        let util_cache = reactive.then(|| {
            let mut c =
                UtilCache::new(max_n, &trace.adapters, &oppoints);
            c.rebuild(&assignment);
            c
        });
        let controller: Option<ScaleController> =
            cfg.autoscale.map(ScaleController::new);
        if let Some(a) = cfg.autoscale {
            q.push(a.decision_period, SimEvent::AutoscaleTick);
        }
        if let Some(frng) = failure_rng.as_mut() {
            // first crash: exponential gap after the settle-in floor
            let first =
                failures.start + frng.exponential(1.0 / failures.mtbf);
            if first <= trace_end && failures.max_crashes > 0 {
                q.push(first, SimEvent::ServerCrash);
            }
        }

        SimEngine {
            trace,
            cfg,
            spec,
            cm,
            oppoints,
            server_capacity_tps,
            uniform_demand,
            placer,
            max_n,
            trace_end,
            replicate,
            table_routed,
            shards: cfg.shards.clamp(1, max_n),
            remote_attach: spec.rebalance.remote_attach && !replicate,
            obs,
            stall_snap: 0.0,
            remote_hot: BTreeMap::new(),
            failure_rng,
            crashes_done: 0,
            hbm_bounded: cfg.cluster.server.hbm_pages > 0,
            st: EngineState {
                rng,
                topo,
                servers,
                pool,
                router,
                assignment,
                demand,
                q,
                report,
                controller,
                busy_snap: vec![0.0f64; max_n],
                last_tick: 0.0,
                win_completed: 0,
                win_violations: 0,
                migrations: Vec::new(),
                trigger,
                events: 0,
                lanes: (0..max_n)
                    .map(|_| Lane {
                        heap: EventQueue::new(),
                        outbox: Vec::new(),
                        events: 0,
                        touched: false,
                    })
                    .collect(),
                lane_events: 0,
                lane_backlog: 0,
                lane_times: ArgminTree::new(max_n),
                flushed_lanes: Vec::new(),
                flushed_all: false,
                // least-loaded: start all-dirty so the first refresh
                // seeds every server's key (masked servers go to ∞)
                router_dirty: if table_routed {
                    Vec::new()
                } else {
                    (0..max_n).collect()
                },
                router_dirty_flag: vec![!table_routed; max_n],
                util_cache,
            },
        }
    }

    /// Drain the event queue to completion and emit the report: pop
    /// each control event in `(time, seq)` order, advance every lane
    /// to its timestamp (the epoch barrier) when the event reads or
    /// writes server state, then dispatch it. Table-routed arrivals
    /// skip the barrier — the φ table reads no server state — which is
    /// what keeps epochs long enough to be worth parallelizing.
    pub fn run(mut self) -> SimReport {
        while let Some((now, ev)) = self.st.q.pop() {
            self.st.events += 1;
            self.check_event_budget();
            if self.needs_barrier(&ev) {
                self.flush_lanes(now);
                self.merge_completions();
                self.drain_evictions();
                self.retire_sweep(now);
            }
            self.handle(now, ev);
        }
        // control queue dry: lanes can only chain server-local
        // iterations from here (fetch decisions already happened at
        // delivery time), so run them out in one final epoch
        self.flush_lanes(f64::INFINITY);
        self.merge_completions();
        self.drain_evictions();
        self.check_event_budget();
        let end = self.st.report.makespan.max(self.st.q.now());
        self.retire_sweep(end);
        self.finish()
    }

    /// Does `ev` need the lanes flushed to `now` before it runs?
    /// Table-routed arrivals don't: `Router::Table` ignores the load
    /// buffer, so routing reads no server state. Everything else
    /// (least-loaded routing, fetch/migration landings, rebalance,
    /// trigger checks, autoscaling, drain) must observe servers as of
    /// `now`.
    fn needs_barrier(&self, ev: &SimEvent) -> bool {
        !(self.table_routed && matches!(ev, SimEvent::Arrive(_)))
    }

    /// The runaway backstop, aggregated across the control queue and
    /// every lane (the guard must still fire under sharding). Panics
    /// only on the control thread so the message survives
    /// `std::thread::scope`.
    fn check_event_budget(&self) {
        if self.st.events + self.st.lane_events > self.cfg.max_events {
            panic!(
                "simulation exceeded {} events (trace {}, system {})",
                self.cfg.max_events, self.trace.name, self.spec.label
            );
        }
    }

    /// Advance every lane to `horizon` (inclusive). Lanes are
    /// independent between barriers, so with `shards > 1` they advance
    /// on scoped worker threads — unless observability is on (trace
    /// emission must stay in deterministic lane order through the
    /// shared sink) or the pending backlog is too small to amortize a
    /// spawn. The inline path is index-directed: the `lane_times`
    /// argmin tree yields only the lanes with an event due by
    /// `horizon`, so a barrier over a mostly-idle fleet costs O(due ·
    /// log n) instead of O(fleet). Each lane's computation is the
    /// same regardless of which path (or thread) runs it and
    /// completions are still merged in lane-index order, so results
    /// are bit-identical for any shard count.
    fn flush_lanes(&mut self, horizon: f64) {
        if self.st.lane_backlog == 0 {
            return;
        }
        let inline = self.shards <= 1
            || self.obs.on()
            || self.st.lane_backlog < PARALLEL_FLUSH_MIN;
        if inline {
            while self.st.lane_backlog > 0
                && self.st.lane_times.min_key() <= horizon
            {
                let s = self.st.lane_times.argmin();
                let before = self.st.lanes[s].events;
                self.flush_one_lane(s, horizon);
                if self.st.lanes[s].events == before {
                    // no progress: the lane hit the `max_events` cap —
                    // bail out and let the control-thread budget check
                    // raise the real panic
                    break;
                }
            }
        } else {
            let timeout = self.cfg.cluster.slo.timeout;
            let cap = self.cfg.max_events.saturating_add(1);
            let shards = self.shards;
            let table_routed = self.table_routed;
            let st = &mut self.st;
            {
                let servers = &mut st.servers;
                let lanes = &mut st.lanes;
                let chunk = servers.len().div_ceil(shards);
                std::thread::scope(|scope| {
                    for (srvs, lns) in servers
                        .chunks_mut(chunk)
                        .zip(lanes.chunks_mut(chunk))
                    {
                        scope.spawn(move || {
                            for (srv, lane) in
                                srvs.iter_mut().zip(lns.iter_mut())
                            {
                                flush_lane(
                                    srv, lane, horizon, timeout, cap,
                                );
                            }
                        });
                    }
                });
            }
            // O(fleet) bookkeeping, amortized over the
            // ≥ PARALLEL_FLUSH_MIN events the workers just processed
            let EngineState {
                lanes,
                lane_times,
                lane_backlog,
                lane_events,
                flushed_all,
                router_dirty,
                router_dirty_flag,
                ..
            } = st;
            *lane_backlog = 0;
            *lane_events = 0;
            for (s, lane) in lanes.iter_mut().enumerate() {
                *lane_backlog += lane.heap.len();
                *lane_events += lane.events;
                if lane.touched {
                    lane.touched = false;
                    if !table_routed && !router_dirty_flag[s] {
                        router_dirty_flag[s] = true;
                        router_dirty.push(s);
                    }
                }
            }
            lane_times.rebuild(|i| {
                lanes[i].heap.peek_time().unwrap_or(f64::INFINITY)
            });
            *flushed_all = true;
        }
        #[cfg(debug_assertions)]
        {
            let sum: usize =
                self.st.lanes.iter().map(|l| l.heap.len()).sum();
            assert_eq!(
                self.st.lane_backlog, sum,
                "lane backlog counter out of sync"
            );
        }
    }

    /// Advance one lane to `horizon` with full incremental
    /// bookkeeping: backlog and event counters, the next-due argmin
    /// key, the merge list, and the router dirty mark. Used by the
    /// index-directed barrier flush and by drain-time re-routing
    /// (each least-loaded re-route must observe the previous
    /// delivery).
    fn flush_one_lane(&mut self, s: ServerId, horizon: f64) {
        let timeout = self.cfg.cluster.slo.timeout;
        let cap = self.cfg.max_events.saturating_add(1);
        let table_routed = self.table_routed;
        let st = &mut self.st;
        let lane = &mut st.lanes[s];
        let len_before = lane.heap.len();
        let ev_before = lane.events;
        flush_lane(&mut st.servers[s], lane, horizon, timeout, cap);
        lane.touched = false;
        let len_after = lane.heap.len();
        let ev_after = lane.events;
        let peek = lane.heap.peek_time().unwrap_or(f64::INFINITY);
        st.lane_backlog -= len_before;
        st.lane_backlog += len_after;
        st.lane_events += ev_after - ev_before;
        st.lane_times.update(s, peek);
        if ev_after > ev_before {
            st.flushed_lanes.push(s);
            if !table_routed && !st.router_dirty_flag[s] {
                st.router_dirty_flag[s] = true;
                st.router_dirty.push(s);
            }
        }
    }

    /// Fold every lane's completions into the report, in lane-index
    /// order then per-lane completion order — both independent of the
    /// shard count, so every sample stream's push order (and therefore
    /// the digest) is byte-identical sharded or not. Only lanes that
    /// actually popped events since the last merge are visited (the
    /// sorted `flushed_lanes` list after inline flushes, everything
    /// after a parallel flush) — same order, same result, no O(fleet)
    /// scan per barrier.
    fn merge_completions(&mut self) {
        if self.st.flushed_all {
            self.st.flushed_all = false;
            self.st.flushed_lanes.clear();
            for s in 0..self.max_n {
                self.absorb_outbox(s);
            }
            return;
        }
        if self.st.flushed_lanes.is_empty() {
            return;
        }
        let mut flushed = std::mem::take(&mut self.st.flushed_lanes);
        flushed.sort_unstable();
        flushed.dedup();
        for &s in &flushed {
            self.absorb_outbox(s);
        }
        // hand the list back so the next epoch reuses its capacity
        flushed.clear();
        self.st.flushed_lanes = flushed;
    }

    /// Reconcile bounded-pool adapter evictions with the distributed
    /// pool: a server that evicted an adapter's pages under KV
    /// pressure no longer holds a usable copy, so the pool must stop
    /// routing to it — the next delivery misses, re-fetches, and the
    /// wait is priced through the existing fetch-stall attribution.
    /// Runs at epoch barriers only, iterating servers in lane-index
    /// order: evictions are lane-local state and the barrier schedule
    /// is shard-invariant, so pool mutations stay byte-identical at
    /// any `--shards` count. The last replica of an adapter is never
    /// dropped from the pool (`AdapterPool::drop_copy` refuses):
    /// the pages are gone either way, so the copy re-pages in on next
    /// use, but coverage is preserved. No-op for unbounded pools.
    fn drain_evictions(&mut self) {
        if !self.hbm_bounded {
            return;
        }
        for s in 0..self.max_n {
            if !self.st.servers[s].hbm.has_evicted() {
                continue;
            }
            for a in self.st.servers[s].hbm.take_evicted() {
                // a later iteration in the same epoch may have paged
                // the victim straight back in — still resident means
                // nothing to reconcile
                if !self.replicate && !self.st.servers[s].hbm.resident(a)
                {
                    self.st.pool.drop_copy(s, a);
                }
            }
        }
    }

    /// Absorb one lane's completions (if any) into the report.
    fn absorb_outbox(&mut self, s: ServerId) {
        if self.st.lanes[s].outbox.is_empty() {
            return;
        }
        let outbox = std::mem::take(&mut self.st.lanes[s].outbox);
        for c in &outbox {
            self.absorb_completion(s, c);
        }
        // hand the buffer back so the next epoch reuses its
        // capacity instead of re-allocating
        let mut buf = outbox;
        buf.clear();
        self.st.lanes[s].outbox = buf;
    }

    /// [`SimEngine::run`], then export the observability bundle the
    /// run recorded. The bundle is empty when `SimConfig::obs` left
    /// everything off.
    pub fn run_observed(self) -> (SimReport, ObsOutput) {
        let obs = self.obs.clone();
        let report = self.run();
        (report, obs.export())
    }

    /// One dispatch per `SimEvent` variant — the whole control-plane
    /// alphabet (`IterDone` lives in the lanes now).
    fn handle(&mut self, now: f64, ev: SimEvent) {
        match ev {
            SimEvent::Arrive(i) => self.on_arrive(now, i),
            SimEvent::FetchDone(s, a) => self.on_fetch_done(now, s, a),
            SimEvent::MigrationDone(s, m) => {
                self.on_migration_done(now, s, m)
            }
            SimEvent::Rebalance => self.on_rebalance(now),
            SimEvent::TriggerCheck => self.on_trigger_check(now),
            SimEvent::AutoscaleTick => self.on_autoscale_tick(now),
            SimEvent::ServerReady(s) => self.on_server_ready(now, s),
            SimEvent::DrainCheck(s) => self.on_drain_check(now, s),
            SimEvent::ServerCrash => self.on_server_crash(now),
            SimEvent::ServerRecover(s) => {
                self.on_server_recover(now, s)
            }
        }
    }

    /// Push every dirty server's load signal into the router's argmin
    /// tree — the incremental replacement for the old per-arrival
    /// O(fleet) load-buffer scan. Dirty = touched by a lane flush, a
    /// fetch/migration landing, or a topology transition since the
    /// last refresh. Non-routable (cold, provisioning, draining,
    /// retired) servers are masked to ∞.
    fn refresh_router_loads(&mut self) {
        let load_signal = self.spec.load_signal;
        let st = &mut self.st;
        if !st.router_dirty.is_empty() {
            let dirty = std::mem::take(&mut st.router_dirty);
            for &s in &dirty {
                let load = if st.topo.state(s) == SrvState::Active {
                    match load_signal {
                        LoadSignal::RequestCount => {
                            st.servers[s].pending_count() as f64
                        }
                        LoadSignal::ServiceSeconds => {
                            st.servers[s].outstanding
                        }
                    }
                } else {
                    f64::INFINITY
                };
                st.router.update_load(s, load);
                st.router_dirty_flag[s] = false;
            }
            st.router_dirty = dirty;
            st.router_dirty.clear();
        }
        #[cfg(debug_assertions)]
        self.assert_router_loads();
    }

    /// Debug net for the dirty-tracking refresh: every tree key must
    /// equal the signal a full scan would produce (a mismatch means a
    /// mutation site forgot `mark_router_dirty`), and the tree's
    /// argmin must equal the linear scan's lowest-index minimum.
    #[cfg(debug_assertions)]
    fn assert_router_loads(&self) {
        let Some(tree) = self.st.router.load_index() else {
            return;
        };
        let keys = tree.keys();
        for (s, &k) in keys.iter().enumerate() {
            let want = if self.st.topo.state(s) == SrvState::Active {
                match self.spec.load_signal {
                    LoadSignal::RequestCount => {
                        self.st.servers[s].pending_count() as f64
                    }
                    LoadSignal::ServiceSeconds => {
                        self.st.servers[s].outstanding
                    }
                }
            } else {
                f64::INFINITY
            };
            assert!(
                k.to_bits() == want.to_bits(),
                "stale router load for server {s}: tree has {k}, \
                 scan says {want} (missed dirty mark)"
            );
        }
        let mut scan = 0usize;
        for (s, &k) in keys.iter().enumerate().skip(1) {
            if k < keys[scan] {
                scan = s;
            }
        }
        assert!(
            tree.argmin() == scan,
            "argmin tree diverged from linear scan"
        );
    }

    /// Mark a server's load signal stale for the least-work router.
    /// No-op for table-routed systems (the φ table reads no loads).
    fn mark_router_dirty(&mut self, s: ServerId) {
        if self.table_routed {
            return;
        }
        let st = &mut self.st;
        if !st.router_dirty_flag[s] {
            st.router_dirty_flag[s] = true;
            st.router_dirty.push(s);
        }
    }

    /// Push into a lane's heap, keeping the backlog counter and the
    /// next-due-lane argmin in sync. Every control-side lane push
    /// goes through here (lane-internal pushes during a flush are
    /// reconciled by the flush paths instead).
    fn lane_push(&mut self, s: ServerId, t: f64, ev: LaneEvent) {
        let st = &mut self.st;
        st.lanes[s].heap.push(t, ev);
        st.lane_backlog += 1;
        let peek = st.lanes[s]
            .heap
            .peek_time()
            .unwrap_or(f64::INFINITY);
        st.lane_times.update(s, peek);
    }

    /// Swap in a new assignment, re-pinning the utilization cache's
    /// copy sets (triggered/hybrid modes; the cache is `None`
    /// otherwise and the swap is plain).
    fn set_assignment(&mut self, next: Assignment) {
        if let Some(cache) = &mut self.st.util_cache {
            cache.rebuild(&next);
        }
        self.st.assignment = next;
    }

    /// Hand one request to `target`: decide how it will be served
    /// (the pool and the fetch path are coordinator state), then push
    /// the delivery into the target's lane — the lane enqueues it and
    /// kicks the server at this same timestamp during the next flush.
    /// Shared by fresh arrivals and drain-time re-routing.
    fn deliver(&mut self, target: ServerId, mut sreq: SimReq, now: f64) {
        let a = sreq.req.adapter;
        let uid = sreq.uid as u64;
        let ready = if self.st.pool.is_resident(target, a) {
            // a drain re-route may carry a stale remote flag from its
            // first delivery; here the adapter is served locally
            sreq.remote = false;
            true
        } else if self.remote_attach {
            // Remote attach: the adapter stays in its peer's HBM and
            // this server serves it over GPUDirect RDMA — no fetch
            // wait and no copy moved; every iteration touching the
            // request pays `CostModel::remote_attach_penalty` instead.
            // Counts remote-serving *episodes*: a re-delivery while
            // the request is already remote is not a new one (a
            // request that went local and later misses again is).
            if !sreq.remote {
                self.st.report.remote_served += 1;
                self.obs.counter_add("sim_remote_episodes_total", 1);
            }
            sreq.remote = true;
            if self.spec.rebalance.promote_hot > 0 {
                // remote-attach hotness window (satellite promotion)
                *self.remote_hot.entry((a, target)).or_insert(0) += 1;
            }
            if self.obs.trace_on() {
                self.obs.async_instant(
                    "remote_attach",
                    "req",
                    uid,
                    now,
                    obs::server_pid(target),
                    vec![("adapter", a.into())],
                );
            }
            true
        } else {
            sreq.remote = false;
            if self.obs.trace_on() {
                self.obs.async_instant(
                    "wait_fetch",
                    "req",
                    uid,
                    now,
                    obs::server_pid(target),
                    vec![("adapter", a.into())],
                );
            }
            if let Some(dt) = self.st.pool.start_fetch(
                target,
                a,
                &self.trace.adapters,
                &self.cfg.cluster.server.gpu,
            ) {
                if self.obs.trace_on() {
                    self.obs.async_begin(
                        "fetch",
                        "fetch",
                        fetch_id(target, a),
                        now,
                        obs::PID_CONTROL,
                        vec![
                            ("server", target.into()),
                            ("adapter", a.into()),
                        ],
                    );
                }
                self.st.q.push(now + dt, SimEvent::FetchDone(target, a));
            }
            false
        };
        self.lane_push(target, now, LaneEvent::Deliver { sreq, ready });
    }

    fn replace_assignment(
        &mut self,
        active: &[ServerId],
        demand: &BTreeMap<AdapterId, f64>,
    ) -> Assignment {
        compute_assignment(
            self.placer.as_mut(),
            &self.trace.adapters,
            active,
            demand,
            &self.oppoints,
            Some(&self.st.assignment),
        )
    }

    /// Start one batched RDMA transfer per destination (the drain
    /// protocol's machinery) for a plan's accepted copies; each lands
    /// as a single `MigrationDone`.
    fn start_transfers(
        &mut self,
        now: f64,
        transfers: BTreeMap<ServerId, Vec<AdapterId>>,
    ) {
        for (tgt, ids) in transfers {
            if let Some((dt, started)) = self.st.pool.start_fetch_batch(
                tgt,
                &ids,
                &self.trace.adapters,
                &self.cfg.cluster.server.gpu,
            ) {
                let mid = self.st.migrations.len() as u32;
                if self.obs.trace_on() {
                    self.obs.async_begin(
                        "migration",
                        "mig",
                        mid as u64,
                        now,
                        obs::PID_CONTROL,
                        vec![
                            ("server", tgt.into()),
                            ("adapters", started.len().into()),
                        ],
                    );
                }
                self.st.migrations.push(started);
                self.st
                    .q
                    .push(now + dt, SimEvent::MigrationDone(tgt, mid));
            }
        }
    }

    /// Topology-change re-place (drain and scale-up), routed through
    /// `plan_incremental` instead of a wholesale swap: propose a fresh
    /// placement on `active`, apply only the moves whose projected
    /// queued-token relief beats their RDMA cost (moves off a server
    /// leaving the fleet are forced — there is no status quo to keep),
    /// start the accepted copies as batched transfers, and swap the φ
    /// table. Replicated pools just swap routing.
    fn incremental_replace(&mut self, now: f64, active: &[ServerId]) {
        let mut projected = self.st.demand.projected_tps();
        if projected.is_empty() {
            // before the first demand window rolls, fall back to the
            // demand-blind uniform assumption (like the bootstrap)
            projected = self.uniform_demand.clone();
        }
        let proposal = self.replace_assignment(active, &projected);
        if self.replicate {
            self.st
                .router
                .update_table(RoutingTable::from_assignment(&proposal));
            self.set_assignment(proposal);
            return;
        }
        let pool = &self.st.pool;
        let plan = plan_incremental(
            &self.st.assignment,
            &proposal,
            &self.trace.adapters,
            self.max_n,
            active,
            &projected,
            &self.oppoints,
            &self.cfg.cluster.server.gpu,
            // a move keeps paying off until the next full re-place
            // would have happened anyway
            self.cfg.cluster.rebalance_period,
            self.remote_attach,
            &|s, a| pool.is_resident(s, a) || pool.is_fetching(s, a),
        );
        self.st.report.migration_bytes += plan.migrated_bytes;
        self.st.report.incremental_moves += plan.moves_applied;
        self.st.report.rejected_moves += plan.moves_rejected;
        if self.obs.on() {
            self.obs.counter_add(
                "sim_incremental_moves_total",
                plan.moves_applied,
            );
            self.obs.counter_add(
                "sim_rejected_moves_total",
                plan.moves_rejected,
            );
        }
        self.st
            .router
            .update_table(RoutingTable::from_assignment(&plan.assignment));
        self.st.pool.apply_assignment(&plan.residency);
        self.start_transfers(now, plan.transfers);
        self.set_assignment(plan.assignment);
    }

    fn try_retire(&mut self, s: ServerId, now: f64) -> bool {
        try_retire(
            s,
            now,
            &mut self.st.topo,
            &self.st.servers,
            &self.st.pool,
            &mut self.st.report.fleet,
        )
    }

    /// A fetch or migration landing anywhere may complete a drain.
    fn retire_sweep(&mut self, now: f64) {
        for s in 0..self.max_n {
            if self.st.topo.state(s) == SrvState::Draining {
                self.try_retire(s, now);
            }
        }
    }

    fn on_arrive(&mut self, now: f64, i: usize) {
        let req = self.trace.requests[i];
        self.st.demand.record(req.adapter, req.total_tokens());
        if !self.table_routed {
            // the φ table never reads the load signal — least-loaded
            // routing refreshes only the servers dirtied since the
            // last route, O(dirty · log n) instead of O(fleet)
            self.refresh_router_loads();
        }
        let target =
            self.st.router.route(req.adapter, &mut self.st.rng);
        let rank = self.trace.adapters.get(req.adapter).rank;
        // A rank-blind estimate prices every request as if it carried
        // no LoRA cost, so high-rank requests are under-weighted in
        // the outstanding-work signal.
        let est_rank = if self.spec.rank_blind_cost { 0 } else { rank };
        let sreq = SimReq {
            req,
            rank,
            adapter_bytes: self.trace.adapters.get(req.adapter).size_bytes,
            est: SimServer::estimate(&self.cm, &req, est_rank),
            remote: false,
            uid: i as u32,
        };
        if self.obs.on() {
            self.obs.counter_add("sim_arrivals_total", 1);
            self.obs.async_begin(
                "req",
                "req",
                sreq.uid as u64,
                now,
                obs::server_pid(target),
                vec![
                    ("adapter", req.adapter.into()),
                    ("rank", rank.into()),
                    ("prompt", req.prompt_len.into()),
                    ("output", req.output_len.into()),
                ],
            );
            self.obs.with_attrib(|t| {
                let r = t.rec(i as u32);
                r.arrival = req.arrival;
                r.server = target as u32;
                r.rank = rank;
            });
        }
        self.deliver(target, sreq, now);
    }

    /// Fold one completion into the report — the per-completion half
    /// of the old `IterDone` handler. Runs at epoch barriers via
    /// [`SimEngine::merge_completions`]; the timeout purge and the
    /// next-iteration kick happen inside the lane ([`flush_lane`]).
    fn absorb_completion(&mut self, s: ServerId, c: &Completion) {
        self.st.report.completed += 1;
        self.st.report.makespan =
            self.st.report.makespan.max(c.finished_at);
        let violated = c.ttft > self.cfg.cluster.slo.ttft_p95;
        self.st.win_completed += 1;
        self.st.win_violations += violated as u64;
        if self.obs.on() {
            self.obs.counter_add("sim_completed_total", 1);
            if violated {
                self.obs.counter_add("sim_slo_violations_total", 1);
            }
            self.obs.async_end(
                "req",
                "req",
                c.uid as u64,
                c.finished_at,
                obs::server_pid(s),
                vec![("ttft_ms", (c.ttft * 1e3).into())],
            );
            let measured = c.req.arrival >= self.cfg.warmup;
            self.obs.with_attrib(|t| {
                let r = t.rec(c.uid);
                r.ttft = c.ttft;
                r.e2e = c.finished_at - c.req.arrival;
                r.violated = violated;
                r.measured = measured;
                r.done = true;
            });
        }
        if c.req.arrival < self.cfg.warmup {
            return; // simulated, but not measured
        }
        self.st.report.ttft.push(c.ttft);
        self.st.report.e2e.push(c.finished_at - c.req.arrival);
        self.st.report.fleet.record_completion(violated);
        if self.spec.slo.enabled {
            // headroom histograms vs the feedback targets
            // (negative = target blown)
            self.st
                .report
                .ttft_headroom
                .push(self.spec.slo.ttft_target - c.ttft);
            if c.tbt.is_finite() {
                self.st
                    .report
                    .tbt_headroom
                    .push(self.spec.slo.tbt_target - c.tbt);
            }
        }
        if c.tbt.is_finite() {
            self.st.report.tbt.push(c.tbt);
            self.st
                .report
                .tbt_by_class
                .entry(c.rank)
                .or_default()
                .push(c.tbt);
        }
        self.st.report.per_server_ttft[s].push(c.ttft);
        self.st
            .report
            .per_adapter_ttft
            .entry(c.req.adapter)
            .or_default()
            .push(c.ttft);
    }

    fn on_fetch_done(&mut self, now: f64, s: ServerId, a: AdapterId) {
        // `checked`: a crash wipes the destination's in-flight marks,
        // so a completion scheduled before the crash lands on nothing
        let landed = self.st.pool.finish_fetch_checked(s, a);
        debug_assert!(
            landed || self.spec.scenario.failures.enabled,
            "fetch landing lost its in-flight mark"
        );
        if self.obs.on() {
            self.obs.counter_add("sim_fetches_done_total", 1);
            if self.obs.trace_on() {
                // end the span either way so begin/end stay balanced
                self.obs.async_end(
                    "fetch",
                    "fetch",
                    fetch_id(s, a),
                    now,
                    obs::PID_CONTROL,
                    vec![],
                );
            }
        }
        if !landed {
            self.retire_sweep(now);
            return;
        }
        if self.st.topo.state(s) == SrvState::Draining {
            // a fetch that raced the drain decision: discard the fresh
            // copy if covered elsewhere, otherwise it *is* the last
            // copy — migrate it to its new home before this server can
            // go.
            if !self.st.pool.drop_copy(s, a) {
                if let Some(&(tgt, _)) =
                    self.st.assignment.shares[a as usize].first()
                {
                    if let Some(dt) = self.st.pool.start_fetch(
                        tgt,
                        a,
                        &self.trace.adapters,
                        &self.cfg.cluster.server.gpu,
                    ) {
                        if self.obs.trace_on() {
                            self.obs.async_begin(
                                "fetch",
                                "fetch",
                                fetch_id(tgt, a),
                                now,
                                obs::PID_CONTROL,
                                vec![
                                    ("server", tgt.into()),
                                    ("adapter", a.into()),
                                ],
                            );
                        }
                        self.st
                            .q
                            .push(now + dt, SimEvent::FetchDone(tgt, a));
                    }
                }
            }
        } else {
            if self.remote_attach {
                // the copy is local now: stop charging the RDMA
                // penalty to requests it was remotely serving
                self.st.servers[s].mark_local(a);
            }
            self.st.servers[s].release_waiting(a, now);
            // released requests change the server's load signal
            self.mark_router_dirty(s);
            if let Some(dt) = self.st.servers[s].start_iteration(now) {
                self.lane_push(s, now + dt, LaneEvent::IterDone);
            }
        }
        self.retire_sweep(now);
    }

    /// A batched drain migration lands: every adapter in the group
    /// becomes resident at once (single RDMA stream per destination).
    fn on_migration_done(&mut self, now: f64, s: ServerId, mid: u32) {
        let all = std::mem::take(&mut self.st.migrations[mid as usize]);
        // keep only the adapters whose in-flight mark survived — a
        // crash of the destination wipes them, and the batch must not
        // resurrect copies on (or re-home last copies via) a dead box
        let ids: Vec<AdapterId> = all
            .into_iter()
            .filter(|&a| {
                let landed = self.st.pool.finish_fetch_checked(s, a);
                debug_assert!(
                    landed || self.spec.scenario.failures.enabled,
                    "migration landing lost its in-flight mark"
                );
                landed
            })
            .collect();
        if self.obs.trace_on() {
            self.obs.async_end(
                "migration",
                "mig",
                mid as u64,
                now,
                obs::PID_CONTROL,
                vec![("server", s.into()), ("adapters", ids.len().into())],
            );
        }
        if self.st.topo.state(s) == SrvState::Draining {
            // the migration raced a drain of its own destination:
            // re-home whatever became a last copy here
            for &a in &ids {
                if !self.st.pool.drop_copy(s, a) {
                    if let Some(&(tgt, _)) =
                        self.st.assignment.shares[a as usize].first()
                    {
                        if let Some(dt) = self.st.pool.start_fetch(
                            tgt,
                            a,
                            &self.trace.adapters,
                            &self.cfg.cluster.server.gpu,
                        ) {
                            if self.obs.trace_on() {
                                self.obs.async_begin(
                                    "fetch",
                                    "fetch",
                                    fetch_id(tgt, a),
                                    now,
                                    obs::PID_CONTROL,
                                    vec![
                                        ("server", tgt.into()),
                                        ("adapter", a.into()),
                                    ],
                                );
                            }
                            self.st.q.push(
                                now + dt,
                                SimEvent::FetchDone(tgt, a),
                            );
                        }
                    }
                }
            }
        } else {
            for &a in &ids {
                if self.remote_attach {
                    // the copies are local now: stop charging the
                    // RDMA penalty to requests they remotely served
                    self.st.servers[s].mark_local(a);
                }
                self.st.servers[s].release_waiting(a, now);
            }
            // released requests change the server's load signal
            self.mark_router_dirty(s);
            if let Some(dt) = self.st.servers[s].start_iteration(now) {
                self.lane_push(s, now + dt, LaneEvent::IterDone);
            }
        }
        self.retire_sweep(now);
    }

    fn on_rebalance(&mut self, now: f64) {
        if self.spec.rebalance.mode == RebalanceMode::Periodic {
            // periodic mode: the rebalance tick owns the demand window
            // (the pre-trigger behavior, bit for bit). In hybrid mode
            // the TriggerCheck cadence rolls it instead — rolling here
            // too would chop the window short and corrupt the TPS
            // denominators.
            self.st.demand.roll_window();
        }
        let mut projected = self.st.demand.projected_tps();
        if self.spec.rebalance.mode != RebalanceMode::Periodic
            && projected.is_empty()
        {
            // a hybrid wholesale tick can land before the trigger
            // cadence has rolled a first window; fall back to the
            // demand-blind uniform assumption like the drain path does
            projected = self.uniform_demand.clone();
        }
        let active_ids = self.st.topo.active();
        let next = self.replace_assignment(&active_ids, &projected);
        if !self.remote_attach {
            // under remote attach a wholesale re-place moves homes but
            // never bytes (misses are served remotely, not fetched),
            // so the assignment diff must not count as migration
            self.st.report.migration_bytes += next
                .migration_bytes(&self.st.assignment, &self.trace.adapters);
        }
        self.st
            .router
            .update_table(RoutingTable::from_assignment(&next));
        if !self.replicate {
            self.st.pool.apply_assignment(&homes_of(&next));
        }
        self.set_assignment(next);
        self.st.report.rebalances += 1;
        self.st.report.rebalance_times.push(now);
        if self.obs.on() {
            self.obs.counter_add("sim_rebalances_total", 1);
            self.obs.instant(
                "rebalance",
                now,
                obs::PID_CONTROL,
                0,
                vec![("kind", "periodic".into())],
            );
        }
        // bootstrap cadence is paced by *periodic* re-places only —
        // trigger fires in hybrid mode must not eat the quarter-period
        // bootstrap schedule
        let periodic_rebalances = self.st.report.rebalances
            - self.st.report.triggered_rebalances;
        let next_in = if periodic_rebalances < 4 {
            self.cfg.cluster.rebalance_period / 4.0
        } else {
            self.cfg.cluster.rebalance_period
        };
        if now + next_in <= self.trace_end {
            self.st.q.push(now + next_in, SimEvent::Rebalance);
        }
        debug_assert!(
            self.st.pool.check_coverage(self.trace.adapters.len()).is_ok(),
            "rebalance lost coverage"
        );
    }

    /// Drift-reactive sensing (triggered/hybrid modes): roll the
    /// demand window, read the projected load-imbalance ratio under
    /// the *current* assignment plus the SLO feedback layer's rolling
    /// TBT headroom, and fire an incremental rebalance when the
    /// Schmitt trigger says the placement has drifted off the
    /// workload.
    fn on_trigger_check(&mut self, now: f64) {
        if self.st.trigger.is_none() {
            return;
        }
        self.st.demand.roll_window();
        let active_ids = self.st.topo.active();
        self.st.report.trigger_checks += 1;
        // Delta path: refresh the maintained per-server utilization
        // vector from the adapters whose projection moved this window
        // and read the ratio off it — the O(adapters × copies) full
        // `server_utils` recompute only runs as a debug net below.
        self.st.demand.ensure_projections();
        let imbalance = {
            let st = &mut self.st;
            let cache = st
                .util_cache
                .as_mut()
                .expect("trigger active implies util cache");
            cache.refresh(
                &st.assignment,
                st.demand.known_ids(),
                st.demand.projections(),
            );
            cache.imbalance(&active_ids)
        };
        #[cfg(debug_assertions)]
        {
            let projected = self.st.demand.projected_tps();
            let utils_full = self.st.assignment.server_utils(
                self.max_n,
                &self.trace.adapters,
                &projected,
                &self.oppoints,
            );
            let cache = self.st.util_cache.as_ref().unwrap();
            for (s, u) in utils_full.iter().enumerate() {
                assert!(
                    cache.utils()[s].to_bits() == u.to_bits(),
                    "cached util diverged for server {s} \
                     (missed a refresh delta)"
                );
            }
            let full = super::rebalance::imbalance_ratio(
                &self.st.assignment,
                self.max_n,
                &active_ids,
                &self.trace.adapters,
                &projected,
                &self.oppoints,
            );
            assert!(
                imbalance.to_bits() == full.to_bits(),
                "cached imbalance diverged from full recompute"
            );
        }
        // Only servers with live decode work can exert TBT pressure: a
        // fully drained server's tracker rings are frozen (nothing
        // retires them while `active` is empty), and a stale negative
        // headroom there would otherwise hold the trigger's latch down
        // for the rest of the run.
        let slo_pressed = self.spec.slo.enabled
            && active_ids.iter().any(|&s| {
                let srv = &self.st.servers[s];
                !srv.active.is_empty()
                    && srv
                        .slo
                        .as_ref()
                        .and_then(|t| t.worst_tbt_headroom())
                        .is_some_and(|h| h < 0.0)
            });
        // Satellite queue-pressure signal (config-gated, default off):
        // mean pending depth over active servers, OR fleet-wide
        // fetch-stall seconds accumulated since the previous check.
        // Both are symptoms the imbalance ratio can miss — a hot
        // server stalled on adapter fetches looks *underloaded* to the
        // projected-utilization signal.
        let queue_pressed = if self.spec.rebalance.queue_signal {
            let depth: usize = active_ids
                .iter()
                .map(|&s| self.st.servers[s].pending_count())
                .sum();
            let mean_depth =
                depth as f64 / active_ids.len().max(1) as f64;
            let stall: f64 = self
                .st
                .servers
                .iter()
                .map(|srv| srv.fetch_stall_s)
                .sum();
            let win_stall = stall - self.stall_snap;
            self.stall_snap = stall;
            mean_depth >= self.spec.rebalance.queue_depth_hot
                || win_stall >= self.spec.rebalance.stall_hot
        } else {
            false
        };
        // Memory-pressure signal (config-gated, default off; inert
        // with unbounded pools): any active server whose unified HBM
        // pool sits at or above the occupancy threshold. Pure KV load
        // can evict every cold adapter and thrash page-ins while the
        // *projected-utilization* imbalance still looks flat — this is
        // the symptom signal for that blind spot.
        let mem_pressed = self.spec.rebalance.memory_signal
            && self.hbm_bounded
            && active_ids.iter().any(|&s| {
                self.st.servers[s].hbm.occupancy()
                    >= self.spec.rebalance.occupancy_hot
            });
        let fired = self.st.trigger.as_mut().unwrap().evaluate(
            now,
            imbalance,
            slo_pressed,
            queue_pressed,
            mem_pressed,
        );
        if self.obs.on() {
            self.obs.counter_add("sim_trigger_checks_total", 1);
            self.obs.gauge_set("sim_imbalance_ratio", imbalance);
            self.obs.instant(
                "trigger_check",
                now,
                obs::PID_CONTROL,
                0,
                vec![
                    ("imbalance", imbalance.into()),
                    ("slo_pressed", slo_pressed.into()),
                    ("queue_pressed", queue_pressed.into()),
                    ("mem_pressed", mem_pressed.into()),
                    ("fired", fired.into()),
                ],
            );
        }
        if fired {
            // the planner wants the id→tps map; built only on the
            // rare fired path, not per check
            let projected = self.st.demand.projected_tps();
            self.triggered_rebalance(now, &projected, &active_ids);
        }
        if self.spec.rebalance.promote_hot > 0 {
            self.promote_remote_hot(now);
        }
        let next = now + self.spec.rebalance.check_period;
        if next <= self.trace_end {
            self.st.q.push(next, SimEvent::TriggerCheck);
        }
    }

    /// Remote-attach promotion (`RebalanceConfig::promote_hot`): an
    /// adapter delivered into remote service from the same server at
    /// least `promote_hot` times since the last trigger check has
    /// sustained traffic there — paying the per-iteration RDMA penalty
    /// indefinitely costs more than materializing the copy once.
    /// Promote it: start a batched RDMA transfer to the hot server
    /// (the drain protocol's machinery; `MigrationDone` flips the
    /// waiting requests to local serving via `mark_local`). Routing is
    /// untouched — the φ table already points here, which is why the
    /// remote episodes piled up.
    fn promote_remote_hot(&mut self, now: f64) {
        let window = std::mem::take(&mut self.remote_hot);
        let mut by_tgt: BTreeMap<ServerId, Vec<AdapterId>> =
            BTreeMap::new();
        for ((a, s), n) in window {
            if n >= self.spec.rebalance.promote_hot
                && self.st.topo.state(s) == SrvState::Active
                && !self.st.pool.is_resident(s, a)
                && !self.st.pool.is_fetching(s, a)
            {
                by_tgt.entry(s).or_default().push(a);
            }
        }
        for (tgt, ids) in by_tgt {
            if let Some((dt, started)) = self.st.pool.start_fetch_batch(
                tgt,
                &ids,
                &self.trace.adapters,
                &self.cfg.cluster.server.gpu,
            ) {
                for &a in &started {
                    self.st.report.migration_bytes +=
                        self.trace.adapters.get(a).size_bytes;
                }
                self.st.report.promotions += started.len() as u64;
                if self.obs.on() {
                    self.obs.counter_add(
                        "sim_remote_promotions_total",
                        started.len() as u64,
                    );
                    self.obs.instant(
                        "remote_promote",
                        now,
                        obs::PID_CONTROL,
                        0,
                        vec![
                            ("server", tgt.into()),
                            ("adapters", started.len().into()),
                        ],
                    );
                }
                let mid = self.st.migrations.len() as u32;
                if self.obs.trace_on() {
                    self.obs.async_begin(
                        "migration",
                        "mig",
                        mid as u64,
                        now,
                        obs::PID_CONTROL,
                        vec![
                            ("server", tgt.into()),
                            ("adapters", started.len().into()),
                        ],
                    );
                }
                self.st.migrations.push(started);
                self.st
                    .q
                    .push(now + dt, SimEvent::MigrationDone(tgt, mid));
            }
        }
    }

    /// A trigger-fired re-placement: ask the placer for a fresh
    /// proposal, keep only the moves whose projected queued-token
    /// relief beats their RDMA cost (`sim::rebalance::
    /// plan_incremental`), start the accepted copies as one batched
    /// transfer per destination (the drain protocol's machinery), and
    /// swap the routing table. Rejected moves stay home — or, under
    /// remote attach, move only their routing and get served out of
    /// their old home's HBM.
    fn triggered_rebalance(
        &mut self,
        now: f64,
        projected: &BTreeMap<AdapterId, f64>,
        active: &[ServerId],
    ) {
        let proposal = self.replace_assignment(active, projected);
        if self.replicate {
            // every copy already lives everywhere: a rebalance is a
            // pure routing swap
            self.st
                .router
                .update_table(RoutingTable::from_assignment(&proposal));
            self.set_assignment(proposal);
        } else {
            let pool = &self.st.pool;
            let plan = plan_incremental(
                &self.st.assignment,
                &proposal,
                &self.trace.adapters,
                self.max_n,
                active,
                projected,
                &self.oppoints,
                &self.cfg.cluster.server.gpu,
                // a move keeps paying off until the next full
                // re-place would have happened anyway
                self.cfg.cluster.rebalance_period,
                self.remote_attach,
                // a destination already holding a copy — resident or
                // in flight from an earlier on-demand miss fetch —
                // makes the move free
                &|s, a| pool.is_resident(s, a) || pool.is_fetching(s, a),
            );
            self.st.report.migration_bytes += plan.migrated_bytes;
            self.st.report.incremental_moves += plan.moves_applied;
            self.st.report.rejected_moves += plan.moves_rejected;
            if self.obs.on() {
                self.obs.counter_add(
                    "sim_incremental_moves_total",
                    plan.moves_applied,
                );
                self.obs.counter_add(
                    "sim_rejected_moves_total",
                    plan.moves_rejected,
                );
            }
            self.st
                .router
                .update_table(RoutingTable::from_assignment(
                    &plan.assignment,
                ));
            self.st.pool.apply_assignment(&plan.residency);
            self.start_transfers(now, plan.transfers);
            self.set_assignment(plan.assignment);
        }
        self.st.report.rebalances += 1;
        self.st.report.triggered_rebalances += 1;
        self.st.report.rebalance_times.push(now);
        if self.obs.on() {
            self.obs.counter_add("sim_rebalances_total", 1);
            self.obs.counter_add("sim_triggered_rebalances_total", 1);
            self.obs.instant(
                "rebalance",
                now,
                obs::PID_CONTROL,
                0,
                vec![("kind", "triggered".into())],
            );
        }
        debug_assert!(
            self.st.pool.check_coverage(self.trace.adapters.len()).is_ok(),
            "triggered rebalance lost coverage"
        );
    }

    fn on_autoscale_tick(&mut self, now: f64) {
        let Some(acfg) = self.cfg.autoscale else {
            return;
        };
        if self.st.controller.is_none() {
            return;
        }
        let active_ids = self.st.topo.active();
        let window = (now - self.st.last_tick).max(1e-9);
        let mut busy = 0.0;
        for &s in &active_ids {
            busy += (self.st.servers[s].busy_time
                - self.st.busy_snap[s])
                .max(0.0);
        }
        for (snap, srv) in
            self.st.busy_snap.iter_mut().zip(self.st.servers.iter())
        {
            *snap = srv.busy_time;
        }
        let sig = ScaleSignals {
            busy_frac: busy
                / (window * active_ids.len().max(1) as f64),
            violation_rate: if self.st.win_completed > 0 {
                self.st.win_violations as f64
                    / self.st.win_completed as f64
            } else {
                0.0
            },
            queue_depth: active_ids
                .iter()
                .map(|&s| self.st.servers[s].pending_count())
                .sum(),
            projected_tps: self.st.demand.total_projected_tps(),
            server_tps_capacity: self.server_capacity_tps,
        };
        self.st.win_completed = 0;
        self.st.win_violations = 0;
        self.st.last_tick = now;
        let cand: Vec<(ServerId, f64)> = active_ids
            .iter()
            .map(|&s| (s, self.st.servers[s].outstanding))
            .collect();
        let provisioning = self.st.topo.provisioning();
        let decision = self
            .st
            .controller
            .as_mut()
            .unwrap()
            .decide(now, &sig, &cand, provisioning);
        if self.obs.on() {
            let (kind, arg) = match decision {
                ScaleDecision::Hold => ("hold", 0usize),
                ScaleDecision::Up(k) => ("up", k),
                ScaleDecision::Down(victim) => ("down", victim),
            };
            self.obs.counter_add("sim_autoscale_ticks_total", 1);
            self.obs.gauge_set("sim_busy_frac", sig.busy_frac);
            self.obs.instant(
                "autoscale",
                now,
                obs::PID_CONTROL,
                0,
                vec![
                    ("decision", kind.into()),
                    ("arg", arg.into()),
                    ("busy_frac", sig.busy_frac.into()),
                    ("violation_rate", sig.violation_rate.into()),
                    ("queue_depth", sig.queue_depth.into()),
                ],
            );
        }
        match decision {
            ScaleDecision::Hold => {}
            ScaleDecision::Up(k) => {
                for _ in 0..k {
                    let Some(slot) = self.st.topo.free_slot() else {
                        break;
                    };
                    self.st.topo.set(slot, SrvState::Provisioning);
                    self.st.servers[slot].draining = false;
                    self.st.report.fleet.scale_ups += 1;
                    self.st.q.push(
                        now + acfg.provision_delay,
                        SimEvent::ServerReady(slot),
                    );
                }
                // billing starts at provisioning (cloud instances bill
                // from launch)
                self.st.report.fleet.set_fleet(
                    now,
                    active_ids.len(),
                    self.st.topo.billed(),
                );
            }
            ScaleDecision::Down(victim) => {
                self.on_scale_down(now, victim);
            }
        }
        if now + acfg.decision_period <= self.trace_end {
            self.st
                .q
                .push(now + acfg.decision_period, SimEvent::AutoscaleTick);
        }
    }

    /// The drain-and-migrate protocol: the victim leaves the routing
    /// table at once, its queued/waiting work is re-routed, its
    /// adapters are re-placed onto the survivors, last-copy adapters
    /// are RDMA-migrated **in one batched transfer per destination**
    /// (overlapping the victim's decode tail), and only a fully
    /// quiesced, copy-free server retires.
    fn on_scale_down(&mut self, now: f64, victim: ServerId) {
        self.st.topo.set(victim, SrvState::Draining);
        // draining servers are masked out of the least-work index
        self.mark_router_dirty(victim);
        self.st.servers[victim].draining = true;
        self.st.report.fleet.scale_downs += 1;
        if self.obs.on() {
            self.obs.counter_add("sim_drains_total", 1);
            self.obs.instant(
                "drain",
                now,
                obs::PID_CONTROL,
                0,
                vec![("server", victim.into())],
            );
        }
        let survivors = self.st.topo.active();
        // routable drops now; the victim stays billed until it retires
        self.st.report.fleet.set_fleet(
            now,
            survivors.len(),
            self.st.topo.billed(),
        );
        if self.table_routed {
            // swap the table: the victim stops receiving traffic
            // *now*. The re-place runs through `plan_incremental` —
            // moves off the departing victim are forced (and their
            // bytes counted), while survivor-to-survivor churn only
            // happens where the relief beats the RDMA cost.
            self.incremental_replace(now, &survivors);
        }
        if self.replicate {
            // fully replicated: every copy exists on the survivors;
            // just release the victim's
            for a in 0..self.trace.adapters.len() as AdapterId {
                self.st.pool.drop_copy(victim, a);
            }
        } else {
            // Batch the victim's last-copy RDMA migrations per
            // destination: one scheduled completion per target server,
            // amortizing the per-transfer latency, instead of one
            // event per adapter. (Adapters the incremental plan
            // already started moving are skipped by the pool.)
            let mut by_tgt: BTreeMap<ServerId, Vec<AdapterId>> =
                BTreeMap::new();
            for a in self.st.pool.evacuations(victim) {
                let Some(&(tgt, _)) =
                    self.st.assignment.shares[a as usize].first()
                else {
                    continue;
                };
                by_tgt.entry(tgt).or_default().push(a);
            }
            self.start_transfers(now, by_tgt);
        }
        // re-route not-yet-running work through the swapped table
        // (active decodes finish here)
        let pending = self.st.servers[victim].extract_pending();
        for sreq in pending {
            if !self.table_routed {
                self.refresh_router_loads();
            }
            let target = self
                .st
                .router
                .route(sreq.req.adapter, &mut self.st.rng);
            self.deliver(target, sreq, now);
            if !self.table_routed {
                // least-loaded re-routes must observe each other's
                // load: drain the just-pushed delivery into the server
                // before the next request reads the signal
                self.flush_one_lane(target, now);
            }
        }
        self.st.q.push(now, SimEvent::DrainCheck(victim));
        debug_assert!(
            self.st.pool.check_coverage(self.trace.adapters.len()).is_ok(),
            "drain lost coverage"
        );
    }

    fn on_server_ready(&mut self, now: f64, s: ServerId) {
        if self.st.topo.state(s) != SrvState::Provisioning {
            return; // stale (slot repurposed)
        }
        self.st.topo.set(s, SrvState::Active);
        // the newcomer becomes routable: unmask it in the least-work
        // index (its real load seeds on the next refresh)
        self.mark_router_dirty(s);
        let active_ids = self.st.topo.active();
        self.st.report.fleet.set_fleet(
            now,
            active_ids.len(),
            self.st.topo.billed(),
        );
        if self.obs.trace_on() {
            self.obs.instant(
                "server_ready",
                now,
                obs::PID_CONTROL,
                0,
                vec![("server", s.into())],
            );
        }
        if self.replicate {
            self.st.report.migration_bytes += self
                .st
                .pool
                .replicate_all_to(s, &self.trace.adapters);
        }
        if self.table_routed {
            // spread load onto the newcomer through `plan_incremental`:
            // only the moves whose projected relief beats their RDMA
            // cost actually copy bytes (under remote attach the rest
            // move routing only and serve out of their old home's HBM)
            self.incremental_replace(now, &active_ids);
        }
        debug_assert!(
            self.st.pool.check_coverage(self.trace.adapters.len()).is_ok(),
            "scale-up lost coverage"
        );
    }

    fn on_drain_check(&mut self, now: f64, s: ServerId) {
        self.try_retire(s, now);
    }

    /// Kill a crashed server's lane: every scheduled delivery and
    /// iteration completion dies with the hardware. The heap keeps its
    /// clock and sequence counter (determinism), and the backlog /
    /// next-due-lane bookkeeping stays exact.
    fn wipe_lane(&mut self, s: ServerId) {
        let st = &mut self.st;
        let lane = &mut st.lanes[s];
        st.lane_backlog -= lane.heap.len();
        lane.heap.clear();
        st.lane_times.update(s, f64::INFINITY);
    }

    /// Scenario failure injection: hard-stop one active server. Unlike
    /// the graceful drain protocol there is no migrate-then-retire
    /// window — the lane is wiped, in-flight requests are requeued to
    /// survivors (or failed, per `FailureConfig::requeue`), every
    /// adapter copy on the box dies, and adapters it held the *last*
    /// copy of are re-fetched from the host/registry tier. The victim
    /// is drawn from the live fleet at fire time with the dedicated
    /// failure stream, so the schedule is deterministic per seed and
    /// independent of shard count (crashes are coordinator-epoch
    /// events — all lanes flush before one lands).
    fn on_server_crash(&mut self, now: f64) {
        let fail = self.spec.scenario.failures;
        if self.crashes_done >= fail.max_crashes {
            return;
        }
        let active = self.st.topo.active();
        if active.len() <= 1 {
            // never kill the last survivor; re-arm the MTBF process
            let gap = self
                .failure_rng
                .as_mut()
                .expect("crash event without failure process")
                .exponential(1.0 / fail.mtbf);
            if now + gap <= self.trace_end {
                self.st.q.push(now + gap, SimEvent::ServerCrash);
            }
            return;
        }
        // fixed draw order: victim, downtime, next inter-crash gap
        let frng = self
            .failure_rng
            .as_mut()
            .expect("crash event without failure process");
        let victim = active[frng.below(active.len() as u64) as usize];
        let mttr = frng.exponential(1.0 / fail.mttr);
        let gap = frng.exponential(1.0 / fail.mtbf);
        self.crashes_done += 1;
        self.st.report.crashes += 1;
        self.st.topo.set(victim, SrvState::Crashed);
        // crashed servers are masked out of the least-work index
        self.mark_router_dirty(victim);
        self.wipe_lane(victim);
        // in-flight work dies with the box: running prefill batch,
        // active decodes, queue, and fetch-waiters (their stall time
        // is charged to fetch_stall attribution on the way out)
        let recovered = self.st.servers[victim].crash_reset(now);
        // every copy on the box — resident and in flight — is gone
        let lost = self.st.pool.crash_server(victim);
        let survivors = self.st.topo.active();
        // a crashed box stops billing immediately (it is not ours to
        // pay for while it is down), unlike a draining one
        self.st.report.fleet.set_fleet(
            now,
            survivors.len(),
            self.st.topo.billed(),
        );
        if self.obs.on() {
            self.obs.counter_add("sim_crashes_total", 1);
            self.obs.instant(
                "server_crash",
                now,
                obs::PID_CONTROL,
                0,
                vec![
                    ("server", victim.into()),
                    ("requests", recovered.len().into()),
                    ("lost_last_copies", lost.len().into()),
                ],
            );
        }
        if self.table_routed {
            // swap the table off the victim *now*; the incremental
            // planner sees the post-crash pool, so moves it proposes
            // onto survivors price their RDMA from surviving copies
            self.incremental_replace(now, &survivors);
        }
        // Re-materialize adapters whose last copy died: one batched
        // host-tier fetch per destination (the drain protocol's
        // transfer machinery; `transfer_time` prices replica-less
        // fetches as host page-ins because `host_fallback` is armed).
        if !lost.is_empty() {
            let mut by_tgt: BTreeMap<ServerId, Vec<AdapterId>> =
                BTreeMap::new();
            for a in lost {
                let tgt = self.st.assignment.shares[a as usize]
                    .iter()
                    .map(|&(s, _)| s)
                    .find(|&s| {
                        self.st.topo.state(s) == SrvState::Active
                    })
                    .unwrap_or(survivors[0]);
                by_tgt.entry(tgt).or_default().push(a);
            }
            self.start_transfers(now, by_tgt);
        }
        // the victim's in-flight requests: requeue to survivors
        // through the (already-swapped) router, or fail outright
        if fail.requeue {
            self.st.report.crash_requeued += recovered.len() as u64;
            for sreq in recovered {
                if !self.table_routed {
                    self.refresh_router_loads();
                }
                let target = self
                    .st
                    .router
                    .route(sreq.req.adapter, &mut self.st.rng);
                self.deliver(target, sreq, now);
                if !self.table_routed {
                    // least-loaded requeues must observe each other
                    self.flush_one_lane(target, now);
                }
            }
        } else {
            self.st.report.crash_failed += recovered.len() as u64;
        }
        self.st
            .q
            .push(now + mttr, SimEvent::ServerRecover(victim));
        if self.crashes_done < fail.max_crashes
            && now + gap <= self.trace_end
        {
            self.st.q.push(now + gap, SimEvent::ServerCrash);
        }
        debug_assert!(
            self.st.pool.check_coverage(self.trace.adapters.len()).is_ok(),
            "crash lost coverage"
        );
    }

    /// MTTR elapsed: the crashed box rejoins the fleet empty-handed —
    /// same re-entry path as a freshly provisioned server (replicated
    /// pools re-copy everything; table-routed systems spread load back
    /// onto it through the incremental planner).
    fn on_server_recover(&mut self, now: f64, s: ServerId) {
        if self.st.topo.state(s) != SrvState::Crashed {
            return; // stale (slot repurposed by the autoscaler)
        }
        self.st.topo.set(s, SrvState::Active);
        self.st.servers[s].draining = false;
        self.mark_router_dirty(s);
        self.st.report.recoveries += 1;
        let active_ids = self.st.topo.active();
        self.st.report.fleet.set_fleet(
            now,
            active_ids.len(),
            self.st.topo.billed(),
        );
        if self.obs.on() {
            self.obs.counter_add("sim_recoveries_total", 1);
            self.obs.instant(
                "server_recover",
                now,
                obs::PID_CONTROL,
                0,
                vec![("server", s.into())],
            );
        }
        if self.replicate {
            self.st.report.migration_bytes += self
                .st
                .pool
                .replicate_all_to(s, &self.trace.adapters);
        }
        if self.table_routed {
            self.incremental_replace(now, &active_ids);
        }
        debug_assert!(
            self.st.pool.check_coverage(self.trace.adapters.len()).is_ok(),
            "recovery lost coverage"
        );
    }

    fn finish(mut self) -> SimReport {
        debug_assert!(
            self.st.pool.check_coverage(self.trace.adapters.len()).is_ok(),
            "pool lost coverage"
        );
        let end = self.st.report.makespan.max(self.trace_end);
        self.st.report.fleet.finish(end);
        for (s, srv) in self.st.servers.iter().enumerate() {
            self.st.report.per_server_busy.push(srv.busy_time);
            self.st
                .report
                .per_server_max_adapters
                .push(self.st.pool.max_resident(s));
            self.st.report.timeouts += srv.timeouts;
            self.st.report.gpu_loads += srv.hbm.loads;
            self.st.report.gpu_load_bytes += srv.hbm.load_bytes;
            self.st.report.fetch_stall_s += srv.fetch_stall_s;
            self.st.report.per_server_highrank_frac.push(
                srv.iters_highrank as f64 / srv.iters.max(1) as f64,
            );
            self.st.report.iters += srv.iters;
            self.st.report.iters_highrank += srv.iters_highrank;
            self.st.report.prefill_iters += srv.prefill_iters;
            self.st.report.mixed_prefill_iters +=
                srv.mixed_prefill_iters;
            self.st.report.pad_rank_tokens += srv.pad_rank_tokens;
            self.st.report.decode_steps += srv.decode_steps;
            self.st.report.mixed_decode_steps += srv.mixed_decode_steps;
            self.st.report.decode_pad_rank += srv.decode_pad_rank;
            self.st.report.decode_preemptions += srv.preemptions;
            // same steady-state cutoff as every other latency stream:
            // the cold-start storm is simulated, not measured
            for &(arrival, t) in &srv.ttft_under_pressure {
                if arrival >= self.cfg.warmup {
                    self.st.report.ttft_under_pressure.push(t);
                }
            }
            for (&class, &n) in &srv.decode_steps_by_class {
                *self
                    .st
                    .report
                    .decode_steps_by_class
                    .entry(class)
                    .or_insert(0) += n;
            }
        }
        self.st.report.fetches = self.st.pool.total_fetches;
        self.st.report.fetch_bytes = self.st.pool.total_fetch_bytes;
        self.st.report.host_fetches = self.st.pool.host_fetches;
        // Bounded unified HBM pools: aggregate the per-server page
        // economy into the report (the `hbm` digest field appears only
        // here, so unbounded-default digests stay byte-identical to
        // the pre-refactor engine).
        if self.hbm_bounded {
            let mut h = crate::pool::hbm::HbmStats {
                total_pages: self.cfg.cluster.server.hbm_pages as u64,
                policy: self
                    .cfg
                    .cluster
                    .server
                    .evict_policy
                    .label()
                    .to_string(),
                ..Default::default()
            };
            for srv in &self.st.servers {
                h.evictions += srv.hbm.evictions;
                h.evicted_bytes += srv.hbm.evicted_bytes;
                h.peak_pages = h.peak_pages.max(srv.hbm.peak_pages);
                h.peak_kv_pages =
                    h.peak_kv_pages.max(srv.hbm.peak_kv_pages);
            }
            if self.obs.metrics_on() {
                self.obs
                    .counter_set("sim_hbm_evictions_total", h.evictions);
                self.obs.counter_set(
                    "sim_hbm_evicted_bytes_total",
                    h.evicted_bytes,
                );
                self.obs.gauge_set(
                    "sim_hbm_peak_occupancy",
                    h.peak_pages as f64 / h.total_pages.max(1) as f64,
                );
            }
            self.st.report.hbm = Some(h);
        }
        // control + lane events: identical for any shard count (the
        // control schedule and per-lane work never depend on it), so
        // this is safe to fold into the determinism digest
        self.st.report.events = self.st.events + self.st.lane_events;
        if self.obs.on() {
            self.st.report.attribution = self
                .obs
                .attribution_summary(self.cfg.cluster.slo.ttft_p95);
            if self.obs.metrics_on() {
                // sync the report's authoritative totals into the
                // registry (overwriting any live-bumped counters with
                // the same final values)
                let r = &mut self.st.report;
                self.obs.counter_set("sim_completed_total", r.completed);
                self.obs.counter_set("sim_timeouts_total", r.timeouts);
                self.obs.counter_set("sim_iters_total", r.iters);
                self.obs
                    .counter_set("sim_prefill_iters_total", r.prefill_iters);
                self.obs
                    .counter_set("sim_decode_steps_total", r.decode_steps);
                self.obs.counter_set(
                    "sim_decode_preemptions_total",
                    r.decode_preemptions,
                );
                self.obs.counter_set("sim_fetches_total", r.fetches);
                self.obs
                    .counter_set("sim_fetch_bytes_total", r.fetch_bytes);
                self.obs.counter_set(
                    "sim_migration_bytes_total",
                    r.migration_bytes,
                );
                self.obs
                    .counter_set("sim_rebalances_total", r.rebalances);
                self.obs.counter_set(
                    "sim_trigger_checks_total",
                    r.trigger_checks,
                );
                self.obs.counter_set(
                    "sim_triggered_rebalances_total",
                    r.triggered_rebalances,
                );
                self.obs.counter_set(
                    "sim_incremental_moves_total",
                    r.incremental_moves,
                );
                self.obs.counter_set(
                    "sim_rejected_moves_total",
                    r.rejected_moves,
                );
                self.obs.counter_set(
                    "sim_remote_promotions_total",
                    r.promotions,
                );
                self.obs.counter_set(
                    "sim_remote_served_total",
                    r.remote_served,
                );
                self.obs.gauge_set("sim_makespan_seconds", r.makespan);
                self.obs
                    .gauge_set("sim_ttft_p95_seconds", r.ttft.p95());
                self.obs.gauge_set("sim_tbt_p95_seconds", r.tbt.p95());
                self.obs.gauge_set("sim_e2e_p95_seconds", r.e2e.p95());
                let stall: f64 = self
                    .st
                    .servers
                    .iter()
                    .map(|srv| srv.fetch_stall_s)
                    .sum();
                self.obs.gauge_set("sim_fetch_stall_seconds", stall);
            }
        }
        self.st.report
    }
}
