//! LoRAServe — a reproduction of *"Serving Heterogeneous LoRA Adapters
//! in Distributed LLM Inference Systems"* (CS.DC 2025).
//!
//! Rank-aware, workload-adaptive adapter placement + routing for
//! multi-tenant LoRA serving, as a three-layer stack:
//!
//! * **L3 (this crate)** — cluster orchestrator: the placement
//!   algorithm (Algorithm 1), probabilistic routing table, distributed
//!   adapter pool, discrete-event cluster simulator, the elastic
//!   capacity subsystem ([`autoscale`]: SLO-aware scale controller,
//!   drain-and-migrate protocol, minimum-GPU capacity planner), and a
//!   *real* mini-cluster whose servers execute AOT-compiled XLA
//!   artifacts via PJRT (`runtime`/`server`, behind the `pjrt`
//!   feature).
//! * **L2 (python/compile/model.py)** — a LoRA transformer (prefill +
//!   decode) lowered once to HLO text at build time.
//! * **L1 (python/compile/kernels/sgmv.py)** — the Pallas
//!   multi-adapter SGMV/BGMV kernels whose pad-to-max-rank behaviour is
//!   the interference the paper measures.
//!
//! See DESIGN.md for the full inventory and EXPERIMENTS.md for the
//! paper-vs-measured results of every figure.

pub mod autoscale;
pub mod config;
pub mod costmodel;
pub mod placement;
pub mod coordinator;
pub mod pool;
pub mod sim;
// The real PJRT mini-cluster needs the vendored `xla` + `anyhow`
// crates, which the offline build image does not carry; the modules
// (and the `serve` subcommand) are gated behind the `pjrt` feature so
// the default build stays self-contained.
#[cfg(feature = "pjrt")]
pub mod runtime;
#[cfg(feature = "pjrt")]
pub mod server;
pub mod figures;
pub mod metrics;
pub mod obs;
pub mod trace;
pub mod util;
pub mod workload;
