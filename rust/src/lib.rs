//! LoRAServe — a reproduction of *"Serving Heterogeneous LoRA Adapters
//! in Distributed LLM Inference Systems"* (CS.DC 2025).
//!
//! Rank-aware, workload-adaptive adapter placement + routing for
//! multi-tenant LoRA serving, as a three-layer stack:
//!
//! * **L3 (this crate)** — cluster orchestrator: the placement
//!   algorithm (Algorithm 1), probabilistic routing table, distributed
//!   adapter pool, discrete-event cluster simulator, and a *real*
//!   mini-cluster whose servers execute AOT-compiled XLA artifacts via
//!   PJRT ([`runtime`], [`server`]).
//! * **L2 (python/compile/model.py)** — a LoRA transformer (prefill +
//!   decode) lowered once to HLO text at build time.
//! * **L1 (python/compile/kernels/sgmv.py)** — the Pallas
//!   multi-adapter SGMV/BGMV kernels whose pad-to-max-rank behaviour is
//!   the interference the paper measures.
//!
//! See DESIGN.md for the full inventory and EXPERIMENTS.md for the
//! paper-vs-measured results of every figure.

pub mod config;
pub mod costmodel;
pub mod placement;
pub mod coordinator;
pub mod pool;
pub mod sim;
pub mod runtime;
pub mod server;
pub mod figures;
pub mod metrics;
pub mod trace;
pub mod util;
pub mod workload;
