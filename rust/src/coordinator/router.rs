//! Request routing: the probabilistic routing table plus the Toppings
//! baseline's request-level least-work router.
//!
//! The least-work router is index-backed: server loads live in an
//! [`ArgminTree`], so routing a request is an O(1) root read and a
//! load change is an O(log n) point update, instead of the former
//! O(n_servers) scan per arrival. Ties still resolve to the lowest
//! server id, bit-identical to the old scan.

use crate::placement::Assignment;
use crate::util::argmin::ArgminTree;
use crate::util::rng::Pcg32;
use crate::workload::{AdapterId, ServerId};

/// The routing table of Fig 11: per adapter, `(server, φ)` tuples with
/// Σφ = 1. Requests are routed to server s with probability φ_s.
#[derive(Debug, Clone, Default)]
pub struct RoutingTable {
    entries: Vec<Vec<(ServerId, f64)>>,
}

impl RoutingTable {
    pub fn from_assignment(asg: &Assignment) -> Self {
        RoutingTable {
            entries: asg.shares.clone(),
        }
    }

    pub fn n_adapters(&self) -> usize {
        self.entries.len()
    }

    pub fn entry(&self, adapter: AdapterId) -> &[(ServerId, f64)] {
        &self.entries[adapter as usize]
    }

    /// Sample a server for this adapter according to φ.
    pub fn route(&self, adapter: AdapterId, rng: &mut Pcg32) -> ServerId {
        let entry = &self.entries[adapter as usize];
        debug_assert!(!entry.is_empty(), "adapter {adapter} unrouted");
        if entry.len() == 1 {
            return entry[0].0;
        }
        let mut x = rng.f64();
        for &(s, phi) in entry {
            x -= phi;
            if x <= 0.0 {
                return s;
            }
        }
        entry.last().unwrap().0
    }
}

/// Routing policy, matching the paper's systems:
///  * `Table` — LORASERVE and the static S-LoRA placements (their
///    assignments just never change);
///  * `Toppings` — request-level global least-outstanding-work router,
///    rank-agnostic, with every adapter replicated on every server.
///    Loads are held in an argmin tree; the caller pushes load
///    changes via [`Router::update_load`] / [`Router::set_loads`]
///    (masked servers carry `f64::INFINITY`).
#[derive(Debug, Clone)]
pub enum Router {
    Table(RoutingTable),
    Toppings { tree: ArgminTree },
}

impl Router {
    /// A least-work router over `n_servers` slots, all loads masked
    /// (`INFINITY`) until the first `update_load`/`set_loads`.
    pub fn toppings(n_servers: usize) -> Router {
        Router::Toppings {
            tree: ArgminTree::new(n_servers),
        }
    }

    /// Route one request: φ-sample the table, or read the argmin root
    /// for Toppings (lowest server id among load ties, matching the
    /// pre-index linear scan bit-for-bit).
    pub fn route(&self, adapter: AdapterId, rng: &mut Pcg32) -> ServerId {
        match self {
            Router::Table(table) => table.route(adapter, rng),
            Router::Toppings { tree } => tree.argmin(),
        }
    }

    /// Publish server `s`'s outstanding-work estimate (O(log n);
    /// no-op for table routers). Use `f64::INFINITY` to mask a
    /// non-routable (draining/cold) server.
    #[inline]
    pub fn update_load(&mut self, s: ServerId, load: f64) {
        if let Router::Toppings { tree } = self {
            tree.update(s, load);
        }
    }

    /// Bulk-publish every server's load in one O(n) rebuild (no-op
    /// for table routers).
    pub fn set_loads(&mut self, loads: &[f64]) {
        if let Router::Toppings { tree } = self {
            debug_assert_eq!(loads.len(), tree.len());
            tree.rebuild(|i| loads[i]);
        }
    }

    /// The load index, when this is a Toppings router (parity
    /// checks and tests).
    pub fn load_index(&self) -> Option<&ArgminTree> {
        match self {
            Router::Table(_) => None,
            Router::Toppings { tree } => Some(tree),
        }
    }

    pub fn update_table(&mut self, table: RoutingTable) {
        if let Router::Table(t) = self {
            *t = table;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::Assignment;

    fn table() -> RoutingTable {
        let mut asg = Assignment::new(2);
        asg.add(0, 1, 1.0);
        asg.add(1, 0, 0.3);
        asg.add(1, 2, 0.7);
        RoutingTable::from_assignment(&asg)
    }

    #[test]
    fn deterministic_single_entry() {
        let t = table();
        let mut rng = Pcg32::new(0);
        for _ in 0..20 {
            assert_eq!(t.route(0, &mut rng), 1);
        }
    }

    #[test]
    fn respects_phi_distribution() {
        let t = table();
        let mut rng = Pcg32::new(1);
        let mut counts = [0u32; 3];
        for _ in 0..20_000 {
            counts[t.route(1, &mut rng)] += 1;
        }
        assert_eq!(counts[1], 0);
        let f0 = counts[0] as f64 / 20_000.0;
        assert!((f0 - 0.3).abs() < 0.02, "f0={f0}");
    }

    #[test]
    fn toppings_picks_least_work() {
        let mut r = Router::toppings(3);
        let mut rng = Pcg32::new(2);
        r.set_loads(&[5.0, 1.0, 3.0]);
        assert_eq!(r.route(0, &mut rng), 1);
        r.set_loads(&[0.0, 0.0, 0.0]);
        assert_eq!(r.route(7, &mut rng), 0); // ties -> lowest id
        // point updates steer the argmin too
        r.update_load(2, -1.0);
        assert_eq!(r.route(7, &mut rng), 2);
        r.update_load(2, 0.0);
        assert_eq!(r.route(7, &mut rng), 0);
    }

    #[test]
    fn toppings_masks_with_infinity() {
        let mut r = Router::toppings(4);
        let mut rng = Pcg32::new(5);
        r.set_loads(&[2.0, f64::INFINITY, 1.0, 1.0]);
        assert_eq!(r.route(0, &mut rng), 2);
        r.update_load(2, f64::INFINITY);
        assert_eq!(r.route(0, &mut rng), 3);
    }

    #[test]
    fn table_update() {
        let mut r = Router::Table(table());
        let mut asg = Assignment::new(1);
        asg.add(0, 2, 1.0);
        r.update_table(RoutingTable::from_assignment(&asg));
        let mut rng = Pcg32::new(3);
        assert_eq!(r.route(0, &mut rng), 2);
    }
}
