//! Request routing: the probabilistic routing table plus the Toppings
//! baseline's request-level least-work router.

use crate::placement::Assignment;
use crate::util::rng::Pcg32;
use crate::workload::{AdapterId, ServerId};

/// The routing table of Fig 11: per adapter, `(server, φ)` tuples with
/// Σφ = 1. Requests are routed to server s with probability φ_s.
#[derive(Debug, Clone, Default)]
pub struct RoutingTable {
    entries: Vec<Vec<(ServerId, f64)>>,
}

impl RoutingTable {
    pub fn from_assignment(asg: &Assignment) -> Self {
        RoutingTable {
            entries: asg.shares.clone(),
        }
    }

    pub fn n_adapters(&self) -> usize {
        self.entries.len()
    }

    pub fn entry(&self, adapter: AdapterId) -> &[(ServerId, f64)] {
        &self.entries[adapter as usize]
    }

    /// Sample a server for this adapter according to φ.
    pub fn route(&self, adapter: AdapterId, rng: &mut Pcg32) -> ServerId {
        let entry = &self.entries[adapter as usize];
        debug_assert!(!entry.is_empty(), "adapter {adapter} unrouted");
        if entry.len() == 1 {
            return entry[0].0;
        }
        let mut x = rng.f64();
        for &(s, phi) in entry {
            x -= phi;
            if x <= 0.0 {
                return s;
            }
        }
        entry.last().unwrap().0
    }
}

/// Routing policy, matching the paper's systems:
///  * `Table` — LORASERVE and the static S-LoRA placements (their
///    assignments just never change);
///  * `Toppings` — request-level global least-outstanding-work router,
///    rank-agnostic, with every adapter replicated on every server.
#[derive(Debug, Clone)]
pub enum Router {
    Table(RoutingTable),
    Toppings { n_servers: usize },
}

impl Router {
    /// Route one request. `outstanding_work[s]` is the live estimate of
    /// queued + running service seconds on server s (what Toppings
    /// inspects; the table policies ignore it).
    pub fn route(
        &self,
        adapter: AdapterId,
        outstanding_work: &[f64],
        rng: &mut Pcg32,
    ) -> ServerId {
        match self {
            Router::Table(table) => table.route(adapter, rng),
            Router::Toppings { n_servers } => {
                debug_assert_eq!(outstanding_work.len(), *n_servers);
                let mut best = 0;
                for s in 1..*n_servers {
                    if outstanding_work[s] < outstanding_work[best] {
                        best = s;
                    }
                }
                best
            }
        }
    }

    pub fn update_table(&mut self, table: RoutingTable) {
        if let Router::Table(t) = self {
            *t = table;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::Assignment;

    fn table() -> RoutingTable {
        let mut asg = Assignment::new(2);
        asg.add(0, 1, 1.0);
        asg.add(1, 0, 0.3);
        asg.add(1, 2, 0.7);
        RoutingTable::from_assignment(&asg)
    }

    #[test]
    fn deterministic_single_entry() {
        let t = table();
        let mut rng = Pcg32::new(0);
        for _ in 0..20 {
            assert_eq!(t.route(0, &mut rng), 1);
        }
    }

    #[test]
    fn respects_phi_distribution() {
        let t = table();
        let mut rng = Pcg32::new(1);
        let mut counts = [0u32; 3];
        for _ in 0..20_000 {
            counts[t.route(1, &mut rng)] += 1;
        }
        assert_eq!(counts[1], 0);
        let f0 = counts[0] as f64 / 20_000.0;
        assert!((f0 - 0.3).abs() < 0.02, "f0={f0}");
    }

    #[test]
    fn toppings_picks_least_work() {
        let r = Router::Toppings { n_servers: 3 };
        let mut rng = Pcg32::new(2);
        assert_eq!(r.route(0, &[5.0, 1.0, 3.0], &mut rng), 1);
        assert_eq!(r.route(7, &[0.0, 0.0, 0.0], &mut rng), 0); // ties -> lowest id
    }

    #[test]
    fn table_update() {
        let mut r = Router::Table(table());
        let mut asg = Assignment::new(1);
        asg.add(0, 2, 1.0);
        r.update_table(RoutingTable::from_assignment(&asg));
        let mut rng = Pcg32::new(3);
        assert_eq!(r.route(0, &[], &mut rng), 2);
    }
}
