//! Cluster orchestrator: routing table, demand tracking, rebalancing.
//!
//! This is the LORASERVE *service* of Fig 11/13: it receives every
//! request, looks up the routing table, picks a server with probability
//! φ, tracks per-adapter demand, and every time step re-runs the
//! placement algorithm and updates the table + the adapter-location
//! map. Both the DES simulator and the real mini-cluster drive the same
//! coordinator code.

pub mod demand;
pub mod router;

pub use demand::DemandTracker;
pub use router::{Router, RoutingTable};
