//! Per-adapter demand tracking + extrapolation (Algorithm 1 step 1:
//! GETPREVTIMESTEPTPS + EXTRAPOLATE over TPSHistory).

use crate::util::stats::linear_fit;
use crate::workload::AdapterId;
use std::collections::BTreeMap;

/// Accumulates request tokens per adapter within the current time step
/// and keeps a bounded TPS history for extrapolation.
#[derive(Debug, Clone)]
pub struct DemandTracker {
    window: f64,
    history_len: usize,
    current_tokens: BTreeMap<AdapterId, f64>,
    history: BTreeMap<AdapterId, Vec<f64>>,
    /// Disable trend extrapolation (ablation A3): project last value.
    pub last_value_only: bool,
}

impl DemandTracker {
    pub fn new(window: f64, history_len: usize) -> Self {
        assert!(window > 0.0 && history_len >= 1);
        DemandTracker {
            window,
            history_len,
            current_tokens: BTreeMap::new(),
            history: BTreeMap::new(),
            last_value_only: false,
        }
    }

    /// Record an arriving request's token demand.
    pub fn record(&mut self, adapter: AdapterId, tokens: u64) {
        *self.current_tokens.entry(adapter).or_insert(0.0) +=
            tokens as f64;
    }

    /// Close the current time step: fold the accumulated tokens into
    /// per-adapter TPS history.
    pub fn roll_window(&mut self) {
        let current = std::mem::take(&mut self.current_tokens);
        // every adapter with history also gets a 0 sample when silent
        let ids: std::collections::BTreeSet<AdapterId> = self
            .history
            .keys()
            .copied()
            .chain(current.keys().copied())
            .collect();
        for id in ids {
            let tps =
                current.get(&id).copied().unwrap_or(0.0) / self.window;
            let h = self.history.entry(id).or_default();
            h.push(tps);
            if h.len() > self.history_len {
                h.remove(0);
            }
        }
    }

    /// Projected TPS for the *next* time step per adapter: linear trend
    /// over the history, evaluated one step ahead, clamped to ≥ 0.
    /// Unseen adapters project 0.
    pub fn projected_tps(&self) -> BTreeMap<AdapterId, f64> {
        self.history
            .iter()
            .map(|(&id, h)| {
                let proj = if self.last_value_only || h.len() < 3 {
                    *h.last().unwrap_or(&0.0)
                } else {
                    let (slope, intercept) = linear_fit(h);
                    (slope * h.len() as f64 + intercept).max(0.0)
                };
                (id, proj)
            })
            .collect()
    }

    /// Last completed-window TPS (no extrapolation), for reporting.
    pub fn last_tps(&self) -> BTreeMap<AdapterId, f64> {
        self.history
            .iter()
            .map(|(&id, h)| (id, *h.last().unwrap_or(&0.0)))
            .collect()
    }

    /// Cluster-wide projected tokens/sec for the next time step — the
    /// autoscaler's demand-side load signal
    /// (`autoscale::ScaleSignals::projected_tps`).
    pub fn total_projected_tps(&self) -> f64 {
        self.projected_tps().values().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tps_is_tokens_over_window() {
        let mut d = DemandTracker::new(10.0, 8);
        d.record(0, 500);
        d.record(0, 500);
        d.record(1, 100);
        d.roll_window();
        let tps = d.last_tps();
        assert_eq!(tps[&0], 100.0);
        assert_eq!(tps[&1], 10.0);
    }

    #[test]
    fn silent_adapter_decays_to_zero() {
        let mut d = DemandTracker::new(1.0, 8);
        d.record(0, 100);
        d.roll_window();
        d.roll_window();
        d.roll_window();
        assert_eq!(d.last_tps()[&0], 0.0);
        // projection also heads to zero (clamped)
        assert!(d.projected_tps()[&0] <= 100.0 / 3.0);
    }

    #[test]
    fn extrapolates_rising_trend() {
        let mut d = DemandTracker::new(1.0, 8);
        for step in 1..=5u64 {
            d.record(0, step * 100);
            d.roll_window();
        }
        // history: 100..500, trend +100/step -> projection ~600
        let proj = d.projected_tps()[&0];
        assert!((proj - 600.0).abs() < 1.0, "proj={proj}");
        // ablation: last-value-only projects 500
        let mut d2 = d.clone();
        d2.last_value_only = true;
        assert_eq!(d2.projected_tps()[&0], 500.0);
    }

    #[test]
    fn projection_never_negative() {
        let mut d = DemandTracker::new(1.0, 8);
        for step in (1..=5u64).rev() {
            d.record(0, step * 100);
            d.roll_window();
        }
        assert!(d.projected_tps()[&0] >= 0.0);
    }

    #[test]
    fn aggregate_signal() {
        let mut d = DemandTracker::new(10.0, 8);
        d.record(0, 500);
        d.record(1, 300);
        assert_eq!(d.total_projected_tps(), 0.0); // nothing rolled yet
        d.roll_window();
        assert!((d.total_projected_tps() - 80.0).abs() < 1e-9);
    }

    #[test]
    fn history_bounded() {
        let mut d = DemandTracker::new(1.0, 3);
        for _ in 0..10 {
            d.record(0, 1);
            d.roll_window();
        }
        assert_eq!(d.history[&0].len(), 3);
    }
}
