//! Per-adapter demand tracking + extrapolation (Algorithm 1 step 1:
//! GETPREVTIMESTEPTPS + EXTRAPOLATE over TPSHistory).
//!
//! The tracker is allocation-free on the hot path: adapter ids are
//! dense (trace construction interns them), so token accumulation,
//! the per-window roll, and projections all run over flat vectors —
//! ring-buffer TPS histories instead of `Vec::remove(0)`, an
//! incrementally-maintained known-id set instead of a per-roll
//! `BTreeSet` union, and projections cached per `(roll, ablation
//! flag)` generation instead of a fresh `BTreeMap` per query. The
//! legacy map-shaped accessors survive for cold paths (planners,
//! reports) and produce bit-identical values: per-id projections use
//! the same chronological sample order and fold, and the cluster
//! total sums in ascending id order exactly like the old
//! `BTreeMap::values().sum()`.

use crate::util::stats::linear_fit;
use crate::workload::AdapterId;
use std::collections::BTreeMap;

/// Accumulates request tokens per adapter within the current time step
/// and keeps a bounded TPS history for extrapolation.
#[derive(Debug, Clone)]
pub struct DemandTracker {
    window: f64,
    history_len: usize,
    /// tokens accumulated this window, dense by adapter id
    current: Vec<f64>,
    /// ids with tokens this window (`in_current` dedups the pushes)
    seen: Vec<AdapterId>,
    in_current: Vec<bool>,
    /// every id that has rolled at least once, ascending
    known: Vec<AdapterId>,
    is_known: Vec<bool>,
    /// ring-buffer TPS histories, `history_len` slots per id at
    /// `id * history_len` (block layout is stable under growth)
    hist: Vec<f64>,
    /// filled samples per id (saturates at `history_len`)
    hist_n: Vec<u32>,
    /// ring write cursor per id — once full, also the oldest sample
    hist_pos: Vec<u32>,
    /// nonzero samples currently in the ring per id: a zero count
    /// short-circuits projection to 0.0 (bit-exact: a linear fit of
    /// all-zero samples is (0, 0))
    nz: Vec<u32>,
    /// cached projections (dense by id) + their ascending-id total,
    /// valid for `cached == Some((version, last_value_only))`
    proj: Vec<f64>,
    total_proj: f64,
    version: u64,
    cached: Option<(u64, bool)>,
    fit_buf: Vec<f64>,
    /// Disable trend extrapolation (ablation A3): project last value.
    pub last_value_only: bool,
}

impl DemandTracker {
    pub fn new(window: f64, history_len: usize) -> Self {
        assert!(window > 0.0 && history_len >= 1);
        DemandTracker {
            window,
            history_len,
            current: Vec::new(),
            seen: Vec::new(),
            in_current: Vec::new(),
            known: Vec::new(),
            is_known: Vec::new(),
            hist: Vec::new(),
            hist_n: Vec::new(),
            hist_pos: Vec::new(),
            nz: Vec::new(),
            proj: Vec::new(),
            total_proj: 0.0,
            version: 0,
            cached: None,
            fit_buf: Vec::new(),
            last_value_only: false,
        }
    }

    /// Grow every dense-by-id vector to cover `id` (amortized O(1):
    /// ids are interned densely by trace construction).
    fn ensure_id(&mut self, id: AdapterId) {
        let need = id as usize + 1;
        if need <= self.current.len() {
            return;
        }
        self.current.resize(need, 0.0);
        self.in_current.resize(need, false);
        self.is_known.resize(need, false);
        self.hist.resize(need * self.history_len, 0.0);
        self.hist_n.resize(need, 0);
        self.hist_pos.resize(need, 0);
        self.nz.resize(need, 0);
        self.proj.resize(need, 0.0);
    }

    /// Record an arriving request's token demand.
    #[inline]
    pub fn record(&mut self, adapter: AdapterId, tokens: u64) {
        self.ensure_id(adapter);
        let i = adapter as usize;
        self.current[i] += tokens as f64;
        if !self.in_current[i] {
            self.in_current[i] = true;
            self.seen.push(adapter);
        }
    }

    /// Close the current time step: fold the accumulated tokens into
    /// per-adapter TPS history. Every known adapter gets a sample
    /// (0 when silent); newly seen adapters join the known set.
    pub fn roll_window(&mut self) {
        // fold first-time ids into the ascending known set — an
        // incremental merge, not a per-roll set union
        if !self.seen.is_empty() {
            let seen = std::mem::take(&mut self.seen);
            let mut added = false;
            for &id in &seen {
                if !self.is_known[id as usize] {
                    self.is_known[id as usize] = true;
                    self.known.push(id);
                    added = true;
                }
            }
            self.seen = seen;
            self.seen.clear();
            if added {
                self.known.sort_unstable();
            }
        }
        let known = std::mem::take(&mut self.known);
        for &id in &known {
            let i = id as usize;
            let tps = self.current[i] / self.window;
            self.current[i] = 0.0;
            self.in_current[i] = false;
            let base = i * self.history_len;
            let n = self.hist_n[i] as usize;
            if n < self.history_len {
                self.hist[base + n] = tps;
                self.hist_n[i] = (n + 1) as u32;
            } else {
                let pos = self.hist_pos[i] as usize;
                if self.hist[base + pos] != 0.0 {
                    self.nz[i] -= 1;
                }
                self.hist[base + pos] = tps;
                self.hist_pos[i] =
                    ((pos + 1) % self.history_len) as u32;
            }
            if tps != 0.0 {
                self.nz[i] += 1;
            }
        }
        self.known = known;
        self.version += 1;
    }

    /// Chronological (oldest→newest) ring contents for `id`.
    fn fill_history(&self, id: AdapterId, out: &mut Vec<f64>) {
        out.clear();
        let i = id as usize;
        if i >= self.hist_n.len() {
            return;
        }
        let base = i * self.history_len;
        let n = self.hist_n[i] as usize;
        if n < self.history_len {
            out.extend_from_slice(&self.hist[base..base + n]);
        } else {
            let pos = self.hist_pos[i] as usize;
            for k in 0..n {
                out.push(self.hist[base + (pos + k) % self.history_len]);
            }
        }
    }

    /// Snapshot of `id`'s TPS history, oldest→newest (tests and
    /// inspection; the hot path never materializes this).
    pub fn history_of(&self, id: AdapterId) -> Vec<f64> {
        let mut out = Vec::new();
        self.fill_history(id, &mut out);
        out
    }

    /// One adapter's next-step projection from its ring — the same
    /// value the pre-index tracker computed from its grow-and-shift
    /// `Vec` history.
    fn project_one(&self, id: AdapterId, buf: &mut Vec<f64>) -> f64 {
        let i = id as usize;
        let n = self.hist_n[i] as usize;
        if self.nz[i] == 0 {
            // all samples zero: last value is 0 and a linear fit is
            // (slope 0, intercept 0) — both project exactly 0.0
            return 0.0;
        }
        let base = i * self.history_len;
        let last = if n < self.history_len {
            self.hist[base + n - 1]
        } else {
            let pos = self.hist_pos[i] as usize;
            self.hist[base + (pos + self.history_len - 1) % self.history_len]
        };
        if self.last_value_only || n < 3 {
            return last;
        }
        self.fill_history(id, buf);
        let (slope, intercept) = linear_fit(buf);
        (slope * n as f64 + intercept).max(0.0)
    }

    /// Refresh the projection cache if the window rolled or the
    /// ablation flag flipped since it was last built.
    pub fn ensure_projections(&mut self) {
        if self.cached == Some((self.version, self.last_value_only)) {
            return;
        }
        let known = std::mem::take(&mut self.known);
        let mut buf = std::mem::take(&mut self.fit_buf);
        let mut total = 0.0f64;
        for &id in &known {
            let p = self.project_one(id, &mut buf);
            self.proj[id as usize] = p;
            total += p; // ascending-id order, like the old map sum
        }
        self.known = known;
        self.fit_buf = buf;
        self.total_proj = total;
        self.cached = Some((self.version, self.last_value_only));
    }

    /// Known adapter ids (rolled at least once), ascending.
    pub fn known_ids(&self) -> &[AdapterId] {
        &self.known
    }

    /// Dense per-id projections; valid for ids in
    /// [`Self::known_ids`] after [`Self::ensure_projections`]
    /// (never-rolled ids read 0.0).
    pub fn projections(&self) -> &[f64] {
        &self.proj
    }

    /// Projected TPS for the *next* time step per adapter: linear trend
    /// over the history, evaluated one step ahead, clamped to ≥ 0.
    /// Unseen adapters project 0. (Map-shaped accessor for cold
    /// paths; served from the projection cache.)
    pub fn projected_tps(&mut self) -> BTreeMap<AdapterId, f64> {
        self.ensure_projections();
        self.known
            .iter()
            .map(|&id| (id, self.proj[id as usize]))
            .collect()
    }

    /// Last completed-window TPS (no extrapolation), for reporting.
    pub fn last_tps(&self) -> BTreeMap<AdapterId, f64> {
        self.known
            .iter()
            .map(|&id| {
                let i = id as usize;
                let n = self.hist_n[i] as usize;
                let base = i * self.history_len;
                let last = if n == 0 {
                    0.0
                } else if n < self.history_len {
                    self.hist[base + n - 1]
                } else {
                    let pos = self.hist_pos[i] as usize;
                    self.hist[base
                        + (pos + self.history_len - 1) % self.history_len]
                };
                (id, last)
            })
            .collect()
    }

    /// Cluster-wide projected tokens/sec for the next time step — the
    /// autoscaler's demand-side load signal
    /// (`autoscale::ScaleSignals::projected_tps`). Cached alongside
    /// the per-adapter projections.
    pub fn total_projected_tps(&mut self) -> f64 {
        self.ensure_projections();
        self.total_proj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tps_is_tokens_over_window() {
        let mut d = DemandTracker::new(10.0, 8);
        d.record(0, 500);
        d.record(0, 500);
        d.record(1, 100);
        d.roll_window();
        let tps = d.last_tps();
        assert_eq!(tps[&0], 100.0);
        assert_eq!(tps[&1], 10.0);
    }

    #[test]
    fn silent_adapter_decays_to_zero() {
        let mut d = DemandTracker::new(1.0, 8);
        d.record(0, 100);
        d.roll_window();
        d.roll_window();
        d.roll_window();
        assert_eq!(d.last_tps()[&0], 0.0);
        // projection also heads to zero (clamped)
        assert!(d.projected_tps()[&0] <= 100.0 / 3.0);
    }

    #[test]
    fn extrapolates_rising_trend() {
        let mut d = DemandTracker::new(1.0, 8);
        for step in 1..=5u64 {
            d.record(0, step * 100);
            d.roll_window();
        }
        // history: 100..500, trend +100/step -> projection ~600
        let proj = d.projected_tps()[&0];
        assert!((proj - 600.0).abs() < 1.0, "proj={proj}");
        // ablation: last-value-only projects 500 — and must bust the
        // projection cache built above under the other flag value
        let mut d2 = d.clone();
        d2.last_value_only = true;
        assert_eq!(d2.projected_tps()[&0], 500.0);
    }

    #[test]
    fn projection_never_negative() {
        let mut d = DemandTracker::new(1.0, 8);
        for step in (1..=5u64).rev() {
            d.record(0, step * 100);
            d.roll_window();
        }
        assert!(d.projected_tps()[&0] >= 0.0);
    }

    #[test]
    fn aggregate_signal() {
        let mut d = DemandTracker::new(10.0, 8);
        d.record(0, 500);
        d.record(1, 300);
        assert_eq!(d.total_projected_tps(), 0.0); // nothing rolled yet
        d.roll_window();
        assert!((d.total_projected_tps() - 80.0).abs() < 1e-9);
    }

    #[test]
    fn history_bounded() {
        let mut d = DemandTracker::new(1.0, 3);
        for _ in 0..10 {
            d.record(0, 1);
            d.roll_window();
        }
        assert_eq!(d.history_of(0).len(), 3);
    }

    #[test]
    fn ring_keeps_newest_samples_in_order() {
        let mut d = DemandTracker::new(1.0, 3);
        for step in 1..=5u64 {
            d.record(0, step * 10);
            d.roll_window();
        }
        // rolled 10,20,30,40,50 through a 3-deep ring
        assert_eq!(d.history_of(0), vec![30.0, 40.0, 50.0]);
        assert_eq!(d.last_tps()[&0], 50.0);
    }

    #[test]
    fn cache_invalidates_on_roll_and_new_adapter() {
        let mut d = DemandTracker::new(1.0, 8);
        d.record(0, 100);
        d.roll_window();
        assert_eq!(d.total_projected_tps(), 100.0);
        // a fresh adapter only enters the projections once rolled
        d.record(1, 50);
        assert_eq!(d.projected_tps().len(), 1);
        d.roll_window();
        let m = d.projected_tps();
        assert_eq!(m.len(), 2);
        assert_eq!(m[&1], 50.0);
    }

    /// The dense/ring tracker must reproduce the pre-index
    /// map-of-vecs tracker bit for bit: same ids, same projections,
    /// same total, under a randomized record/roll schedule.
    #[test]
    fn matches_map_reference_bitwise() {
        use crate::util::rng::Pcg32;

        struct Reference {
            window: f64,
            history_len: usize,
            current: BTreeMap<AdapterId, f64>,
            history: BTreeMap<AdapterId, Vec<f64>>,
            last_value_only: bool,
        }
        impl Reference {
            fn roll(&mut self) {
                let current = std::mem::take(&mut self.current);
                let ids: std::collections::BTreeSet<AdapterId> = self
                    .history
                    .keys()
                    .copied()
                    .chain(current.keys().copied())
                    .collect();
                for id in ids {
                    let tps = current.get(&id).copied().unwrap_or(0.0)
                        / self.window;
                    let h = self.history.entry(id).or_default();
                    h.push(tps);
                    if h.len() > self.history_len {
                        h.remove(0);
                    }
                }
            }
            fn projected(&self) -> BTreeMap<AdapterId, f64> {
                self.history
                    .iter()
                    .map(|(&id, h)| {
                        let proj = if self.last_value_only || h.len() < 3
                        {
                            *h.last().unwrap_or(&0.0)
                        } else {
                            let (slope, intercept) = linear_fit(h);
                            (slope * h.len() as f64 + intercept)
                                .max(0.0)
                        };
                        (id, proj)
                    })
                    .collect()
            }
        }

        for flag in [false, true] {
            let mut d = DemandTracker::new(2.0, 4);
            d.last_value_only = flag;
            let mut r = Reference {
                window: 2.0,
                history_len: 4,
                current: BTreeMap::new(),
                history: BTreeMap::new(),
                last_value_only: flag,
            };
            let mut rng = Pcg32::new(42);
            for _ in 0..40 {
                for _ in 0..(rng.next_u32() % 8) {
                    let id = rng.next_u32() % 9;
                    let tokens = (rng.next_u32() % 1000) as u64;
                    d.record(id, tokens);
                    *r.current.entry(id).or_insert(0.0) +=
                        tokens as f64;
                }
                d.roll_window();
                r.roll();
                let got = d.projected_tps();
                let want = r.projected();
                assert_eq!(got.len(), want.len());
                for (id, w) in &want {
                    assert_eq!(
                        got[id].to_bits(),
                        w.to_bits(),
                        "adapter {id} diverged (flag={flag})"
                    );
                }
                let total: f64 = want.values().sum();
                assert_eq!(
                    d.total_projected_tps().to_bits(),
                    total.to_bits()
                );
            }
        }
    }
}
