//! Algorithm 1: the LORASERVE rank-aware, demand-aware placer.
//!
//! Steps (paper §IV-A):
//!  1. estimate per-adapter TPS demand and the average target
//!     utilization per server (demand extrapolation happens upstream in
//!     `coordinator::demand`; this placer consumes projected TPS);
//!  2. compute each rank's *server budget* — how many whole servers the
//!     rank's aggregate utilization deserves;
//!  3. fractionally bin-pack each budgeted rank's adapters into its
//!     servers (splits become routing φ's);
//!  4. allocate leftovers (zero-budget ranks, overflow) to the server
//!     with the highest max resident rank, least-utilized first —
//!     keeping big-rank adapters away from small-rank servers;
//!  5. permute the new placement's server labels to maximize overlap
//!     with the previous placement (minimizes migration bytes);
//!  6. emit the routing table (done by the coordinator from the
//!     returned `Assignment`).


use super::{Assignment, PlacementCtx, Placer};
use crate::workload::AdapterId;
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct LoraServePlacer {
    /// Disable step 5 (ablation A2 in DESIGN.md §8).
    pub skip_permutation: bool,
}

impl LoraServePlacer {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Placer for LoraServePlacer {
    fn name(&self) -> &'static str {
        "loraserve"
    }

    fn place(&mut self, ctx: &PlacementCtx) -> Assignment {
        let n = ctx.n_servers;
        let adapters = ctx.adapters;
        assert!(n > 0 && !adapters.is_empty());

        // ---- step 1: per-rank utilization and target utilization
        let util_of = |a: AdapterId| -> f64 {
            let adapter = adapters.get(a);
            let demand = ctx.demand_tps.get(&a).copied().unwrap_or(0.0);
            let op = ctx
                .operating_points
                .get(&adapter.rank)
                .copied()
                .unwrap_or(f64::INFINITY);
            demand / op
        };
        let ranks = adapters.unique_ranks();
        let mut rank_util: BTreeMap<u32, f64> = BTreeMap::new();
        for a in adapters.iter() {
            *rank_util.entry(a.rank).or_insert(0.0) += util_of(a.id);
        }
        let total_util: f64 = rank_util.values().sum();
        // Guard: an idle cluster still needs a placement; use a uniform
        // nominal utilization so packing degenerates gracefully.
        let target_util = if total_util > 1e-9 {
            total_util / n as f64
        } else {
            1.0
        };

        // ---- step 2: server budget per rank (ROUND + repair) — kept
        // for reporting and for sizing intuition; the packing below
        // realizes these budgets implicitly (a rank's contiguous span
        // covers ~util/target servers).
        let mut budget: BTreeMap<u32, usize> = BTreeMap::new();
        for &r in &ranks {
            let b = (rank_util[&r] / target_util).round() as usize;
            budget.insert(r, b);
        }
        repair_budgets(&mut budget, &rank_util, target_util, n);

        // ---- steps 3+4: rank-contiguous *stream* packing. Adapters
        // are laid out grouped by rank (descending), demand-sorted
        // within each rank, and the stream is cut into n bins of
        // exactly targetUtil, splitting an adapter across consecutive
        // servers at each cut (the split fractions are the routing
        // φ's). By construction every server lands on the average
        // utilization and at most two adjacent rank classes share a
        // boundary server — the minimal heterogeneity achievable when
        // ranks outnumber servers (Fig 12's LORASERVE picture).
        // Low-demand ranks occupy slivers of shared servers rather
        // than dedicated ones ("co-locating low-demand adapters").
        //
        // Mixing-aware pricing: a piece placed on a server whose max
        // resident rank exceeds its own is consumed at the *server's*
        // rank price (its requests co-batch to the server's max rank —
        // the pad-to-max-rank tax). Since mixing inflates the total
        // effective utilization, the packing runs a short fixed-point:
        // pack, recompute the inflated total, repack with the larger
        // target.
        let op_of_rank = |r: u32| -> f64 {
            ctx.operating_points
                .get(&r)
                .copied()
                .unwrap_or(f64::INFINITY)
        };
        let mut ranks_desc = ranks.clone();
        ranks_desc.sort_unstable_by(|a, b| b.cmp(a));
        const EPS: f64 = 1e-12;

        let pack = |target: f64| -> (Assignment, f64) {
            let mut assignment = Assignment::new(adapters.len());
            let mut server_util = vec![0.0f64; n];
            let mut bin_max_rank = vec![0u32; n];
            let mut bin = 0usize;
            for &r in &ranks_desc {
                let mut members: Vec<(AdapterId, f64)> = adapters
                    .iter()
                    .filter(|a| a.rank == r)
                    .map(|a| {
                        (a.id, ctx.demand_tps.get(&a.id).copied().unwrap_or(0.0))
                    })
                    .collect();
                members.sort_by(|a, b| {
                    b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
                });
                let rank_first_bin = bin;
                for &(a, demand) in &members {
                    if demand / op_of_rank(r) <= EPS {
                        continue; // zero-demand: parked below
                    }
                    let mut remaining = demand; // in tokens/sec
                    while remaining > EPS * op_of_rank(r) {
                        if bin_max_rank[bin] == 0 {
                            bin_max_rank[bin] = r;
                        }
                        // price at the server's max rank (co-batching)
                        let op = op_of_rank(bin_max_rank[bin].max(r));
                        let free = target - server_util[bin];
                        if free <= EPS {
                            if bin + 1 < n {
                                bin += 1;
                                continue;
                            }
                            // stream residue (fixed-point error):
                            // water-fill onto the least-loaded server
                            // instead of melting the last one
                            let lightest = (0..n)
                                .min_by(|&x, &y| {
                                    server_util[x]
                                        .partial_cmp(&server_util[y])
                                        .unwrap()
                                })
                                .unwrap();
                            if bin_max_rank[lightest] == 0 {
                                bin_max_rank[lightest] = r;
                            }
                            let op2 = op_of_rank(
                                bin_max_rank[lightest].max(r),
                            );
                            assignment.add(a, lightest, remaining / demand);
                            server_util[lightest] += remaining / op2;
                            remaining = 0.0;
                            continue;
                        }
                        let take_demand = remaining.min(free * op);
                        assignment.add(a, bin, take_demand / demand);
                        server_util[bin] += take_demand / op;
                        remaining -= take_demand;
                    }
                }
                // zero-demand members: park on the least-loaded server
                // of this rank's span (no utilization added)
                let rank_last_bin = bin;
                for &(a, demand) in &members {
                    if demand / op_of_rank(r) > EPS {
                        continue;
                    }
                    let t = (rank_first_bin..=rank_last_bin)
                        .min_by(|&x, &y| {
                            server_util[x]
                                .partial_cmp(&server_util[y])
                                .unwrap()
                        })
                        .unwrap_or(bin);
                    assignment.add(a, t, 1.0);
                }
            }
            (assignment, server_util.iter().sum())
        };

        // short fixed point on the mixing-inflated target
        let mut target = target_util;
        let mut assignment = Assignment::new(adapters.len());
        for _ in 0..4 {
            let (asg, total_eff) = pack(target);
            assignment = asg;
            let next = (total_eff / n as f64).max(target_util);
            if (next - target).abs() <= 0.01 * target {
                break;
            }
            target = next;
        }

        assignment.normalize();

        // ---- step 5: permute server labels to match prev assignment
        if let (false, Some(prev)) = (self.skip_permutation, ctx.prev) {
            assignment =
                permute_to_match(&assignment, prev, ctx.adapters, n);
        }
        #[cfg(debug_assertions)]
        if let Err(e) = assignment.validate(n) {
            panic!("loraserve placement invalid: {e}");
        }
        assignment
    }
}

/// Repair rank budgets after rounding so Σ budgets ≤ n and every unit
/// of leftover capacity goes to the most-utilized ranks.
fn repair_budgets(
    budget: &mut BTreeMap<u32, usize>,
    rank_util: &BTreeMap<u32, f64>,
    target_util: f64,
    n: usize,
) {
    // shrink: while over budget, decrement the rank whose last server
    // is least justified (smallest util/budget ratio)
    loop {
        let total: usize = budget.values().sum();
        if total <= n {
            break;
        }
        let victim = budget
            .iter()
            .filter(|(_, &b)| b > 0)
            .min_by(|(r1, &b1), (r2, &b2)| {
                let j1 = rank_util[r1] - (b1 as f64 - 1.0) * target_util;
                let j2 = rank_util[r2] - (b2 as f64 - 1.0) * target_util;
                j1.partial_cmp(&j2).unwrap()
            })
            .map(|(r, _)| *r)
            .expect("over budget but no positive budgets");
        *budget.get_mut(&victim).unwrap() -= 1;
    }
    // grow: hand spare servers to the rank with most residual util
    loop {
        let total: usize = budget.values().sum();
        if total >= n {
            break;
        }
        let winner = budget
            .iter()
            .max_by(|(r1, &b1), (r2, &b2)| {
                let res1 = rank_util[r1] - b1 as f64 * target_util;
                let res2 = rank_util[r2] - b2 as f64 * target_util;
                res1.partial_cmp(&res2).unwrap()
            })
            .map(|(r, _)| *r)
            .unwrap();
        *budget.get_mut(&winner).unwrap() += 1;
    }
}

/// Step 5: relabel servers in `next` to maximize byte overlap with
/// `prev` (greedy maximum matching on the overlap matrix).
fn permute_to_match(
    next: &Assignment,
    prev: &Assignment,
    adapters: &crate::workload::AdapterSet,
    n: usize,
) -> Assignment {
    // overlap[new][old] = bytes of adapters on both
    let mut overlap = vec![vec![0u64; n]; n];
    for (a, ss) in next.shares.iter().enumerate() {
        let bytes = adapters.get(a as AdapterId).size_bytes;
        let old_servers: Vec<usize> = prev
            .shares
            .get(a)
            .map(|v| v.iter().map(|(s, _)| *s).collect())
            .unwrap_or_default();
        for &(s_new, _) in ss {
            for &s_old in &old_servers {
                overlap[s_new][s_old] += bytes;
            }
        }
    }
    // greedy: repeatedly take the largest overlap pair
    let mut mapping = vec![usize::MAX; n]; // new -> old label
    let mut used_old = vec![false; n];
    let mut used_new = vec![false; n];
    for _ in 0..n {
        let mut best = (0usize, 0usize, 0u64);
        let mut found = false;
        for s_new in 0..n {
            if used_new[s_new] {
                continue;
            }
            for s_old in 0..n {
                if used_old[s_old] {
                    continue;
                }
                if !found || overlap[s_new][s_old] > best.2 {
                    best = (s_new, s_old, overlap[s_new][s_old]);
                    found = true;
                }
            }
        }
        if !found {
            break;
        }
        mapping[best.0] = best.1;
        used_new[best.0] = true;
        used_old[best.1] = true;
    }
    // any unmatched new slots get remaining old labels
    let mut spare: Vec<usize> =
        (0..n).filter(|&s| !used_old[s]).collect();
    for m in mapping.iter_mut() {
        if *m == usize::MAX {
            *m = spare.pop().expect("label underflow");
        }
    }

    let mut out = Assignment::new(next.shares.len());
    for (a, ss) in next.shares.iter().enumerate() {
        for &(s, phi) in ss {
            out.add(a as AdapterId, mapping[s], phi);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::testutil::random_ctx;

    #[test]
    fn valid_assignment_across_random_instances() {
        for seed in 0..40 {
            let data = random_ctx(seed, 5 + (seed as usize * 7) % 120, 1 + (seed as usize) % 12);
            let mut placer = LoraServePlacer::new();
            let asg = placer.place(&data.ctx());
            asg.validate(data.n_servers)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }
    }

    #[test]
    fn load_balanced_within_tolerance() {
        // with many adapters, expected utils should be near-uniform
        let data = random_ctx(7, 100, 4);
        let mut placer = LoraServePlacer::new();
        let asg = placer.place(&data.ctx());
        let utils = asg.server_utils(
            4,
            &data.adapters,
            &data.demand,
            &data.oppoints,
        );
        let mean: f64 = utils.iter().sum::<f64>() / 4.0;
        for (s, &u) in utils.iter().enumerate() {
            assert!(
                u < mean * 1.8 + 1e-9,
                "server {s} util {u} vs mean {mean} ({utils:?})"
            );
        }
    }

    #[test]
    fn reduces_heterogeneity_vs_random() {
        use crate::placement::baselines::RandomPlacer;
        let data = random_ctx(11, 60, 4);
        let mut ls = LoraServePlacer::new();
        let mut rnd = RandomPlacer::new(0);
        let a_ls = ls.place(&data.ctx());
        let a_rnd = rnd.place(&data.ctx());
        let het = |a: &Assignment| -> f64 {
            let h = a.heterogeneity(4, &data.adapters);
            h.iter().sum::<usize>() as f64 / 4.0
        };
        assert!(
            het(&a_ls) < het(&a_rnd),
            "loraserve {} !< random {}",
            het(&a_ls),
            het(&a_rnd)
        );
    }

    #[test]
    fn hot_adapter_splits_across_servers() {
        // one adapter with demand far above a single server's capacity
        let mut data = random_ctx(13, 10, 4);
        let hot = 0u32;
        let op = data.oppoints[&data.adapters.get(hot).rank];
        data.demand.insert(hot, op * 3.0); // needs ~3 servers
        for a in 1..10u32 {
            data.demand.insert(a, 1.0);
        }
        let mut placer = LoraServePlacer::new();
        let asg = placer.place(&data.ctx());
        assert!(
            asg.servers_of(hot).len() >= 2,
            "hot adapter on {:?}",
            asg.servers_of(hot)
        );
        asg.validate(4).unwrap();
    }

    #[test]
    fn permutation_reduces_migration() {
        let data = random_ctx(17, 80, 6);
        let mut placer = LoraServePlacer::new();
        let prev = placer.place(&data.ctx());

        // drift the demand a little and re-place with/without step 5
        let mut drifted = data.demand.clone();
        for (i, (_, d)) in drifted.iter_mut().enumerate() {
            *d *= 1.0 + 0.1 * ((i % 5) as f64 - 2.0);
            *d = d.max(0.0);
        }
        let ctx = crate::placement::PlacementCtx {
            adapters: &data.adapters,
            n_servers: data.n_servers,
            demand_tps: &drifted,
            operating_points: &data.oppoints,
            prev: Some(&prev),
        };
        let with_perm = LoraServePlacer::new().place(&ctx);
        let without = LoraServePlacer {
            skip_permutation: true,
        }
        .place(&ctx);
        let m_with = with_perm.migration_bytes(&prev, &data.adapters);
        let m_without = without.migration_bytes(&prev, &data.adapters);
        assert!(
            m_with <= m_without,
            "with={m_with} without={m_without}"
        );
        with_perm.validate(data.n_servers).unwrap();
    }

    #[test]
    fn zero_demand_cluster_still_places_everything() {
        let mut data = random_ctx(19, 30, 4);
        for (_, d) in data.demand.iter_mut() {
            *d = 0.0;
        }
        let asg = LoraServePlacer::new().place(&data.ctx());
        asg.validate(4).unwrap();
    }

    #[test]
    fn single_server_cluster() {
        let data = random_ctx(23, 20, 1);
        let asg = LoraServePlacer::new().place(&data.ctx());
        asg.validate(1).unwrap();
        for a in 0..20u32 {
            assert_eq!(asg.servers_of(a), &[(0usize, 1.0)]);
        }
    }

    #[test]
    fn budgets_repair_to_cluster_size() {
        let mut budget: BTreeMap<u32, usize> = BTreeMap::new();
        budget.insert(8, 3);
        budget.insert(128, 3);
        let mut util = BTreeMap::new();
        util.insert(8u32, 2.6);
        util.insert(128u32, 2.9);
        repair_budgets(&mut budget, &util, 1.0, 4);
        assert_eq!(budget.values().sum::<usize>(), 4);
        // the rank with more residual util keeps more servers
        assert!(budget[&128] >= budget[&8]);
    }
}
