//! Adapter placement: the paper's contribution (Algorithm 1) and the
//! baselines it is evaluated against (§V-D).
//!
//! A placement maps every adapter to one or more servers with
//! fractional load shares φ (Σφ = 1 per adapter) — the tuples
//! `(adapter_id, server_id, φ)` of the paper's routing table.

pub mod baselines;
pub mod binpack;
pub mod loraserve;

use crate::workload::{AdapterId, AdapterSet, ServerId};
use std::collections::BTreeMap;

/// Per-adapter server shares. Invariants (checked by `validate`):
/// every adapter appears, shares are positive, Σφ = 1.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Assignment {
    /// Indexed by adapter id (dense).
    pub shares: Vec<Vec<(ServerId, f64)>>,
}

impl Assignment {
    pub fn new(n_adapters: usize) -> Self {
        Assignment {
            shares: vec![Vec::new(); n_adapters],
        }
    }

    pub fn add(&mut self, adapter: AdapterId, server: ServerId, phi: f64) {
        debug_assert!(phi > 0.0);
        let entry = &mut self.shares[adapter as usize];
        if let Some(e) = entry.iter_mut().find(|(s, _)| *s == server) {
            e.1 += phi;
        } else {
            entry.push((server, phi));
        }
    }

    /// Servers hosting the adapter.
    pub fn servers_of(&self, adapter: AdapterId) -> &[(ServerId, f64)] {
        &self.shares[adapter as usize]
    }

    /// Set of adapters assigned to `server`.
    pub fn adapters_on(&self, server: ServerId) -> Vec<AdapterId> {
        self.shares
            .iter()
            .enumerate()
            .filter(|(_, ss)| ss.iter().any(|(s, _)| *s == server))
            .map(|(a, _)| a as AdapterId)
            .collect()
    }

    /// Check the routing-table invariants. Returns an error string
    /// describing the first violation.
    pub fn validate(&self, n_servers: usize) -> Result<(), String> {
        for (a, ss) in self.shares.iter().enumerate() {
            if ss.is_empty() {
                return Err(format!("adapter {a} unassigned"));
            }
            let mut total = 0.0;
            for &(s, phi) in ss {
                if s >= n_servers {
                    return Err(format!("adapter {a}: bad server {s}"));
                }
                if phi <= 0.0 || phi > 1.0 + 1e-9 {
                    return Err(format!("adapter {a}: bad phi {phi}"));
                }
                total += phi;
            }
            if (total - 1.0).abs() > 1e-6 {
                return Err(format!("adapter {a}: Σφ = {total}"));
            }
        }
        Ok(())
    }

    /// Normalize shares so Σφ = 1 exactly (fixes rounding drift).
    pub fn normalize(&mut self) {
        for ss in self.shares.iter_mut() {
            let total: f64 = ss.iter().map(|(_, p)| p).sum();
            if total > 0.0 {
                for e in ss.iter_mut() {
                    e.1 /= total;
                }
            }
        }
    }

    /// Expected utilization per server given demands + operating points
    /// (util of adapter a on server s = φ · demand_a / oppoint[rank_a]).
    pub fn server_utils(
        &self,
        n_servers: usize,
        adapters: &AdapterSet,
        demand_tps: &BTreeMap<AdapterId, f64>,
        oppoints: &BTreeMap<u32, f64>,
    ) -> Vec<f64> {
        let mut utils = vec![0.0; n_servers];
        for (a, ss) in self.shares.iter().enumerate() {
            let adapter = adapters.get(a as AdapterId);
            let demand =
                demand_tps.get(&(a as AdapterId)).copied().unwrap_or(0.0);
            let op = oppoints.get(&adapter.rank).copied().unwrap_or(1.0);
            for &(s, phi) in ss {
                utils[s] += phi * demand / op;
            }
        }
        utils
    }

    /// Max adapter rank hosted per server (u32::MIN=0 if none).
    pub fn max_rank_per_server(
        &self,
        n_servers: usize,
        adapters: &AdapterSet,
    ) -> Vec<u32> {
        let mut max_rank = vec![0u32; n_servers];
        for (a, ss) in self.shares.iter().enumerate() {
            let rank = adapters.get(a as AdapterId).rank;
            for &(s, _) in ss {
                max_rank[s] = max_rank[s].max(rank);
            }
        }
        max_rank
    }

    /// Rank-heterogeneity score per server: number of distinct ranks
    /// hosted (1 = perfectly homogeneous). Used by the Fig 12 harness.
    pub fn heterogeneity(
        &self,
        n_servers: usize,
        adapters: &AdapterSet,
    ) -> Vec<usize> {
        let mut ranks: Vec<std::collections::BTreeSet<u32>> =
            vec![Default::default(); n_servers];
        for (a, ss) in self.shares.iter().enumerate() {
            let rank = adapters.get(a as AdapterId).rank;
            for &(s, _) in ss {
                ranks[s].insert(rank);
            }
        }
        ranks.into_iter().map(|r| r.len()).collect()
    }

    /// Relabel virtual server indices to physical ids: entry
    /// `(i, φ)` becomes `(map[i], φ)`. `map` must cover every virtual
    /// id used by `self` (panics otherwise). Used when a placer that
    /// thinks in dense `0..k` bins places onto an elastic fleet whose
    /// active server ids are sparse.
    pub fn remap_servers(&self, map: &[ServerId]) -> Assignment {
        let mut out = Assignment::new(self.shares.len());
        for (a, ss) in self.shares.iter().enumerate() {
            for &(s, phi) in ss {
                out.add(a as AdapterId, map[s], phi);
            }
        }
        out
    }

    /// Project onto a physical→virtual server mapping, dropping
    /// entries on servers outside the map (e.g. draining ones). The
    /// result can violate Σφ = 1 — it is only meant as the
    /// churn-matching `prev` of a re-placement onto the mapped subset.
    pub fn project_onto(
        &self,
        phys_to_virt: &BTreeMap<ServerId, usize>,
    ) -> Assignment {
        let mut out = Assignment::new(self.shares.len());
        for (a, ss) in self.shares.iter().enumerate() {
            for &(s, phi) in ss {
                if let Some(&v) = phys_to_virt.get(&s) {
                    out.add(a as AdapterId, v, phi);
                }
            }
        }
        out
    }

    /// Total bytes that must move to go from `prev` to `self`:
    /// adapters newly appearing on a server they weren't on.
    /// Membership in the previous copy set is a binary search over a
    /// sorted scratch vector (reused across adapters), not the old
    /// O(copies²) `Vec::contains` inner loop — at replication-heavy
    /// fan-outs the quadratic term dominated every rebalance.
    pub fn migration_bytes(&self, prev: &Assignment, adapters: &AdapterSet) -> u64 {
        let mut bytes = 0;
        let mut old: Vec<ServerId> = Vec::new();
        for (a, ss) in self.shares.iter().enumerate() {
            old.clear();
            if let Some(v) = prev.shares.get(a) {
                old.extend(v.iter().map(|(s, _)| *s));
            }
            old.sort_unstable();
            for &(s, _) in ss {
                if old.binary_search(&s).is_err() {
                    bytes += adapters.get(a as AdapterId).size_bytes;
                }
            }
        }
        bytes
    }
}

/// Inputs to a placement decision at one time step.
pub struct PlacementCtx<'a> {
    pub adapters: &'a AdapterSet,
    pub n_servers: usize,
    /// Projected tokens/sec demand per adapter (Algorithm 1 step 1).
    pub demand_tps: &'a BTreeMap<AdapterId, f64>,
    /// Profiled operating point (tokens/sec under SLO) per rank.
    pub operating_points: &'a BTreeMap<u32, f64>,
    /// Previous assignment, for churn minimization (step 5).
    pub prev: Option<&'a Assignment>,
}

pub trait Placer {
    fn name(&self) -> &'static str;
    fn place(&mut self, ctx: &PlacementCtx) -> Assignment;
}

/// Run `placer` against an arbitrary *active* subset of physical
/// servers — the elastic topology-change path. The placer sees a dense
/// virtual cluster `0..active.len()`, with `prev` projected into that
/// space for churn minimization (entries on servers outside the active
/// set — e.g. a draining victim — simply vanish from the overlap
/// matrix, so their adapters land wherever packing puts them). The
/// returned assignment is in physical server ids and satisfies the
/// routing-table invariants over the active set.
pub fn place_onto(
    placer: &mut dyn Placer,
    adapters: &AdapterSet,
    active: &[ServerId],
    demand_tps: &BTreeMap<AdapterId, f64>,
    operating_points: &BTreeMap<u32, f64>,
    prev: Option<&Assignment>,
) -> Assignment {
    assert!(!active.is_empty(), "placement needs at least one server");
    let phys_to_virt: BTreeMap<ServerId, usize> =
        active.iter().enumerate().map(|(v, &p)| (p, v)).collect();
    let prev_virt = prev.map(|p| p.project_onto(&phys_to_virt));
    let ctx = PlacementCtx {
        adapters,
        n_servers: active.len(),
        demand_tps,
        operating_points,
        prev: prev_virt.as_ref(),
    };
    placer.place(&ctx).remap_servers(active)
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::config::ModelSpec;
    use crate::util::rng::Pcg32;
    use crate::workload::RANK_CLASSES;

    /// Random but reproducible placement context for property tests.
    pub struct CtxData {
        pub adapters: AdapterSet,
        pub demand: BTreeMap<AdapterId, f64>,
        pub oppoints: BTreeMap<u32, f64>,
        pub n_servers: usize,
    }

    pub fn random_ctx(seed: u64, n_adapters: usize, n_servers: usize) -> CtxData {
        let mut rng = Pcg32::new(seed);
        let adapters = AdapterSet::power_law_counts(
            n_adapters,
            &RANK_CLASSES,
            1.0,
            &ModelSpec::LLAMA_7B,
        );
        let mut demand = BTreeMap::new();
        for a in adapters.iter() {
            // heavy-tailed demand incl. zero-demand adapters
            let d = if rng.f64() < 0.2 {
                0.0
            } else {
                rng.lognormal((200.0f64).ln(), 1.5)
            };
            demand.insert(a.id, d);
        }
        let oppoints = crate::costmodel::operating_points(
            &crate::config::ServerConfig::default(),
            &RANK_CLASSES,
        );
        CtxData {
            adapters,
            demand,
            oppoints,
            n_servers,
        }
    }

    impl CtxData {
        pub fn ctx(&self) -> PlacementCtx<'_> {
            PlacementCtx {
                adapters: &self.adapters,
                n_servers: self.n_servers,
                demand_tps: &self.demand,
                operating_points: &self.oppoints,
                prev: None,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;

    fn tiny_adapters() -> AdapterSet {
        AdapterSet::uniform_per_rank(4, &[8, 128], &ModelSpec::LLAMA_7B)
    }

    #[test]
    fn add_merges_duplicate_servers() {
        let mut a = Assignment::new(1);
        a.add(0, 2, 0.5);
        a.add(0, 2, 0.5);
        assert_eq!(a.servers_of(0), &[(2, 1.0)]);
    }

    #[test]
    fn validate_catches_violations() {
        let mut a = Assignment::new(2);
        a.add(0, 0, 1.0);
        assert!(a.validate(4).unwrap_err().contains("unassigned"));
        a.add(1, 0, 0.5);
        assert!(a.validate(4).unwrap_err().contains("Σφ"));
        a.add(1, 1, 0.5);
        assert!(a.validate(4).is_ok());
        assert!(a.validate(1).unwrap_err().contains("bad server"));
    }

    #[test]
    fn normalize_fixes_drift() {
        let mut a = Assignment::new(1);
        a.add(0, 0, 0.3);
        a.add(0, 1, 0.3);
        a.normalize();
        assert!(a.validate(2).is_ok());
    }

    #[test]
    fn utils_and_ranks() {
        let adapters = tiny_adapters(); // ids 0,1 rank 8; 2,3 rank 128
        let mut asg = Assignment::new(4);
        asg.add(0, 0, 1.0);
        asg.add(1, 0, 1.0);
        asg.add(2, 1, 0.5);
        asg.add(2, 0, 0.5);
        asg.add(3, 1, 1.0);
        let mut demand = BTreeMap::new();
        for id in 0..4 {
            demand.insert(id, 100.0);
        }
        let mut op = BTreeMap::new();
        op.insert(8u32, 100.0);
        op.insert(128u32, 50.0);
        let utils = asg.server_utils(2, &adapters, &demand, &op);
        // server0: 1 + 1 + 0.5*(100/50)=1 => 3; server1: 1 + 2 = 3
        assert!((utils[0] - 3.0).abs() < 1e-9);
        assert!((utils[1] - 3.0).abs() < 1e-9);
        assert_eq!(asg.max_rank_per_server(2, &adapters), vec![128, 128]);
        assert_eq!(asg.heterogeneity(2, &adapters), vec![2, 1]);
        assert_eq!(asg.adapters_on(0), vec![0, 1, 2]);
    }

    #[test]
    fn remap_and_project() {
        let mut a = Assignment::new(2);
        a.add(0, 0, 0.4);
        a.add(0, 1, 0.6);
        a.add(1, 2, 1.0);
        // virtual 0,1,2 -> physical 5,7,9
        let phys = a.remap_servers(&[5, 7, 9]);
        assert_eq!(phys.servers_of(0), &[(5, 0.4), (7, 0.6)]);
        assert_eq!(phys.servers_of(1), &[(9, 1.0)]);
        assert!(phys.validate(10).is_ok());
        // project back onto {5, 9} only: server 7's share drops
        let map: BTreeMap<ServerId, usize> =
            [(5, 0), (9, 1)].into_iter().collect();
        let virt = phys.project_onto(&map);
        assert_eq!(virt.servers_of(0), &[(0, 0.4)]);
        assert_eq!(virt.servers_of(1), &[(1, 1.0)]);
    }

    #[test]
    fn place_onto_sparse_active_set() {
        use crate::placement::loraserve::LoraServePlacer;
        let data = testutil::random_ctx(31, 40, 8);
        // elastic fleet: only physical servers 1, 4, 6 are active
        let active = [1usize, 4, 6];
        let mut placer = LoraServePlacer::new();
        let asg = place_onto(
            &mut placer,
            &data.adapters,
            &active,
            &data.demand,
            &data.oppoints,
            None,
        );
        asg.validate(8).unwrap();
        for ss in &asg.shares {
            for &(s, _) in ss {
                assert!(active.contains(&s), "placed on inactive {s}");
            }
        }
        // churn matching across a topology change stays valid
        let smaller = [1usize, 6];
        let asg2 = place_onto(
            &mut placer,
            &data.adapters,
            &smaller,
            &data.demand,
            &data.oppoints,
            Some(&asg),
        );
        asg2.validate(8).unwrap();
        for ss in &asg2.shares {
            for &(s, _) in ss {
                assert!(smaller.contains(&s), "placed on inactive {s}");
            }
        }
    }

    #[test]
    fn migration_bytes_counts_new_copies() {
        let adapters = tiny_adapters();
        let mut prev = Assignment::new(4);
        for id in 0..4 {
            prev.add(id, 0, 1.0);
        }
        let mut next = prev.clone();
        next.shares[3] = vec![(1, 1.0)]; // adapter 3 moves 0 -> 1
        let bytes = next.migration_bytes(&prev, &adapters);
        assert_eq!(bytes, adapters.get(3).size_bytes);
        assert_eq!(prev.migration_bytes(&prev, &adapters), 0);
    }
}
