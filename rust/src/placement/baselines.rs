//! Baseline placements (§V-D): S-LoRA Random and S-LoRA Contiguous.
//! (Toppings is a request-level router, not a placement — it lives in
//! `coordinator::router` and replicates every adapter on every server.)

use super::{Assignment, PlacementCtx, Placer};
use crate::util::rng::Pcg32;

/// *S-LoRA Random*: each adapter is statically assigned to one
/// uniformly random server — "resembles the placement used at
/// Company X".
#[derive(Debug, Clone)]
pub struct RandomPlacer {
    rng: Pcg32,
    /// Static *per topology*: place once per (server count, adapter
    /// count), then keep returning the same assignment — the elastic
    /// subsystem re-invokes placers when the fleet grows or shrinks.
    cached: Option<(usize, Assignment)>,
}

impl RandomPlacer {
    pub fn new(seed: u64) -> Self {
        RandomPlacer {
            rng: Pcg32::with_stream(seed, 0x5a0d),
            cached: None,
        }
    }
}

impl Placer for RandomPlacer {
    fn name(&self) -> &'static str {
        "slora-random"
    }

    fn place(&mut self, ctx: &PlacementCtx) -> Assignment {
        if let Some((n, a)) = &self.cached {
            if *n == ctx.n_servers && a.shares.len() == ctx.adapters.len() {
                return a.clone();
            }
        }
        let mut asg = Assignment::new(ctx.adapters.len());
        for a in ctx.adapters.iter() {
            let s = self.rng.below(ctx.n_servers as u64) as usize;
            asg.add(a.id, s, 1.0);
        }
        self.cached = Some((ctx.n_servers, asg.clone()));
        asg
    }
}

/// *S-LoRA Contiguous*: adapters ordered by rank, split into
/// equal-count contiguous chunks, one chunk per server — co-locates
/// similar ranks but ignores demand.
#[derive(Debug, Clone, Default)]
pub struct ContiguousPlacer {
    cached: Option<(usize, Assignment)>,
}

impl ContiguousPlacer {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Placer for ContiguousPlacer {
    fn name(&self) -> &'static str {
        "slora-contiguous"
    }

    fn place(&mut self, ctx: &PlacementCtx) -> Assignment {
        if let Some((n, a)) = &self.cached {
            if *n == ctx.n_servers && a.shares.len() == ctx.adapters.len() {
                return a.clone();
            }
        }
        let mut order: Vec<u32> =
            (0..ctx.adapters.len() as u32).collect();
        order.sort_by_key(|&a| (ctx.adapters.get(a).rank, a));
        let n = ctx.n_servers;
        let per = order.len().div_ceil(n);
        let mut asg = Assignment::new(ctx.adapters.len());
        for (i, &a) in order.iter().enumerate() {
            let s = (i / per.max(1)).min(n - 1);
            asg.add(a, s, 1.0);
        }
        self.cached = Some((ctx.n_servers, asg.clone()));
        asg
    }
}

/// Rank- and demand-blind round-robin: adapter `i` lives on server
/// `i mod n`. Deliberately simple — it is the demo registration target
/// for the custom-system registry (`sim::register_custom_system`, the
/// CLI's `--system round-robin`), showing that a new placer plugs into
/// the composition seam without touching the engine.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundRobinPlacer;

impl RoundRobinPlacer {
    pub fn new() -> Self {
        RoundRobinPlacer
    }
}

impl Placer for RoundRobinPlacer {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn place(&mut self, ctx: &PlacementCtx) -> Assignment {
        let mut asg = Assignment::new(ctx.adapters.len());
        for a in ctx.adapters.iter() {
            asg.add(a.id, a.id as usize % ctx.n_servers, 1.0);
        }
        asg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::testutil::random_ctx;

    #[test]
    fn random_is_valid_and_static() {
        let data = random_ctx(3, 50, 4);
        let mut p = RandomPlacer::new(9);
        let a1 = p.place(&data.ctx());
        a1.validate(4).unwrap();
        // single server per adapter
        for ss in &a1.shares {
            assert_eq!(ss.len(), 1);
        }
        // static across calls (no churn on rebalance)
        let a2 = p.place(&data.ctx());
        assert_eq!(a1, a2);
        // different seeds give different placements
        let a3 = RandomPlacer::new(10).place(&data.ctx());
        assert_ne!(a1, a3);
    }

    #[test]
    fn random_roughly_balanced_in_count() {
        let data = random_ctx(5, 400, 4);
        let a = RandomPlacer::new(1).place(&data.ctx());
        for s in 0..4 {
            let c = a.adapters_on(s).len();
            assert!((60..=140).contains(&c), "server {s}: {c}");
        }
    }

    #[test]
    fn cache_invalidated_on_topology_change() {
        // elastic path: the same placer re-places when the fleet size
        // changes, and the result fits the smaller virtual cluster
        let data = random_ctx(13, 30, 4);
        let mut p = RandomPlacer::new(3);
        let a4 = p.place(&data.ctx());
        a4.validate(4).unwrap();
        let mut ctx3 = data.ctx();
        ctx3.n_servers = 3;
        let a3 = p.place(&ctx3);
        a3.validate(3).unwrap();
        let mut c = ContiguousPlacer::new();
        c.place(&data.ctx()).validate(4).unwrap();
        c.place(&ctx3).validate(3).unwrap();
    }

    #[test]
    fn contiguous_homogeneous_chunks() {
        let data = random_ctx(7, 100, 5);
        let a = ContiguousPlacer::new().place(&data.ctx());
        a.validate(5).unwrap();
        // each server hosts a contiguous rank range: max rank of server
        // s <= min rank of server s+1
        let mut ranges = Vec::new();
        for s in 0..5 {
            let ranks: Vec<u32> = a
                .adapters_on(s)
                .iter()
                .map(|&ad| data.adapters.get(ad).rank)
                .collect();
            let min = *ranks.iter().min().unwrap();
            let max = *ranks.iter().max().unwrap();
            ranges.push((min, max));
        }
        for w in ranges.windows(2) {
            assert!(w[0].1 <= w[1].0, "{ranges:?}");
        }
        // heterogeneity lower than random
        let r = RandomPlacer::new(2).place(&data.ctx());
        let h = |x: &Assignment| {
            x.heterogeneity(5, &data.adapters).iter().sum::<usize>()
        };
        assert!(h(&a) <= h(&r));
    }

    #[test]
    fn round_robin_valid_and_spread() {
        let data = random_ctx(17, 41, 4);
        let mut p = RoundRobinPlacer::new();
        let a = p.place(&data.ctx());
        a.validate(4).unwrap();
        let counts: Vec<usize> =
            (0..4).map(|s| a.adapters_on(s).len()).collect();
        let max = counts.iter().max().unwrap();
        let min = counts.iter().min().unwrap();
        assert!(max - min <= 1, "{counts:?}");
        // shrinks with the topology like every placer
        let mut ctx2 = data.ctx();
        ctx2.n_servers = 2;
        p.place(&ctx2).validate(2).unwrap();
    }

    #[test]
    fn contiguous_counts_balanced() {
        let data = random_ctx(11, 103, 4);
        let a = ContiguousPlacer::new().place(&data.ctx());
        let counts: Vec<usize> =
            (0..4).map(|s| a.adapters_on(s).len()).collect();
        let max = counts.iter().max().unwrap();
        let min = counts.iter().min().unwrap();
        assert!(max - min <= 26, "{counts:?}");
    }
}
