//! Fractional bin packing (Algorithm 1 step 3).
//!
//! Packs items with sizes (utilization units) into bins of equal
//! capacity, first-fit-decreasing, *splitting* an item across bins when
//! it doesn't fit — the split fractions become the routing φ's. Items
//! larger than one bin's capacity spread over several bins.

/// One packed piece: (item index, bin index, fraction of the item).
pub type Piece = (usize, usize, f64);

/// Pack `sizes` into `n_bins` bins of `capacity`. Returns the pieces
/// and the indices of items that could not be (fully) packed because
/// the bins ran out. Zero-size items are packed whole onto the
/// currently-least-loaded bin (they consume no capacity but must live
/// somewhere).
pub fn fractional_pack(
    sizes: &[f64],
    n_bins: usize,
    capacity: f64,
) -> (Vec<Piece>, Vec<usize>) {
    assert!(capacity >= 0.0);
    let mut pieces = Vec::new();
    let mut leftovers = Vec::new();
    if n_bins == 0 {
        return (pieces, (0..sizes.len()).collect());
    }

    let mut order: Vec<usize> = (0..sizes.len()).collect();
    order.sort_by(|&a, &b| sizes[b].partial_cmp(&sizes[a]).unwrap());

    let mut load = vec![0.0f64; n_bins];
    let mut bin = 0usize;
    for &i in &order {
        let size = sizes[i];
        // sizes below the packing epsilon are parked like zero-demand
        // items (they would otherwise fall through both the packing
        // loop and the leftover check)
        if size <= 1e-12 {
            // zero-demand adapter: park on the least-loaded bin
            let target = (0..n_bins)
                .min_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap())
                .unwrap();
            pieces.push((i, target, 1.0));
            continue;
        }
        let mut remaining = size;
        while remaining > 1e-12 && bin < n_bins {
            let free = capacity - load[bin];
            if free <= 1e-12 {
                bin += 1;
                continue;
            }
            let take = remaining.min(free);
            load[bin] += take;
            pieces.push((i, bin, take / size));
            remaining -= take;
        }
        if remaining > 1e-9 * size.max(1.0) {
            // Ran out of bins: the caller re-routes whole leftover
            // items, so drop this item's partial pieces (keeping Σφ = 1
            // for everything packed) and give their load back to the
            // exact bins that held them.
            let mut removed: Vec<(usize, f64)> = Vec::new();
            pieces.retain(|&(item, b, f)| {
                if item == i {
                    removed.push((b, f));
                    false
                } else {
                    true
                }
            });
            for (b, f) in removed {
                load[b] -= f * size;
            }
            leftovers.push(i);
        }
    }
    (pieces, leftovers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use std::collections::BTreeMap;

    fn check_invariants(
        sizes: &[f64],
        n_bins: usize,
        capacity: f64,
        pieces: &[Piece],
        leftovers: &[usize],
    ) {
        // every non-leftover item's fractions sum to 1
        let mut frac: BTreeMap<usize, f64> = BTreeMap::new();
        let mut load = vec![0.0; n_bins];
        for &(i, b, f) in pieces {
            assert!(b < n_bins);
            assert!(f > 0.0 && f <= 1.0 + 1e-9);
            *frac.entry(i).or_insert(0.0) += f;
            load[b] += f * sizes[i];
        }
        for (i, &size) in sizes.iter().enumerate() {
            let total = frac.get(&i).copied().unwrap_or(0.0);
            if leftovers.contains(&i) {
                assert_eq!(total, 0.0, "leftover {i} has pieces");
            } else {
                assert!(
                    (total - 1.0).abs() < 1e-6,
                    "item {i} (size {size}) frac {total}"
                );
            }
        }
        for (b, &l) in load.iter().enumerate() {
            assert!(l <= capacity * (1.0 + 1e-6), "bin {b} load {l}");
        }
    }

    #[test]
    fn simple_split() {
        // capacity 1.0: item of 1.5 splits 1.0 + 0.5
        let sizes = [1.5, 0.4];
        let (pieces, leftovers) = fractional_pack(&sizes, 2, 1.0);
        assert!(leftovers.is_empty());
        check_invariants(&sizes, 2, 1.0, &pieces, &leftovers);
        // item 0 spans both bins
        let bins0: Vec<usize> = pieces
            .iter()
            .filter(|p| p.0 == 0)
            .map(|p| p.1)
            .collect();
        assert_eq!(bins0.len(), 2);
    }

    #[test]
    fn overflow_becomes_leftover() {
        let sizes = [1.0, 1.0, 1.0];
        let (pieces, leftovers) = fractional_pack(&sizes, 2, 1.0);
        assert_eq!(leftovers.len(), 1);
        check_invariants(&sizes, 2, 1.0, &pieces, &leftovers);
    }

    #[test]
    fn zero_bins_all_leftover() {
        let (pieces, leftovers) = fractional_pack(&[0.5, 0.5], 0, 1.0);
        assert!(pieces.is_empty());
        assert_eq!(leftovers, vec![0, 1]);
    }

    #[test]
    fn subepsilon_items_parked_whole() {
        let sizes = [1e-14, 0.5];
        let (pieces, leftovers) = fractional_pack(&sizes, 1, 1.0);
        assert!(leftovers.is_empty());
        let item0: Vec<_> =
            pieces.iter().filter(|p| p.0 == 0).collect();
        assert_eq!(item0.len(), 1);
        assert_eq!(item0[0].2, 1.0);
    }

    #[test]
    fn zero_size_items_parked() {
        let sizes = [0.0, 0.9, 0.0];
        let (pieces, leftovers) = fractional_pack(&sizes, 2, 1.0);
        assert!(leftovers.is_empty());
        check_invariants(&sizes, 2, 1.0, &pieces, &leftovers);
        // zero items placed whole
        for &(i, _, f) in &pieces {
            if sizes[i] == 0.0 {
                assert_eq!(f, 1.0);
            }
        }
    }

    #[test]
    fn property_random_instances() {
        let mut rng = Pcg32::new(123);
        for case in 0..300 {
            let n_items = 1 + rng.below(20) as usize;
            let n_bins = rng.below(6) as usize;
            let capacity = rng.range_f64(0.5, 3.0);
            let sizes: Vec<f64> = (0..n_items)
                .map(|_| {
                    if rng.f64() < 0.15 {
                        0.0
                    } else {
                        rng.range_f64(0.01, 2.5)
                    }
                })
                .collect();
            let (pieces, leftovers) =
                fractional_pack(&sizes, n_bins, capacity);
            check_invariants(&sizes, n_bins, capacity, &pieces, &leftovers);
            // if total size fits comfortably, nothing is leftover
            let total: f64 = sizes.iter().sum();
            if n_bins > 0 && total <= capacity * n_bins as f64 * 0.999 {
                assert!(
                    leftovers.is_empty(),
                    "case {case}: total={total} cap={capacity}x{n_bins} leftovers={leftovers:?}"
                );
            }
        }
    }
}
