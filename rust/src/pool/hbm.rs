//! Paged HBM economy: one per-server memory pool for adapter slices
//! *and* KV cache (S-LoRA's unified paging, PAPERS.md).
//!
//! [`HbmPool`] replaces the old byte-budget `GpuAdapterCache` and runs
//! in one of two regimes, picked by `ServerConfig::hbm_pages`:
//!
//! * **Unbounded** (`hbm_pages == 0`, the default): KV is not modeled
//!   and adapter paging uses the legacy free-form byte budget
//!   (`gpu_adapter_cache_bytes`) with LRU eviction — arithmetic
//!   bit-identical to the pre-refactor cache, so every default-config
//!   digest is unchanged.
//! * **Bounded** (`hbm_pages > 0`): a single page-granular budget of
//!   `hbm_pages × HBM_PAGE_BYTES` from which both adapter copies and
//!   the active set's KV footprint are carved. The server refreshes
//!   the KV page count each iteration (`set_kv_tokens`), admission
//!   reads a *dynamic* token budget off the free pages
//!   (`admissible_tokens`), and adapter page-ins evict under the
//!   configured [`EvictPolicy`]. Evicted adapter ids are parked in a
//!   takeout list the engine drains at epoch barriers (eviction →
//!   pool-miss → re-fetch, priced through `fetch_stall`).
//!
//! Both regimes price a page-in miss identically:
//! `100 µs + bytes / pcie_bw`.

use crate::util::json::Json;
use crate::workload::AdapterId;
use std::collections::{BTreeMap, BTreeSet};

/// Which resident adapter a bounded [`HbmPool`] evicts under page
/// pressure. All policies skip pinned adapters (the current batch and
/// every active sequence's adapter) — an in-use adapter is never
/// evicted.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EvictPolicy {
    /// Least-recently-used (the legacy byte-budget order).
    #[default]
    Lru,
    /// Evict the largest-and-coldest first: maximize `age × bytes`,
    /// so one eviction of a stale high-rank adapter frees many pages
    /// instead of churning through several hot low-rank ones.
    RankWeighted,
    /// LRU that protects adapters with queued demand: evicting an
    /// adapter a queued request is about to need lands a page-in (or a
    /// re-fetch) squarely on that request's TTFT path. Falls back to
    /// plain LRU when everything unpinned is protected.
    SloAware,
}

impl EvictPolicy {
    pub fn parse(s: &str) -> Option<EvictPolicy> {
        match s {
            "lru" => Some(EvictPolicy::Lru),
            "rank-weighted" => Some(EvictPolicy::RankWeighted),
            "slo-aware" => Some(EvictPolicy::SloAware),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            EvictPolicy::Lru => "lru",
            EvictPolicy::RankWeighted => "rank-weighted",
            EvictPolicy::SloAware => "slo-aware",
        }
    }
}

/// End-of-run memory-economy counters, aggregated over the fleet by
/// the engine. Present in `SimReport` (and appended to the JSON
/// digest) only for bounded runs — an unbounded run's digest is
/// byte-identical to the pre-refactor one.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HbmStats {
    /// Per-server page budget the run was bounded to.
    pub total_pages: u64,
    /// Eviction policy label the servers ran.
    pub policy: String,
    /// Adapter evictions under page pressure, fleet-wide.
    pub evictions: u64,
    pub evicted_bytes: u64,
    /// Max pages in use (adapter + KV) on any server at any point.
    pub peak_pages: u64,
    /// Max KV-only pages on any server at any point.
    pub peak_kv_pages: u64,
}

impl HbmStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_pages", Json::from(self.total_pages)),
            ("policy", Json::from(self.policy.as_str())),
            ("evictions", Json::from(self.evictions)),
            ("evicted_bytes", Json::from(self.evicted_bytes)),
            ("peak_pages", Json::from(self.peak_pages)),
            ("peak_kv_pages", Json::from(self.peak_kv_pages)),
        ])
    }
}

/// Per-server paged HBM pool (see the module docs for the two
/// regimes). Owned by `SimServer`; mutated only from that server's
/// event lane, which is what keeps the sharded determinism contract —
/// the engine reads occupancy and drains the eviction takeout list
/// only at epoch barriers, in lane-index order.
#[derive(Debug, Default)]
pub struct HbmPool {
    /// Legacy byte budget (unbounded regime only).
    budget: u64,
    used: u64,
    /// adapter -> (bytes, last-use tick)
    entries: BTreeMap<AdapterId, (u64, u64)>,
    tick: u64,
    pub loads: u64,
    pub load_bytes: u64,
    /// Page budget; 0 = unbounded (legacy byte-budget regime).
    total_pages: u64,
    page_bytes: u64,
    policy: EvictPolicy,
    /// KV bytes one token of the served model occupies across layers.
    kv_bytes_per_token: f64,
    /// Pages the adapter entries occupy (bounded regime only).
    adapter_pages: u64,
    /// Pages the active set's KV footprint occupies, refreshed by the
    /// server each iteration from prompt + produced token counts.
    kv_pages: u64,
    /// Adapters with queued demand (`EvictPolicy::SloAware` only),
    /// refreshed by the server before each admission.
    protected: BTreeSet<AdapterId>,
    /// Adapters evicted since the engine last drained the list.
    evicted_out: Vec<AdapterId>,
    pub evictions: u64,
    pub evicted_bytes: u64,
    pub peak_pages: u64,
    pub peak_kv_pages: u64,
}

impl HbmPool {
    /// Legacy-compatible pool: `total_pages == 0` reproduces the old
    /// `GpuAdapterCache::new(budget)` bit for bit.
    pub fn new(
        budget: u64,
        total_pages: u64,
        page_bytes: u64,
        policy: EvictPolicy,
        kv_bytes_per_token: f64,
    ) -> Self {
        assert!(page_bytes > 0, "page size must be positive");
        HbmPool {
            budget,
            total_pages,
            page_bytes,
            policy,
            kv_bytes_per_token,
            ..Default::default()
        }
    }

    /// Unbounded legacy pool (tests and default-config servers).
    pub fn unbounded(budget: u64) -> Self {
        HbmPool::new(
            budget,
            0,
            crate::costmodel::calib::HBM_PAGE_BYTES,
            EvictPolicy::Lru,
            1.0,
        )
    }

    /// Page-granular budget active?
    pub fn bounded(&self) -> bool {
        self.total_pages > 0
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    fn pages_for(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.page_bytes)
    }

    /// Pages in use (adapters + KV). 0 in the unbounded regime.
    pub fn pages_used(&self) -> u64 {
        self.adapter_pages + self.kv_pages
    }

    /// Free pages under the budget (saturating: an overcommitted pool
    /// — everything pinned — reads 0, not a wrap).
    pub fn free_pages(&self) -> u64 {
        self.total_pages.saturating_sub(self.pages_used())
    }

    /// Occupancy in [0, 1] (0 when unbounded) — the memory-pressure
    /// signal `RebalanceTrigger` reads. Overcommit clamps to 1.
    pub fn occupancy(&self) -> f64 {
        if self.total_pages == 0 {
            return 0.0;
        }
        (self.pages_used() as f64 / self.total_pages as f64).min(1.0)
    }

    /// Refresh the KV footprint from the active set's token count.
    /// No-op when unbounded (KV is not modeled there).
    pub fn set_kv_tokens(&mut self, tokens: u64) {
        if !self.bounded() {
            return;
        }
        let bytes = (tokens as f64 * self.kv_bytes_per_token) as u64;
        self.kv_pages = self.pages_for(bytes);
        self.peak_kv_pages = self.peak_kv_pages.max(self.kv_pages);
        self.peak_pages = self.peak_pages.max(self.pages_used());
    }

    /// Dynamic admission budget: how many prefill tokens the free
    /// pages can hold, capped at the configured `max_batch_tokens`.
    /// Unbounded pools pass the configured budget through untouched.
    /// Admission policies exempt the queue head from the token budget,
    /// so a zero here still admits one request (no deadlock).
    pub fn admissible_tokens(&self, configured: u64) -> u64 {
        if !self.bounded() {
            return configured;
        }
        let per_page =
            (self.page_bytes as f64 / self.kv_bytes_per_token.max(1.0))
                .floor()
                .max(1.0) as u64;
        configured.min(self.free_pages() * per_page)
    }

    /// SloAware only: replace the protected set with the adapters of
    /// currently queued requests.
    pub fn set_protected<I: IntoIterator<Item = AdapterId>>(
        &mut self,
        ids: I,
    ) {
        self.protected.clear();
        self.protected.extend(ids);
    }

    /// Does this pool's policy consult the protected set? (Lets the
    /// server skip the per-iteration queue scan otherwise.)
    pub fn wants_protected(&self) -> bool {
        self.bounded() && self.policy == EvictPolicy::SloAware
    }

    /// Anything in the eviction takeout list? (Cheap barrier check.)
    pub fn has_evicted(&self) -> bool {
        !self.evicted_out.is_empty()
    }

    /// Drain the adapters evicted since the last call (engine-side,
    /// at epoch barriers): the engine drops their pool copies so the
    /// next routed request re-fetches over RDMA.
    pub fn take_evicted(&mut self) -> Vec<AdapterId> {
        std::mem::take(&mut self.evicted_out)
    }

    /// Ensure `adapter` is resident; returns the PCIe paging time
    /// (0 on hit). `pinned` adapters are never evicted.
    pub fn touch(
        &mut self,
        adapter: AdapterId,
        bytes: u64,
        pcie_bw: f64,
        pinned: &BTreeSet<AdapterId>,
    ) -> f64 {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&adapter) {
            e.1 = self.tick;
            return 0.0;
        }
        if self.bounded() {
            self.evict_for(bytes, pinned);
        } else {
            // legacy byte-budget LRU, bit for bit
            while self.used + bytes > self.budget
                && !self.entries.is_empty()
            {
                let victim = self
                    .entries
                    .iter()
                    .filter(|(a, _)| !pinned.contains(a))
                    .min_by_key(|(_, (_, t))| *t)
                    .map(|(a, _)| *a);
                match victim {
                    Some(a) => {
                        let (b, _) = self.entries.remove(&a).unwrap();
                        self.used -= b;
                    }
                    None => break, // everything pinned; overcommit
                }
            }
        }
        self.entries.insert(adapter, (bytes, self.tick));
        self.used += bytes;
        if self.bounded() {
            self.adapter_pages += self.pages_for(bytes);
            self.peak_pages = self.peak_pages.max(self.pages_used());
        }
        self.loads += 1;
        self.load_bytes += bytes;
        100e-6 + bytes as f64 / pcie_bw
    }

    /// Bounded-regime eviction loop: free pages for an incoming
    /// `bytes`-sized adapter under the configured policy. Stops when
    /// it fits or only pinned entries remain (overcommit, like the
    /// legacy cache).
    fn evict_for(&mut self, bytes: u64, pinned: &BTreeSet<AdapterId>) {
        let need = self.pages_for(bytes);
        while self.pages_used() + need > self.total_pages
            && !self.entries.is_empty()
        {
            let Some(victim) = self.pick_victim(pinned) else {
                break;
            };
            let (b, _) = self.entries.remove(&victim).unwrap();
            self.used -= b;
            self.adapter_pages -= self.pages_for(b);
            self.evictions += 1;
            self.evicted_bytes += b;
            self.evicted_out.push(victim);
        }
    }

    /// Policy-directed victim selection over unpinned entries; ties
    /// break toward the lowest adapter id (BTreeMap iteration order),
    /// keeping eviction order fully deterministic.
    fn pick_victim(
        &self,
        pinned: &BTreeSet<AdapterId>,
    ) -> Option<AdapterId> {
        let unpinned = self
            .entries
            .iter()
            .filter(|(a, _)| !pinned.contains(a));
        match self.policy {
            EvictPolicy::Lru => unpinned
                .min_by_key(|(_, (_, t))| *t)
                .map(|(a, _)| *a),
            EvictPolicy::RankWeighted => {
                // maximize age × bytes; strict '>' keeps the first
                // (lowest-id) of a tied pair
                let mut best: Option<(AdapterId, u64)> = None;
                for (&a, &(b, t)) in unpinned {
                    let score = (self.tick - t) * b;
                    if best.map_or(true, |(_, s)| score > s) {
                        best = Some((a, score));
                    }
                }
                best.map(|(a, _)| a)
            }
            EvictPolicy::SloAware => {
                let mut cold: Option<(AdapterId, u64)> = None;
                let mut any: Option<(AdapterId, u64)> = None;
                for (&a, &(_, t)) in unpinned {
                    if any.map_or(true, |(_, bt)| t < bt) {
                        any = Some((a, t));
                    }
                    if !self.protected.contains(&a)
                        && cold.map_or(true, |(_, bt)| t < bt)
                    {
                        cold = Some((a, t));
                    }
                }
                cold.or(any).map(|(a, _)| a)
            }
        }
    }

    pub fn resident(&self, adapter: AdapterId) -> bool {
        self.entries.contains_key(&adapter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAGE: u64 = 2 * 1024 * 1024;
    const PCIE: f64 = 16e9;

    fn bounded(pages: u64, policy: EvictPolicy) -> HbmPool {
        // kv_bytes_per_token = half a page per 1024 tokens keeps the
        // arithmetic easy to reason about in tests
        HbmPool::new(u64::MAX, pages, PAGE, policy, 1024.0)
    }

    #[test]
    fn parse_and_label_roundtrip() {
        for p in [
            EvictPolicy::Lru,
            EvictPolicy::RankWeighted,
            EvictPolicy::SloAware,
        ] {
            assert_eq!(EvictPolicy::parse(p.label()), Some(p));
        }
        assert_eq!(EvictPolicy::parse("nope"), None);
        assert_eq!(EvictPolicy::default(), EvictPolicy::Lru);
    }

    #[test]
    fn unbounded_matches_legacy_lru_semantics() {
        // budget of 2 adapters; third insert evicts the LRU one
        let mut p = HbmPool::unbounded(2 * (17 << 20));
        let pinned = BTreeSet::new();
        let t0 = p.touch(0, 17 << 20, PCIE, &pinned);
        assert!(t0 > 100e-6);
        assert_eq!(p.touch(0, 17 << 20, PCIE, &pinned), 0.0, "hit");
        p.touch(1, 17 << 20, PCIE, &pinned);
        p.touch(2, 17 << 20, PCIE, &pinned); // evicts 0 (LRU)
        assert!(!p.resident(0) && p.resident(1) && p.resident(2));
        assert_eq!(p.loads, 3);
        assert_eq!(p.load_bytes, 3 * (17 << 20));
        // unbounded: no pages, no pressure, no takeout list
        assert!(!p.bounded());
        assert_eq!(p.occupancy(), 0.0);
        assert_eq!(p.admissible_tokens(2048), 2048);
        assert!(!p.has_evicted());
        assert_eq!(p.evictions, 0);
    }

    #[test]
    fn unbounded_pinned_overcommits_like_legacy() {
        let mut p = HbmPool::unbounded(17 << 20);
        let pinned: BTreeSet<AdapterId> = [0].into_iter().collect();
        p.touch(0, 17 << 20, PCIE, &pinned);
        p.touch(1, 17 << 20, PCIE, &pinned); // 0 pinned → overcommit
        assert!(p.resident(0) && p.resident(1));
    }

    #[test]
    fn bounded_pages_conserve_and_evict() {
        let mut p = bounded(16, EvictPolicy::Lru);
        let pinned = BTreeSet::new();
        // 8 pages each: two fit, the third evicts the LRU
        p.touch(0, 8 * PAGE, PCIE, &pinned);
        p.touch(1, 8 * PAGE, PCIE, &pinned);
        assert_eq!(p.pages_used(), 16);
        assert_eq!(p.free_pages(), 0);
        p.touch(2, 8 * PAGE, PCIE, &pinned);
        assert!(!p.resident(0), "LRU victim");
        assert_eq!(p.pages_used(), 16, "page conservation");
        assert_eq!(p.evictions, 1);
        assert_eq!(p.evicted_bytes, 8 * PAGE);
        assert_eq!(p.take_evicted(), vec![0]);
        assert!(!p.has_evicted(), "takeout list drains");
        assert_eq!(p.peak_pages, 16);
    }

    #[test]
    fn bounded_never_evicts_pinned() {
        let mut p = bounded(16, EvictPolicy::Lru);
        let pinned: BTreeSet<AdapterId> = [0, 1].into_iter().collect();
        p.touch(0, 8 * PAGE, PCIE, &pinned);
        p.touch(1, 8 * PAGE, PCIE, &pinned);
        p.touch(2, 8 * PAGE, PCIE, &pinned); // everything pinned
        assert!(p.resident(0) && p.resident(1) && p.resident(2));
        assert_eq!(p.pages_used(), 24, "overcommitted");
        assert_eq!(p.occupancy(), 1.0, "clamped");
        assert_eq!(p.free_pages(), 0);
        assert_eq!(p.evictions, 0);
    }

    #[test]
    fn kv_pressure_shrinks_admission_and_evicts_adapters() {
        let mut p = bounded(16, EvictPolicy::Lru);
        let pinned = BTreeSet::new();
        p.touch(0, 4 * PAGE, PCIE, &pinned);
        // 1024 bytes/token → 2048 tokens/page; 8 pages of KV
        p.set_kv_tokens(8 * 2048);
        assert_eq!(p.pages_used(), 12);
        // 4 free pages × 2048 tokens, capped by the configured budget
        assert_eq!(p.admissible_tokens(u64::MAX), 4 * 2048);
        assert_eq!(p.admissible_tokens(1000), 1000);
        // a long-context burst: KV wants 14 pages → adapter 0 must go
        p.set_kv_tokens(14 * 2048);
        p.touch(1, 4 * PAGE, PCIE, &pinned);
        assert!(!p.resident(0), "KV pressure evicted the adapter");
        assert_eq!(p.take_evicted(), vec![0]);
        assert_eq!(p.peak_kv_pages, 14);
        // KV shrinks back as requests complete
        p.set_kv_tokens(0);
        assert_eq!(p.pages_used(), 4);
    }

    #[test]
    fn rank_weighted_evicts_large_cold_first() {
        let mut p = bounded(20, EvictPolicy::RankWeighted);
        let pinned = BTreeSet::new();
        p.touch(0, 8 * PAGE, PCIE, &pinned); // big, cold
        p.touch(1, PAGE, PCIE, &pinned); // small, colder-adjacent
        p.touch(2, 8 * PAGE, PCIE, &pinned); // big, warm
        p.touch(1, PAGE, PCIE, &pinned); // re-touch: 1 is hot now
        // needs 4 pages; LRU would evict 0 then (tie) — rank-weighted
        // also picks 0 (biggest age × bytes), freeing 8 pages at once
        p.touch(3, 4 * PAGE, PCIE, &pinned);
        assert!(!p.resident(0));
        assert!(p.resident(1), "small hot adapter survives");
        assert!(p.resident(2) && p.resident(3));
        // now force another squeeze: 2 is older than 1 AND bigger
        p.touch(4, 8 * PAGE, PCIE, &pinned);
        assert!(!p.resident(2), "large cold beats small hot");
        assert!(p.resident(1));
    }

    #[test]
    fn slo_aware_protects_queued_demand() {
        let mut p = bounded(16, EvictPolicy::SloAware);
        let pinned = BTreeSet::new();
        p.touch(0, 8 * PAGE, PCIE, &pinned); // LRU victim normally
        p.touch(1, 8 * PAGE, PCIE, &pinned);
        p.set_protected([0]); // 0 has queued demand
        p.touch(2, 8 * PAGE, PCIE, &pinned);
        assert!(p.resident(0), "protected adapter survives");
        assert!(!p.resident(1), "unprotected one goes instead");
        // all unpinned protected → falls back to LRU over them
        p.set_protected([0, 2]);
        p.touch(3, 8 * PAGE, PCIE, &pinned);
        assert!(!p.resident(0), "fallback evicts the coldest");
    }

    #[test]
    fn stats_json_shape() {
        let s = HbmStats {
            total_pages: 512,
            policy: "lru".into(),
            evictions: 3,
            evicted_bytes: 99,
            peak_pages: 500,
            peak_kv_pages: 300,
        };
        let j = s.to_json().to_string();
        for key in [
            "\"total_pages\":512",
            "\"policy\":\"lru\"",
            "\"evictions\":3",
            "\"peak_kv_pages\":300",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }
}
