//! Distributed adapter pool (§IV-B, Fig 13).
//!
//! Each server keeps only its assigned adapters in local host memory;
//! the union across servers is the universal adapter set. On a routing
//! miss the adapter is fetched from a peer over GPUDirect-RDMA and
//! becomes resident; when a rebalance removes an adapter from a
//! server's assignment it is deleted locally — but never while it is
//! the last copy in the cluster (the coverage invariant).

use crate::costmodel::{
    fetch_time, inter_region_fetch_time, FetchSource,
};
use crate::config::GpuSpec;
use crate::workload::{AdapterId, AdapterSet, ServerId};
use std::collections::BTreeSet;

pub mod hbm;

pub use hbm::{EvictPolicy, HbmPool, HbmStats};

#[derive(Debug, Clone)]
pub struct AdapterPool {
    n_servers: usize,
    /// resident[s] = adapters in server s's host memory.
    resident: Vec<BTreeSet<AdapterId>>,
    /// in-flight fetches per server.
    fetching: Vec<BTreeSet<AdapterId>>,
    /// desired state from the latest placement.
    assigned: Vec<BTreeSet<AdapterId>>,
    /// high-water mark of resident+fetching per server (Fig 18 bottom).
    max_resident: Vec<usize>,
    /// Region-aware RDMA pricing (scenario pack): `(n_regions,
    /// inter_bw_factor, inter_latency)`; server `s` lives in region
    /// `s % n_regions`. `None` = flat intra-region pricing (default).
    regions: Option<(usize, f64, f64)>,
    /// When true, a fetch of an adapter with no replica anywhere falls
    /// back to the host/registry tier (`LocalHostMem` pricing) instead
    /// of panicking — the crash path legitimately loses last copies.
    host_fallback: bool,
    /// Fetches that had to come from the host/registry tier (a crash
    /// destroyed the last GPU-side copy).
    pub host_fetches: u64,
    pub total_fetches: u64,
    pub total_fetch_bytes: u64,
}

impl AdapterPool {
    /// `initial` assigns each adapter's starting replicas (typically
    /// from the first placement); those are resident immediately (the
    /// paper's deployment loads the initial subset at startup).
    pub fn new(n_servers: usize, initial: &[Vec<ServerId>]) -> Self {
        let mut resident = vec![BTreeSet::new(); n_servers];
        for (a, servers) in initial.iter().enumerate() {
            assert!(!servers.is_empty(), "adapter {a} has no home");
            for &s in servers {
                resident[s].insert(a as AdapterId);
            }
        }
        let max_resident = resident.iter().map(|r| r.len()).collect();
        AdapterPool {
            n_servers,
            assigned: resident.clone(),
            resident,
            fetching: vec![BTreeSet::new(); n_servers],
            max_resident,
            regions: None,
            host_fallback: false,
            host_fetches: 0,
            total_fetches: 0,
            total_fetch_bytes: 0,
        }
    }

    /// Enable region-aware RDMA pricing: server `s` is in region
    /// `s % n_regions`, and cross-region transfers pay the derated
    /// inter-region path. `n_regions <= 1` keeps flat pricing.
    pub fn set_regions(
        &mut self,
        n_regions: usize,
        inter_bw_factor: f64,
        inter_latency: f64,
    ) {
        self.regions = (n_regions > 1)
            .then_some((n_regions, inter_bw_factor, inter_latency));
    }

    /// Allow fetches of replica-less adapters to fall back to the
    /// host/registry tier instead of panicking (crash scenarios only).
    pub fn set_host_fallback(&mut self, on: bool) {
        self.host_fallback = on;
    }

    /// Transfer time of `bytes` into `server` from `source` (`None` =
    /// the host/registry tier): intra-region RDMA, derated
    /// inter-region RDMA, or a host-memory page-in.
    fn transfer_time(
        &self,
        gpu: &GpuSpec,
        source: Option<ServerId>,
        server: ServerId,
        bytes: u64,
    ) -> f64 {
        match source {
            None => fetch_time(gpu, FetchSource::LocalHostMem, bytes),
            Some(src) => match self.regions {
                Some((n, bw, lat)) if src % n != server % n => {
                    inter_region_fetch_time(gpu, bytes, bw, lat)
                }
                _ => fetch_time(gpu, FetchSource::RemoteRdma, bytes),
            },
        }
    }

    /// Replicate everything everywhere (the Toppings baseline).
    pub fn fully_replicated(n_servers: usize, n_adapters: usize) -> Self {
        let initial: Vec<Vec<ServerId>> = (0..n_adapters)
            .map(|_| (0..n_servers).collect())
            .collect();
        AdapterPool::new(n_servers, &initial)
    }

    pub fn is_resident(&self, server: ServerId, adapter: AdapterId) -> bool {
        self.resident[server].contains(&adapter)
    }

    pub fn is_fetching(&self, server: ServerId, adapter: AdapterId) -> bool {
        self.fetching[server].contains(&adapter)
    }

    pub fn resident_count(&self, server: ServerId) -> usize {
        self.resident[server].len()
    }

    pub fn max_resident(&self, server: ServerId) -> usize {
        self.max_resident[server]
    }

    /// Begin fetching `adapter` into `server`. Returns the transfer
    /// time (the caller schedules the completion event), or None if it
    /// is already resident/in flight. Panics if no replica exists
    /// anywhere (coverage invariant broken upstream) — unless
    /// `set_host_fallback` armed the host/registry tier, in which case
    /// the fetch is priced as a host-memory page-in.
    pub fn start_fetch(
        &mut self,
        server: ServerId,
        adapter: AdapterId,
        adapters: &AdapterSet,
        gpu: &GpuSpec,
    ) -> Option<f64> {
        if self.is_resident(server, adapter) || self.is_fetching(server, adapter)
        {
            return None;
        }
        let source = self.find_replica(adapter);
        if source.is_none() {
            if !self.host_fallback {
                panic!("adapter {adapter}: no replica left in cluster");
            }
            self.host_fetches += 1;
        }
        debug_assert_ne!(source, Some(server));
        let bytes = adapters.get(adapter).size_bytes;
        self.fetching[server].insert(adapter);
        self.bump_watermark(server);
        self.total_fetches += 1;
        self.total_fetch_bytes += bytes;
        Some(self.transfer_time(gpu, source, server, bytes))
    }

    /// Begin fetching a *group* of adapters into `server` as one
    /// RDMA stream — the drain protocol's batched last-copy migration.
    /// Already-resident / already-in-flight adapters are skipped.
    /// Returns the single transfer time for the group's total bytes
    /// (one per-transfer latency, amortized) plus the adapters
    /// actually started, or None if nothing needed to move. The caller
    /// schedules ONE completion event and then calls `finish_fetch`
    /// for each started adapter.
    pub fn start_fetch_batch(
        &mut self,
        server: ServerId,
        ids: &[AdapterId],
        adapters: &AdapterSet,
        gpu: &GpuSpec,
    ) -> Option<(f64, Vec<AdapterId>)> {
        // One amortized stream per path class: intra-region RDMA,
        // derated inter-region RDMA, and host page-ins each pay their
        // own latency floor over their share of the bytes.
        let mut class_bytes = [0u64; 3]; // [intra, inter, host]
        let mut started = Vec::new();
        for &a in ids {
            if self.is_resident(server, a) || self.is_fetching(server, a)
            {
                continue;
            }
            // same release-mode invariant as the serial start_fetch:
            // never fabricate a copy of an adapter nobody holds
            let class = match self.find_replica(a) {
                Some(src) => match self.regions {
                    Some((n, _, _)) if src % n != server % n => 1,
                    _ => 0,
                },
                None => {
                    if !self.host_fallback {
                        panic!(
                            "adapter {a}: no replica left in cluster"
                        );
                    }
                    self.host_fetches += 1;
                    2
                }
            };
            self.fetching[server].insert(a);
            class_bytes[class] += adapters.get(a).size_bytes;
            started.push(a);
            self.total_fetches += 1;
        }
        if started.is_empty() {
            return None;
        }
        self.bump_watermark(server);
        self.total_fetch_bytes += class_bytes.iter().sum::<u64>();
        let mut t = 0.0;
        if class_bytes[0] > 0 {
            t += fetch_time(gpu, FetchSource::RemoteRdma, class_bytes[0]);
        }
        if class_bytes[1] > 0 {
            let (_, bw, lat) = self.regions.unwrap();
            t += inter_region_fetch_time(gpu, class_bytes[1], bw, lat);
        }
        if class_bytes[2] > 0 {
            t += fetch_time(
                gpu,
                FetchSource::LocalHostMem,
                class_bytes[2],
            );
        }
        Some((t, started))
    }

    /// Complete an in-flight fetch: the adapter becomes resident and,
    /// per Fig 13, source copies that are no longer assigned anywhere
    /// can now be garbage collected.
    pub fn finish_fetch(&mut self, server: ServerId, adapter: AdapterId) {
        let was = self.finish_fetch_checked(server, adapter);
        debug_assert!(was, "finish_fetch without start_fetch");
    }

    /// `finish_fetch` that tolerates a vanished in-flight mark: a
    /// server crash wipes its `fetching` set, so a completion event
    /// that was already scheduled lands on nothing. Returns whether
    /// the copy actually materialized.
    pub fn finish_fetch_checked(
        &mut self,
        server: ServerId,
        adapter: AdapterId,
    ) -> bool {
        if !self.fetching[server].remove(&adapter) {
            return false;
        }
        self.resident[server].insert(adapter);
        self.bump_watermark(server);
        // The freshly fetched copy is in active use (a request routed
        // here), so it survives GC even if a rebalance has since moved
        // the assignment; stale *source* copies are collected now.
        self.gc_adapter_keeping(adapter, Some(server));
        true
    }

    /// Hardware failure: every copy on `server` — resident and in
    /// flight — dies with it, and it stops being a desired home until
    /// the next placement. Returns the adapters this leaves with no
    /// copy anywhere (no resident replica, no in-flight fetch), in
    /// ascending id order; the engine must re-fetch those from the
    /// host/registry tier or the universal set shrinks.
    pub fn crash_server(&mut self, server: ServerId) -> Vec<AdapterId> {
        let gone: BTreeSet<AdapterId> = self.resident[server]
            .iter()
            .chain(self.fetching[server].iter())
            .copied()
            .collect();
        self.resident[server].clear();
        self.fetching[server].clear();
        self.assigned[server].clear();
        gone.into_iter()
            .filter(|&a| {
                self.find_replica(a).is_none()
                    && !(0..self.n_servers)
                        .any(|s| self.fetching[s].contains(&a))
            })
            .collect()
    }

    /// Apply a new placement: update desired sets and GC copies that
    /// are neither assigned nor the last replica. New assignments are
    /// *not* prefetched — the paper fetches on first access.
    pub fn apply_assignment(&mut self, assigned: &[Vec<ServerId>]) {
        for set in self.assigned.iter_mut() {
            set.clear();
        }
        for (a, servers) in assigned.iter().enumerate() {
            for &s in servers {
                self.assigned[s].insert(a as AdapterId);
            }
        }
        for a in 0..assigned.len() {
            self.gc_adapter(a as AdapterId);
        }
    }

    /// Drop unassigned copies of `adapter`, keeping at least one copy
    /// cluster-wide (prefer keeping an assigned one; else keep the
    /// lowest-id holder until a fetch lands elsewhere).
    fn gc_adapter(&mut self, adapter: AdapterId) {
        self.gc_adapter_keeping(adapter, None);
    }

    fn gc_adapter_keeping(
        &mut self,
        adapter: AdapterId,
        extra_keep: Option<ServerId>,
    ) {
        let holders: Vec<ServerId> = (0..self.n_servers)
            .filter(|&s| self.resident[s].contains(&adapter))
            .collect();
        if holders.is_empty() {
            return; // still only in flight; nothing to GC
        }
        let assigned_holders: Vec<ServerId> = holders
            .iter()
            .copied()
            .filter(|&s| self.assigned[s].contains(&adapter))
            .collect();
        let mut keep: BTreeSet<ServerId> = if assigned_holders.is_empty()
            && extra_keep.is_none()
        {
            // keep one survivor until the new home fetches it
            std::iter::once(holders[0]).collect()
        } else {
            assigned_holders.iter().copied().collect()
        };
        if let Some(s) = extra_keep {
            keep.insert(s);
        }
        for s in holders {
            if !keep.contains(&s) {
                self.resident[s].remove(&adapter);
            }
        }
    }

    /// Any server currently holding a resident copy.
    pub fn find_replica(&self, adapter: AdapterId) -> Option<ServerId> {
        (0..self.n_servers).find(|&s| self.resident[s].contains(&adapter))
    }

    pub fn n_servers(&self) -> usize {
        self.n_servers
    }

    pub fn fetching_count(&self, server: ServerId) -> usize {
        self.fetching[server].len()
    }

    /// Grow the pool by one empty server slot; returns its id. The new
    /// server receives adapters lazily (fetch on first access) or via
    /// `replicate_all_to`.
    pub fn add_server(&mut self) -> ServerId {
        self.resident.push(BTreeSet::new());
        self.fetching.push(BTreeSet::new());
        self.assigned.push(BTreeSet::new());
        self.max_resident.push(0);
        self.n_servers += 1;
        self.n_servers - 1
    }

    /// Adapters whose *only* resident copy lives on `server` — the set
    /// the drain-and-migrate protocol must RDMA-copy elsewhere before
    /// the server can leave the fleet.
    pub fn evacuations(&self, server: ServerId) -> Vec<AdapterId> {
        self.resident[server]
            .iter()
            .copied()
            .filter(|&a| {
                (0..self.n_servers)
                    .all(|s| s == server || !self.resident[s].contains(&a))
            })
            .collect()
    }

    /// Drop `server`'s copy of `adapter`, but only if at least one
    /// other resident replica exists. Returns true when the server no
    /// longer holds a copy (dropped or never had one); false means the
    /// copy is the cluster's last and must be migrated instead.
    pub fn drop_copy(&mut self, server: ServerId, adapter: AdapterId) -> bool {
        if !self.resident[server].contains(&adapter) {
            return true;
        }
        let covered = (0..self.n_servers)
            .any(|s| s != server && self.resident[s].contains(&adapter));
        if covered {
            self.resident[server].remove(&adapter);
        }
        covered
    }

    /// Make every adapter resident (and assigned) on `server` — the
    /// full-replication (Toppings) path when a new server joins the
    /// fleet. Returns the bytes copied over the fabric.
    pub fn replicate_all_to(
        &mut self,
        server: ServerId,
        adapters: &AdapterSet,
    ) -> u64 {
        let mut bytes = 0;
        for a in adapters.iter() {
            if self.resident[server].insert(a.id) {
                bytes += a.size_bytes;
            }
            self.assigned[server].insert(a.id);
        }
        self.bump_watermark(server);
        bytes
    }

    /// Coverage invariant: every adapter id < n has ≥ 1 copy, resident
    /// or in flight. On the normal paths an in-flight copy still has
    /// its source resident (GC keeps survivors until `finish_fetch`);
    /// after a crash an adapter's only copy can be the in-flight host
    /// re-fetch itself, which is why the in-flight check is part of
    /// the invariant.
    pub fn check_coverage(&self, n_adapters: usize) -> Result<(), String> {
        for a in 0..n_adapters as AdapterId {
            let covered = self.find_replica(a).is_some()
                || (0..self.n_servers)
                    .any(|s| self.fetching[s].contains(&a));
            if !covered {
                return Err(format!("adapter {a} lost (no replica)"));
            }
        }
        Ok(())
    }

    fn bump_watermark(&mut self, server: ServerId) {
        let now =
            self.resident[server].len() + self.fetching[server].len();
        if now > self.max_resident[server] {
            self.max_resident[server] = now;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};
    use crate::workload::AdapterSet;

    fn setup() -> (AdapterPool, AdapterSet) {
        let adapters = AdapterSet::uniform_per_rank(
            4,
            &[8, 128],
            &ModelSpec::LLAMA_7B,
        );
        // adapters 0,1 on server 0; 2,3 on server 1
        let initial = vec![vec![0], vec![0], vec![1], vec![1]];
        (AdapterPool::new(3, &initial), adapters)
    }

    #[test]
    fn fetch_lifecycle() {
        let (mut pool, adapters) = setup();
        let g = GpuSpec::A100_40G;
        assert!(!pool.is_resident(2, 0));
        let t = pool.start_fetch(2, 0, &adapters, &g).unwrap();
        assert!(t > 0.0);
        // duplicate fetch coalesces
        assert!(pool.start_fetch(2, 0, &adapters, &g).is_none());
        assert!(pool.is_fetching(2, 0));
        pool.finish_fetch(2, 0);
        assert!(pool.is_resident(2, 0));
        assert_eq!(pool.total_fetches, 1);
        pool.check_coverage(4).unwrap();
    }

    #[test]
    fn resident_fetch_is_noop() {
        let (mut pool, adapters) = setup();
        let g = GpuSpec::A100_40G;
        assert!(pool.start_fetch(0, 0, &adapters, &g).is_none());
    }

    #[test]
    fn reassignment_moves_and_gcs() {
        let (mut pool, adapters) = setup();
        let g = GpuSpec::A100_40G;
        // move adapter 0 from server 0 to server 2
        pool.apply_assignment(&[
            vec![2],
            vec![0],
            vec![1],
            vec![1],
        ]);
        // not yet copied: server 0 must keep the survivor copy
        assert!(pool.is_resident(0, 0));
        pool.check_coverage(4).unwrap();
        // first access on server 2 triggers the fetch; after it lands,
        // the old unassigned copy is GC'd
        pool.start_fetch(2, 0, &adapters, &g).unwrap();
        pool.finish_fetch(2, 0);
        assert!(pool.is_resident(2, 0));
        assert!(!pool.is_resident(0, 0), "old copy must be deleted");
        pool.check_coverage(4).unwrap();
    }

    #[test]
    fn replicated_assignment_keeps_all_copies() {
        let (mut pool, adapters) = setup();
        let g = GpuSpec::A100_40G;
        pool.apply_assignment(&[
            vec![0, 2],
            vec![0],
            vec![1],
            vec![1],
        ]);
        pool.start_fetch(2, 0, &adapters, &g).unwrap();
        pool.finish_fetch(2, 0);
        assert!(pool.is_resident(0, 0) && pool.is_resident(2, 0));
    }

    #[test]
    fn watermark_tracks_high_water() {
        let (mut pool, adapters) = setup();
        let g = GpuSpec::A100_40G;
        assert_eq!(pool.max_resident(2), 0);
        pool.start_fetch(2, 0, &adapters, &g).unwrap();
        pool.start_fetch(2, 2, &adapters, &g).unwrap();
        pool.finish_fetch(2, 0);
        pool.finish_fetch(2, 2);
        assert_eq!(pool.max_resident(2), 2);
        // deleting later never lowers the watermark
        pool.apply_assignment(&[
            vec![0],
            vec![0],
            vec![1],
            vec![1],
        ]);
        assert!(pool.max_resident(2) >= 2);
    }

    #[test]
    fn fully_replicated_counts() {
        let pool = AdapterPool::fully_replicated(4, 10);
        for s in 0..4 {
            assert_eq!(pool.resident_count(s), 10);
        }
        pool.check_coverage(10).unwrap();
    }

    #[test]
    fn add_server_and_replicate() {
        let (mut pool, adapters) = setup();
        let s = pool.add_server();
        assert_eq!(s, 3);
        assert_eq!(pool.n_servers(), 4);
        assert_eq!(pool.resident_count(s), 0);
        let bytes = pool.replicate_all_to(s, &adapters);
        assert_eq!(bytes, adapters.total_bytes());
        assert_eq!(pool.resident_count(s), 4);
        // already resident: copying again moves no bytes
        assert_eq!(pool.replicate_all_to(s, &adapters), 0);
        pool.check_coverage(4).unwrap();
    }

    #[test]
    fn drop_copy_refuses_last_replica() {
        let (mut pool, adapters) = setup();
        let g = GpuSpec::A100_40G;
        // adapter 0 only on server 0 — dropping it must be refused
        assert!(!pool.drop_copy(0, 0));
        assert!(pool.is_resident(0, 0));
        assert_eq!(pool.evacuations(0), vec![0, 1]);
        // replicate to server 2, then the drop succeeds
        pool.start_fetch(2, 0, &adapters, &g).unwrap();
        pool.finish_fetch(2, 0);
        assert!(pool.drop_copy(0, 0));
        assert!(!pool.is_resident(0, 0));
        assert_eq!(pool.evacuations(0), vec![1]);
        // dropping a copy the server never had is a no-op success
        assert!(pool.drop_copy(1, 0));
        pool.check_coverage(4).unwrap();
    }

    #[test]
    fn batched_fetch_amortizes_latency_and_coalesces() {
        let (mut pool, adapters) = setup();
        let g = GpuSpec::A100_40G;
        // serial: two separate transfers pay two latency floors
        let t0 = pool.start_fetch(2, 0, &adapters, &g).unwrap();
        let t1 = pool.start_fetch(2, 1, &adapters, &g).unwrap();
        pool.finish_fetch(2, 0);
        pool.finish_fetch(2, 1);
        // batched from a fresh pool: one transfer over the total bytes
        let (mut pool2, _) = setup();
        let (tb, started) = pool2
            .start_fetch_batch(2, &[0, 1], &adapters, &g)
            .unwrap();
        assert_eq!(started, vec![0, 1]);
        assert!(
            tb < t0 + t1,
            "batched {tb} should beat serial {}",
            t0 + t1
        );
        assert!(tb > t0.max(t1), "still moves all the bytes");
        assert!(pool2.is_fetching(2, 0) && pool2.is_fetching(2, 1));
        for &a in &started {
            pool2.finish_fetch(2, a);
        }
        assert!(pool2.is_resident(2, 0) && pool2.is_resident(2, 1));
        assert_eq!(pool2.total_fetches, 2);
        assert_eq!(
            pool2.total_fetch_bytes,
            adapters.get(0).size_bytes + adapters.get(1).size_bytes
        );
        pool2.check_coverage(4).unwrap();
        // already-resident / in-flight members are skipped; an
        // all-skipped batch is a no-op
        let (_, started) = pool2
            .start_fetch_batch(2, &[0, 1, 2], &adapters, &g)
            .unwrap();
        assert_eq!(started, vec![2]);
        assert!(pool2
            .start_fetch_batch(2, &[0, 1, 2], &adapters, &g)
            .is_none());
        pool2.finish_fetch(2, 2);
        pool2.check_coverage(4).unwrap();
    }

    #[test]
    fn crash_drops_copies_and_reports_lost_last_copies() {
        let (mut pool, adapters) = setup();
        let g = GpuSpec::A100_40G;
        // replicate adapter 1 onto server 1 so it survives the crash
        pool.start_fetch(1, 1, &adapters, &g).unwrap();
        pool.finish_fetch(1, 1);
        // adapter 0's only copy is on server 0 → lost by the crash
        let lost = pool.crash_server(0);
        assert_eq!(lost, vec![0]);
        assert_eq!(pool.resident_count(0), 0);
        assert!(pool.check_coverage(4).is_err(), "0 is really gone");
        // host-tier re-fetch restores coverage (priced as a page-in,
        // cheaper latency floor than RDMA for equal bytes)
        pool.set_host_fallback(true);
        let t_host = pool.start_fetch(2, 0, &adapters, &g).unwrap();
        assert_eq!(pool.host_fetches, 1);
        pool.check_coverage(4).unwrap(); // in-flight copy counts
        pool.finish_fetch(2, 0);
        pool.check_coverage(4).unwrap();
        let t_rdma = pool.start_fetch(1, 0, &adapters, &g).unwrap();
        assert!(t_host < t_rdma, "host {t_host} vs rdma {t_rdma}");
        pool.finish_fetch(1, 0);
    }

    #[test]
    fn crash_wipes_inflight_and_checked_finish_tolerates_it() {
        let (mut pool, adapters) = setup();
        let g = GpuSpec::A100_40G;
        pool.start_fetch(2, 0, &adapters, &g).unwrap();
        assert!(pool.is_fetching(2, 0));
        pool.crash_server(2);
        assert!(!pool.is_fetching(2, 0));
        // the scheduled completion lands on nothing
        assert!(!pool.finish_fetch_checked(2, 0));
        assert!(!pool.is_resident(2, 0));
        // the source copy on server 0 survived
        pool.check_coverage(4).unwrap();
    }

    #[test]
    fn inter_region_fetches_priced_above_intra() {
        // servers 0,2 in region 0; servers 1,3 in region 1
        let initial = vec![vec![0], vec![0], vec![1], vec![1]];
        let adapters = AdapterSet::uniform_per_rank(
            4,
            &[8, 128],
            &ModelSpec::LLAMA_7B,
        );
        let g = GpuSpec::A100_40G;
        let mut flat = AdapterPool::new(4, &initial);
        let mut regional = AdapterPool::new(4, &initial);
        regional.set_regions(2, 0.25, 750e-6);
        // adapter 0 lives on server 0 (region 0): fetch to server 2
        // stays intra-region, fetch to server 3 crosses
        let t_flat_intra = flat.start_fetch(2, 0, &adapters, &g).unwrap();
        let t_flat_cross = flat.start_fetch(3, 0, &adapters, &g).unwrap();
        let t_reg_intra =
            regional.start_fetch(2, 0, &adapters, &g).unwrap();
        let t_reg_cross =
            regional.start_fetch(3, 0, &adapters, &g).unwrap();
        assert_eq!(t_flat_intra, t_flat_cross, "flat pricing");
        assert_eq!(t_reg_intra, t_flat_intra, "intra unchanged");
        assert!(
            t_reg_cross > 2.0 * t_reg_intra,
            "cross-region must cost well above intra: {t_reg_cross} \
             vs {t_reg_intra}"
        );
        // batched: a cross-region group is dearer than the same group
        // intra-region
        let (tb_cross, _) = regional
            .start_fetch_batch(3, &[1], &adapters, &g)
            .unwrap();
        let (tb_intra, _) = regional
            .start_fetch_batch(2, &[1], &adapters, &g)
            .unwrap();
        assert!(tb_cross > tb_intra);
    }

    #[test]
    fn property_random_churn_never_loses_coverage() {
        use crate::util::rng::Pcg32;
        let adapters = AdapterSet::uniform_per_rank(
            12,
            &[8, 16, 32, 64, 128],
            &ModelSpec::LLAMA_7B,
        );
        let g = GpuSpec::A100_40G;
        let mut rng = Pcg32::new(42);
        let n_servers = 4;
        let initial: Vec<Vec<ServerId>> = (0..12)
            .map(|_| vec![rng.below(n_servers as u64) as usize])
            .collect();
        let mut pool = AdapterPool::new(n_servers, &initial);
        let mut in_flight: Vec<(ServerId, AdapterId)> = Vec::new();
        for _step in 0..500 {
            match rng.below(3) {
                0 => {
                    // random reassignment
                    let asg: Vec<Vec<ServerId>> = (0..12)
                        .map(|_| {
                            let k = 1 + rng.below(2) as usize;
                            let mut v: Vec<usize> = (0..n_servers).collect();
                            rng.shuffle(&mut v);
                            v.truncate(k);
                            v
                        })
                        .collect();
                    pool.apply_assignment(&asg);
                }
                1 => {
                    let s = rng.below(n_servers as u64) as usize;
                    let a = rng.below(12) as AdapterId;
                    if pool.start_fetch(s, a, &adapters, &g).is_some() {
                        in_flight.push((s, a));
                    }
                }
                _ => {
                    if !in_flight.is_empty() {
                        let i = rng.below(in_flight.len() as u64) as usize;
                        let (s, a) = in_flight.swap_remove(i);
                        pool.finish_fetch(s, a);
                    }
                }
            }
            pool.check_coverage(12).unwrap_or_else(|e| {
                panic!("step {_step}: {e}")
            });
        }
    }
}
