//! Analytical service-time model for paper-scale hardware.
//!
//! The paper's evaluation ran on A100 clusters we don't have; the DES
//! simulator (`sim/`) drives this model instead. Constants in
//! [`calib`] are fitted to the *measured ratios* the paper reports
//! (Fig 3: rank-128 ≈ 2.7× rank-8 prefill at input 2000 on Llama-7B;
//! Fig 4: ≈45% heterogeneity penalty on 70B TP8; Fig 5: ≈20% at TP8 on
//! 7B), so the shape of every reproduced figure — who wins, where the
//! crossovers fall — is inherited from the paper's own measurements,
//! not from our CPU testbed. See DESIGN.md §7.

pub mod calib;
pub mod fetch;
pub mod latency;
pub mod oppoint;

pub use fetch::{fetch_time, inter_region_fetch_time, FetchSource};
pub use latency::{decode_lora_time, decode_time, prefill_time, CostModel};
pub use oppoint::operating_points;
