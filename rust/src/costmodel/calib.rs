//! Calibration constants for the analytical cost model.
//!
//! Derivation (DESIGN.md §7): with
//!   base(N,M,t)  = 2·N·P(M) / (t · F_peak · EFF_PREFILL)
//!   beta(M,t)    = BETA0 + BETA_TP·(t−1) + BETA_LAYER·(L(M)−32)
//!   lora(N,r,M,t)= KAPPA · N·L(M)·d(M)·r / (t · F_peak)
//! the three paper-reported ratios pin the constants:
//!
//!  (1) Fig 3  — TTFT(r128)/TTFT(r8) = 2.7 at N=2000, 7B, TP1:
//!      16x + B1 = 2.7(B1 + x)      with x = lora(r8), B1 = base+beta
//!  (2) Fig 5  — ratio = 1.2 at N=2000, 7B, TP8
//!  (3) Fig 4  — ratio = 1.45 at N=2000, 70B, TP8
//!
//! Solving with A100 peak 312 TFLOP/s and EFF_PREFILL = 0.55 gives
//! BETA0 ≈ 0.05 s, BETA_TP ≈ 0.025 s/GPU, BETA_LAYER ≈ 2.2 ms/layer,
//! KAPPA ≈ 3.94e3 (the effective inefficiency of pad-to-max-rank
//! BGMV/MBGMV on skinny LoRA GEMMs, consistent with the paper's
//! observation that kernel latency tracks the maximum rank rather than
//! useful FLOPs). The tests in `latency.rs` assert all three target
//! ratios within tolerance.

/// MXU/tensor-core efficiency achieved by dense prefill GEMMs.
pub const EFF_PREFILL: f64 = 0.55;

/// HBM bandwidth efficiency achieved by decode (weight streaming).
pub const EFF_BW: f64 = 0.6;

/// Fixed per-prefill-batch overhead (scheduling, tokenization, kernel
/// launches), seconds.
pub const BETA0: f64 = 0.05;

/// TP communication/sync overhead per extra GPU, seconds *per
/// BETA_REF_TOKENS prefill tokens* (activation allreduces scale with
/// token count).
pub const BETA_TP: f64 = 0.025;

/// Per-layer overhead beyond 32 layers, seconds per BETA_REF_TOKENS
/// tokens (deeper stacks run more kernels per token).
pub const BETA_LAYER: f64 = 0.0022;

/// Token count at which BETA_TP/BETA_LAYER are quoted (the paper's
/// Fig 3-5 measurement point).
pub const BETA_REF_TOKENS: f64 = 2000.0;

/// Effective inefficiency multiplier of the multi-adapter LoRA kernel
/// on *prefill* GEMMs: time = KAPPA · (ideal MXU time of the padded
/// LoRA GEMMs).
pub const KAPPA: f64 = 3.94e3;

/// Decode-side multiplier: the BGMV/MBGMV GEMV path is launch- and
/// gather-bound per token, far less efficient than the prefill GEMM
/// path (Punica reports decode kernel latency tracking max rank).
/// Calibrated so Fig 6's crossover lands where the paper measured it:
/// at 4 RPS of 512/128 on one Llama-7B TP4 server, ranks 64/128 blow a
/// 20 s P95 TTFT SLO while ranks 8-32 meet it.
pub const KAPPA_DECODE: f64 = 13.0 * KAPPA;

/// Fixed per-decode-step overhead, seconds.
pub const GAMMA0: f64 = 0.002;

/// Per-sequence decode cost per step (scheduler bookkeeping, sampler,
/// python-era serving-stack overhead the paper's testbed carried),
/// seconds. Most decode overhead is per-sequence rather than per-step
/// so that consolidation does not get an artificial amortization bonus
/// (calibration point: GAMMA0 + 24*GAMMA_PER_SEQ = 16.4 ms, the same
/// per-step overhead as the Fig 6 fit at the saturated batch size).
pub const GAMMA_PER_SEQ: f64 = 0.0006;

/// Per-sub-batch kernel-launch overhead of grouped (SGMV-style)
/// decode, seconds: splitting one decode round into per-rank-class
/// sub-batch steps launches one kernel sequence per class, and each
/// extra launch costs scheduler + dispatch time. Punica/S-LoRA report
/// sub-millisecond grouped-GEMV launch cost at decode batch sizes;
/// 0.8 ms sits between the bare launch latency and the full per-step
/// GAMMA0 so grouping is a real tradeoff rather than free. Default of
/// `ServerConfig::decode_launch_overhead` (JSON
/// `decode_launch_overhead_ms`); a unified single-group decode pays
/// nothing.
pub const DECODE_LAUNCH_OVERHEAD: f64 = 0.0008;

/// Per-iteration penalty of touching one remotely-attached adapter
/// (`RebalanceConfig::remote_attach`), seconds. Derived from the
/// `FetchSource::RemoteRdma` link model (fetch.rs): each iteration
/// issues one pipelined round of low-rank slice reads against the
/// peer's HBM, so it pays the 250 µs two-hop GPUDirect latency floor
/// (LAT_RDMA) plus ~60% dispatch/pipelining slack — the slices
/// themselves stream concurrently with the layer compute, so the
/// latency floor, not the bytes, dominates. Default of
/// `ServerConfig::remote_attach_penalty` (JSON
/// `remote_attach_penalty_ms`); locally resident adapters pay nothing.
pub const REMOTE_ATTACH_PENALTY: f64 = 0.0004;

/// Utilization headroom when converting a capacity into an
/// operating point under SLO (Algorithm 1's profiled "operating
/// points"): serving at full capacity has unbounded queueing delay, so
/// the operating point is this fraction of saturation throughput.
pub const OPPOINT_HEADROOM: f64 = 0.85;

/// Request shape used when profiling operating points a priori
/// (the paper profiles with a representative fixed shape; Fig 6 uses
/// 512/128).
pub const PROFILE_PROMPT: u32 = 512;
pub const PROFILE_OUTPUT: u32 = 128;

/// Page size of the unified HBM pool (`pool::hbm::HbmPool`): the
/// S-LoRA unified-paging granularity at which adapter slices and KV
/// blocks are carved from one per-server budget. 2 MiB matches the
/// huge-page-aligned pool S-LoRA-generation stacks allocate (one page
/// holds 4 KV tokens of Llama-7B at 512 KiB/token, or one 32-length
/// rank-8 adapter chunk). `ServerConfig::hbm_pages` counts these.
pub const HBM_PAGE_BYTES: u64 = 2 * 1024 * 1024;
