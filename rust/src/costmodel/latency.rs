//! Prefill/decode service-time formulas (see `calib` for constants and
//! their derivation from the paper's measured ratios).

use super::calib::*;
use crate::config::ServerConfig;

/// Cost model bound to one server configuration.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub server: ServerConfig,
}

impl CostModel {
    pub fn new(server: ServerConfig) -> Self {
        CostModel { server }
    }

    /// Service time of one prefill iteration over `n_tokens` co-batched
    /// prompt tokens whose largest adapter rank is `max_rank`
    /// (0 = no LoRA in batch).
    pub fn prefill(&self, n_tokens: u64, max_rank: u32) -> f64 {
        prefill_time(&self.server, n_tokens, max_rank)
    }

    /// Service time of one decode step over `batch` sequences with
    /// `cached_tokens` total KV residency and max adapter rank
    /// `max_rank`.
    pub fn decode(&self, batch: usize, cached_tokens: u64, max_rank: u32) -> f64 {
        decode_time(&self.server, batch, cached_tokens, max_rank)
    }

    /// Shared forward-pass base of one *grouped* (SGMV-style) decode
    /// round: weight streaming, KV reads, and per-step/per-sequence
    /// overheads over the whole round's membership — billed once per
    /// round regardless of how many rank-class sub-batches the LoRA
    /// kernels are split into. Equals a unified decode step with no
    /// LoRA work (`max_rank = 0`).
    pub fn decode_base(&self, batch: usize, cached_tokens: u64) -> f64 {
        decode_time(&self.server, batch, cached_tokens, 0)
    }

    /// Per-class cost of one decode sub-batch: the grouped LoRA kernel
    /// for `batch` sequences at `rank` (each class pays only its own
    /// rank's padded-GEMV work), plus — when the round has more than
    /// one sub-batch (`extra_launch`) — the per-sub-batch kernel
    /// launch overhead. The shared forward-pass base is *not* included
    /// (see [`CostModel::decode_base`]).
    pub fn decode_class(
        &self,
        batch: usize,
        rank: u32,
        extra_launch: bool,
    ) -> f64 {
        decode_lora_time(&self.server, batch, rank)
            + if extra_launch {
                self.server.decode_launch_overhead
            } else {
                0.0
            }
    }

    /// Net service-time gain of splitting a `members`-sequence
    /// rank-`rank` class out of a grouped decode round that would
    /// otherwise pad it to `padded_to`: the padded LoRA kernel work
    /// recovered minus the extra per-sub-batch launch overhead.
    /// Positive ⇒ the split pays for itself — the
    /// launch-overhead/padding break-even behind the adaptive
    /// `class-subbatch:auto` decode composition.
    pub fn decode_split_gain(
        &self,
        members: usize,
        rank: u32,
        padded_to: u32,
    ) -> f64 {
        decode_lora_time(&self.server, members, padded_to)
            - decode_lora_time(&self.server, members, rank)
            - self.server.decode_launch_overhead
    }

    /// Per-iteration penalty of touching one remotely-attached adapter
    /// (served from a peer server's HBM over GPUDirect RDMA instead of
    /// being migrated — `RebalanceConfig::remote_attach`), seconds.
    /// The `FetchSource::RemoteRdma`-derived default lives in
    /// `calib::REMOTE_ATTACH_PENALTY`; the JSON knob
    /// `remote_attach_penalty_ms` overrides it.
    pub fn remote_attach_penalty(&self) -> f64 {
        self.server.remote_attach_penalty
    }

    /// [`CostModel::remote_attach_penalty`] in milliseconds (the unit
    /// the config knob is quoted in).
    pub fn remote_attach_penalty_ms(&self) -> f64 {
        self.server.remote_attach_penalty * 1e3
    }

    /// Saturation throughput (tokens/s) for a single-rank workload of
    /// the given request shape: the steady-state rate at which the
    /// server can complete requests, counting prompt+output tokens.
    pub fn saturation_tps(
        &self,
        rank: u32,
        prompt: u32,
        output: u32,
        decode_batch: usize,
    ) -> f64 {
        // Per-request busy time: its share of a full prefill batch plus
        // its share of `output` decode steps at the typical decode
        // batch size.
        let bt = self.server.max_batch_tokens as u64;
        let per_batch = (bt / prompt.max(1) as u64).max(1);
        let prefill_share =
            self.prefill(per_batch * prompt as u64, rank) / per_batch as f64;
        let cached = decode_batch as u64 * (prompt as u64 + output as u64 / 2);
        let step = self.decode(decode_batch, cached, rank);
        let decode_share = step / decode_batch as f64 * output as f64;
        let req_time = prefill_share + decode_share;
        (prompt as u64 + output as u64) as f64 / req_time
    }
}

/// Per-prefill-batch overhead for this model/TP (seconds): a fixed
/// scheduler term plus token-proportional TP-sync and depth terms
/// (quoted per BETA_REF_TOKENS tokens — see calib.rs derivation).
pub fn beta(server: &ServerConfig, n_tokens: u64) -> f64 {
    let scale = n_tokens as f64 / BETA_REF_TOKENS;
    BETA0
        + scale
            * (BETA_TP * (server.tp as f64 - 1.0)
                + BETA_LAYER
                    * (server.model.n_layers as f64 - 32.0).max(0.0))
}

/// Ideal (100%-efficient) time of the padded LoRA GEMMs for `n_tokens`
/// at rank `r`: 4 projections × (shrink+expand) ≈ 16·N·d·r FLOPs/layer.
fn lora_ideal(server: &ServerConfig, n_tokens: u64, r: u32) -> f64 {
    if r == 0 {
        return 0.0;
    }
    let m = &server.model;
    // The kernel's tiles are sized by the max rank present; KAPPA folds
    // the resulting padding + skinny-GEMM inefficiency into one factor.
    n_tokens as f64 * m.n_layers as f64 * m.d_model as f64 * r as f64
        / (server.tp as f64 * server.gpu.peak_flops)
}

pub fn prefill_time(server: &ServerConfig, n_tokens: u64, max_rank: u32) -> f64 {
    let m = &server.model;
    let base = 2.0 * n_tokens as f64 * m.params
        / (server.tp as f64 * server.gpu.peak_flops * EFF_PREFILL);
    base + beta(server, n_tokens)
        + KAPPA * lora_ideal(server, n_tokens, max_rank)
}

pub fn decode_time(
    server: &ServerConfig,
    batch: usize,
    cached_tokens: u64,
    max_rank: u32,
) -> f64 {
    if batch == 0 {
        return 0.0;
    }
    let m = &server.model;
    let g = &server.gpu;
    let weights = m.weight_bytes()
        / (server.tp as f64 * g.hbm_bw * EFF_BW);
    let kv = cached_tokens as f64 * m.kv_bytes_per_token()
        / (server.tp as f64 * g.hbm_bw * EFF_BW);
    let lora = KAPPA_DECODE * lora_ideal(server, batch as u64, max_rank);
    weights + kv + lora + GAMMA0 + GAMMA_PER_SEQ * batch as f64
}

/// Decode-side LoRA kernel time for one rank-class sub-batch: the
/// padded-GEMV work of `batch` sequences at `rank`, excluding the
/// shared forward-pass base (weights/KV/overheads, which a grouped
/// round pays once — `CostModel::decode_base`).
pub fn decode_lora_time(server: &ServerConfig, batch: usize, rank: u32) -> f64 {
    KAPPA_DECODE * lora_ideal(server, batch as u64, rank)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec, ServerConfig};

    fn server(model: ModelSpec, tp: usize) -> ServerConfig {
        ServerConfig {
            model,
            gpu: GpuSpec::A100_40G,
            tp,
            ..Default::default()
        }
    }

    fn ttft_ratio(model: ModelSpec, tp: usize, n: u64, r_hi: u32, r_lo: u32) -> f64 {
        let s = server(model, tp);
        prefill_time(&s, n, r_hi) / prefill_time(&s, n, r_lo)
    }

    /// Fig 3: rank-128 isolated prefill ≈ 2.7× rank-8 at input 2000, 7B.
    #[test]
    fn calibration_fig3_ratio() {
        let r = ttft_ratio(ModelSpec::LLAMA_7B, 1, 2000, 128, 8);
        assert!((r - 2.7).abs() < 0.15, "ratio={r}");
    }

    /// Fig 5: ratio shrinks to ≈1.2 at TP8 on 7B.
    #[test]
    fn calibration_fig5_ratio() {
        let r = ttft_ratio(ModelSpec::LLAMA_7B, 8, 2000, 128, 8);
        assert!((r - 1.2).abs() < 0.1, "ratio={r}");
        // and decreases monotonically with TP
        let mut prev = f64::MAX;
        for tp in [1, 2, 4, 8] {
            let x = ttft_ratio(ModelSpec::LLAMA_7B, tp, 2000, 128, 8);
            assert!(x < prev, "tp={tp} ratio={x} prev={prev}");
            prev = x;
        }
    }

    /// Fig 4: ≈45% penalty on 70B TP8; penalty grows with model size.
    #[test]
    fn calibration_fig4_ratio() {
        let r = ttft_ratio(ModelSpec::LLAMA_70B, 8, 2000, 128, 8);
        assert!((r - 1.45).abs() < 0.12, "ratio={r}");
        let r7 = ttft_ratio(ModelSpec::LLAMA_7B, 8, 2000, 128, 8);
        let r30 = ttft_ratio(ModelSpec::LLAMA_30B, 8, 2000, 128, 8);
        assert!(r7 < r30 && r30 < r, "7b={r7} 30b={r30} 70b={r}");
    }

    /// Fig 3 bottom: TBT is only mildly rank-sensitive but grows with
    /// cache size.
    #[test]
    fn decode_shape() {
        let s = server(ModelSpec::LLAMA_7B, 4);
        let d8 = decode_time(&s, 8, 8 * 512, 8);
        let d128 = decode_time(&s, 8, 8 * 512, 128);
        let rel = d128 / d8;
        assert!(rel > 1.0 && rel < 1.6, "rel={rel}");
        // longer context => slower steps
        let long = decode_time(&s, 8, 8 * 4096, 8);
        assert!(long > d8);
        // larger batch => higher step time but lower per-seq time
        let d16 = decode_time(&s, 16, 16 * 512, 8);
        assert!(d16 > d8);
        assert!(d16 / 16.0 < d8 / 8.0);
    }

    #[test]
    fn prefill_monotonicity() {
        let s = server(ModelSpec::LLAMA_7B, 4);
        assert!(prefill_time(&s, 2000, 8) > prefill_time(&s, 500, 8));
        assert!(prefill_time(&s, 2000, 64) > prefill_time(&s, 2000, 16));
        // no-LoRA batch is cheapest
        assert!(prefill_time(&s, 2000, 0) < prefill_time(&s, 2000, 8));
        // more TP is faster in absolute terms
        let s8 = server(ModelSpec::LLAMA_7B, 8);
        assert!(
            prefill_time(&s8, 4000, 128) < prefill_time(&server(ModelSpec::LLAMA_7B, 1), 4000, 128)
        );
    }

    #[test]
    fn saturation_tps_decreases_with_rank() {
        let cm = CostModel::new(server(ModelSpec::LLAMA_7B, 4));
        let mut prev = f64::MAX;
        for r in [8u32, 16, 32, 64, 128] {
            let tps = cm.saturation_tps(r, 512, 128, 16);
            assert!(tps < prev, "rank {r}: {tps} !< {prev}");
            assert!(tps > 100.0, "rank {r}: {tps}");
            prev = tps;
        }
    }

    #[test]
    fn decode_empty_batch_is_free() {
        let s = server(ModelSpec::LLAMA_7B, 4);
        assert_eq!(decode_time(&s, 0, 0, 128), 0.0);
    }

    /// The launch/padding break-even: splitting is worth one launch
    /// overhead only when the class is padded far enough, with enough
    /// members — and the gain is exactly the padding recovered minus
    /// the launch.
    #[test]
    fn decode_split_gain_breakeven() {
        let cm = CostModel::new(server(ModelSpec::LLAMA_7B, 4));
        // a big low-rank class padded to 128 recovers real kernel time
        assert!(cm.decode_split_gain(12, 8, 128) > 0.0);
        // a single member padded 64→128 can't pay for a launch
        assert!(cm.decode_split_gain(1, 64, 128) < 0.0);
        // no padding, no gain — pure launch cost
        let g = cm.decode_split_gain(8, 128, 128);
        assert!((g + cm.server.decode_launch_overhead).abs() < 1e-15);
        // exact decomposition against the kernel-time formula
        let want = decode_lora_time(&cm.server, 6, 128)
            - decode_lora_time(&cm.server, 6, 16)
            - cm.server.decode_launch_overhead;
        assert_eq!(cm.decode_split_gain(6, 16, 128).to_bits(), want.to_bits());
        // monotone in member count
        assert!(
            cm.decode_split_gain(10, 8, 128)
                > cm.decode_split_gain(2, 8, 128)
        );
    }

    /// The remote-attach penalty mirrors the config knob exactly and
    /// stays in the RDMA-latency regime: cheaper than re-fetching the
    /// adapter every iteration, far from free.
    #[test]
    fn remote_attach_penalty_scale() {
        let cm = CostModel::new(server(ModelSpec::LLAMA_7B, 4));
        let p = cm.remote_attach_penalty();
        assert_eq!(
            p,
            crate::costmodel::calib::REMOTE_ATTACH_PENALTY
        );
        assert_eq!(cm.remote_attach_penalty_ms(), p * 1e3);
        // at least one RDMA latency floor, well under a decode step's
        // fixed overhead
        assert!(p >= 250e-6, "{p}");
        assert!(p < crate::costmodel::calib::GAMMA0, "{p}");
        // a full rank-64 adapter re-fetch would cost ~15x more per
        // iteration than remote attach — the reason the mode exists
        let refetch = crate::costmodel::fetch_time(
            &cm.server.gpu,
            crate::costmodel::FetchSource::RemoteRdma,
            ModelSpec::LLAMA_7B.adapter_bytes(64),
        );
        assert!(refetch > 5.0 * p, "refetch={refetch} penalty={p}");
    }

    /// Grouped decode cost split: the shared base is a LoRA-free
    /// unified step; per-class sub-batches add only their own padded
    /// kernel work plus the launch-overhead knob, so splitting a mixed
    /// round recovers the low-rank classes' padding without paying the
    /// forward pass twice.
    #[test]
    fn grouped_decode_cost_split() {
        let cm = CostModel::new(server(ModelSpec::LLAMA_7B, 4));
        let base = cm.decode_base(8, 8 * 512);
        assert_eq!(base.to_bits(), cm.decode(8, 8 * 512, 0).to_bits());
        // single-class sub-batch without launch overhead: base + class
        // ≈ the unified step of the same membership (same terms, so
        // well within float noise)
        let unified = cm.decode(8, 8 * 512, 128);
        let split = base + cm.decode_class(8, 128, false);
        assert!((split - unified).abs() < 1e-12 * unified.max(1.0));
        // the launch-overhead knob is additive and exact
        let with_launch = cm.decode_class(8, 128, true);
        assert!(
            (with_launch
                - cm.decode_class(8, 128, false)
                - cm.server.decode_launch_overhead)
                .abs()
                < 1e-15
        );
        // a class pays its own rank: splitting a half-8/half-128 round
        // into two sub-batches beats one pad-to-128 round even with
        // two launch overheads
        let mixed_unified = cm.decode(16, 16 * 512, 128);
        let grouped = cm.decode_base(16, 16 * 512)
            + cm.decode_class(8, 8, true)
            + cm.decode_class(8, 128, true);
        assert!(
            grouped < mixed_unified,
            "grouped {grouped} !< unified {mixed_unified}"
        );
    }
}
