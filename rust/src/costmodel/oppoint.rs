//! Operating points: tokens/second a server sustains per adapter rank
//! under the SLO — the a-priori profiling step Algorithm 1 consumes
//! ("operatingPoints[rank]", §IV-A).
//!
//! The analytic path derives each rank's saturation throughput from the
//! cost model and applies a utilization headroom (serving *at*
//! saturation has unbounded queueing delay). The `profile` CLI
//! subcommand cross-checks this against the DES simulator by binary
//! search on offered load.

use super::calib::{OPPOINT_HEADROOM, PROFILE_OUTPUT, PROFILE_PROMPT};
use super::latency::CostModel;
use crate::config::ServerConfig;
use std::collections::BTreeMap;

/// Analytic operating point (tokens/s) for one rank.
pub fn operating_point(server: &ServerConfig, rank: u32) -> f64 {
    let cm = CostModel::new(*server);
    let decode_batch = (server.max_batch_size / 2).max(1);
    cm.saturation_tps(rank, PROFILE_PROMPT, PROFILE_OUTPUT, decode_batch)
        * OPPOINT_HEADROOM
}

/// Operating points for every rank in `ranks`.
pub fn operating_points(
    server: &ServerConfig,
    ranks: &[u32],
) -> BTreeMap<u32, f64> {
    ranks
        .iter()
        .map(|&r| (r, operating_point(server, r)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelSpec, ServerConfig};
    use crate::workload::RANK_CLASSES;

    #[test]
    fn monotone_decreasing_in_rank() {
        let server = ServerConfig::default();
        let ops = operating_points(&server, &RANK_CLASSES);
        let vals: Vec<f64> = RANK_CLASSES.iter().map(|r| ops[r]).collect();
        for w in vals.windows(2) {
            assert!(w[0] > w[1], "{vals:?}");
        }
    }

    #[test]
    fn bigger_model_lower_oppoint() {
        let mut s7 = ServerConfig::default();
        s7.tp = 8;
        let mut s70 = s7;
        s70.model = ModelSpec::LLAMA_70B;
        assert!(operating_point(&s7, 32) > operating_point(&s70, 32));
    }

    #[test]
    fn more_tp_higher_oppoint() {
        let mut s1 = ServerConfig::default();
        s1.tp = 1;
        let mut s8 = s1;
        s8.tp = 8;
        assert!(operating_point(&s8, 64) > operating_point(&s1, 64));
    }

    #[test]
    fn plausible_scale() {
        // Llama-7B TP4 at 512/128 shape: thousands of tokens/sec.
        let op = operating_point(&ServerConfig::default(), 8);
        assert!(op > 1000.0 && op < 100_000.0, "op={op}");
    }
}
