//! Adapter fetch/fabric model (Fig 14): latency of materializing an
//! adapter's tensors in GPU memory from each possible source.
//!
//! The paper's measurement: GPUDirect-RDMA over InfiniBand from a
//! remote server's GPU costs about the same as a local host-memory →
//! GPU copy over PCIe, while local SSD is prohibitively slower — which
//! is what makes the distributed adapter pool viable.

use crate::config::GpuSpec;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchSource {
    /// Already resident in GPU HBM (cache hit) — free.
    GpuResident,
    /// Host DRAM of the same server, over PCIe.
    LocalHostMem,
    /// Remote server: host→GPU on the remote side, then GPUDirect RDMA
    /// over InfiniBand into the local GPU (the Fig 13 two-hop path).
    RemoteRdma,
    /// Local NVMe SSD.
    LocalSsd,
}

impl FetchSource {
    pub fn label(&self) -> &'static str {
        match self {
            FetchSource::GpuResident => "gpu-resident",
            FetchSource::LocalHostMem => "local-host-mem",
            FetchSource::RemoteRdma => "remote-rdma",
            FetchSource::LocalSsd => "local-ssd",
        }
    }
}

/// Fixed software latency per transfer (driver, registration), seconds.
const LAT_PCIE: f64 = 100e-6;
const LAT_RDMA: f64 = 250e-6; // two hops + IB setup
const LAT_SSD: f64 = 250e-6; // io submission + fs

/// Time to materialize `bytes` in local GPU memory from `src`.
pub fn fetch_time(gpu: &GpuSpec, src: FetchSource, bytes: u64) -> f64 {
    let b = bytes as f64;
    match src {
        FetchSource::GpuResident => 0.0,
        FetchSource::LocalHostMem => LAT_PCIE + b / gpu.pcie_bw,
        FetchSource::RemoteRdma => {
            // remote host -> remote GPU (PCIe), then remote GPU ->
            // local GPU (GPUDirect RDMA over IB). The two stages
            // pipeline in chunks; the slower link dominates, plus one
            // chunk of the faster one (approximate with 10% overlap
            // slack).
            let stage = b / gpu.pcie_bw.min(gpu.ib_bw);
            LAT_RDMA + stage * 1.1
        }
        FetchSource::LocalSsd => LAT_SSD + b / gpu.ssd_bw,
    }
}

/// Inter-region variant of the `RemoteRdma` two-hop path: the second
/// hop crosses the inter-region fabric, so the NIC-bound stage runs at
/// `bw_factor` of the intra-region bandwidth (WAN/fabric
/// oversubscription) and pays `extra_lat` seconds of added one-way
/// latency on top of the IB setup cost.
pub fn inter_region_fetch_time(
    gpu: &GpuSpec,
    bytes: u64,
    bw_factor: f64,
    extra_lat: f64,
) -> f64 {
    let b = bytes as f64;
    let bw =
        gpu.pcie_bw.min(gpu.ib_bw) * bw_factor.clamp(1e-3, 1.0);
    LAT_RDMA + extra_lat.max(0.0) + (b / bw) * 1.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};

    const G: GpuSpec = GpuSpec::A100_40G;

    #[test]
    fn fig14_ordering_rdma_close_to_local_ssd_far() {
        // across adapter-scale tensor sizes (16 MB – 2 GB)
        for mb in [16u64, 64, 256, 1024, 2048] {
            let bytes = mb * (1 << 20);
            let local = fetch_time(&G, FetchSource::LocalHostMem, bytes);
            let rdma = fetch_time(&G, FetchSource::RemoteRdma, bytes);
            let ssd = fetch_time(&G, FetchSource::LocalSsd, bytes);
            assert!(rdma < 1.5 * local, "{mb}MB rdma={rdma} local={local}");
            assert!(ssd > 5.0 * local, "{mb}MB ssd={ssd} local={local}");
            assert!(ssd > 5.0 * rdma);
        }
    }

    #[test]
    fn resident_is_free_and_latency_floors_hold() {
        assert_eq!(fetch_time(&G, FetchSource::GpuResident, 1 << 30), 0.0);
        // tiny transfers are latency-bound
        let t = fetch_time(&G, FetchSource::RemoteRdma, 1);
        assert!(t >= 250e-6);
    }

    #[test]
    fn adapter_scale_sanity() {
        // 7B rank-64 adapter ≈ 134 MB: local fetch ≈ 5.5 ms, rdma ≈ 6 ms
        let bytes = ModelSpec::LLAMA_7B.adapter_bytes(64);
        let local = fetch_time(&G, FetchSource::LocalHostMem, bytes);
        let rdma = fetch_time(&G, FetchSource::RemoteRdma, bytes);
        assert!(local > 3e-3 && local < 10e-3, "local={local}");
        assert!(rdma > 3e-3 && rdma < 12e-3, "rdma={rdma}");
    }

    #[test]
    fn inter_region_priced_above_intra() {
        for mb in [16u64, 134, 512] {
            let bytes = mb * (1 << 20);
            let intra = fetch_time(&G, FetchSource::RemoteRdma, bytes);
            let inter =
                inter_region_fetch_time(&G, bytes, 0.25, 750e-6);
            assert!(
                inter > intra,
                "{mb}MB inter={inter} intra={intra}"
            );
            // unit bandwidth factor + zero extra latency degenerates
            // to the intra-region price
            let same = inter_region_fetch_time(&G, bytes, 1.0, 0.0);
            assert!((same - intra).abs() < 1e-12);
        }
        // slower fabric => strictly slower fetch
        let a = inter_region_fetch_time(&G, 1 << 27, 0.5, 0.0);
        let b = inter_region_fetch_time(&G, 1 << 27, 0.25, 0.0);
        assert!(b > a);
    }

    #[test]
    fn monotone_in_bytes() {
        for src in [
            FetchSource::LocalHostMem,
            FetchSource::RemoteRdma,
            FetchSource::LocalSsd,
        ] {
            let a = fetch_time(&G, src, 1 << 20);
            let b = fetch_time(&G, src, 1 << 24);
            assert!(b > a, "{src:?}");
        }
    }
}
