//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! the CPU PJRT client (`xla` crate). The interchange is HLO *text* —
//! xla_extension 0.5.1 rejects jax>=0.5 serialized protos (64-bit ids);
//! the text parser reassigns ids and round-trips cleanly.

pub mod engine;
pub mod manifest;
pub mod tensorfile;

pub use engine::{argmax, BankAdapter, KvState, ModelEngine};
pub use manifest::{load_manifest, Manifest};
