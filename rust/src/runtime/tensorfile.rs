//! Reader for the `LSTF` binary tensor container written by
//! `python/compile/tensorfile.py` (params.bin / adapters.bin).
//! The byte layout is pinned by `python/tests/test_tensorfile.py`.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Read;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
    /// Raw little-endian data (len = product(dims) * 4).
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("{}: not f32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("{}: not i32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Convert to an XLA literal of the right shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        let lit = match self.dtype {
            DType::F32 => {
                xla::Literal::vec1(&self.as_f32()?).reshape(&dims)?
            }
            DType::I32 => {
                xla::Literal::vec1(&self.as_i32()?).reshape(&dims)?
            }
        };
        Ok(lit)
    }
}

/// Read every tensor in the file, preserving order.
pub fn read_tensors(path: &str) -> Result<Vec<Tensor>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {path}"))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    parse_tensors(&buf).with_context(|| format!("parse {path}"))
}

pub fn read_tensor_map(path: &str) -> Result<BTreeMap<String, Tensor>> {
    Ok(read_tensors(path)?
        .into_iter()
        .map(|t| (t.name.clone(), t))
        .collect())
}

fn parse_tensors(buf: &[u8]) -> Result<Vec<Tensor>> {
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > buf.len() {
            bail!("truncated at byte {pos}");
        }
        let s = &buf[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    if take(&mut pos, 4)? != b"LSTF" {
        bail!("bad magic");
    }
    let version = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
    if version != 1 {
        bail!("unsupported version {version}");
    }
    let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
    let mut out = Vec::with_capacity(count as usize);
    for _ in 0..count {
        let name_len =
            u16::from_le_bytes(take(&mut pos, 2)?.try_into()?) as usize;
        let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())?;
        let dt = take(&mut pos, 1)?[0];
        let ndim = take(&mut pos, 1)?[0] as usize;
        let dtype = match dt {
            0 => DType::F32,
            1 => DType::I32,
            other => bail!("{name}: unknown dtype {other}"),
        };
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(
                u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize,
            );
        }
        let n: usize = dims.iter().product::<usize>().max(1);
        // 0-dim tensors carry one element
        let n = if ndim == 0 { 1 } else { n };
        let data = take(&mut pos, n * 4)?.to_vec();
        out.push(Tensor {
            name,
            dtype,
            dims,
            data,
        });
    }
    if pos != buf.len() {
        bail!("trailing garbage: {} bytes", buf.len() - pos);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file_with(tensors: &[(&str, DType, &[usize], &[u8])]) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"LSTF");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for (name, dt, dims, data) in tensors {
            buf.extend_from_slice(&(name.len() as u16).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.push(match dt {
                DType::F32 => 0,
                DType::I32 => 1,
            });
            buf.push(dims.len() as u8);
            for &d in *dims {
                buf.extend_from_slice(&(d as u32).to_le_bytes());
            }
            buf.extend_from_slice(data);
        }
        buf
    }

    #[test]
    fn parses_roundtrip() {
        let data: Vec<u8> = [1.5f32, -2.0]
            .iter()
            .flat_map(|x| x.to_le_bytes())
            .collect();
        let idata: Vec<u8> =
            [7i32].iter().flat_map(|x| x.to_le_bytes()).collect();
        let buf = file_with(&[
            ("w", DType::F32, &[2], &data),
            ("i", DType::I32, &[1], &idata),
        ]);
        let ts = parse_tensors(&buf).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].as_f32().unwrap(), vec![1.5, -2.0]);
        assert_eq!(ts[1].as_i32().unwrap(), vec![7]);
        assert!(ts[0].as_i32().is_err());
    }

    #[test]
    fn rejects_corruption() {
        assert!(parse_tensors(b"NOPE").is_err());
        let buf = file_with(&[("w", DType::F32, &[2], &[0u8; 8])]);
        assert!(parse_tensors(&buf[..buf.len() - 1]).is_err());
        let mut extra = buf.clone();
        extra.push(0);
        assert!(parse_tensors(&extra).is_err());
        let mut badver = buf;
        badver[4] = 9;
        assert!(parse_tensors(&badver).is_err());
    }

    #[test]
    fn reads_real_params_if_built() {
        // only runs when `make artifacts` has produced the file
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/params.bin");
        if !std::path::Path::new(path).exists() {
            return;
        }
        let map = read_tensor_map(path).unwrap();
        assert!(map.contains_key("embed"));
        let embed = &map["embed"];
        assert_eq!(embed.dims.len(), 2);
        assert_eq!(embed.data.len(), embed.element_count() * 4);
    }
}
