//! The PJRT model engine: loads the AOT artifacts and executes real
//! prefill/decode batches on the CPU PJRT client. This is the compute
//! backend of the *real* mini-cluster (`server/`) — Python is never on
//! this path.

use super::manifest::{load_manifest, Manifest};
use super::tensorfile::read_tensor_map;
use anyhow::{anyhow, bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// One adapter's weights from the bank (row-major host copies — these
/// are the bytes the distributed pool moves between servers).
#[derive(Debug, Clone)]
pub struct BankAdapter {
    pub rank: u32,
    pub alpha: f32,
    /// A: [d_model][rank]
    pub a: Vec<f32>,
    /// B: [rank][d_model]
    pub b: Vec<f32>,
}

impl BankAdapter {
    pub fn size_bytes(&self) -> u64 {
        ((self.a.len() + self.b.len()) * 4) as u64
    }
}

/// KV cache state between prefill and decode calls (host literals;
/// shapes are [L, B, Lmax, H, Dh]).
pub struct KvState {
    pub k: Literal,
    pub v: Literal,
    pub batch: usize,
}

pub struct ModelEngine {
    pub manifest: Manifest,
    client: PjRtClient,
    params: Vec<Literal>,
    prefill_exes: Vec<(usize, usize, PjRtLoadedExecutable)>,
    decode_exes: Vec<(usize, PjRtLoadedExecutable)>,
}

impl ModelEngine {
    /// Load manifest + params and compile every artifact.
    pub fn load(dir: &str) -> Result<ModelEngine> {
        let manifest = load_manifest(dir)?;
        let client = PjRtClient::cpu()?;
        let params_map = read_tensor_map(&format!("{dir}/params.bin"))?;
        let mut params = Vec::new();
        for name in &manifest.param_names {
            let t = params_map
                .get(name)
                .ok_or_else(|| anyhow!("params.bin missing {name}"))?;
            params.push(t.to_literal()?);
        }
        let mut prefill_exes = Vec::new();
        let mut decode_exes = Vec::new();
        for a in &manifest.artifacts {
            let path = format!("{dir}/{}", a.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("load {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {}", a.name))?;
            match a.kind.as_str() {
                "prefill" => prefill_exes.push((a.batch, a.prompt_len, exe)),
                _ => decode_exes.push((a.batch, exe)),
            }
        }
        prefill_exes.sort_by_key(|(b, l, _)| (*b, *l));
        decode_exes.sort_by_key(|(b, _)| *b);
        Ok(ModelEngine {
            manifest,
            client,
            params,
            prefill_exes,
            decode_exes,
        })
    }

    /// Load the deterministic adapter bank emitted by aot.py.
    pub fn load_bank(dir: &str) -> Result<Vec<BankAdapter>> {
        let map = read_tensor_map(&format!("{dir}/adapters.bin"))?;
        let mut bank = Vec::new();
        for i in 0.. {
            let Some(a) = map.get(&format!("adapter{i}.a")) else {
                break;
            };
            let b = map
                .get(&format!("adapter{i}.b"))
                .ok_or_else(|| anyhow!("adapter{i}.b missing"))?;
            let alpha = map
                .get(&format!("adapter{i}.alpha"))
                .ok_or_else(|| anyhow!("adapter{i}.alpha missing"))?
                .as_f32()?[0];
            let rank = a.dims[1] as u32;
            bank.push(BankAdapter {
                rank,
                alpha,
                a: a.as_f32()?,
                b: b.as_f32()?,
            });
        }
        if bank.is_empty() {
            bail!("adapters.bin holds no adapters");
        }
        Ok(bank)
    }

    /// Available (batch, prompt_len) prefill shapes.
    pub fn prefill_shapes(&self) -> Vec<(usize, usize)> {
        self.prefill_exes.iter().map(|(b, l, _)| (*b, *l)).collect()
    }

    pub fn decode_batches(&self) -> Vec<usize> {
        self.decode_exes.iter().map(|(b, _)| *b).collect()
    }

    /// Smallest prefill shape fitting `n` requests of max prompt `lp`
    /// that also has a matching decode artifact.
    pub fn pick_shape(&self, n: usize, lp: usize) -> Option<(usize, usize)> {
        self.prefill_exes
            .iter()
            .filter(|(b, l, _)| {
                *b >= n
                    && *l >= lp
                    && self.decode_exes.iter().any(|(db, _)| db == b)
            })
            .map(|(b, l, _)| (*b, *l))
            .min()
    }

    /// Build the stacked [slots, d, r_max] / [slots, r_max, d] /
    /// [slots] literals from per-slot adapters (None = zero slot).
    pub fn stack_adapters(
        &self,
        slots: &[Option<&BankAdapter>],
    ) -> Result<(Literal, Literal, Literal)> {
        let s = self.manifest.batch_slots;
        let d = self.manifest.model.d_model;
        let rm = self.manifest.model.r_max;
        if slots.len() > s {
            bail!("{} adapters > {s} batch slots", slots.len());
        }
        let mut la = vec![0f32; s * d * rm];
        let mut lb = vec![0f32; s * rm * d];
        let mut sc = vec![0f32; s];
        for (i, slot) in slots.iter().enumerate() {
            let Some(ad) = slot else { continue };
            let r = ad.rank as usize;
            // A [d][r] into [d][rm] zero-padded
            for row in 0..d {
                la[i * d * rm + row * rm..i * d * rm + row * rm + r]
                    .copy_from_slice(&ad.a[row * r..(row + 1) * r]);
            }
            // B [r][d] into [rm][d]
            lb[i * rm * d..i * rm * d + r * d]
                .copy_from_slice(&ad.b[..r * d]);
            sc[i] = ad.alpha / ad.rank as f32;
        }
        Ok((
            Literal::vec1(&la).reshape(&[s as i64, d as i64, rm as i64])?,
            Literal::vec1(&lb).reshape(&[s as i64, rm as i64, d as i64])?,
            Literal::vec1(&sc),
        ))
    }

    /// Run one prefill batch. `prompts[i]` is request i's token ids,
    /// `slot_of_req[i]` its adapter slot in the stack. Rows beyond
    /// `prompts.len()` are padded (slot 0, len 1) and their outputs
    /// ignored. Returns per-request logits and the KV state.
    pub fn prefill(
        &self,
        shape: (usize, usize),
        prompts: &[Vec<i32>],
        slot_of_req: &[usize],
        stack: &(Literal, Literal, Literal),
    ) -> Result<(Vec<Vec<f32>>, KvState)> {
        let (b, lp) = shape;
        let bt = self.manifest.model.block_tokens;
        let exe = self
            .prefill_exes
            .iter()
            .find(|(eb, el, _)| (*eb, *el) == shape)
            .map(|(_, _, e)| e)
            .ok_or_else(|| anyhow!("no prefill artifact {shape:?}"))?;
        if prompts.len() > b || prompts.len() != slot_of_req.len() {
            bail!("bad batch: {} prompts for shape {shape:?}", prompts.len());
        }
        let mut tokens = vec![0i32; b * lp];
        let mut lens = vec![1i32; b];
        let mut bseg = vec![0i32; b * lp / bt];
        for (i, p) in prompts.iter().enumerate() {
            if p.is_empty() || p.len() > lp {
                bail!("prompt {i} len {} out of range (lp={lp})", p.len());
            }
            tokens[i * lp..i * lp + p.len()].copy_from_slice(p);
            lens[i] = p.len() as i32;
            for blk in 0..lp / bt {
                bseg[i * (lp / bt) + blk] = slot_of_req[i] as i32;
            }
        }
        let mut args: Vec<&Literal> = self.params.iter().collect();
        let tokens_l = Literal::vec1(&tokens)
            .reshape(&[b as i64, lp as i64])?;
        let bseg_l = Literal::vec1(&bseg);
        let lens_l = Literal::vec1(&lens);
        args.push(&stack.0);
        args.push(&stack.1);
        args.push(&stack.2);
        args.push(&tokens_l);
        args.push(&bseg_l);
        args.push(&lens_l);

        let result = exe.execute::<&Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (logits, k, v) = result.to_tuple3()?;
        let logits = split_rows(&logits, b, self.manifest.model.vocab)?;
        Ok((
            logits[..prompts.len()].to_vec(),
            KvState { k, v, batch: b },
        ))
    }

    /// One decode step over the whole KV batch. `tokens[i]`/`pos[i]`
    /// apply to row i; inactive rows pass token 0 / their last pos and
    /// are ignored by the caller.
    pub fn decode(
        &self,
        kv: KvState,
        tokens: &[i32],
        slot_of_row: &[usize],
        pos: &[i32],
        stack: &(Literal, Literal, Literal),
    ) -> Result<(Vec<Vec<f32>>, KvState)> {
        let b = kv.batch;
        if tokens.len() != b || pos.len() != b || slot_of_row.len() != b {
            bail!("decode arity mismatch (batch {b})");
        }
        let exe = self
            .decode_exes
            .iter()
            .find(|(eb, _)| *eb == b)
            .map(|(_, e)| e)
            .ok_or_else(|| anyhow!("no decode artifact for batch {b}"))?;
        let mut args: Vec<&Literal> = self.params.iter().collect();
        let bseg: Vec<i32> =
            slot_of_row.iter().map(|&s| s as i32).collect();
        let tokens_l = Literal::vec1(tokens);
        let bseg_l = Literal::vec1(&bseg);
        let pos_l = Literal::vec1(pos);
        args.push(&stack.0);
        args.push(&stack.1);
        args.push(&stack.2);
        args.push(&kv.k);
        args.push(&kv.v);
        args.push(&tokens_l);
        args.push(&bseg_l);
        args.push(&pos_l);
        let result = exe.execute::<&Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (logits, k, v) = result.to_tuple3()?;
        let logits = split_rows(&logits, b, self.manifest.model.vocab)?;
        Ok((logits, KvState { k, v, batch: b }))
    }

    /// Convenience: greedy generation for one prompt — used by the
    /// quickstart example and the golden-file integration test.
    pub fn generate(
        &self,
        prompt: &[i32],
        adapter: &BankAdapter,
        steps: usize,
    ) -> Result<Vec<i32>> {
        let stack = self.stack_adapters(&[Some(adapter)])?;
        let lp = self
            .manifest
            .model
            .block_tokens
            .max(prompt.len().div_ceil(self.manifest.model.block_tokens)
                * self.manifest.model.block_tokens);
        let shape = self
            .pick_shape(1, lp)
            .ok_or_else(|| anyhow!("no artifact fits prompt {}", prompt.len()))?;
        let (logits, mut kv) =
            self.prefill(shape, &[prompt.to_vec()], &[0], &stack)?;
        let mut out = vec![argmax(&logits[0])];
        let mut pos = prompt.len() as i32;
        for _ in 1..steps {
            let mut tokens = vec![0i32; kv.batch];
            tokens[0] = *out.last().unwrap();
            let mut posv = vec![0i32; kv.batch];
            posv[0] = pos;
            let slots = vec![0usize; kv.batch];
            let (logits, nkv) =
                self.decode(kv, &tokens, &slots, &posv, &stack)?;
            kv = nkv;
            out.push(argmax(&logits[0]));
            pos += 1;
        }
        Ok(out)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

pub fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

fn split_rows(lit: &Literal, rows: usize, cols: usize) -> Result<Vec<Vec<f32>>> {
    let flat = lit.to_vec::<f32>()?;
    if flat.len() != rows * cols {
        bail!("logits shape mismatch: {} != {rows}x{cols}", flat.len());
    }
    Ok(flat.chunks(cols).map(|c| c.to_vec()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
        // first max wins on ties
        assert_eq!(argmax(&[5.0, 5.0]), 0);
    }
}
