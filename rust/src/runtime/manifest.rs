//! Parser for `artifacts/manifest.json` — the ABI contract between
//! `python/compile/aot.py` and the PJRT engine.

use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "float32" | "int32"
}

#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: String, // "prefill" | "decode"
    pub batch: usize,
    pub prompt_len: usize,
    pub file: String,
    pub args: Vec<ArgSpec>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub r_max: usize,
    pub block_tokens: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub model: ModelDims,
    pub batch_slots: usize,
    pub param_names: Vec<String>,
    pub bank_ranks: Vec<u32>,
    pub artifacts: Vec<ArtifactSpec>,
    pub seed: u64,
}

fn need_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("manifest: missing numeric '{key}'"))
}

pub fn parse_manifest(text: &str) -> Result<Manifest> {
    let v = json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
    let m = v
        .get("model")
        .ok_or_else(|| anyhow!("manifest: missing model"))?;
    let model = ModelDims {
        vocab: need_usize(m, "vocab")?,
        d_model: need_usize(m, "d_model")?,
        n_heads: need_usize(m, "n_heads")?,
        n_layers: need_usize(m, "n_layers")?,
        d_ff: need_usize(m, "d_ff")?,
        max_seq: need_usize(m, "max_seq")?,
        r_max: need_usize(m, "r_max")?,
        block_tokens: need_usize(m, "block_tokens")?,
    };
    let param_names = v
        .get("param_names")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest: missing param_names"))?
        .iter()
        .map(|x| x.as_str().unwrap_or_default().to_string())
        .collect();
    let bank_ranks = v
        .get("bank_ranks")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest: missing bank_ranks"))?
        .iter()
        .map(|x| x.as_f64().unwrap_or(0.0) as u32)
        .collect();
    let mut artifacts = Vec::new();
    for a in v
        .get("artifacts")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest: missing artifacts"))?
    {
        let args = a
            .get("args")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("artifact missing args"))?
            .iter()
            .map(|arg| -> Result<ArgSpec> {
                Ok(ArgSpec {
                    name: arg
                        .get("name")
                        .and_then(Json::as_str)
                        .context("arg name")?
                        .to_string(),
                    shape: arg
                        .get("shape")
                        .and_then(Json::as_arr)
                        .context("arg shape")?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    dtype: arg
                        .get("dtype")
                        .and_then(Json::as_str)
                        .context("arg dtype")?
                        .to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let spec = ArtifactSpec {
            name: a
                .get("name")
                .and_then(Json::as_str)
                .context("artifact name")?
                .to_string(),
            kind: a
                .get("kind")
                .and_then(Json::as_str)
                .context("artifact kind")?
                .to_string(),
            batch: need_usize(a, "batch")?,
            prompt_len: need_usize(a, "prompt_len")?,
            file: a
                .get("file")
                .and_then(Json::as_str)
                .context("artifact file")?
                .to_string(),
            args,
        };
        if spec.kind != "prefill" && spec.kind != "decode" {
            bail!("artifact {}: unknown kind {}", spec.name, spec.kind);
        }
        artifacts.push(spec);
    }
    Ok(Manifest {
        model,
        batch_slots: need_usize(&v, "batch_slots")?,
        param_names,
        bank_ranks,
        artifacts,
        seed: v.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64,
    })
}

pub fn load_manifest(dir: &str) -> Result<Manifest> {
    let path = format!("{dir}/manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("read {path} (run `make artifacts`)"))?;
    parse_manifest(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"vocab": 512, "d_model": 256, "n_heads": 4,
                "n_layers": 2, "d_ff": 1024, "max_seq": 160,
                "r_max": 128, "block_tokens": 32},
      "batch_slots": 8,
      "param_names": ["embed", "unembed"],
      "bank_ranks": [8, 128],
      "seed": 42,
      "artifacts": [
        {"name": "prefill_b1_l32", "kind": "prefill", "batch": 1,
         "prompt_len": 32, "file": "prefill_b1_l32.hlo.txt",
         "args": [{"name": "param:embed", "shape": [512, 256],
                   "dtype": "float32"}],
         "outputs": ["logits", "k_cache", "v_cache"]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = parse_manifest(SAMPLE).unwrap();
        assert_eq!(m.model.d_model, 256);
        assert_eq!(m.batch_slots, 8);
        assert_eq!(m.bank_ranks, vec![8, 128]);
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.kind, "prefill");
        assert_eq!(a.args[0].shape, vec![512, 256]);
        assert_eq!(m.seed, 42);
    }

    #[test]
    fn rejects_bad_kind() {
        let bad = SAMPLE.replace("\"prefill\"", "\"training\"");
        assert!(parse_manifest(&bad).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(parse_manifest("{}").is_err());
        let no_model = SAMPLE.replace("\"model\"", "\"not_model\"");
        assert!(parse_manifest(&no_model).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let path =
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if !std::path::Path::new(path).exists() {
            return;
        }
        let m = load_manifest(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
            .unwrap();
        assert!(!m.artifacts.is_empty());
        assert!(m.artifacts.iter().any(|a| a.kind == "prefill"));
        assert!(m.artifacts.iter().any(|a| a.kind == "decode"));
        // ABI: every artifact's first args are the params in order
        for a in &m.artifacts {
            for (i, p) in m.param_names.iter().enumerate() {
                assert_eq!(a.args[i].name, format!("param:{p}"));
            }
        }
    }
}
