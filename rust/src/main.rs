//! LoRAServe CLI — the cluster launcher and experiment driver.
//!
//! Subcommands:
//!   figures   regenerate paper tables/figures (`--all` or `--fig N`)
//!   simulate  run one trace × system on the DES cluster
//!   trace     synthesize + characterize traces (writes CSV)
//!   profile   print operating points for a server config
//!   serve     run the real PJRT mini-cluster on a synthetic workload

use loraserve::config::ClusterConfig;
use loraserve::figures::{self, FigOpts};
use loraserve::sim::{self, SystemKind};
use loraserve::trace::{azure, production};
use loraserve::util::cli::Args;
use loraserve::util::table::{fmt_bytes, fmt_secs, Table};

fn main() {
    let args = match Args::from_env(&["all", "fast", "help", "empirical"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.subcommand().is_none() {
        usage();
        return;
    }
    let result = match args.subcommand().unwrap() {
        "figures" => cmd_figures(&args),
        "simulate" => cmd_simulate(&args),
        "trace" => cmd_trace(&args),
        "profile" => cmd_profile(&args),
        "serve" => cmd_serve(&args),
        other => {
            eprintln!("unknown subcommand '{other}'");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    println!(
        "loraserve — rank-aware LoRA adapter placement & routing \
         (paper reproduction)\n\n\
         USAGE: loraserve <subcommand> [options]\n\n\
         figures  --all | --fig <id>   [--fast] [--seed S]\n\
         simulate --system <loraserve|slora-random|slora-contiguous|\
         toppings>\n         \
         [--trace prod|shifting|uniform] [--rps R] [--servers N]\n         \
         [--adapters N] [--duration S] [--seed S] [--config file.json]\n\
         trace    --kind prod|azure [--adapters N] [--out file.csv]\n\
         profile  [--model 7b|13b|30b|70b] [--tp N]\n\
         serve    [--servers N] [--requests N] [--duration S]"
    );
}

fn parse_system(s: &str) -> Result<SystemKind, String> {
    match s {
        "loraserve" => Ok(SystemKind::LoraServe),
        "slora-random" | "random" => Ok(SystemKind::SLoraRandom),
        "slora-contiguous" | "contiguous" => {
            Ok(SystemKind::SLoraContiguous)
        }
        "toppings" => Ok(SystemKind::Toppings),
        other => Err(format!("unknown system '{other}'")),
    }
}

fn cmd_figures(args: &Args) -> Result<(), String> {
    let opts = FigOpts {
        fast: args.flag("fast"),
        seed: args.get_u64("seed", 0)?,
    };
    if args.flag("all") {
        figures::run_all(&opts).map_err(|e| e.to_string())
    } else if let Some(id) = args.get("fig") {
        if figures::run_one(id, &opts).map_err(|e| e.to_string())? {
            Ok(())
        } else {
            let ids: Vec<&str> = figures::registry()
                .iter()
                .map(|(id, _, _)| *id)
                .collect();
            Err(format!("unknown figure '{id}'; have {ids:?}"))
        }
    } else {
        println!("available figures:");
        for (id, desc, _) in figures::registry() {
            println!("  {id:10} {desc}");
        }
        Ok(())
    }
}

fn build_cluster(args: &Args) -> Result<ClusterConfig, String> {
    let mut cluster = match args.get("config") {
        Some(path) => ClusterConfig::from_file(path)?,
        None => ClusterConfig::default(),
    };
    cluster.n_servers = args.get_usize("servers", cluster.n_servers)?;
    cluster.seed = args.get_u64("seed", cluster.seed)?;
    if let Some(m) = args.get("model") {
        cluster.server.model = loraserve::config::ModelSpec::by_name(m)
            .ok_or_else(|| format!("unknown model '{m}'"))?;
    }
    cluster.server.tp = args.get_usize("tp", cluster.server.tp)?;
    Ok(cluster)
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let system = parse_system(args.get_or("system", "loraserve"))?;
    let cluster = build_cluster(args)?;
    let rps = args.get_f64("rps", 16.0)?;
    let duration = args.get_f64("duration", 600.0)?;
    let n_adapters = args.get_usize("adapters", 100)?;
    let seed = args.get_u64("seed", 0)?;
    let kind = args.get_or("trace", "prod");
    let trace = match kind {
        "prod" => production::generate(&production::ProductionConfig {
            n_adapters,
            n_requests: (rps * duration) as usize,
            duration,
            seed,
            ..Default::default()
        }),
        "shifting" => azure::generate(&azure::AzureConfig {
            popularity: azure::RankPopularity::ShiftingSkew,
            rps,
            duration,
            seed,
            ..Default::default()
        }),
        "uniform" => azure::generate(&azure::AzureConfig {
            rps,
            duration,
            seed,
            ..Default::default()
        }),
        "skew" => loraserve::figures::sensitivity::skew_trace(
            args.get_f64("alpha", 1.0)?,
            rps,
            duration,
            seed,
        ),
        other => return Err(format!("unknown trace kind '{other}'")),
    };
    println!(
        "simulating {} on '{}' ({} reqs, {:.1} rps, {} servers)",
        system.label(),
        trace.name,
        trace.requests.len(),
        trace.mean_rps(),
        cluster.n_servers
    );
    let t0 = std::time::Instant::now();
    let mut rep = sim::run(
        &trace,
        &sim::SimConfig::new(cluster.clone(), system),
    );
    let wall = t0.elapsed().as_secs_f64();
    let mut table = Table::new("simulation report", &["metric", "value"]);
    let meets = rep.meets_slo(cluster.slo.ttft_p95);
    let rows: Vec<(&str, String)> = vec![
        ("completed", rep.completed.to_string()),
        ("timeouts", rep.timeouts.to_string()),
        ("throughput", format!("{:.2} req/s", rep.throughput_rps())),
        ("ttft p50", fmt_secs(rep.ttft.p50())),
        ("ttft p95", fmt_secs(rep.ttft_p95())),
        ("tbt p50", fmt_secs(rep.tbt.p50())),
        ("tbt p95", fmt_secs(rep.tbt_p95())),
        ("meets slo", meets.to_string()),
        ("rebalances", rep.rebalances.to_string()),
        ("migrated", fmt_bytes(rep.migration_bytes)),
        ("fetches", rep.fetches.to_string()),
        (
            "max resident adapters",
            rep.per_server_max_adapters
                .iter()
                .max()
                .copied()
                .unwrap_or(0)
                .to_string(),
        ),
        ("sim wall time", format!("{wall:.2}s")),
    ];
    for (k, v) in rows {
        table.row(vec![k.to_string(), v]);
    }
    println!("{}", table.to_markdown());
    for s in 0..cluster.n_servers {
        println!(
            "  server {s}: n={:5} p50={} p95={} busy={:.0}s max_adapters={} hi_frac={:.2}",
            rep.per_server_ttft[s].len(),
            fmt_secs(rep.per_server_ttft[s].p50()),
            fmt_secs(rep.per_server_ttft[s].p95()),
            rep.per_server_busy[s],
            rep.per_server_max_adapters[s],
            rep.per_server_highrank_frac[s],
        );
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<(), String> {
    let kind = args.get_or("kind", "prod");
    let n_adapters = args.get_usize("adapters", 100)?;
    let seed = args.get_u64("seed", 0)?;
    let trace = match kind {
        "prod" => production::generate(&production::ProductionConfig {
            n_adapters,
            seed,
            ..Default::default()
        }),
        "azure" => azure::generate(&azure::AzureConfig {
            seed,
            ..Default::default()
        }),
        other => return Err(format!("unknown kind '{other}'")),
    };
    println!(
        "trace '{}': {} requests over {:.0}s, {} adapters",
        trace.name,
        trace.requests.len(),
        trace.duration(),
        trace.adapters.len()
    );
    let shares =
        loraserve::trace::characterize::rank_request_shares(&trace);
    for (rank, s) in shares {
        println!("  rank {rank:3}: {:.1}% of requests", s * 100.0);
    }
    if let Some(out) = args.get("out") {
        trace.save_csv(out).map_err(|e| e.to_string())?;
        println!("written {out}");
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<(), String> {
    let cluster = build_cluster(args)?;
    let ops = if args.flag("empirical") {
        loraserve::sim::profile::empirical_operating_points(
            &cluster.server,
            &loraserve::workload::RANK_CLASSES,
            cluster.slo.ttft_p95,
        )
    } else {
        loraserve::costmodel::operating_points(
            &cluster.server,
            &loraserve::workload::RANK_CLASSES,
        )
    };
    let mut table = Table::new(
        &format!(
            "operating points — {} TP{}",
            cluster.server.model.name, cluster.server.tp
        ),
        &["rank", "tokens/s under SLO"],
    );
    for (rank, tps) in ops {
        table.row(vec![rank.to_string(), format!("{tps:.0}")]);
    }
    println!("{}", table.to_markdown());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    // thin wrapper over the E2E example path
    let n_servers = args.get_usize("servers", 2)?;
    let n_requests = args.get_usize("requests", 40)?;
    let duration = args.get_f64("duration", 15.0)?;
    let seed = args.get_u64("seed", 0)?;
    let system = parse_system(args.get_or("system", "loraserve"))?;
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let mut cluster = loraserve::server::RealCluster::start(
        loraserve::server::RealClusterConfig {
            n_servers,
            artifacts_dir: dir,
            system,
            rebalance_period: duration / 4.0,
            seed,
        },
    )
    .map_err(|e| format!("{e:#}"))?;
    let ranks: Vec<u32> =
        cluster.adapters.iter().map(|a| a.rank).collect();
    let mut rng = loraserve::util::rng::Pcg32::with_stream(seed, 0x5e);
    let workload: Vec<loraserve::server::cluster::TimedRequest> = (0
        ..n_requests)
        .map(|i| {
            let plen = 8 + rng.below(24) as usize;
            loraserve::server::cluster::TimedRequest {
                at: duration * i as f64 / n_requests as f64,
                adapter: rng.below(ranks.len() as u64) as u32,
                prompt: (0..plen)
                    .map(|_| 1 + rng.below(500) as i32)
                    .collect(),
                output_len: 4 + rng.below(8) as usize,
            }
        })
        .collect();
    let rep = cluster.run(&workload).map_err(|e| format!("{e:#}"))?;
    cluster.shutdown();
    let mut ttft = rep.ttft.clone();
    let mut tbt = rep.tbt.clone();
    println!(
        "{}: {} completed, {:.2} req/s, ttft p95 {}, tbt p50 {}",
        rep.system,
        rep.completed,
        rep.throughput_rps(),
        fmt_secs(ttft.p95()),
        fmt_secs(tbt.p50()),
    );
    Ok(())
}
