//! LoRAServe CLI — the cluster launcher and experiment driver.
//!
//! Subcommands:
//!   figures   regenerate paper tables/figures (`--all` or `--fig N`)
//!   simulate  run one trace × system on the DES cluster
//!   autoscale search the minimum fleet meeting an SLO and replay the
//!             trace under the SLO-aware autoscaler (fleet timeline)
//!   bench     run the canonical large-fleet DES benchmark sequential
//!             vs sharded and write BENCH_sim.json (events/sec,
//!             wall-clock, peak RSS, speedup) — CI tracks this against
//!             the committed baseline
//!   trace     synthesize + characterize traces (writes CSV)
//!   trace-check  validate a Chrome trace export (spans nest, async
//!             begin/end balanced) — the CI smoke runs this on the
//!             artifacts `simulate --trace-out` emits
//!   profile   print operating points for a server config
//!   serve     run the real PJRT mini-cluster on a synthetic workload
//!             (needs the `pjrt` feature)

use loraserve::autoscale::{plan_min_fleet, SloMetric, SloSpec};
use loraserve::config::ClusterConfig;
use loraserve::figures::{self, FigOpts};
use loraserve::sim::{self, SystemKind};
use loraserve::trace::{azure, production, Trace};
use loraserve::util::cli::Args;
use loraserve::util::table::{fmt_bytes, fmt_secs, Table};

fn main() {
    // Demo custom-system registration: any placer registered by name
    // here resolves from `--system <name>` through the same
    // composition seam the canned systems use.
    sim::register_custom_system("round-robin", |_seed| {
        Box::new(loraserve::placement::baselines::RoundRobinPlacer::new())
    });
    let args = match Args::from_env(&["all", "fast", "help", "empirical"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.subcommand().is_none() {
        usage();
        return;
    }
    let result = match args.subcommand().unwrap() {
        "figures" => cmd_figures(&args),
        "simulate" => cmd_simulate(&args),
        "bench" => cmd_bench(&args),
        "autoscale" => cmd_autoscale(&args),
        "trace" => cmd_trace(&args),
        "trace-check" => cmd_trace_check(&args),
        "profile" => cmd_profile(&args),
        "serve" => cmd_serve(&args),
        other => {
            eprintln!("unknown subcommand '{other}'");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    println!(
        "loraserve — rank-aware LoRA adapter placement & routing \
         (paper reproduction)\n\n\
         USAGE: loraserve <subcommand> [options]\n\n\
         figures  --all | --fig <id>   [--fast] [--seed S]\n\
         simulate --system <loraserve|slora-random|slora-contiguous|\
         toppings|round-robin>\n         \
         [--trace prod|shifting|uniform] [--rps R] [--servers N]\n         \
         [--adapters N] [--duration S] [--seed S] [--config file.json]\n         \
         [--batch-policy fifo|rank-bucketed[:W]|rank-bucketed-cost[:W]|\
         rank-cap[:F]]\n         \
         [--decode-policy unified|rank-partitioned|class-subbatch[:G]|\
         class-subbatch:auto]\n         \
         [--slo-ttft-ms MS] [--slo-tbt-ms MS] [--preempt-decode on|off]\n         \
         [--rebalance-mode periodic|triggered|hybrid] \
         [--remote-attach on|off]\n         \
         [--scenario file.json]  (churn/diurnal trace + failure \
         injection + regions)\n         \
         [--hbm-pages N] [--evict-policy lru|rank-weighted|slo-aware]\n         \
         [--shards N] [--report-out file.json]\n         \
         [--trace-out trace.json] [--trace-last N] \
         [--metrics-out file.prom]\n\
         bench    [--scenario full|ci|control|memory] [--servers N] \
         [--shards N] [--seed S]\n         \
         [--out BENCH_sim.json]\n\
         autoscale [--system <kind>|--all] [--slo-ttft MS] \
         [--slo-e2e MS]\n         \
         [--metric ttft|e2e] [--percentile P] [--max-servers N]\n         \
         [--trace prod|shifting|uniform] [--rps R] [--duration S]\n         \
         [--adapters N] [--seed S] [--batch-policy P]\n\
         trace    --kind prod|azure [--adapters N] [--out file.csv]\n\
         trace-check <trace.json>\n\
         profile  [--model 7b|13b|30b|70b] [--tp N]\n\
         serve    [--servers N] [--requests N] [--duration S]   \
         (feature pjrt)"
    );
}

/// A `--system` argument: one of the four canned kinds, or the name of
/// a placer registered with `sim::register_custom_system`.
enum SystemChoice {
    Canned(SystemKind),
    Custom(String),
}

impl SystemChoice {
    fn canned(&self) -> Result<SystemKind, String> {
        match self {
            SystemChoice::Canned(k) => Ok(*k),
            SystemChoice::Custom(name) => Err(format!(
                "custom system '{name}' is only supported by \
                 `simulate` (the capacity planner needs a canned kind)"
            )),
        }
    }
}

fn parse_system(s: &str) -> Result<SystemChoice, String> {
    match s {
        "loraserve" => Ok(SystemChoice::Canned(SystemKind::LoraServe)),
        "slora-random" | "random" => {
            Ok(SystemChoice::Canned(SystemKind::SLoraRandom))
        }
        "slora-contiguous" | "contiguous" => {
            Ok(SystemChoice::Canned(SystemKind::SLoraContiguous))
        }
        "toppings" => Ok(SystemChoice::Canned(SystemKind::Toppings)),
        other => {
            let registered = sim::registered_custom_systems();
            if registered.iter().any(|&n| n == other) {
                Ok(SystemChoice::Custom(other.to_string()))
            } else {
                Err(format!(
                    "unknown system '{other}' (canned: loraserve | \
                     slora-random | slora-contiguous | toppings; \
                     registered custom: {registered:?})"
                ))
            }
        }
    }
}

fn cmd_figures(args: &Args) -> Result<(), String> {
    let opts = FigOpts {
        fast: args.flag("fast"),
        seed: args.get_u64("seed", 0)?,
    };
    if args.flag("all") {
        figures::run_all(&opts).map_err(|e| e.to_string())
    } else if let Some(id) = args.get("fig") {
        if figures::run_one(id, &opts).map_err(|e| e.to_string())? {
            Ok(())
        } else {
            let ids: Vec<&str> = figures::registry()
                .iter()
                .map(|(id, _, _)| *id)
                .collect();
            Err(format!("unknown figure '{id}'; have {ids:?}"))
        }
    } else {
        println!("available figures:");
        for (id, desc, _) in figures::registry() {
            println!("  {id:10} {desc}");
        }
        Ok(())
    }
}

fn build_cluster(args: &Args) -> Result<ClusterConfig, String> {
    let mut cluster = match args.get("config") {
        Some(path) => ClusterConfig::from_file(path)?,
        None => ClusterConfig::default(),
    };
    cluster.n_servers = args.get_usize("servers", cluster.n_servers)?;
    cluster.seed = args.get_u64("seed", cluster.seed)?;
    if let Some(m) = args.get("model") {
        cluster.server.model = loraserve::config::ModelSpec::by_name(m)
            .ok_or_else(|| format!("unknown model '{m}'"))?;
    }
    cluster.server.tp = args.get_usize("tp", cluster.server.tp)?;
    if let Some(bp) = args.get("batch-policy") {
        cluster.batch_policy =
            loraserve::config::BatchPolicyKind::parse(bp)?;
    }
    if let Some(dp) = args.get("decode-policy") {
        cluster.decode_policy =
            loraserve::config::DecodePolicyKind::parse(dp)?;
    }
    // SLO feedback knobs: setting a target (or switching preemption
    // on) enables the per-server tracker
    if args.get("slo-ttft-ms").is_some() {
        let ms = args.get_f64("slo-ttft-ms", 0.0)?;
        if ms <= 0.0 {
            return Err(format!("--slo-ttft-ms must be > 0, got {ms}"));
        }
        cluster.feedback.ttft_target = ms / 1e3;
        cluster.feedback.enabled = true;
    }
    if args.get("slo-tbt-ms").is_some() {
        let ms = args.get_f64("slo-tbt-ms", 0.0)?;
        if ms <= 0.0 {
            return Err(format!("--slo-tbt-ms must be > 0, got {ms}"));
        }
        cluster.feedback.tbt_target = ms / 1e3;
        cluster.feedback.enabled = true;
    }
    if let Some(p) = args.get("preempt-decode") {
        match p {
            "on" | "true" => {
                cluster.feedback.preempt_decode = true;
                cluster.feedback.enabled = true;
            }
            "off" | "false" => cluster.feedback.preempt_decode = false,
            other => {
                return Err(format!(
                    "--preempt-decode takes on|off, got '{other}'"
                ))
            }
        }
    }
    // drift-reactive rebalancing knobs (JSON carries the trigger
    // thresholds; the CLI flips the mode and the remote-attach pool
    // behavior)
    if let Some(m) = args.get("rebalance-mode") {
        cluster.rebalance.mode =
            loraserve::config::RebalanceMode::parse(m)?;
    }
    if let Some(r) = args.get("remote-attach") {
        match r {
            "on" | "true" => cluster.rebalance.remote_attach = true,
            "off" | "false" => cluster.rebalance.remote_attach = false,
            other => {
                return Err(format!(
                    "--remote-attach takes on|off, got '{other}'"
                ))
            }
        }
    }
    // unified HBM economy knobs: a page budget bounds the pool (0 =
    // unbounded, the pre-refactor behavior bit for bit); the eviction
    // policy only matters once bounded
    cluster.server.hbm_pages =
        args.get_usize("hbm-pages", cluster.server.hbm_pages)?;
    if let Some(p) = args.get("evict-policy") {
        cluster.server.evict_policy =
            loraserve::pool::hbm::EvictPolicy::parse(p).ok_or_else(
                || {
                    format!(
                        "unknown evict policy '{p}' \
                         (lru | rank-weighted | slo-aware)"
                    )
                },
            )?;
    }
    Ok(cluster)
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let choice = parse_system(args.get_or("system", "loraserve"))?;
    let cluster = build_cluster(args)?;
    let rps = args.get_f64("rps", 16.0)?;
    let duration = args.get_f64("duration", 600.0)?;
    let n_adapters = args.get_usize("adapters", 100)?;
    let seed = args.get_u64("seed", 0)?;
    // --scenario file.json: failure-injection + region runtime knobs,
    // plus (optionally) a generated churn/diurnal production trace
    // that replaces the --trace choice
    let scenario = match args.get("scenario") {
        Some(path) => Some(sim::scenario::Scenario::from_file(path)?),
        None => None,
    };
    let runtime = scenario
        .as_ref()
        .map(|s| s.runtime)
        .unwrap_or_default();
    let kind = args.get_or("trace", "prod");
    let trace = if let Some(tc) =
        scenario.as_ref().and_then(|s| s.trace.as_ref())
    {
        // an explicit --seed overrides the file's (so CI can run the
        // same scenario file under several seeds)
        let mut tc = tc.clone();
        if args.get("seed").is_some() {
            tc.seed = seed;
        }
        loraserve::trace::scenario::generate(&tc)
    } else {
        match kind {
        "prod" => production::generate(&production::ProductionConfig {
            n_adapters,
            n_requests: (rps * duration) as usize,
            duration,
            seed,
            ..Default::default()
        }),
        "shifting" => azure::generate(&azure::AzureConfig {
            popularity: azure::RankPopularity::ShiftingSkew,
            rps,
            duration,
            seed,
            ..Default::default()
        }),
        "uniform" => azure::generate(&azure::AzureConfig {
            rps,
            duration,
            seed,
            ..Default::default()
        }),
        "skew" => loraserve::figures::sensitivity::skew_trace(
            args.get_f64("alpha", 1.0)?,
            rps,
            duration,
            seed,
        ),
        other => return Err(format!("unknown trace kind '{other}'")),
        }
    };
    // observability knobs — all default off so the plain path stays
    // bit-identical (see tests/obs_tracing.rs)
    let mut obs_cfg = loraserve::obs::ObsConfig::default();
    if args.get("trace-out").is_some() {
        obs_cfg.trace = true;
        // tracing implies the latency decomposition: the trace and the
        // attribution table explain the same run
        obs_cfg.attrib = true;
    }
    if args.get("trace-last").is_some() {
        if !obs_cfg.trace {
            return Err("--trace-last needs --trace-out".into());
        }
        let n = args.get_usize("trace-last", 0)?;
        if n == 0 {
            return Err("--trace-last must be > 0".into());
        }
        obs_cfg.trace_last = Some(n);
    }
    if args.get("metrics-out").is_some() {
        obs_cfg.metrics = true;
    }
    // sharded event loop: any value yields the byte-identical report
    // digest (epoch-barrier determinism contract; the CI gate compares
    // a --shards 4 run against a sequential one)
    let shards = args.get_usize("shards", 1)?;
    let label = match &choice {
        SystemChoice::Canned(k) => k.label().to_string(),
        SystemChoice::Custom(name) => name.clone(),
    };
    println!(
        "simulating {} on '{}' ({} reqs, {:.1} rps, {} servers)",
        label,
        trace.name,
        trace.requests.len(),
        trace.mean_rps(),
        cluster.n_servers
    );
    let t0 = std::time::Instant::now();
    let (mut rep, obs_out) = match &choice {
        SystemChoice::Canned(k) => sim::run_observed(
            &trace,
            &sim::SimConfig::new(cluster.clone(), *k)
                .with_shards(shards)
                .with_obs(obs_cfg)
                .with_params(|p| p.scenario(runtime)),
        ),
        SystemChoice::Custom(name) => {
            // the canned kind inside SimConfig is unused by run_spec;
            // it only carries the cluster/warmup knobs
            let cfg = sim::SimConfig::new(
                cluster.clone(),
                SystemKind::LoraServe,
            )
            .with_shards(shards)
            .with_obs(obs_cfg)
            .with_params(|p| p.scenario(runtime));
            let spec = sim::custom_system_spec(
                name,
                &sim::SpecParams::from_config(&cfg),
            )
            .ok_or_else(|| {
                format!("custom system '{name}' not registered")
            })?;
            sim::run_spec_observed(&trace, &cfg, &spec)
        }
    };
    let wall = t0.elapsed().as_secs_f64();
    let mut table = Table::new("simulation report", &["metric", "value"]);
    let meets = rep.meets_slo(cluster.slo.ttft_p95);
    let rows: Vec<(&str, String)> = vec![
        ("completed", rep.completed.to_string()),
        ("timeouts", rep.timeouts.to_string()),
        ("throughput", format!("{:.2} req/s", rep.throughput_rps())),
        ("ttft p50", fmt_secs(rep.ttft.p50())),
        ("ttft p95", fmt_secs(rep.ttft_p95())),
        ("tbt p50", fmt_secs(rep.tbt.p50())),
        ("tbt p95", fmt_secs(rep.tbt_p95())),
        ("meets slo", meets.to_string()),
        ("batch policy", rep.batch_policy.clone()),
        ("decode policy", rep.decode_policy.clone()),
        (
            "hi-rank iter share",
            format!("{:.1}%", rep.highrank_iter_share() * 100.0),
        ),
        (
            "mixed prefill share",
            format!("{:.1}%", rep.mixed_prefill_share() * 100.0),
        ),
        (
            "hi-rank decode share",
            format!("{:.1}%", rep.highrank_decode_share() * 100.0),
        ),
        (
            "mixed decode share",
            format!("{:.1}%", rep.mixed_decode_share() * 100.0),
        ),
        ("decode pad (rank·tok)", rep.decode_pad_rank.to_string()),
        ("decode preemptions", rep.decode_preemptions.to_string()),
        (
            "ttft-under-pressure p99",
            fmt_secs(rep.ttft_under_pressure_p99()),
        ),
        ("rebalances", rep.rebalances.to_string()),
        (
            "rebalance mode",
            cluster.rebalance.mode.label().to_string(),
        ),
        (
            "triggered rebalances",
            rep.triggered_rebalances.to_string(),
        ),
        ("incremental moves", rep.incremental_moves.to_string()),
        ("rejected moves", rep.rejected_moves.to_string()),
        ("remote served", rep.remote_served.to_string()),
        ("remote promotions", rep.promotions.to_string()),
        ("migrated", fmt_bytes(rep.migration_bytes)),
        ("fetches", rep.fetches.to_string()),
        (
            "max resident adapters",
            rep.per_server_max_adapters
                .iter()
                .max()
                .copied()
                .unwrap_or(0)
                .to_string(),
        ),
        ("sim wall time", format!("{wall:.2}s")),
    ];
    for (k, v) in rows {
        table.row(vec![k.to_string(), v]);
    }
    println!("{}", table.to_markdown());
    for s in 0..cluster.n_servers {
        println!(
            "  server {s}: n={:5} p50={} p95={} busy={:.0}s max_adapters={} hi_frac={:.2}",
            rep.per_server_ttft[s].len(),
            fmt_secs(rep.per_server_ttft[s].p50()),
            fmt_secs(rep.per_server_ttft[s].p95()),
            rep.per_server_busy[s],
            rep.per_server_max_adapters[s],
            rep.per_server_highrank_frac[s],
        );
    }
    // SLO-violation attribution: where the TTFT/E2E time actually went
    // (component means; `recon` = worst |sum − measured| in the cohort)
    if let Some(a) = &rep.attribution {
        let mut at = Table::new(
            "latency attribution (mean per request)",
            &[
                "cohort", "n", "ttft", "queue", "fetch", "prefill",
                "skew", "remote", "decode", "launch", "preempt", "recon",
            ],
        );
        for (name, b) in [
            ("all", &a.all),
            ("ttft violators", &a.violators),
            ("p99 ttft tail", &a.tail),
        ] {
            at.row(vec![
                name.to_string(),
                b.n.to_string(),
                fmt_secs(b.ttft),
                fmt_secs(b.queue_wait),
                fmt_secs(b.fetch_stall),
                fmt_secs(b.prefill_service),
                fmt_secs(b.skew()),
                fmt_secs(b.remote()),
                fmt_secs(b.decode_service),
                fmt_secs(b.decode_launch),
                fmt_secs(b.preempt_delay),
                format!("{:.1e}", b.recon),
            ]);
        }
        println!("{}", at.to_markdown());
    }
    // Deterministic JSON digest of the run (the CI determinism gate
    // runs `simulate` twice and byte-compares exactly this file).
    if let Some(out) = args.get("report-out") {
        write_out(out, &rep.to_json_string())?;
        println!("[report written {out}]");
    }
    // Chrome trace-event export (load in Perfetto / chrome://tracing);
    // same-seed runs of the same build emit byte-identical files.
    if let Some(out) = args.get("trace-out") {
        let json = obs_out.trace_json.as_deref().unwrap_or(
            "{\"traceEvents\":[]}",
        );
        write_out(out, json)?;
        println!("[trace written {out}]");
    }
    // Prometheus text exposition of the end-of-run registry snapshot.
    if let Some(out) = args.get("metrics-out") {
        let text = obs_out.metrics_text.as_deref().unwrap_or("");
        write_out(out, text)?;
        println!("[metrics written {out}]");
    }
    Ok(())
}

/// Peak resident set size of this process in bytes (Linux `VmHWM`
/// from `/proc/self/status`; 0 where procfs is unavailable).
fn peak_rss_bytes() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|kb| kb.parse::<u64>().ok())
        })
        .map(|kb| kb * 1024)
        .unwrap_or(0)
}

/// The canonical DES throughput benchmark: one large-fleet,
/// high-request-count scenario run sequentially and sharded, emitting
/// `BENCH_sim.json` with events/sec, wall-clock, peak RSS, and the
/// sharded speedup. The two runs must produce byte-identical report
/// digests (the epoch-barrier determinism contract) — the bench fails
/// hard if they diverge, so the CI perf step doubles as a determinism
/// check at scale.
fn cmd_bench(args: &Args) -> Result<(), String> {
    use loraserve::util::json::Json;
    let scenario = args.get_or("scenario", "full");
    if scenario == "control" {
        return cmd_bench_control(args);
    }
    // (servers, rps, duration): `full` is the perf-trajectory
    // scenario; `ci` is the same shape scaled down to stay fast on
    // shared runners; `memory` is ci-shaped but runs the bounded
    // unified HBM pool (page accounting, dynamic admission, eviction)
    // so the memory economy's hot paths are benchmarked and
    // digest-checked under sharding. `control` (dispatched above) is
    // the big-fleet coordinator benchmark.
    let (n_servers, rps, duration) = match scenario {
        "full" => (16usize, 240.0, 300.0),
        "ci" | "memory" => (8usize, 80.0, 120.0),
        other => {
            return Err(format!(
                "unknown scenario '{other}' \
                 (full | ci | control | memory)"
            ))
        }
    };
    let seed = args.get_u64("seed", 0)?;
    let host_cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    // default: every core up to the fleet size, but at least the 4
    // shards the perf trajectory is pinned at
    let shards = args
        .get_usize("shards", host_cores.max(4).min(n_servers))?
        .clamp(1, n_servers);
    let trace = azure::generate(&azure::AzureConfig {
        rps,
        duration,
        seed,
        lengths: loraserve::trace::LengthModel::fixed(256, 32),
        ..Default::default()
    });
    let mut cluster = ClusterConfig {
        n_servers,
        rebalance_period: 20.0,
        ..Default::default()
    };
    if scenario == "memory" {
        // constrained unified pool: ~1 GiB of 2 MiB pages per server,
        // tight enough that adapter residency and KV churn contend
        cluster.server.hbm_pages = 512;
        cluster.server.evict_policy =
            loraserve::pool::hbm::EvictPolicy::RankWeighted;
    }
    println!(
        "bench '{scenario}': {} reqs, {:.0} rps, {} servers, \
         {} host cores — sequential vs {} shards",
        trace.requests.len(),
        trace.mean_rps(),
        n_servers,
        host_cores,
        shards,
    );
    let mut runs: Vec<(usize, u64, f64)> = Vec::new();
    let mut digests: Vec<String> = Vec::new();
    for s in [1, shards] {
        let cfg =
            sim::SimConfig::new(cluster.clone(), SystemKind::LoraServe)
                .with_shards(s);
        let t0 = std::time::Instant::now();
        let mut rep = sim::run(&trace, &cfg);
        let wall = t0.elapsed().as_secs_f64();
        let events = rep.events;
        println!(
            "  shards={s}: {events} events in {wall:.3}s \
             ({:.0} events/sec)",
            events as f64 / wall.max(1e-9),
        );
        runs.push((s, events, wall));
        digests.push(rep.to_json_string());
        if s == shards {
            break; // shards == 1: one run is both baseline and result
        }
    }
    if digests.windows(2).any(|w| w[0] != w[1]) {
        return Err(format!(
            "DETERMINISM VIOLATION: shards=1 and shards={shards} \
             report digests differ"
        ));
    }
    let eps = |&(_, events, wall): &(usize, u64, f64)| {
        events as f64 / wall.max(1e-9)
    };
    let seq_eps = eps(&runs[0]);
    let par_eps = eps(runs.last().unwrap());
    let speedup = par_eps / seq_eps.max(1e-9);
    println!(
        "  speedup: {speedup:.2}x events/sec at {} shards",
        runs.last().unwrap().0
    );
    let run_json = |r: &(usize, u64, f64)| {
        Json::obj(vec![
            ("shards", r.0.into()),
            ("events", Json::from(r.1)),
            ("wall_s", Json::Num(r.2)),
            ("events_per_sec", Json::Num(eps(r))),
        ])
    };
    let out_json = Json::obj(vec![
        ("scenario", scenario.into()),
        ("seed", Json::from(seed)),
        ("requests", trace.requests.len().into()),
        ("servers", n_servers.into()),
        ("host_cores", host_cores.into()),
        ("runs", Json::Arr(runs.iter().map(run_json).collect())),
        ("events_per_sec_seq", Json::Num(seq_eps)),
        ("events_per_sec", Json::Num(par_eps)),
        ("speedup", Json::Num(speedup)),
        ("peak_rss_bytes", Json::from(peak_rss_bytes())),
    ]);
    let out = args.get_or("out", "BENCH_sim.json");
    write_out(out, &out_json.to_string())?;
    println!("[bench written {out}]");
    Ok(())
}

/// `bench --scenario control`: the big-fleet control-plane benchmark
/// (≥512 servers by default). Two arms stress the coordinator hot
/// paths the indexed control plane optimizes:
///
/// * `toppings` — least-work routing over the full fleet, which
///   forces an epoch barrier *per arrival*: each request costs one
///   argmin query plus O(due-lanes) flush work instead of the old
///   O(fleet) load scan + O(fleet) lane sweep;
/// * `triggered` — LORASERVE with drift-triggered rebalancing and
///   remote attach over thousands of adapters: every check reads the
///   ring-buffer demand projections and the delta-maintained
///   utilization vector instead of rebuilding BTreeMaps.
///
/// Each arm runs sequential and sharded and must produce
/// byte-identical report digests (the determinism contract at fleet
/// scale). The aggregate events/sec lands in `BENCH_sim.json` under
/// the same top-level keys the CI regression gate reads for the other
/// scenarios.
fn cmd_bench_control(args: &Args) -> Result<(), String> {
    use loraserve::config::{RebalanceConfig, RebalanceMode};
    use loraserve::util::json::Json;
    let n_servers = args.get_usize("servers", 512)?.max(2);
    let seed = args.get_u64("seed", 0)?;
    let host_cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let shards = args
        .get_usize("shards", host_cores.max(4).min(n_servers))?
        .clamp(1, n_servers);

    // Arm 1: per-arrival-barrier least-work routing at fleet width.
    let toppings_trace = azure::generate(&azure::AzureConfig {
        rps: 400.0,
        duration: 120.0,
        seed,
        lengths: loraserve::trace::LengthModel::fixed(256, 32),
        ..Default::default()
    });
    let toppings_cfg = sim::SimConfig::new(
        ClusterConfig {
            n_servers,
            ..Default::default()
        },
        SystemKind::Toppings,
    );

    // Arm 2: reactive control plane over a wide adapter catalog.
    let triggered_trace = azure::generate(&azure::AzureConfig {
        rps: 300.0,
        duration: 120.0,
        seed,
        adapters_per_rank: 400, // 2000 adapters across 5 rank classes
        lengths: loraserve::trace::LengthModel::fixed(256, 32),
        ..Default::default()
    });
    let reb = RebalanceConfig {
        mode: RebalanceMode::Triggered,
        remote_attach: true,
        ..ClusterConfig::default().rebalance
    };
    let triggered_cfg = sim::SimConfig::new(
        ClusterConfig {
            n_servers,
            rebalance_period: 30.0,
            ..Default::default()
        },
        SystemKind::LoraServe,
    )
    .with_params(|p| p.rebalance(reb));

    let arms: Vec<(&str, &Trace, sim::SimConfig)> = vec![
        ("toppings", &toppings_trace, toppings_cfg),
        ("triggered", &triggered_trace, triggered_cfg),
    ];
    println!(
        "bench 'control': {n_servers} servers, {} host cores — \
         sequential vs {shards} shards per arm",
        host_cores,
    );
    let mut arm_jsons: Vec<Json> = Vec::new();
    let mut seq_events = 0u64;
    let mut seq_wall = 0.0f64;
    let mut par_events = 0u64;
    let mut par_wall = 0.0f64;
    for (name, trace, cfg) in arms {
        let mut runs: Vec<(usize, u64, f64)> = Vec::new();
        let mut digests: Vec<String> = Vec::new();
        for s in [1, shards] {
            let cfg = cfg.clone().with_shards(s);
            let t0 = std::time::Instant::now();
            let mut rep = sim::run(trace, &cfg);
            let wall = t0.elapsed().as_secs_f64();
            println!(
                "  {name} shards={s}: {} events in {wall:.3}s \
                 ({:.0} events/sec)",
                rep.events,
                rep.events as f64 / wall.max(1e-9),
            );
            runs.push((s, rep.events, wall));
            digests.push(rep.to_json_string());
            if s == shards {
                break;
            }
        }
        if digests.windows(2).any(|w| w[0] != w[1]) {
            return Err(format!(
                "DETERMINISM VIOLATION: arm '{name}' digests \
                 differ between shards=1 and shards={shards}"
            ));
        }
        let (_, se, sw) = runs[0];
        let &(_, pe, pw) = runs.last().unwrap();
        seq_events += se;
        seq_wall += sw;
        par_events += pe;
        par_wall += pw;
        arm_jsons.push(Json::obj(vec![
            ("arm", name.into()),
            ("requests", trace.requests.len().into()),
            (
                "runs",
                Json::Arr(
                    runs.iter()
                        .map(|&(s, e, w)| {
                            Json::obj(vec![
                                ("shards", s.into()),
                                ("events", Json::from(e)),
                                ("wall_s", Json::Num(w)),
                                (
                                    "events_per_sec",
                                    Json::Num(
                                        e as f64 / w.max(1e-9),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "events_per_sec",
                Json::Num(pe as f64 / pw.max(1e-9)),
            ),
        ]));
    }
    let seq_eps = seq_events as f64 / seq_wall.max(1e-9);
    let par_eps = par_events as f64 / par_wall.max(1e-9);
    let speedup = par_eps / seq_eps.max(1e-9);
    println!(
        "  aggregate: {par_eps:.0} events/sec sharded \
         ({speedup:.2}x over sequential)"
    );
    let out_json = Json::obj(vec![
        ("scenario", "control".into()),
        ("seed", Json::from(seed)),
        ("servers", n_servers.into()),
        ("host_cores", host_cores.into()),
        ("arms", Json::Arr(arm_jsons)),
        ("events_per_sec_seq", Json::Num(seq_eps)),
        ("events_per_sec", Json::Num(par_eps)),
        ("speedup", Json::Num(speedup)),
        ("peak_rss_bytes", Json::from(peak_rss_bytes())),
    ]);
    let out = args.get_or("out", "BENCH_sim.json");
    write_out(out, &out_json.to_string())?;
    println!("[bench written {out}]");
    Ok(())
}

/// Write `contents` to `path`, creating parent directories.
fn write_out(path: &str, contents: &str) -> Result<(), String> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| format!("{path}: {e}"))?;
        }
    }
    std::fs::write(path, contents).map_err(|e| format!("{path}: {e}"))
}

/// Validate a Chrome trace export (CI runs this on the `--trace-out`
/// artifact): parses, complete spans nest per track, async begin/end
/// balanced per `(cat, id)`.
fn cmd_trace_check(args: &Args) -> Result<(), String> {
    let path = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .or_else(|| args.get("file"))
        .ok_or("usage: loraserve trace-check <trace.json>")?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{path}: {e}"))?;
    loraserve::obs::check_spans_nest(&text)
        .map_err(|e| format!("{path}: {e}"))?;
    let n = loraserve::util::json::parse(&text)?
        .get("traceEvents")
        .and_then(|e| e.as_arr().map(|a| a.len()))
        .unwrap_or(0);
    println!("{path}: OK ({n} events; spans nest, async balanced)");
    Ok(())
}

/// Capacity planning + elastic replay: search the minimum fleet per
/// system meeting the configured SLO percentile, then run the trace
/// under the SLO-aware autoscaler and report the fleet-size timeline
/// with GPU-seconds accounting.
fn cmd_autoscale(args: &Args) -> Result<(), String> {
    let mut cluster = build_cluster(args)?;
    // SLO knobs arrive in milliseconds on the CLI, seconds internally
    let ttft_ms = args.get_f64("slo-ttft", cluster.slo.ttft_p95 * 1e3)?;
    cluster.slo.ttft_p95 = ttft_ms / 1e3;
    if args.get("slo-e2e").is_some() {
        cluster.slo.e2e_p95 = args.get_f64("slo-e2e", 0.0)? / 1e3;
    }
    let percentile = args.get_f64("percentile", 95.0)?;
    let metric = match args.get_or("metric", "ttft") {
        "ttft" => SloMetric::Ttft,
        "e2e" => SloMetric::E2e,
        other => return Err(format!("unknown metric '{other}'")),
    };
    let threshold = match metric {
        SloMetric::Ttft => cluster.slo.ttft_p95,
        SloMetric::E2e => {
            if !cluster.slo.e2e_p95.is_finite() {
                return Err("--metric e2e needs --slo-e2e <ms>".into());
            }
            cluster.slo.e2e_p95
        }
    };
    let spec = SloSpec {
        metric,
        percentile,
        threshold,
    };
    let max_servers = args.get_usize("max-servers", 12)?;
    let rps = args.get_f64("rps", 24.0)?;
    let duration = args.get_f64("duration", 600.0)?;
    let n_adapters = args.get_usize("adapters", 100)?;
    let seed = args.get_u64("seed", cluster.seed)?;
    let trace: Trace = match args.get_or("trace", "prod") {
        "prod" => production::generate(&production::ProductionConfig {
            n_adapters,
            n_requests: (rps * duration) as usize,
            duration,
            seed,
            ..Default::default()
        })
        .scale_to_rps(rps),
        "shifting" => azure::generate(&azure::AzureConfig {
            popularity: azure::RankPopularity::ShiftingSkew,
            rps,
            duration,
            seed,
            ..Default::default()
        }),
        "uniform" => azure::generate(&azure::AzureConfig {
            rps,
            duration,
            seed,
            ..Default::default()
        }),
        other => return Err(format!("unknown trace kind '{other}'")),
    };
    let systems: Vec<SystemKind> =
        if args.flag("all") || args.get("system") == Some("all") {
            SystemKind::all().to_vec()
        } else {
            vec![parse_system(args.get_or("system", "loraserve"))?
                .canned()?]
        };
    println!(
        "capacity planning on '{}' ({} reqs, {:.1} rps): {} p{:.0} ≤ {} \
         over ≤{} servers",
        trace.name,
        trace.requests.len(),
        trace.mean_rps(),
        match metric {
            SloMetric::Ttft => "ttft",
            SloMetric::E2e => "e2e",
        },
        percentile,
        fmt_secs(threshold),
        max_servers,
    );
    let mut table = Table::new(
        "minimum fleet meeting the SLO",
        &["system", "min servers", "gpus", "observed", "sims"],
    );
    let mut plans = Vec::new();
    for &system in &systems {
        let plan =
            plan_min_fleet(&trace, &cluster, system, &spec, max_servers);
        table.row(vec![
            system.label().to_string(),
            plan.min_servers
                .map(|n| n.to_string())
                .unwrap_or_else(|| format!(">{max_servers}")),
            plan.gpus(cluster.server.tp)
                .map(|g| g.to_string())
                .unwrap_or_else(|| "-".into()),
            plan.observed_at_min()
                .map(fmt_secs)
                .unwrap_or_else(|| "-".into()),
            plan.probes.len().to_string(),
        ]);
        plans.push(plan);
    }
    println!("{}", table.to_markdown());
    if plans.len() > 1 {
        let ls = plans
            .iter()
            .find(|p| p.system == SystemKind::LoraServe)
            .and_then(|p| p.min_servers);
        let best_baseline = plans
            .iter()
            .filter(|p| p.system != SystemKind::LoraServe)
            .filter_map(|p| p.min_servers)
            .min();
        if let (Some(a), Some(b)) = (ls, best_baseline) {
            println!(
                "loraserve {a} servers vs best baseline {b} \
                 ({:.0}% fewer GPUs)\n",
                (1.0 - a as f64 / b as f64) * 100.0
            );
        }
    }

    // ---- elastic replay: fleet-size-over-time under the autoscaler
    let primary = systems[0];
    let start = plans[0].min_servers.unwrap_or(1).min(max_servers);
    let mut acfg = cluster.autoscale;
    acfg.max_servers = max_servers;
    acfg.min_servers = acfg.min_servers.clamp(1, max_servers);
    let mut elastic = cluster.clone();
    elastic.n_servers = start;
    let mut rep = sim::run(
        &trace,
        &sim::SimConfig::new(elastic, primary).with_autoscale(acfg),
    );
    let ttft_p95 = rep.ttft_p95();
    println!(
        "fleet timeline ({}, start {start} servers, autoscaler on):",
        primary.label()
    );
    for &(t, n) in rep.fleet.timeline.iter().take(50) {
        println!("  t={t:8.1}s  active={n}");
    }
    if rep.fleet.timeline.len() > 50 {
        println!("  ... {} more changes", rep.fleet.timeline.len() - 50);
    }
    let mut summary = Table::new(
        "elastic replay summary",
        &["metric", "value"],
    );
    let rows: Vec<(&str, String)> = vec![
        ("completed", rep.completed.to_string()),
        ("timeouts", rep.timeouts.to_string()),
        ("ttft p95", fmt_secs(ttft_p95)),
        ("slo violation rate", format!("{:.4}", rep.fleet.violation_rate())),
        ("scale-ups", rep.fleet.scale_ups.to_string()),
        ("scale-downs", rep.fleet.scale_downs.to_string()),
        ("peak fleet", rep.fleet.peak_servers().to_string()),
        ("mean fleet", format!("{:.2}", rep.fleet.mean_fleet())),
        ("gpu-seconds", format!("{:.0}", rep.fleet.gpu_seconds)),
        ("migrated", fmt_bytes(rep.migration_bytes)),
    ];
    for (k, v) in rows {
        summary.row(vec![k.to_string(), v]);
    }
    println!("{}", summary.to_markdown());
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<(), String> {
    let kind = args.get_or("kind", "prod");
    let n_adapters = args.get_usize("adapters", 100)?;
    let seed = args.get_u64("seed", 0)?;
    let trace = match kind {
        "prod" => production::generate(&production::ProductionConfig {
            n_adapters,
            seed,
            ..Default::default()
        }),
        "azure" => azure::generate(&azure::AzureConfig {
            seed,
            ..Default::default()
        }),
        other => return Err(format!("unknown kind '{other}'")),
    };
    println!(
        "trace '{}': {} requests over {:.0}s, {} adapters",
        trace.name,
        trace.requests.len(),
        trace.duration(),
        trace.adapters.len()
    );
    let shares =
        loraserve::trace::characterize::rank_request_shares(&trace);
    for (rank, s) in shares {
        println!("  rank {rank:3}: {:.1}% of requests", s * 100.0);
    }
    if let Some(out) = args.get("out") {
        trace.save_csv(out).map_err(|e| e.to_string())?;
        println!("written {out}");
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<(), String> {
    let cluster = build_cluster(args)?;
    let ops = if args.flag("empirical") {
        loraserve::sim::profile::empirical_operating_points(
            &cluster.server,
            &loraserve::workload::RANK_CLASSES,
            cluster.slo.ttft_p95,
        )
    } else {
        loraserve::costmodel::operating_points(
            &cluster.server,
            &loraserve::workload::RANK_CLASSES,
        )
    };
    let mut table = Table::new(
        &format!(
            "operating points — {} TP{}",
            cluster.server.model.name, cluster.server.tp
        ),
        &["rank", "tokens/s under SLO"],
    );
    for (rank, tps) in ops {
        table.row(vec![rank.to_string(), format!("{tps:.0}")]);
    }
    println!("{}", table.to_markdown());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &Args) -> Result<(), String> {
    Err("the `serve` subcommand needs the real PJRT mini-cluster; \
         rebuild with `--features pjrt` in an environment that \
         provides the vendored `xla` dependency closure"
        .into())
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args) -> Result<(), String> {
    // thin wrapper over the E2E example path
    let n_servers = args.get_usize("servers", 2)?;
    let n_requests = args.get_usize("requests", 40)?;
    let duration = args.get_f64("duration", 15.0)?;
    let seed = args.get_u64("seed", 0)?;
    let system =
        parse_system(args.get_or("system", "loraserve"))?.canned()?;
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let mut cluster = loraserve::server::RealCluster::start(
        loraserve::server::RealClusterConfig {
            n_servers,
            artifacts_dir: dir,
            system,
            rebalance_period: duration / 4.0,
            seed,
        },
    )
    .map_err(|e| format!("{e:#}"))?;
    let ranks: Vec<u32> =
        cluster.adapters.iter().map(|a| a.rank).collect();
    let mut rng = loraserve::util::rng::Pcg32::with_stream(seed, 0x5e);
    let workload: Vec<loraserve::server::cluster::TimedRequest> = (0
        ..n_requests)
        .map(|i| {
            let plen = 8 + rng.below(24) as usize;
            loraserve::server::cluster::TimedRequest {
                at: duration * i as f64 / n_requests as f64,
                adapter: rng.below(ranks.len() as u64) as u32,
                prompt: (0..plen)
                    .map(|_| 1 + rng.below(500) as i32)
                    .collect(),
                output_len: 4 + rng.below(8) as usize,
            }
        })
        .collect();
    let rep = cluster.run(&workload).map_err(|e| format!("{e:#}"))?;
    cluster.shutdown();
    let mut ttft = rep.ttft.clone();
    let mut tbt = rep.tbt.clone();
    println!(
        "{}: {} completed, {:.2} req/s, ttft p95 {}, tbt p50 {}",
        rep.system,
        rep.completed,
        rep.throughput_rps(),
        fmt_secs(ttft.p95()),
        fmt_secs(tbt.p50()),
    );
    Ok(())
}
