//! Composable production-scenario trace generator.
//!
//! The `production` module reproduces the paper's five-adapter drift
//! shapes; this one synthesizes the operational stressors the paper's
//! fleet sees but its figures never isolate (PAPERS.md: S-LoRA-scale
//! adapter counts, CaraServe-style constant adapter churn):
//!
//! * **Tenant lifecycle churn** — every adapter gets a `[birth, death)`
//!   window. A `resident_frac` slice of the fleet is live from t = 0;
//!   the rest are created over the run and deleted after an
//!   exponentially distributed lifetime. Traffic only targets live
//!   tenants, so demand continuously shifts onto newly created (cold)
//!   adapters and away from deleted ones.
//! * **Zipf popularity** — request traffic across live adapters follows
//!   a Zipf(`zipf_alpha`) law over a seed-shuffled popularity order, so
//!   popularity is uncorrelated with rank class or adapter id.
//! * **Diurnal tide** — the aggregate arrival rate is modulated by
//!   `1 + amplitude * sin(...)` with `diurnal_cycles` full cycles over
//!   the trace, trough-first so the run opens calm and crests mid-way.
//!
//! Arrivals are per-minute Poisson-thinned like `production::generate`,
//! normalized so the expected request total is `rps * duration`.
//! Everything is driven by one dedicated RNG stream: same seed, same
//! trace, byte for byte.

use crate::config::ModelSpec;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::workload::{AdapterSet, RANK_CLASSES};

use super::{LengthModel, Request, Trace};

/// RNG stream tag for scenario traces (disjoint from the production
/// trace's 0x9d0d and the engine's 0x51).
const SCENARIO_STREAM: u64 = 0x5ce7a;

/// Knobs for the churn + diurnal scenario trace. All fields have inert
/// middle-of-the-road defaults; `from_json` overlays a `--scenario`
/// file's `"trace"` section on top of them.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioTraceConfig {
    pub n_adapters: usize,
    /// Mean offered request rate; the diurnal tide modulates around it.
    pub rps: f64,
    pub duration: f64,
    /// Zipf exponent of the traffic split across live adapters.
    pub zipf_alpha: f64,
    /// Power-law exponent over adapter *counts* per rank class
    /// (mirrors `ProductionConfig::alpha`).
    pub alpha_counts: f64,
    /// Fraction of adapters live at t = 0 (the "resident" tenants);
    /// the remainder churn in over the run.
    pub resident_frac: f64,
    /// Mean tenant lifetime (s) for churned-in adapters; exponential.
    pub mean_lifetime: f64,
    /// Diurnal modulation depth in [0, 1): rate swings between
    /// `rps * (1 ± amplitude)`.
    pub diurnal_amplitude: f64,
    /// Full day/night cycles across the trace duration.
    pub diurnal_cycles: f64,
    pub lengths: LengthModel,
    pub model: ModelSpec,
    pub seed: u64,
}

impl Default for ScenarioTraceConfig {
    fn default() -> Self {
        ScenarioTraceConfig {
            n_adapters: 64,
            rps: 30.0,
            duration: 600.0,
            zipf_alpha: 1.2,
            alpha_counts: 1.0,
            resident_frac: 0.5,
            mean_lifetime: 300.0,
            diurnal_amplitude: 0.6,
            diurnal_cycles: 2.0,
            lengths: LengthModel::default(),
            model: ModelSpec::LLAMA_7B,
            seed: 0,
        }
    }
}

impl ScenarioTraceConfig {
    /// Overlay JSON knobs on the defaults; unknown keys are rejected
    /// upstream by `sim::scenario::Scenario::from_json`'s schema, so
    /// this only validates ranges.
    pub fn from_json(v: &Json) -> Result<ScenarioTraceConfig, String> {
        let mut cfg = ScenarioTraceConfig::default();
        if let Some(n) = v.get("n_adapters").and_then(Json::as_usize) {
            if n < RANK_CLASSES.len() {
                return Err(format!(
                    "trace.n_adapters must be >= {} (one per rank \
                     class), got {n}",
                    RANK_CLASSES.len()
                ));
            }
            cfg.n_adapters = n;
        }
        if let Some(x) = v.get("rps").and_then(Json::as_f64) {
            if x <= 0.0 {
                return Err(format!("trace.rps must be > 0, got {x}"));
            }
            cfg.rps = x;
        }
        if let Some(x) = v.get("duration").and_then(Json::as_f64) {
            if x <= 0.0 {
                return Err(format!(
                    "trace.duration must be > 0, got {x}"
                ));
            }
            cfg.duration = x;
        }
        if let Some(x) = v.get("zipf_alpha").and_then(Json::as_f64) {
            cfg.zipf_alpha = x.max(0.0);
        }
        if let Some(x) = v.get("alpha_counts").and_then(Json::as_f64) {
            cfg.alpha_counts = x.max(0.0);
        }
        if let Some(x) = v.get("resident_frac").and_then(Json::as_f64) {
            if !(0.0..=1.0).contains(&x) {
                return Err(format!(
                    "trace.resident_frac must be in [0, 1], got {x}"
                ));
            }
            cfg.resident_frac = x;
        }
        if let Some(x) = v.get("mean_lifetime").and_then(Json::as_f64) {
            if x <= 0.0 {
                return Err(format!(
                    "trace.mean_lifetime must be > 0, got {x}"
                ));
            }
            cfg.mean_lifetime = x;
        }
        if let Some(x) =
            v.get("diurnal_amplitude").and_then(Json::as_f64)
        {
            if !(0.0..1.0).contains(&x) {
                return Err(format!(
                    "trace.diurnal_amplitude must be in [0, 1), got {x}"
                ));
            }
            cfg.diurnal_amplitude = x;
        }
        if let Some(x) = v.get("diurnal_cycles").and_then(Json::as_f64) {
            cfg.diurnal_cycles = x.max(0.0);
        }
        if let Some(name) = v.get("model").and_then(Json::as_str) {
            cfg.model = ModelSpec::by_name(name)
                .ok_or_else(|| format!("unknown model '{name}'"))?;
        }
        // Request-length knobs (the KV-footprint axis of the unified
        // HBM economy): lognormal medians and spreads plus hard caps,
        // overlaying `LengthModel::default`. Means are medians of the
        // lognormal (mu = ln(median)), matching how the default model
        // is quoted. Draw order in `generate` is untouched, so traces
        // without these keys stay byte-identical.
        if let Some(x) = v.get("prompt_mean").and_then(Json::as_f64) {
            if x < 1.0 {
                return Err(format!(
                    "trace.prompt_mean must be >= 1, got {x}"
                ));
            }
            cfg.lengths.prompt_mu = x.ln();
        }
        if let Some(x) = v.get("prompt_sigma").and_then(Json::as_f64) {
            if x < 0.0 {
                return Err(format!(
                    "trace.prompt_sigma must be >= 0, got {x}"
                ));
            }
            cfg.lengths.prompt_sigma = x;
        }
        if let Some(n) = v.get("max_prompt").and_then(Json::as_usize) {
            if n == 0 {
                return Err("trace.max_prompt must be > 0".into());
            }
            cfg.lengths.max_prompt = n as u32;
        }
        if let Some(x) = v.get("output_mean").and_then(Json::as_f64) {
            if x < 1.0 {
                return Err(format!(
                    "trace.output_mean must be >= 1, got {x}"
                ));
            }
            cfg.lengths.output_mu = x.ln();
        }
        if let Some(x) = v.get("output_sigma").and_then(Json::as_f64) {
            if x < 0.0 {
                return Err(format!(
                    "trace.output_sigma must be >= 0, got {x}"
                ));
            }
            cfg.lengths.output_sigma = x;
        }
        if let Some(n) = v.get("max_output").and_then(Json::as_usize) {
            if n == 0 {
                return Err("trace.max_output must be > 0".into());
            }
            cfg.lengths.max_output = n as u32;
        }
        if let Some(x) = v.get("seed").and_then(Json::as_f64) {
            cfg.seed = x as u64;
        }
        Ok(cfg)
    }
}

/// Aggregate arrival-rate multiplier at trace fraction `f` in [0, 1]:
/// trough-first sinusoid so warmup happens in the quiet phase.
fn diurnal_intensity(cfg: &ScenarioTraceConfig, f: f64) -> f64 {
    1.0 + cfg.diurnal_amplitude
        * (std::f64::consts::TAU * (f * cfg.diurnal_cycles - 0.25)).sin()
}

/// Per-adapter tenant lifecycle window `[birth, death)`.
#[derive(Debug, Clone, Copy)]
struct Lifecycle {
    birth: f64,
    death: f64,
}

/// Synthesize the churn + Zipf + diurnal scenario trace.
pub fn generate(cfg: &ScenarioTraceConfig) -> Trace {
    let mut rng = Pcg32::with_stream(cfg.seed, SCENARIO_STREAM);
    let adapters = AdapterSet::power_law_counts(
        cfg.n_adapters,
        &RANK_CLASSES,
        cfg.alpha_counts,
        &cfg.model,
    );
    let n = adapters.len();

    // Tenant lifecycle: residents live from t = 0, churners are born
    // uniformly over the first 80% of the run (so late tenants still
    // see traffic) and die an exponential lifetime later. A death past
    // `duration` simply means the tenant outlives the trace.
    let lifecycles: Vec<Lifecycle> = (0..n)
        .map(|_| {
            let resident = rng.f64() < cfg.resident_frac;
            let birth = if resident {
                0.0
            } else {
                rng.f64() * cfg.duration * 0.8
            };
            let death =
                birth + rng.exponential(1.0 / cfg.mean_lifetime);
            Lifecycle { birth, death }
        })
        .collect();

    // Zipf popularity over a seed-shuffled order so heavy hitters are
    // uncorrelated with rank class (power_law_counts emits adapters
    // grouped by class).
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut weights = vec![0.0f64; n];
    for (pos, &a) in order.iter().enumerate() {
        weights[a] = ((pos + 1) as f64).powf(-cfg.zipf_alpha);
    }

    // Per-minute Poisson thinning, normalized so the expected total is
    // rps * duration regardless of the diurnal shape.
    let minutes = (cfg.duration / 60.0).ceil().max(1.0) as usize;
    let mut norm = 0.0;
    for m in 0..minutes {
        norm += diurnal_intensity(cfg, m as f64 / minutes as f64);
    }
    let base = cfg.rps * cfg.duration / norm;

    let mut requests =
        Vec::with_capacity((cfg.rps * cfg.duration) as usize + 1024);
    let mut live = vec![0.0f64; n];
    for m in 0..minutes {
        let f = m as f64 / minutes as f64;
        // Live set evaluated at the minute start: the lifecycle
        // resolution of the churn process is one minute.
        let t0 = m as f64 * 60.0;
        let mut any = false;
        for a in 0..n {
            let lc = &lifecycles[a];
            live[a] = if lc.birth <= t0 && t0 < lc.death {
                any = true;
                weights[a]
            } else {
                0.0
            };
        }
        if !any {
            continue;
        }
        let lambda = base * diurnal_intensity(cfg, f);
        let count = rng.poisson(lambda);
        for _ in 0..count {
            let t = (m as f64 + rng.f64()) * 60.0;
            if t > cfg.duration {
                continue;
            }
            let adapter =
                adapters.adapters[rng.weighted_index(&live)].id;
            let (p, o) = cfg.lengths.sample(&mut rng);
            requests.push(Request {
                id: 0,
                adapter,
                prompt_len: p,
                output_len: o,
                arrival: t,
            });
        }
    }
    Trace::new(
        &format!("scenario-n{}-s{}", cfg.n_adapters, cfg.seed),
        adapters,
        requests,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let cfg = ScenarioTraceConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(b.requests.iter()) {
            assert_eq!(x.adapter, y.adapter);
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.prompt_len, y.prompt_len);
        }
        let c = generate(&ScenarioTraceConfig {
            seed: 1,
            ..ScenarioTraceConfig::default()
        });
        assert_ne!(
            a.requests.len(),
            0,
            "default scenario must produce traffic"
        );
        assert!(
            a.requests.len() != c.requests.len()
                || a.requests
                    .iter()
                    .zip(c.requests.iter())
                    .any(|(x, y)| x.adapter != y.adapter),
            "different seeds must differ"
        );
    }

    #[test]
    fn request_count_close_to_target() {
        let cfg = ScenarioTraceConfig {
            resident_frac: 1.0, // no churn: full rate all run
            ..ScenarioTraceConfig::default()
        };
        let t = generate(&cfg);
        let target = cfg.rps * cfg.duration;
        let got = t.requests.len() as f64;
        // Poisson noise: 5 sigma around the normalized target.
        assert!(
            (got - target).abs() < 5.0 * target.sqrt() + 1.0,
            "got {got}, want ~{target}"
        );
    }

    #[test]
    fn churn_gates_traffic_to_lifecycle_windows() {
        let cfg = ScenarioTraceConfig {
            resident_frac: 0.0,
            mean_lifetime: 120.0,
            ..ScenarioTraceConfig::default()
        };
        let t = generate(&cfg);
        // Rebuild the lifecycle windows with the same stream prefix.
        let mut rng = Pcg32::with_stream(cfg.seed, SCENARIO_STREAM);
        let n = t.adapters.len();
        let windows: Vec<(f64, f64)> = (0..n)
            .map(|_| {
                let resident = rng.f64() < cfg.resident_frac;
                let birth = if resident {
                    0.0
                } else {
                    rng.f64() * cfg.duration * 0.8
                };
                (birth, birth + rng.exponential(1.0 / cfg.mean_lifetime))
            })
            .collect();
        for r in &t.requests {
            let (birth, death) = windows[r.adapter as usize];
            // Minute-granularity gating: arrivals land within the
            // window widened by one minute on each side.
            assert!(
                r.arrival >= birth - 60.0 && r.arrival <= death + 60.0,
                "adapter {} hit at {:.1} outside [{birth:.1}, {death:.1})",
                r.adapter,
                r.arrival
            );
        }
        // With pure churn some adapters must die mid-trace and stop
        // receiving traffic.
        assert!(
            windows.iter().any(|&(_, d)| d < cfg.duration / 2.0),
            "expected at least one early tenant deletion"
        );
    }

    #[test]
    fn diurnal_tide_modulates_rate() {
        let cfg = ScenarioTraceConfig {
            resident_frac: 1.0,
            diurnal_amplitude: 0.8,
            diurnal_cycles: 1.0,
            duration: 1200.0,
            ..ScenarioTraceConfig::default()
        };
        let t = generate(&cfg);
        // One trough-first cycle: the first quarter is the quiet
        // phase, the middle half holds the crest.
        let q = cfg.duration / 4.0;
        let quiet = t
            .requests
            .iter()
            .filter(|r| r.arrival < q)
            .count() as f64;
        let crest = t
            .requests
            .iter()
            .filter(|r| r.arrival >= q && r.arrival < 3.0 * q)
            .count() as f64
            / 2.0;
        assert!(
            crest > 1.5 * quiet,
            "crest {crest} should dominate quiet phase {quiet}"
        );
    }

    #[test]
    fn json_overlay_and_validation() {
        let v = crate::util::json::parse(
            r#"{"n_adapters": 16, "rps": 12.5, "resident_frac": 0.25,
                "diurnal_amplitude": 0.3, "seed": 9}"#,
        )
        .unwrap();
        let cfg = ScenarioTraceConfig::from_json(&v).unwrap();
        assert_eq!(cfg.n_adapters, 16);
        assert_eq!(cfg.rps, 12.5);
        assert_eq!(cfg.resident_frac, 0.25);
        assert_eq!(cfg.seed, 9);
        // untouched knobs keep defaults
        assert_eq!(
            cfg.mean_lifetime,
            ScenarioTraceConfig::default().mean_lifetime
        );
        let bad = crate::util::json::parse(r#"{"resident_frac": 1.5}"#)
            .unwrap();
        assert!(ScenarioTraceConfig::from_json(&bad).is_err());
    }

    #[test]
    fn length_knobs_overlay_and_shape_the_trace() {
        let v = crate::util::json::parse(
            r#"{"prompt_mean": 1024.0, "prompt_sigma": 0.3,
                "max_prompt": 4096, "output_mean": 256.0,
                "output_sigma": 0.2, "max_output": 1024}"#,
        )
        .unwrap();
        let cfg = ScenarioTraceConfig::from_json(&v).unwrap();
        assert!((cfg.lengths.prompt_mu - (1024.0f64).ln()).abs() < 1e-12);
        assert_eq!(cfg.lengths.prompt_sigma, 0.3);
        assert_eq!(cfg.lengths.max_prompt, 4096);
        assert!((cfg.lengths.output_mu - (256.0f64).ln()).abs() < 1e-12);
        assert_eq!(cfg.lengths.max_output, 1024);
        // long-context knobs actually shift the generated trace: the
        // median prompt of the long config dominates the default's
        let long = generate(&ScenarioTraceConfig {
            lengths: cfg.lengths,
            ..ScenarioTraceConfig::default()
        });
        let short = generate(&ScenarioTraceConfig::default());
        let mean = |t: &Trace| {
            t.requests.iter().map(|r| r.prompt_len as f64).sum::<f64>()
                / t.requests.len().max(1) as f64
        };
        assert!(
            mean(&long) > 2.0 * mean(&short),
            "long {} vs short {}",
            mean(&long),
            mean(&short)
        );
        for bad in [
            r#"{"prompt_mean": 0.5}"#,
            r#"{"prompt_sigma": -0.1}"#,
            r#"{"max_prompt": 0}"#,
            r#"{"output_mean": 0.0}"#,
            r#"{"output_sigma": -1.0}"#,
            r#"{"max_output": 0}"#,
        ] {
            let v = crate::util::json::parse(bad).unwrap();
            assert!(
                ScenarioTraceConfig::from_json(&v).is_err(),
                "{bad}"
            );
        }
    }
}
