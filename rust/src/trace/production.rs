//! Production-like trace synthesis (the "Company X" substitute).
//!
//! Matches the published marginals of the paper's production trace
//! (§III-B, §V-E):
//!  * 5 base production adapters, one per rank class {8,…,128}, with a
//!    heavy-tailed request share (top adapters dominate — Fig 8/15);
//!  * 250,138 requests over 8 hours (default; configurable);
//!  * distinct arrival shapes per adapter over time — rising/falling
//!    drift, diurnal, stable, late surge (Fig 10);
//!  * annotation into N ∈ {50,100,200} adapters by splitting each rank
//!    class's traffic across same-rank adapters with a power law (α=1).

use super::{LengthModel, Trace};
use crate::config::ModelSpec;
use crate::util::rng::{Pcg32, PowerLaw};
use crate::workload::{AdapterSet, Request, RANK_CLASSES};

/// Request share per rank class in the production trace, mirroring
/// Fig 15's skewed rank-wise distribution (most traffic on small ranks).
pub const RANK_REQUEST_SHARE: [f64; 5] = [0.38, 0.27, 0.17, 0.11, 0.07];

/// Arrival-shape archetypes observed in Fig 10.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalShape {
    /// Gradual upward drift (adapter 1 in Fig 10).
    DriftUp,
    /// Gradual downward drift (adapter 3).
    DriftDown,
    /// Day/night cycle (adapter 5).
    Diurnal,
    /// Flat demand (adapter 4, early part).
    Stable,
    /// Stable then a sudden load surge near the end (adapter 2).
    LateSurge,
}

pub const SHAPES: [ArrivalShape; 5] = [
    ArrivalShape::DriftUp,
    ArrivalShape::LateSurge,
    ArrivalShape::DriftDown,
    ArrivalShape::Stable,
    ArrivalShape::Diurnal,
];

impl ArrivalShape {
    /// Relative intensity at normalized time f ∈ [0,1]; mean ≈ 1.
    pub fn intensity(&self, f: f64) -> f64 {
        match self {
            ArrivalShape::DriftUp => 0.5 + 1.0 * f,
            ArrivalShape::DriftDown => 1.5 - 1.0 * f,
            ArrivalShape::Diurnal => {
                1.0 + 0.6 * (2.0 * std::f64::consts::PI * (f * 2.0 - 0.25))
                    .sin()
            }
            ArrivalShape::Stable => 1.0,
            ArrivalShape::LateSurge => {
                if f < 0.8 {
                    0.85
                } else {
                    0.85 + 2.4 * (f - 0.8) / 0.2
                }
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct ProductionConfig {
    /// Total adapters after annotation (paper: 50 / 100 / 200).
    pub n_adapters: usize,
    pub n_requests: usize,
    pub duration: f64,
    /// Power-law exponent over adapter *counts* per rank class (§V-E:
    /// α = 1).
    pub alpha: f64,
    /// Power-law exponent splitting *traffic* across the same-rank
    /// adapters. The paper leaves this implicit, but its own Fig 8
    /// (top-5 adapters > 70% of requests) requires a much steeper head
    /// than α=1; 2.0 reproduces the published head share.
    pub alpha_traffic: f64,
    pub lengths: LengthModel,
    pub model: ModelSpec,
    pub seed: u64,
}

impl Default for ProductionConfig {
    fn default() -> Self {
        ProductionConfig {
            n_adapters: 100,
            n_requests: 250_138,
            duration: 8.0 * 3600.0,
            alpha: 1.0,
            alpha_traffic: 2.0,
            lengths: LengthModel::default(),
            model: ModelSpec::LLAMA_7B,
            seed: 0,
        }
    }
}

/// Synthesize the production-like trace.
pub fn generate(cfg: &ProductionConfig) -> Trace {
    let mut rng = Pcg32::with_stream(cfg.seed, 0x9d0d);
    let adapters = AdapterSet::power_law_counts(
        cfg.n_adapters,
        &RANK_CLASSES,
        cfg.alpha,
        &cfg.model,
    );

    // Members of each rank class, and a power-law splitter within it.
    let mut class_members: Vec<Vec<u32>> = vec![Vec::new(); RANK_CLASSES.len()];
    for a in adapters.iter() {
        let k = RANK_CLASSES.iter().position(|&r| r == a.rank).unwrap();
        class_members[k].push(a.id);
    }
    let splitters: Vec<PowerLaw> = class_members
        .iter()
        .map(|m| PowerLaw::new(m.len().max(1), cfg.alpha_traffic))
        .collect();

    // Per-minute Poisson thinning: rank class k's rate at minute m is
    // share_k * shape_k(m/M) * base, normalized so the expected total is
    // n_requests.
    let minutes = (cfg.duration / 60.0).ceil() as usize;
    let mut norm = 0.0;
    for (k, share) in RANK_REQUEST_SHARE.iter().enumerate() {
        for m in 0..minutes {
            let f = m as f64 / minutes.max(1) as f64;
            norm += share * SHAPES[k].intensity(f);
        }
    }
    let base = cfg.n_requests as f64 / norm;

    let mut requests = Vec::with_capacity(cfg.n_requests + 1024);
    for m in 0..minutes {
        let f = m as f64 / minutes as f64;
        for (k, share) in RANK_REQUEST_SHARE.iter().enumerate() {
            let lambda = share * SHAPES[k].intensity(f) * base;
            let count = rng.poisson(lambda);
            for _ in 0..count {
                let t = (m as f64 + rng.f64()) * 60.0;
                if t > cfg.duration {
                    continue;
                }
                let within = splitters[k].sample(&mut rng);
                let adapter = class_members[k][within];
                let (p, o) = cfg.lengths.sample(&mut rng);
                requests.push(Request {
                    id: 0,
                    adapter,
                    prompt_len: p,
                    output_len: o,
                    arrival: t,
                });
            }
        }
    }
    Trace::new(
        &format!("prod-n{}-s{}", cfg.n_adapters, cfg.seed),
        adapters,
        requests,
    )
}

/// Raw fleet-level adapter request shares for the Fig 8 characterization:
/// the top-5 of 1000+ production adapters take > 70% of traffic, the
/// rest share the remainder with a power-law tail, each ≪ 1%.
pub fn raw_adapter_shares(n_adapters: usize, seed: u64) -> Vec<f64> {
    assert!(n_adapters > 5);
    let mut rng = Pcg32::with_stream(seed, 0xf18);
    // head shares mirroring Fig 8's reported ~72.4% top-5 total
    let head = [0.28, 0.17, 0.12, 0.09, 0.064];
    let head_total: f64 = head.iter().sum();
    let tail_n = n_adapters - head.len();
    // power-law tail with mild multiplicative noise
    let mut tail: Vec<f64> = (0..tail_n)
        .map(|k| ((k + 2) as f64).powf(-1.1) * rng.lognormal(0.0, 0.25))
        .collect();
    let tail_sum: f64 = tail.iter().sum();
    for x in tail.iter_mut() {
        *x *= (1.0 - head_total) / tail_sum;
    }
    let mut shares: Vec<f64> = head.to_vec();
    shares.extend(tail);
    shares.sort_by(|a, b| b.partial_cmp(a).unwrap());
    shares
}

/// Per-minute request counts over a week for the five busiest adapters —
/// the Fig 10 characterization series (week-long, diurnal period = 1 day).
pub fn week_rpm_series(seed: u64) -> Vec<(ArrivalShape, Vec<f64>)> {
    let mut rng = Pcg32::with_stream(seed, 0x5ee7);
    let minutes = 7 * 24 * 60;
    let base_rpm = [120.0, 90.0, 70.0, 50.0, 30.0];
    SHAPES
        .iter()
        .zip(base_rpm.iter())
        .map(|(&shape, &base)| {
            let series: Vec<f64> = (0..minutes)
                .map(|m| {
                    // diurnal repeats daily over the week; drift spans
                    // the whole week
                    let f_week = m as f64 / minutes as f64;
                    let f_day = (m % (24 * 60)) as f64 / (24.0 * 60.0);
                    let shape_f = match shape {
                        ArrivalShape::Diurnal => {
                            ArrivalShape::Diurnal.intensity(f_day)
                        }
                        s => s.intensity(f_week),
                    };
                    rng.poisson(base * shape_f) as f64
                })
                .collect();
            (shape, series)
        })
        .collect()
}

/// Synthesized fleet snapshot for Figs 7 & 9: per-base-model adapter
/// counts / memory footprints and server shares per model and region.
pub struct FleetSnapshot {
    pub models: Vec<(&'static str, usize, f64)>, // (name, n_adapters, GB)
    pub server_share_by_model: Vec<(&'static str, f64)>,
    pub server_share_by_region: Vec<(&'static str, f64)>,
}

pub fn fleet_snapshot(seed: u64) -> FleetSnapshot {
    let mut rng = Pcg32::with_stream(seed, 0xf1ee7);
    // Three base models with heavy concentration on Model A (Fig 7):
    let counts = [620usize, 310, 140];
    let names = ["model-a", "model-b", "model-c"];
    let mut models = Vec::new();
    for (name, &n) in names.iter().zip(counts.iter()) {
        // mean adapter ≈ 0.6 GB (mix of ranks on a large base model)
        let gb: f64 = (0..n)
            .map(|_| rng.lognormal((0.45f64).ln(), 0.7))
            .sum();
        models.push((*name, n, gb));
    }
    FleetSnapshot {
        models,
        server_share_by_model: vec![
            ("model-a", 0.55),
            ("model-b", 0.27),
            ("model-c", 0.18),
        ],
        server_share_by_region: vec![
            ("region-1", 0.42),
            ("region-2", 0.25),
            ("region-3", 0.14),
            ("region-4", 0.11),
            ("other", 0.08),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::characterize;

    fn small_cfg() -> ProductionConfig {
        ProductionConfig {
            n_adapters: 50,
            n_requests: 20_000,
            duration: 3600.0,
            ..Default::default()
        }
    }

    #[test]
    fn request_count_close_to_target() {
        let t = generate(&small_cfg());
        let n = t.requests.len() as f64;
        assert!(
            (n - 20_000.0).abs() < 20_000.0 * 0.05,
            "n={n}"
        );
        assert!(t.duration() <= 3600.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let t1 = generate(&small_cfg());
        let t2 = generate(&small_cfg());
        assert_eq!(t1.requests.len(), t2.requests.len());
        assert_eq!(t1.requests[100], t2.requests[100]);
        let mut cfg = small_cfg();
        cfg.seed = 1;
        let t3 = generate(&cfg);
        assert_ne!(t1.requests.len(), t3.requests.len());
    }

    #[test]
    fn rank_shares_match_spec() {
        let t = generate(&small_cfg());
        let shares = characterize::rank_request_shares(&t);
        for (k, &r) in RANK_CLASSES.iter().enumerate() {
            let got = shares.iter().find(|(rr, _)| *rr == r).unwrap().1;
            assert!(
                (got - RANK_REQUEST_SHARE[k]).abs() < 0.05,
                "rank {r}: got {got}, want {}",
                RANK_REQUEST_SHARE[k]
            );
        }
    }

    #[test]
    fn top5_share_is_heavy_tailed() {
        // With α=1 within classes + skewed class shares, the top-5
        // adapters take far more than a uniform share of requests.
        let mut cfg = small_cfg();
        cfg.n_adapters = 100;
        let t = generate(&cfg);
        let top5 = characterize::top_k_request_share(&t, 5);
        // head-heavy within-class traffic: top-5 carries ~half of all
        // requests even after annotation to 100 adapters (Fig 8 shows
        // >70% in the raw >1000-adapter fleet)
        assert!(top5 > 0.40, "top5={top5}");
    }

    #[test]
    fn raw_fleet_top5_over_70_percent() {
        // Fig 8: in the raw production workload (1000+ adapters) the
        // top-5 adapters exceed 70% of requests.
        let shares = raw_adapter_shares(1000, 0);
        assert_eq!(shares.len(), 1000);
        let total: f64 = shares.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
        let top5: f64 = shares.iter().take(5).sum();
        assert!(top5 > 0.70 && top5 < 0.85, "top5={top5}");
        // the tail adapters each get well under 1%
        assert!(shares[50] < 0.01);
    }

    #[test]
    fn shapes_mean_about_one() {
        for s in SHAPES {
            let mean: f64 = (0..1000)
                .map(|i| s.intensity(i as f64 / 1000.0))
                .sum::<f64>()
                / 1000.0;
            assert!((mean - 1.0).abs() < 0.15, "{s:?} mean={mean}");
        }
    }

    #[test]
    fn late_surge_actually_surges() {
        let s = ArrivalShape::LateSurge;
        assert!(s.intensity(0.99) > 2.0 * s.intensity(0.5));
    }

    #[test]
    fn week_series_shapes() {
        let series = week_rpm_series(0);
        assert_eq!(series.len(), 5);
        for (_, xs) in &series {
            assert_eq!(xs.len(), 7 * 24 * 60);
        }
        // diurnal series has within-day oscillation: compare first-day
        // max/min of the hourly means
        let diurnal = &series
            .iter()
            .find(|(s, _)| *s == ArrivalShape::Diurnal)
            .unwrap()
            .1;
        let hours: Vec<f64> = (0..24)
            .map(|h| {
                diurnal[h * 60..(h + 1) * 60].iter().sum::<f64>() / 60.0
            })
            .collect();
        let max = hours.iter().cloned().fold(f64::MIN, f64::max);
        let min = hours.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max > 1.5 * min, "max={max} min={min}");
    }

    #[test]
    fn fleet_concentrated() {
        let f = fleet_snapshot(0);
        assert!(f.models[0].1 > f.models[2].1 * 3);
        let total: f64 =
            f.server_share_by_model.iter().map(|(_, s)| s).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(f.server_share_by_region[0].1 > 0.3);
    }
}
