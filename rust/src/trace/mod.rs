//! Trace substrate: synthesis, IO, rescaling, characterization.
//!
//! The paper evaluates on (a) production traces from "Company X"
//! (250,138 requests / 8 h / 5 adapters of distinct ranks, §V-E) and
//! (b) Azure Public Dataset traces annotated with timestamps + adapter
//! names. Neither is available here, so `production.rs` and `azure.rs`
//! synthesize traces matching every published marginal (rank shares,
//! top-5 ≈ 70% popularity, arrival shapes, power-law annotation); see
//! DESIGN.md §4 for the substitution argument.

pub mod azure;
pub mod characterize;
pub mod production;
pub mod scenario;

use crate::workload::{AdapterSet, Request};

/// A workload trace: adapter registry + time-ordered request stream.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub adapters: AdapterSet,
    pub requests: Vec<Request>,
    pub name: String,
}

impl Trace {
    pub fn new(name: &str, adapters: AdapterSet, mut requests: Vec<Request>) -> Self {
        requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        for (i, r) in requests.iter_mut().enumerate() {
            r.id = i as u64;
        }
        Trace {
            adapters,
            requests,
            name: name.to_string(),
        }
    }

    pub fn duration(&self) -> f64 {
        self.requests.last().map(|r| r.arrival).unwrap_or(0.0)
    }

    pub fn mean_rps(&self) -> f64 {
        let d = self.duration();
        if d <= 0.0 {
            return 0.0;
        }
        self.requests.len() as f64 / d
    }

    /// Rescale timestamps proportionally so the trace plays at `rps`
    /// while keeping the original arrival *pattern* (the paper's method:
    /// "we scale the timestamps proportionally", §V-E).
    pub fn scale_to_rps(&self, rps: f64) -> Trace {
        assert!(rps > 0.0);
        let cur = self.mean_rps();
        let factor = if cur > 0.0 { cur / rps } else { 1.0 };
        let mut t = self.clone();
        for r in t.requests.iter_mut() {
            r.arrival *= factor;
        }
        t.name = format!("{}@{}rps", self.name, rps);
        t
    }

    /// Keep only the first `secs` seconds (cheap experiment truncation).
    pub fn truncate(&self, secs: f64) -> Trace {
        let mut t = self.clone();
        t.requests.retain(|r| r.arrival <= secs);
        t
    }

    /// Save as the paper's CSV schema:
    /// request_id,adapter,prompt_length,output_length,timestamp
    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "request_id,adapter,prompt_length,output_length,timestamp")?;
        for r in &self.requests {
            writeln!(
                f,
                "{},{},{},{},{:.6}",
                r.id, r.adapter, r.prompt_len, r.output_len, r.arrival
            )?;
        }
        Ok(())
    }

    /// Load the CSV schema written by `save_csv`. The adapter registry
    /// must be supplied (the CSV stores only ids).
    pub fn load_csv(
        path: &str,
        name: &str,
        adapters: AdapterSet,
    ) -> Result<Trace, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{path}: {e}"))?;
        let mut requests = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if lineno == 0 || line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split(',').collect();
            if cols.len() != 5 {
                return Err(format!("{path}:{}: want 5 cols", lineno + 1));
            }
            let parse_err =
                |e: &dyn std::fmt::Display| format!("{path}:{}: {e}", lineno + 1);
            requests.push(Request {
                id: cols[0].parse().map_err(|e| parse_err(&e))?,
                adapter: cols[1].parse().map_err(|e| parse_err(&e))?,
                prompt_len: cols[2].parse().map_err(|e| parse_err(&e))?,
                output_len: cols[3].parse().map_err(|e| parse_err(&e))?,
                arrival: cols[4].parse().map_err(|e| parse_err(&e))?,
            });
        }
        for r in &requests {
            if r.adapter as usize >= adapters.len() {
                return Err(format!(
                    "{path}: request {} names adapter {} >= registry size {}",
                    r.id,
                    r.adapter,
                    adapters.len()
                ));
            }
        }
        Ok(Trace::new(name, adapters, requests))
    }
}

/// Lognormal request-length model. The default approximates the
/// Azure-trace-like chat traffic the paper evaluates on (median prompt
/// ≈ 192, median output ≈ 48, heavy right tail) — calibrated so a
/// 4-server Llama-7B TP4 cluster saturates around the paper's ~32-36
/// RPS (Fig 21/22) while one server saturates near 4 RPS on the *fixed*
/// 512/128 shape of Fig 6 (`LengthModel::fixed`).
#[derive(Debug, Clone, Copy)]
pub struct LengthModel {
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub output_mu: f64,
    pub output_sigma: f64,
    pub max_prompt: u32,
    pub max_output: u32,
}

impl Default for LengthModel {
    fn default() -> Self {
        LengthModel {
            prompt_mu: (192.0f64).ln(),
            prompt_sigma: 0.8,
            output_mu: (48.0f64).ln(),
            output_sigma: 0.6,
            max_prompt: 2048,
            max_output: 512,
        }
    }
}

impl LengthModel {
    pub fn fixed(prompt: u32, output: u32) -> Self {
        LengthModel {
            prompt_mu: (prompt as f64).ln(),
            prompt_sigma: 0.0,
            output_mu: (output as f64).ln(),
            output_sigma: 0.0,
            max_prompt: prompt,
            max_output: output,
        }
    }

    pub fn sample(&self, rng: &mut crate::util::rng::Pcg32) -> (u32, u32) {
        let p = rng
            .lognormal(self.prompt_mu, self.prompt_sigma)
            .round()
            .clamp(1.0, self.max_prompt as f64) as u32;
        let o = rng
            .lognormal(self.output_mu, self.output_sigma)
            .round()
            .clamp(1.0, self.max_output as f64) as u32;
        (p, o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::util::rng::Pcg32;
    use crate::workload::RANK_CLASSES;

    fn tiny_trace() -> Trace {
        let adapters = AdapterSet::uniform_per_rank(
            5,
            &RANK_CLASSES,
            &ModelSpec::LLAMA_7B,
        );
        let reqs = vec![
            Request { id: 9, adapter: 1, prompt_len: 10, output_len: 2, arrival: 2.0 },
            Request { id: 7, adapter: 0, prompt_len: 20, output_len: 4, arrival: 1.0 },
        ];
        Trace::new("tiny", adapters, reqs)
    }

    #[test]
    fn new_sorts_and_renumbers() {
        let t = tiny_trace();
        assert_eq!(t.requests[0].arrival, 1.0);
        assert_eq!(t.requests[0].id, 0);
        assert_eq!(t.requests[1].id, 1);
    }

    #[test]
    fn scale_to_rps_preserves_pattern() {
        let t = tiny_trace();
        let t2 = t.scale_to_rps(2.0 * t.mean_rps());
        assert!((t2.mean_rps() - 2.0 * t.mean_rps()).abs() < 1e-9);
        // relative spacing preserved
        let r0 = t.requests[1].arrival / t.requests[0].arrival;
        let r2 = t2.requests[1].arrival / t2.requests[0].arrival;
        assert!((r0 - r2).abs() < 1e-9);
    }

    #[test]
    fn csv_roundtrip() {
        let t = tiny_trace();
        let dir = std::env::temp_dir().join("loraserve_test_trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let path = path.to_str().unwrap();
        t.save_csv(path).unwrap();
        let t2 = Trace::load_csv(path, "tiny", t.adapters.clone()).unwrap();
        assert_eq!(t.requests, t2.requests);
    }

    #[test]
    fn csv_rejects_unknown_adapter() {
        let t = tiny_trace();
        let dir = std::env::temp_dir().join("loraserve_test_trace2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let path = path.to_str().unwrap();
        t.save_csv(path).unwrap();
        let small = AdapterSet::uniform_per_rank(
            1,
            &[8],
            &ModelSpec::LLAMA_7B,
        );
        assert!(Trace::load_csv(path, "x", small).is_err());
    }

    #[test]
    fn length_model_fixed_and_random() {
        let mut rng = Pcg32::new(3);
        let fixed = LengthModel::fixed(512, 128);
        for _ in 0..10 {
            assert_eq!(fixed.sample(&mut rng), (512, 128));
        }
        let lm = LengthModel::default();
        let mut sum = 0.0;
        for _ in 0..2000 {
            let (p, o) = lm.sample(&mut rng);
            assert!(p >= 1 && p <= lm.max_prompt);
            assert!(o >= 1 && o <= lm.max_output);
            sum += p as f64;
        }
        let mean = sum / 2000.0;
        // lognormal mean = exp(mu + sigma^2/2) ≈ 264
        assert!(mean > 180.0 && mean < 380.0, "mean={mean}");
    }

    #[test]
    fn truncate_drops_tail() {
        let t = tiny_trace();
        assert_eq!(t.truncate(1.5).requests.len(), 1);
    }
}
