//! Trace characterization: the statistics behind Figs 7-10 and 15-16.

use super::Trace;
use crate::util::stats::moving_average;
use crate::workload::AdapterId;
use std::collections::BTreeMap;

/// Request share per adapter, sorted descending (Fig 8).
pub fn adapter_request_shares(trace: &Trace) -> Vec<(AdapterId, f64)> {
    let mut counts: BTreeMap<AdapterId, u64> = BTreeMap::new();
    for r in &trace.requests {
        *counts.entry(r.adapter).or_insert(0) += 1;
    }
    let total = trace.requests.len().max(1) as f64;
    let mut shares: Vec<(AdapterId, f64)> = counts
        .into_iter()
        .map(|(a, c)| (a, c as f64 / total))
        .collect();
    shares.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    shares
}

/// Combined request share of the top-k adapters (paper: top 5 > 70%).
pub fn top_k_request_share(trace: &Trace, k: usize) -> f64 {
    adapter_request_shares(trace)
        .iter()
        .take(k)
        .map(|(_, s)| s)
        .sum()
}

/// Request share per rank class (Fig 15 left).
pub fn rank_request_shares(trace: &Trace) -> Vec<(u32, f64)> {
    let mut counts: BTreeMap<u32, u64> = BTreeMap::new();
    for r in &trace.requests {
        let rank = trace.adapters.get(r.adapter).rank;
        *counts.entry(rank).or_insert(0) += 1;
    }
    let total = trace.requests.len().max(1) as f64;
    counts
        .into_iter()
        .map(|(r, c)| (r, c as f64 / total))
        .collect()
}

/// Token share per rank class (Fig 15 right).
pub fn rank_token_shares(trace: &Trace) -> Vec<(u32, f64)> {
    let mut counts: BTreeMap<u32, u64> = BTreeMap::new();
    let mut total = 0u64;
    for r in &trace.requests {
        let rank = trace.adapters.get(r.adapter).rank;
        let toks = r.total_tokens();
        *counts.entry(rank).or_insert(0) += toks;
        total += toks;
    }
    counts
        .into_iter()
        .map(|(r, c)| (r, c as f64 / total.max(1) as f64))
        .collect()
}

/// Requests-per-minute series for one adapter, optionally smoothed with
/// a moving average (Fig 10's presentation).
pub fn requests_per_minute(
    trace: &Trace,
    adapter: AdapterId,
    smooth_window: usize,
) -> Vec<f64> {
    let minutes = (trace.duration() / 60.0).ceil().max(1.0) as usize;
    let mut counts = vec![0.0; minutes];
    for r in &trace.requests {
        if r.adapter == adapter {
            let m = ((r.arrival / 60.0) as usize).min(minutes - 1);
            counts[m] += 1.0;
        }
    }
    if smooth_window > 1 {
        moving_average(&counts, smooth_window)
    } else {
        counts
    }
}

/// Rank popularity in consecutive windows — visualizes the shifting
/// skew (Fig 16): returns, per window, the share of each unique rank.
pub fn rank_share_over_time(
    trace: &Trace,
    n_windows: usize,
) -> Vec<BTreeMap<u32, f64>> {
    let duration = trace.duration().max(1e-9);
    let mut wins: Vec<BTreeMap<u32, u64>> =
        vec![BTreeMap::new(); n_windows];
    let mut totals = vec![0u64; n_windows];
    for r in &trace.requests {
        let w = ((r.arrival / duration * n_windows as f64) as usize)
            .min(n_windows - 1);
        let rank = trace.adapters.get(r.adapter).rank;
        *wins[w].entry(rank).or_insert(0) += 1;
        totals[w] += 1;
    }
    wins.into_iter()
        .zip(totals)
        .map(|(m, tot)| {
            m.into_iter()
                .map(|(r, c)| (r, c as f64 / tot.max(1) as f64))
                .collect()
        })
        .collect()
}

/// Estimated tokens-per-second demand per adapter over a window —
/// the signal Algorithm 1 consumes (GETPREVTIMESTEPTPS).
pub fn adapter_tps_in_window(
    trace: &Trace,
    t0: f64,
    t1: f64,
) -> BTreeMap<AdapterId, f64> {
    assert!(t1 > t0);
    let mut toks: BTreeMap<AdapterId, u64> = BTreeMap::new();
    for r in &trace.requests {
        if r.arrival >= t0 && r.arrival < t1 {
            *toks.entry(r.adapter).or_insert(0) += r.total_tokens();
        }
    }
    toks.into_iter()
        .map(|(a, t)| (a, t as f64 / (t1 - t0)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::workload::{AdapterSet, Request};

    fn trace_with(counts: &[(u32, usize)]) -> Trace {
        // counts: (adapter id, n requests); 4 adapters ranks 8/8/64/128
        let adapters = AdapterSet::new(vec![
            crate::workload::Adapter { id: 0, rank: 8, size_bytes: 1 },
            crate::workload::Adapter { id: 1, rank: 8, size_bytes: 1 },
            crate::workload::Adapter { id: 2, rank: 64, size_bytes: 1 },
            crate::workload::Adapter { id: 3, rank: 128, size_bytes: 1 },
        ]);
        let mut reqs = Vec::new();
        let mut t = 0.0;
        for &(a, n) in counts {
            for _ in 0..n {
                t += 1.0;
                reqs.push(Request {
                    id: 0,
                    adapter: a,
                    prompt_len: 100,
                    output_len: 10,
                    arrival: t,
                });
            }
        }
        Trace::new("t", adapters, reqs)
    }

    #[test]
    fn shares_sorted_and_sum_to_one() {
        let t = trace_with(&[(0, 10), (1, 30), (2, 40), (3, 20)]);
        let shares = adapter_request_shares(&t);
        assert_eq!(shares[0].0, 2);
        assert!((shares.iter().map(|(_, s)| s).sum::<f64>() - 1.0).abs()
            < 1e-9);
        assert!((top_k_request_share(&t, 2) - 0.7).abs() < 1e-9);
    }

    #[test]
    fn rank_shares() {
        let t = trace_with(&[(0, 10), (1, 10), (2, 60), (3, 20)]);
        let rs = rank_request_shares(&t);
        assert_eq!(rs, vec![(8, 0.2), (64, 0.6), (128, 0.2)]);
        // equal lengths => token shares match request shares
        let ts = rank_token_shares(&t);
        for ((r1, s1), (r2, s2)) in rs.iter().zip(ts.iter()) {
            assert_eq!(r1, r2);
            assert!((s1 - s2).abs() < 1e-9);
        }
    }

    #[test]
    fn rpm_series_counts() {
        let t = trace_with(&[(0, 120)]); // one per second for 2 minutes
        let rpm = requests_per_minute(&t, 0, 1);
        assert_eq!(rpm.len(), 2);
        assert!((rpm[0] - 59.0).abs() <= 1.0); // arrivals start at t=1
        assert_eq!(requests_per_minute(&t, 3, 1).iter().sum::<f64>(), 0.0);
    }

    #[test]
    fn rank_share_windows() {
        // first half adapter 3 (rank 128), second half adapter 0 (rank 8)
        let adapters = trace_with(&[]).adapters.clone();
        let mut reqs = Vec::new();
        for i in 0..100 {
            reqs.push(Request {
                id: 0,
                adapter: if i < 50 { 3 } else { 0 },
                prompt_len: 10,
                output_len: 1,
                arrival: i as f64,
            });
        }
        let t = Trace::new("w", adapters, reqs);
        let wins = rank_share_over_time(&t, 2);
        assert!(wins[0].get(&128).copied().unwrap_or(0.0) > 0.9);
        assert!(wins[1].get(&8).copied().unwrap_or(0.0) > 0.9);
    }

    #[test]
    fn tps_window() {
        let t = trace_with(&[(0, 10)]); // 110 tokens each, t=1..10
        let tps = adapter_tps_in_window(&t, 0.0, 11.0);
        assert!((tps[&0] - 10.0 * 110.0 / 11.0).abs() < 1e-9);
        assert!(adapter_tps_in_window(&t, 100.0, 101.0).is_empty());
    }
}
