//! Azure-Public-Dataset-style derived traces (§V-E).
//!
//! The paper annotates the (timestamp-free) Azure traces with arrival
//! times and adapter names, producing six traces from the cross product
//!
//!   arrival  ∈ {Uniform, Poisson}
//!   rank-popularity ∈ {Uniform, ShiftingSkew, Exponential}
//!
//! over 25 adapters (5 per rank class 8/16/32/64/128), matching prior
//! work (Chameleon, Toppings). Within a rank class the adapter is chosen
//! uniformly.

use super::{LengthModel, Trace};
use crate::config::ModelSpec;
use crate::util::rng::Pcg32;
use crate::workload::{AdapterSet, Request, RANK_CLASSES};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// Request times i.i.d. uniform over the duration.
    Uniform,
    /// Homogeneous Poisson process.
    Poisson,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RankPopularity {
    /// Every rank class equally likely.
    Uniform,
    /// Fig 16: at t=0 the largest rank gets 50% (rest uniform); the
    /// skew shifts linearly until at t=T the smallest rank gets 50%.
    ShiftingSkew,
    /// Rank-class popularity exponentially distributed, smaller ranks
    /// more popular (Chameleon's setting).
    Exponential,
}

impl RankPopularity {
    /// Probability of each rank class at normalized time f ∈ [0,1].
    pub fn class_probs(&self, n_classes: usize, f: f64) -> Vec<f64> {
        match self {
            RankPopularity::Uniform => {
                vec![1.0 / n_classes as f64; n_classes]
            }
            RankPopularity::ShiftingSkew => {
                // class order: index 0 = smallest rank. Interpolate
                // between "largest gets 0.5" and "smallest gets 0.5";
                // the remaining mass is uniform over the other classes.
                let rest = 0.5 / (n_classes - 1) as f64;
                let mut probs = vec![0.0; n_classes];
                for (k, p) in probs.iter_mut().enumerate() {
                    let at_start =
                        if k == n_classes - 1 { 0.5 } else { rest };
                    let at_end = if k == 0 { 0.5 } else { rest };
                    *p = at_start * (1.0 - f) + at_end * f;
                }
                probs
            }
            RankPopularity::Exponential => {
                let raw: Vec<f64> =
                    (0..n_classes).map(|k| (-(k as f64)).exp()).collect();
                let total: f64 = raw.iter().sum();
                raw.into_iter().map(|x| x / total).collect()
            }
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RankPopularity::Uniform => "uniform",
            RankPopularity::ShiftingSkew => "shifting",
            RankPopularity::Exponential => "exponential",
        }
    }
}

impl Arrival {
    pub fn label(&self) -> &'static str {
        match self {
            Arrival::Uniform => "uniform-arrival",
            Arrival::Poisson => "poisson-arrival",
        }
    }
}

#[derive(Debug, Clone)]
pub struct AzureConfig {
    pub arrival: Arrival,
    pub popularity: RankPopularity,
    /// 25 adapters: 5 per rank class, as in prior work.
    pub adapters_per_rank: usize,
    pub rps: f64,
    pub duration: f64,
    pub lengths: LengthModel,
    pub model: ModelSpec,
    pub seed: u64,
}

impl Default for AzureConfig {
    fn default() -> Self {
        AzureConfig {
            arrival: Arrival::Poisson,
            popularity: RankPopularity::Uniform,
            adapters_per_rank: 5,
            rps: 8.0,
            duration: 600.0,
            lengths: LengthModel::default(),
            model: ModelSpec::LLAMA_7B,
            seed: 0,
        }
    }
}

/// All six (arrival × popularity) combinations, Fig 19/20's x-axis.
pub fn six_trace_matrix() -> Vec<(Arrival, RankPopularity)> {
    let mut out = Vec::new();
    for arrival in [Arrival::Uniform, Arrival::Poisson] {
        for pop in [
            RankPopularity::Uniform,
            RankPopularity::ShiftingSkew,
            RankPopularity::Exponential,
        ] {
            out.push((arrival, pop));
        }
    }
    out
}

pub fn generate(cfg: &AzureConfig) -> Trace {
    let mut rng = Pcg32::with_stream(cfg.seed, 0xa27e);
    let n_classes = RANK_CLASSES.len();
    let adapters = AdapterSet::uniform_per_rank(
        cfg.adapters_per_rank * n_classes,
        &RANK_CLASSES,
        &cfg.model,
    );
    let mut class_members: Vec<Vec<u32>> = vec![Vec::new(); n_classes];
    for a in adapters.iter() {
        let k = RANK_CLASSES.iter().position(|&r| r == a.rank).unwrap();
        class_members[k].push(a.id);
    }

    // arrival times
    let n = (cfg.rps * cfg.duration).round() as usize;
    let mut times = Vec::with_capacity(n);
    match cfg.arrival {
        Arrival::Uniform => {
            for _ in 0..n {
                times.push(rng.f64() * cfg.duration);
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
        Arrival::Poisson => {
            let mut t = 0.0;
            while times.len() < n {
                t += rng.exponential(cfg.rps);
                if t > cfg.duration {
                    break;
                }
                times.push(t);
            }
        }
    }

    let requests: Vec<Request> = times
        .into_iter()
        .map(|t| {
            let f = t / cfg.duration;
            let probs = cfg.popularity.class_probs(n_classes, f);
            let k = rng.weighted_index(&probs);
            let members = &class_members[k];
            let adapter = members[rng.below(members.len() as u64) as usize];
            let (p, o) = cfg.lengths.sample(&mut rng);
            Request {
                id: 0,
                adapter,
                prompt_len: p,
                output_len: o,
                arrival: t,
            }
        })
        .collect();

    Trace::new(
        &format!(
            "azure-{}-{}",
            cfg.arrival.label(),
            cfg.popularity.label()
        ),
        adapters,
        requests,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::characterize;

    #[test]
    fn class_probs_sum_to_one() {
        for pop in [
            RankPopularity::Uniform,
            RankPopularity::ShiftingSkew,
            RankPopularity::Exponential,
        ] {
            for f in [0.0, 0.3, 1.0] {
                let p = pop.class_probs(5, f);
                let sum: f64 = p.iter().sum();
                assert!((sum - 1.0).abs() < 1e-9, "{pop:?} f={f}");
                assert!(p.iter().all(|&x| x >= 0.0));
            }
        }
    }

    #[test]
    fn shifting_skew_endpoints() {
        let p0 = RankPopularity::ShiftingSkew.class_probs(5, 0.0);
        assert!((p0[4] - 0.5).abs() < 1e-9); // largest rank 50% at start
        assert!((p0[0] - 0.125).abs() < 1e-9);
        let p1 = RankPopularity::ShiftingSkew.class_probs(5, 1.0);
        assert!((p1[0] - 0.5).abs() < 1e-9); // smallest rank 50% at end
        assert!((p1[4] - 0.125).abs() < 1e-9);
    }

    #[test]
    fn exponential_prefers_small_ranks() {
        let p = RankPopularity::Exponential.class_probs(5, 0.5);
        for w in p.windows(2) {
            assert!(w[0] > w[1]);
        }
        assert!(p[0] > 0.5);
    }

    #[test]
    fn poisson_arrival_rate() {
        let cfg = AzureConfig {
            rps: 20.0,
            duration: 300.0,
            ..Default::default()
        };
        let t = generate(&cfg);
        let rps = t.requests.len() as f64 / 300.0;
        assert!((rps - 20.0).abs() < 2.0, "rps={rps}");
        // inter-arrival CV ≈ 1 for Poisson
        let gaps: Vec<f64> = t
            .requests
            .windows(2)
            .map(|w| w[1].arrival - w[0].arrival)
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>()
            / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.15, "cv={cv}");
    }

    #[test]
    fn uniform_arrival_sorted_and_in_range() {
        let cfg = AzureConfig {
            arrival: Arrival::Uniform,
            rps: 10.0,
            duration: 100.0,
            ..Default::default()
        };
        let t = generate(&cfg);
        for w in t.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert!(t.duration() <= 100.0);
    }

    #[test]
    fn shifting_skew_moves_traffic() {
        let cfg = AzureConfig {
            popularity: RankPopularity::ShiftingSkew,
            rps: 50.0,
            duration: 600.0,
            seed: 3,
            ..Default::default()
        };
        let t = generate(&cfg);
        let half = 300.0;
        let (mut hi_first, mut hi_second) = (0usize, 0usize);
        let (mut n_first, mut n_second) = (0usize, 0usize);
        for r in &t.requests {
            let rank = t.adapters.get(r.adapter).rank;
            if r.arrival < half {
                n_first += 1;
                if rank == 128 {
                    hi_first += 1;
                }
            } else {
                n_second += 1;
                if rank == 128 {
                    hi_second += 1;
                }
            }
        }
        let f1 = hi_first as f64 / n_first as f64;
        let f2 = hi_second as f64 / n_second as f64;
        // analytic halves: mean of (0.5, 0.3125) vs (0.3125, 0.125)
        assert!((f1 - 0.406).abs() < 0.04, "first-half r128 share {f1}");
        assert!((f2 - 0.219).abs() < 0.04, "second-half r128 share {f2}");
    }

    #[test]
    fn six_traces_distinct() {
        let combos = six_trace_matrix();
        assert_eq!(combos.len(), 6);
        let names: std::collections::BTreeSet<String> = combos
            .iter()
            .map(|(a, p)| {
                let cfg = AzureConfig {
                    arrival: *a,
                    popularity: *p,
                    rps: 5.0,
                    duration: 60.0,
                    ..Default::default()
                };
                generate(&cfg).name
            })
            .collect();
        assert_eq!(names.len(), 6);
    }

    #[test]
    fn adapters_within_class_roughly_uniform() {
        let cfg = AzureConfig {
            rps: 100.0,
            duration: 200.0,
            ..Default::default()
        };
        let t = generate(&cfg);
        let shares = characterize::adapter_request_shares(&t);
        // 25 adapters, uniform popularity => each ~4%
        for &(_, s) in &shares {
            assert!(s < 0.10, "share={s}");
        }
    }
}
