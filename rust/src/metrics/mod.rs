//! Cluster-level metrics: the fleet accounting behind the elastic
//! capacity subsystem (GPU-seconds, scale-event counters, fleet-size
//! timeline, SLO-violation rate), plus re-exports of the metric
//! primitives (`util::stats`) and the per-run report (`sim::report`).
//!
//! Ad-hoc run counters (arrivals, fetches, rebalances, ...) live in
//! the [`MetricsRegistry`] from `obs::metrics` — a counter/gauge
//! registry with deterministic snapshot ordering and Prometheus text
//! export (`simulate --metrics-out`), re-exported here so metric
//! consumers have one import path.

pub use crate::obs::MetricsRegistry;
pub use crate::sim::report::SimReport;
pub use crate::util::stats::{Histogram, Samples};

/// Fleet-level accounting for one simulation run. Maintained by the
/// DES loop (`sim::cluster`) and consumed by `sim::report`, the
/// `autoscale` CLI subcommand, and the GPUs-under-SLO figures.
///
/// Two step functions of time are tracked: the **routable** fleet
/// (what the router can send traffic to — the `timeline`) and the
/// **billed** fleet (provisioning + active + draining — servers that
/// occupy GPUs whether or not they take new work). `gpu_seconds`
/// integrates the *billed* count scaled by GPUs per server (TP
/// degree) — the resource the paper's "up to 50% fewer GPUs" claim
/// counts, generalized to a fleet that changes size at runtime: a
/// draining server is still burning GPUs until it retires, and a
/// provisioning one is billed from the scale-up decision (cloud
/// instances bill from launch, not from readiness).
#[derive(Debug, Clone, Default)]
pub struct FleetMetrics {
    /// ∫ billed_servers(t) · gpus_per_server dt.
    pub gpu_seconds: f64,
    /// ∫ billed_servers(t) dt.
    pub server_seconds: f64,
    /// Scale-up decisions that provisioned a server.
    pub scale_ups: u64,
    /// Scale-down decisions that started a drain.
    pub scale_downs: u64,
    /// Step function of the *routable* fleet size: (time, active).
    pub timeline: Vec<(f64, usize)>,
    /// Measured completions whose TTFT exceeded the SLO.
    pub slo_violations: u64,
    /// Measured completions total.
    pub measured: u64,
    gpus_per_server: usize,
    cur_active: usize,
    cur_billed: usize,
    last_t: f64,
    end_t: f64,
}

impl FleetMetrics {
    pub fn new(gpus_per_server: usize, initial_active: usize) -> Self {
        FleetMetrics {
            gpus_per_server,
            cur_active: initial_active,
            cur_billed: initial_active,
            timeline: vec![(0.0, initial_active)],
            ..Default::default()
        }
    }

    /// Integrate the current billed fleet size up to `now`.
    fn advance(&mut self, now: f64) {
        let dt = (now - self.last_t).max(0.0);
        self.server_seconds += dt * self.cur_billed as f64;
        self.gpu_seconds +=
            dt * (self.cur_billed * self.gpus_per_server) as f64;
        self.last_t = self.last_t.max(now);
    }

    /// Record a fleet change. `routable` is what the router can
    /// target (drives the timeline); `billed` is provisioning +
    /// active + draining (drives the GPU-seconds integral). The
    /// timeline only records routable-size *changes*, so pure billing
    /// transitions (provision start, retirement) don't add steps.
    pub fn set_fleet(&mut self, now: f64, routable: usize, billed: usize) {
        self.advance(now);
        self.cur_billed = billed;
        if routable != self.cur_active {
            self.cur_active = routable;
            self.timeline.push((now, routable));
        }
    }

    /// Record one measured completion and whether it violated the SLO.
    pub fn record_completion(&mut self, violated: bool) {
        self.measured += 1;
        if violated {
            self.slo_violations += 1;
        }
    }

    /// Close the accounting interval at the end of the run.
    pub fn finish(&mut self, now: f64) {
        self.advance(now);
        self.end_t = now;
    }

    /// Length of the accounted interval (set by `finish`).
    pub fn duration(&self) -> f64 {
        self.end_t
    }

    pub fn peak_servers(&self) -> usize {
        self.timeline.iter().map(|&(_, n)| n).max().unwrap_or(0)
    }

    pub fn min_servers(&self) -> usize {
        self.timeline.iter().map(|&(_, n)| n).min().unwrap_or(0)
    }

    /// Time-weighted mean *billed* fleet size.
    pub fn mean_fleet(&self) -> f64 {
        if self.end_t > 0.0 {
            self.server_seconds / self.end_t
        } else {
            self.cur_billed as f64
        }
    }

    /// Fraction of measured completions past the SLO (NaN if none).
    pub fn violation_rate(&self) -> f64 {
        if self.measured == 0 {
            return f64::NAN;
        }
        self.slo_violations as f64 / self.measured as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_fleet_integral() {
        let mut f = FleetMetrics::new(4, 3);
        f.finish(100.0);
        assert!((f.gpu_seconds - 3.0 * 4.0 * 100.0).abs() < 1e-9);
        assert!((f.server_seconds - 300.0).abs() < 1e-9);
        assert!((f.mean_fleet() - 3.0).abs() < 1e-9);
        assert_eq!(f.peak_servers(), 3);
        assert_eq!(f.min_servers(), 3);
        assert_eq!(f.duration(), 100.0);
    }

    #[test]
    fn step_function_integral() {
        // billed: 2 for 10 s, 4 for 20 s, 1 for 30 s (1 GPU each)
        let mut f = FleetMetrics::new(1, 2);
        f.set_fleet(10.0, 4, 4);
        f.scale_ups += 2;
        f.set_fleet(30.0, 1, 1);
        f.scale_downs += 3;
        f.finish(60.0);
        let want = 2.0 * 10.0 + 4.0 * 20.0 + 1.0 * 30.0;
        assert!((f.gpu_seconds - want).abs() < 1e-9, "{}", f.gpu_seconds);
        assert_eq!(f.peak_servers(), 4);
        assert_eq!(f.min_servers(), 1);
        assert!((f.mean_fleet() - want / 60.0).abs() < 1e-9);
        assert_eq!(f.timeline.len(), 3);
    }

    #[test]
    fn billed_fleet_diverges_from_routable() {
        // a drain: routable drops at t=10, billing continues until
        // the victim retires at t=40
        let mut f = FleetMetrics::new(2, 3);
        f.set_fleet(10.0, 2, 3); // drain start: victim still billed
        f.set_fleet(40.0, 2, 2); // retired: billing drops, no step
        f.finish(100.0);
        let want_servers = 3.0 * 40.0 + 2.0 * 60.0;
        assert!((f.server_seconds - want_servers).abs() < 1e-9);
        assert!((f.gpu_seconds - 2.0 * want_servers).abs() < 1e-9);
        // the timeline only shows the routable change
        assert_eq!(f.timeline, vec![(0.0, 3), (10.0, 2)]);
        assert_eq!(f.peak_servers(), 3);
    }

    #[test]
    fn violation_rate() {
        let mut f = FleetMetrics::new(1, 1);
        assert!(f.violation_rate().is_nan());
        for i in 0..10 {
            f.record_completion(i % 5 == 0);
        }
        assert!((f.violation_rate() - 0.2).abs() < 1e-12);
        assert_eq!(f.measured, 10);
        assert_eq!(f.slo_violations, 2);
    }

    #[test]
    fn default_is_inert() {
        let f = FleetMetrics::default();
        assert_eq!(f.peak_servers(), 0);
        assert_eq!(f.mean_fleet(), 0.0);
        assert_eq!(f.gpu_seconds, 0.0);
    }
}
