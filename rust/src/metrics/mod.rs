//! Re-exports of the metric primitives (kept as a stable public path;
//! the implementations live in `util::stats` and `sim::report`).

pub use crate::sim::report::SimReport;
pub use crate::util::stats::{Histogram, Samples};
